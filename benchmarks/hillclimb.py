"""§Perf hillclimb for the three chosen dry-run cells.

Cells (rationale in EXPERIMENTS.md §Perf):
  · qwen3-32b  train_4k — worst roofline MFU among large dense cells
  · moonshot-v1-16b-a3b train_4k — most collective-bound (MoE, MFU 0.007)
  · gemma2-9b  prefill_32k — the serving-side collective-bound cell

Method: hypothesis → napkin math over the closed-form terms (sweep the mesh
split dp×tp, microbatch depth M, Megatron-style sequence parallelism) →
implement the winning config → re-lower/compile at 256 devices to verify
sharding coherence + HBM fit → record before/after.
"""

from __future__ import annotations

import json
import os

from benchmarks.roofline import PEAK_FLOPS, analytic_terms, DRYRUN_JSON

CELLS = [
    ("qwen3-32b", "train_4k"),
    ("moonshot-v1-16b-a3b", "train_4k"),
    ("gemma2-9b", "prefill_32k"),
]

SPLITS = [(16, 16), (32, 8), (64, 4), (128, 2), (256, 1)]


def terms_of(rec, **kw):
    a = analytic_terms(rec, **kw)
    return {
        "compute_s": a["flops_dev"] / PEAK_FLOPS,
        "memory_s": a["mem_dev"] / 819e9,
        "collective_s": a["coll_dev"] / 50e9,
        "mfu": (a["model_flops_dev"] / PEAK_FLOPS)
        / max(a["flops_dev"] / PEAK_FLOPS, a["mem_dev"] / 819e9,
              a["coll_dev"] / 50e9),
    }


def sweep(rec):
    rows = []
    B = {"train_4k": 256, "prefill_32k": 32}[rec["shape"]]
    for dp, tp in SPLITS:
        if dp > B or B % dp:
            continue   # batch must shard over dp (no context-parallel path)
        for sp in (False, True):
            m_opts = ([1, 2, 4, 8, 16] if rec["mode"] == "train" else [1])
            for M in m_opts:
                if rec["mode"] == "train" and (B // dp) % M:
                    continue
                if rec["mode"] == "train" and B // dp // M < 1:
                    continue
                t = terms_of(rec, dp=dp, tp=tp, M=M, seq_parallel=sp)
                rows.append({"dp": dp, "tp": tp, "M": M, "sp": sp, **t})
    rows.sort(key=lambda r: -r["mfu"])
    return rows


def main():
    recs = json.load(open(DRYRUN_JSON))
    by_key = {(r["arch"], r["shape"], r["mesh"]): r for r in recs}
    out = {}
    for arch, shape in CELLS:
        rec = by_key[(arch, shape, "16x16")]
        base = terms_of(rec)
        rows = sweep(rec)
        print(f"\n=== {arch} {shape} ===")
        print(f"baseline dp=16 tp=16 M=auto sp=False: mfu={base['mfu']:.3f} "
              f"(compute={base['compute_s']:.3f}s "
              f"coll={base['collective_s']:.3f}s)")
        for r in rows[:6]:
            print(f"  dp={r['dp']:<3} tp={r['tp']:<2} M={r['M']:<2} "
                  f"sp={str(r['sp']):5s} mfu={r['mfu']:.3f} "
                  f"compute={r['compute_s']:.3f} mem={r['memory_s']:.3f} "
                  f"coll={r['collective_s']:.3f}")
        out[f"{arch}/{shape}"] = {"baseline": base, "best": rows[0],
                                  "sweep_top6": rows[:6]}
    path = os.path.join(os.path.dirname(DRYRUN_JSON), "hillclimb.json")
    json.dump(out, open(path, "w"), indent=1)
    print(f"\n-> {path}")


if __name__ == "__main__":
    main()
