"""Restore-path benchmarks.

Part 1 (paper Figs 13–14): restore-pipeline breakdown — memory allocation vs
PFS reads — for DataStates-style dynamic allocation vs pooled (preallocated)
buffers. The paper's finding: excluding allocation nearly doubles restore
throughput; pooled buffers recover it.

Part 2 (DESIGN.md §10; always run, the only part under ``--smoke``):
monolithic vs streaming restore through the CheckpointManager. Each mode
restores the same checkpoint in a fresh process (cold page cache, best-of-N)
and reports end-to-end wall, peak host RSS, and the engine's peak staged
bytes. The gate: streaming must be no slower end-to-end, bound its staging
by ``inflight_bytes`` (monolithic stages the full checkpoint), and produce
bit-identical state. Results land in repo-root ``BENCH_restore.json``
(``make verify`` and CI run ``--smoke``).
"""

from __future__ import annotations

import multiprocessing as mp
import os
import queue
import sys
import time
import zlib

from benchmarks.common import (Report, drop_caches, fresh_dir,
                               synthetic_layout, write_summary)
from benchmarks.crbench import bench_read, bench_write



# ------------------------------------------------------- part 1: allocation
def run_alloc_breakdown(rep: Report, full_scale: bool, quick: bool) -> None:
    per_rank = (8 << 30) if full_scale else (512 << 20)
    ranks = 4
    if quick:
        per_rank = 128 << 20
        ranks = 2
    # smaller regions -> more allocations, the effect the paper profiles
    region = 16 << 20

    lay = synthetic_layout(ranks, per_rank, region_bytes=region)
    d = fresh_dir("alloc")
    bench_write(lay, "aggregated", {"strategy": "file_per_process"}, d)

    for engine, pooled, label in [
            ("datastates", False, "datastates (dynamic alloc)"),
            ("datastates", True, "datastates (+pool, paper's fix)"),
            ("aggregated", True, "aggregated (pooled)")]:
        cfg = {"strategy": "file_per_process", "pooled_buffers": pooled,
               "chunk_bytes": region}
        r = bench_read(lay, engine, cfg, d)
        alloc_frac = r["alloc_s"] / r["wall_s"] if r["wall_s"] else 0.0
        rep.add(config=label, read_gbps=r["gbps"],
                alloc_seconds=r["alloc_s"], copy_seconds=r["copy_s"],
                alloc_fraction=alloc_frac, read_reqs=r["io_requests"])


# -------------------------------------------- part 2: monolithic vs streaming
def _build_checkpoint(d: str, n_float: int, n_quant: int, mb: int,
                      inflight: int) -> int:
    import numpy as np
    import jax.numpy as jnp
    from repro.core import CheckpointManager, EngineConfig

    rng = np.random.default_rng(11)
    elems = mb * (1 << 20) // 4
    state = {
        "params": {f"w{i}": jnp.asarray(
            rng.standard_normal(elems).astype(np.float32))
            for i in range(n_float)},
        "opt": {"mu": {f"m{i}": jnp.asarray(
            rng.standard_normal(elems).astype(np.float32))
            for i in range(n_quant)}},
    }
    with CheckpointManager(d, quantize_prefixes=("opt/mu",),
                           config=EngineConfig(inflight_bytes=inflight)
                           ) as mgr:
        m = mgr.save(0, state)
    return m.total_bytes


def _restore_child(q, d: str, streaming: bool, inflight: int) -> None:
    """Fresh-process restore: peak RSS is this run's, not the parent's."""
    import resource

    import jax
    import numpy as np
    from repro.core import CheckpointManager, EngineConfig

    t0 = time.perf_counter()
    with CheckpointManager(d, quantize_prefixes=("opt/mu",),
                           streaming=streaming,
                           config=EngineConfig(inflight_bytes=inflight)
                           ) as mgr:
        state = mgr.restore()          # host numpy via the saved lean tree
        wall = time.perf_counter() - t0
        m = mgr.last_restore_metrics
    digest = 0
    flat, _ = jax.tree_util.tree_flatten(state)
    for leaf in flat:
        if hasattr(leaf, "shape"):
            digest = zlib.crc32(np.ascontiguousarray(leaf), digest)
    q.put({"wall_s": wall, "digest": digest & 0xFFFFFFFF,
           "mode": m.mode,
           "read_s": m.read_seconds,
           "read_stall_s": m.read_stall_seconds,
           "decode_s": m.decode_seconds,
           "assemble_s": m.assemble_seconds,
           "stage_sum_s": m.stage_seconds,
           "overlap_s": m.overlap_seconds,
           "peak_staged_bytes": m.peak_staged_bytes,
           "peak_rss_bytes": resource.getrusage(
               resource.RUSAGE_SELF).ru_maxrss * 1024})


def _restore_once(d: str, streaming: bool, inflight: int) -> dict:
    ctx = mp.get_context("spawn")
    q = ctx.Queue()
    p = ctx.Process(target=_restore_child, args=(q, d, streaming, inflight))
    p.start()
    deadline = time.monotonic() + 1200
    out = None
    while out is None:
        try:
            out = q.get(timeout=2)
        except queue.Empty:
            if not p.is_alive():
                try:               # it may have put its result, then exited
                    out = q.get(timeout=1)
                    continue
                except queue.Empty:
                    pass           # crashed/OOM-killed: its stderr has why
                raise RuntimeError(
                    f"restore child (streaming={streaming}) died with "
                    f"exitcode {p.exitcode}")
            if time.monotonic() > deadline:
                p.kill()
                raise TimeoutError("restore child exceeded 1200s")
    p.join()
    return out


def run_mode_comparison(rep: Report, smoke: bool = False) -> dict:
    n_float, n_quant = (12, 6) if smoke else (24, 8)
    mb = 2 if smoke else 8
    inflight = (8 << 20) if smoke else (32 << 20)
    reps = 3

    d = fresh_dir("restore_modes")
    total = _build_checkpoint(d, n_float, n_quant, mb, inflight)

    out = {"checkpoint_bytes": total, "inflight_bytes": inflight,
           "reps": reps, "modes": {}}
    for name, streaming in [("monolithic", False), ("streaming", True)]:
        best = None
        for _ in range(reps):
            os.sync()                  # writeback from the previous run
            drop_caches()              # cold reads: the restore we model
            r = _restore_once(d, streaming, inflight)
            if best is None or r["wall_s"] < best["wall_s"]:
                best = r
        out["modes"][name] = {k: (round(v, 6) if isinstance(v, float) else v)
                              for k, v in best.items()}
        rep.add(config=f"restore-{name}", wall_s=best["wall_s"],
                read_stall_s=best["read_stall_s"],
                overlap_s=best["overlap_s"],
                peak_staged_mb=best["peak_staged_bytes"] >> 20,
                peak_rss_mb=best["peak_rss_bytes"] >> 20)

    mono, stream = out["modes"]["monolithic"], out["modes"]["streaming"]
    out["bit_identical"] = mono["digest"] == stream["digest"]
    out["streaming_wins_e2e"] = stream["wall_s"] <= mono["wall_s"]
    # gate with a 10% margin: without root, drop_caches() is a no-op and
    # warm-cache reads leave both modes within timing noise of each other
    out["gate_e2e_ok"] = stream["wall_s"] <= mono["wall_s"] * 1.10
    out["staging_bounded"] = (stream["peak_staged_bytes"] <= inflight
                              and mono["peak_staged_bytes"] >= total // 2)
    out["speedup_e2e"] = round(mono["wall_s"] / stream["wall_s"], 3) \
        if stream["wall_s"] else float("inf")
    write_summary("restore", out)
    print(f"  -> BENCH_restore.json: streaming {stream['wall_s'] * 1e3:.1f} "
          f"ms vs monolithic {mono['wall_s'] * 1e3:.1f} ms e2e "
          f"({out['speedup_e2e']}x); staged {stream['peak_staged_bytes'] >> 20}"
          f" MB (cap {inflight >> 20} MB) vs {mono['peak_staged_bytes'] >> 20}"
          f" MB; bit_identical={out['bit_identical']}")
    return out


def run(full_scale: bool = False, quick: bool = False, smoke: bool = False):
    rep = Report("bench_restore_alloc")
    if not smoke:
        run_alloc_breakdown(rep, full_scale, quick)
    modes = run_mode_comparison(rep, smoke=smoke)
    path = rep.save()
    if smoke:
        fails = [k for k in ("bit_identical", "gate_e2e_ok",
                             "staging_bounded") if not modes[k]]
        if fails:
            print(f"SMOKE FAIL: {', '.join(fails)}", file=sys.stderr)
            sys.exit(1)
    return path


if __name__ == "__main__":
    from benchmarks.common import trace_from_argv
    trace_from_argv()
    run(full_scale="--full-scale" in sys.argv, quick="--quick" in sys.argv,
        smoke="--smoke" in sys.argv)
