"""Paper Figs 13–14: restore-pipeline breakdown — memory allocation vs PFS
reads — for DataStates-style dynamic allocation vs pooled (preallocated)
buffers. The paper's finding: excluding allocation nearly doubles restore
throughput; pooled buffers recover it."""

from __future__ import annotations

from benchmarks.common import Report, fresh_dir, synthetic_layout
from benchmarks.crbench import bench_read, bench_write


def run(full_scale: bool = False, quick: bool = False):
    per_rank = (8 << 30) if full_scale else (512 << 20)
    ranks = 4
    if quick:
        per_rank = 128 << 20
        ranks = 2
    # smaller regions -> more allocations, the effect the paper profiles
    region = 16 << 20

    rep = Report("bench_restore_alloc")
    lay = synthetic_layout(ranks, per_rank, region_bytes=region)
    d = fresh_dir("alloc")
    bench_write(lay, "aggregated", {"strategy": "file_per_process"}, d)

    for engine, pooled, label in [
            ("datastates", False, "datastates (dynamic alloc)"),
            ("datastates", True, "datastates (+pool, paper's fix)"),
            ("aggregated", True, "aggregated (pooled)")]:
        cfg = {"strategy": "file_per_process", "pooled_buffers": pooled,
               "chunk_bytes": region}
        r = bench_read(lay, engine, cfg, d)
        alloc_frac = r["alloc_s"] / r["wall_s"] if r["wall_s"] else 0.0
        rep.add(config=label, read_gbps=r["gbps"],
                alloc_seconds=r["alloc_s"], copy_seconds=r["copy_s"],
                alloc_fraction=alloc_frac, read_reqs=r["io_requests"])
    return rep.save()


if __name__ == "__main__":
    import sys
    run(full_scale="--full-scale" in sys.argv, quick="--quick" in sys.argv)
