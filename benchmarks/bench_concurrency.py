"""Concurrent multi-writer checkpointing sweep (DESIGN.md §11).

Sweeps writers ∈ {1, 2, 4, 8} × layout ∈ {file-per-tensor, file-per-rank,
single-file} through ``MultiWriterCheckpointer`` — N rank threads, each with
its own engine, one shared two-phase rank-0 commit — and records the
aggregate write throughput of every cell into a repo-root
``BENCH_concurrency.json``. This is the paper's "many processes hit the PFS
at once" axis: layouts differ in file count and metadata load, the
single-file layout additionally pays the cross-rank prefix-sum exchange.

``--smoke`` shrinks the state and additionally gates on protocol
correctness: a 4-writer SINGLE_FILE save must leave exactly one committed
step dir (no stray tmp dirs), and its merged manifest must restore
bit-identically on 1-, 2-, and 4-rank reader meshes. Exits nonzero on any
violation — wired into ``make verify`` and CI.
"""

from __future__ import annotations

import os
import shutil
import sys

import numpy as np

from benchmarks.common import Report, fresh_dir, write_summary

WRITERS = (1, 2, 4, 8)
LAYOUTS = [
    ("file-per-tensor", "file_per_tensor"),
    ("file-per-rank", "file_per_process"),
    ("single-file", "single_file"),
]


def _state(n_tensors: int, rows: int, cols: int) -> dict:
    rng = np.random.default_rng(11)
    return {"params": {
        f"w{i}": rng.standard_normal((rows, cols)).astype(np.float32)
        for i in range(n_tensors)}, "step": 0}


def _total_bytes(state) -> int:
    return sum(a.nbytes for a in state["params"].values())


def run_sweep(rep: Report, smoke: bool) -> dict:
    from repro.core import EngineConfig, MultiWriterCheckpointer

    # full scale is sized to the container's one ~0.65 GB/s disk (§7):
    # 64 MB state × 12 cells × reps stays inside a few minutes
    n_tensors = 4 if smoke else 8
    rows = 256 if smoke else 2048
    cols = 1024
    reps = 2 if smoke else 3
    state = _state(n_tensors, rows, cols)
    total = _total_bytes(state)

    out = {"state_bytes": total, "tensors": n_tensors, "reps": reps,
           "cells": {}}
    for writers in WRITERS:
        for label, strategy in LAYOUTS:
            d = fresh_dir(f"conc_{writers}_{strategy}")
            cfg = EngineConfig(strategy=strategy)
            best = float("inf")
            with MultiWriterCheckpointer(d, writers, config=cfg,
                                         keep=2) as mw:
                mw.save(0, state)          # warm: pools, prealloc
                for r in range(1, reps + 1):
                    os.sync()
                    m = mw.save(r, state)
                    best = min(best, m.end_to_end_seconds)
            gbps = total / best / 1e9 if best else 0.0
            out["cells"][f"{writers}x{label}"] = {
                "writers": writers, "layout": label,
                "seconds": round(best, 6),
                "aggregate_write_gbps": round(gbps, 4)}
            rep.add(config=f"{writers}w-{label}", seconds=best,
                    aggregate_gbps=gbps, state_mb=total >> 20)
    write_summary("concurrency", out)
    print(f"  -> BENCH_concurrency.json: {len(out['cells'])} cells, "
          f"{total >> 20} MB state")
    return out


def check_protocol() -> list[str]:
    """The §11 acceptance experiment: 4 concurrent SINGLE_FILE writers →
    exactly one committed step dir, merged manifest, and bit-identical
    restore on 1-, 2-, and 4-rank reader meshes."""
    from repro.core import (EngineConfig, LocalShard, Manifest,
                            MultiWriterCheckpointer)

    errors: list[str] = []
    state = _state(4, 128, 512)
    d = fresh_dir("conc_protocol")
    with MultiWriterCheckpointer(
            d, 4, config=EngineConfig(strategy="single_file")) as mw:
        mw.save(7, state)
        entries = sorted(os.listdir(d))
        if entries != ["step_00000007"]:
            errors.append(f"expected exactly one committed step dir, "
                          f"found {entries}")
        else:
            man = Manifest.load(os.path.join(d, "step_00000007"))
            if man.num_ranks != 4:
                errors.append(f"merged manifest num_ranks={man.num_ranks}")
            if sorted(man.extra.get("merged_ranks", [])) != [0, 1, 2, 3]:
                errors.append(
                    f"merged_ranks={man.extra.get('merged_ranks')}")
        full = mw.restore(step=7)
        for k, want in state["params"].items():
            if not np.array_equal(full["params"][k], want):
                errors.append(f"full restore of {k} not bit-identical")
        for m_ranks in (1, 2, 4):
            trees = mw.restore_sharded(m_ranks, step=7)
            for k, want in state["params"].items():
                got = np.zeros_like(want)
                for tree in trees:
                    leaf = tree["params"][k]
                    if isinstance(leaf, LocalShard):
                        (lo, hi) = leaf.index[0]
                        got[lo:hi] = leaf.data
                    else:
                        got[:] = leaf
                if not np.array_equal(got, want):
                    errors.append(
                        f"{m_ranks}-rank elastic restore of {k} differs")
    shutil.rmtree(d, ignore_errors=True)
    return errors


def run(smoke: bool = False):
    rep = Report("bench_concurrency")
    run_sweep(rep, smoke=smoke)
    errors = check_protocol()
    path = rep.save()
    for e in errors:
        print(f"SMOKE FAIL: {e}", file=sys.stderr)
    if errors:
        sys.exit(1)
    print("  protocol check: 1 committed dir, merged manifest, "
          "1/2/4-rank restores bit-identical")
    return path


if __name__ == "__main__":
    from benchmarks.common import trace_from_argv
    trace_from_argv()
    run(smoke="--smoke" in sys.argv)
