"""Paper Figs 5–8: aggregation strategies on the synthetic benchmark.

Fig 5/6: write/read throughput, 3 strategies × rank scaling.
Fig 7/8: write/read throughput, 3 strategies × per-rank data size sweep.
"""

from __future__ import annotations

from benchmarks.common import Report, fresh_dir, synthetic_layout
from benchmarks.crbench import bench_read, bench_write

STRATEGIES = ["file_per_tensor", "file_per_process", "single_file"]


def run(full_scale: bool = False, quick: bool = False):
    per_rank = (8 << 30) if full_scale else (512 << 20)
    ranks_sweep = [1, 2, 4] if not quick else [1, 2]
    size_sweep = ([128 << 20, 512 << 20, 2 << 30, 8 << 30] if full_scale
                  else [32 << 20, 128 << 20, 512 << 20])
    if quick:
        per_rank = 128 << 20
        size_sweep = [32 << 20, 128 << 20]

    rep = Report("bench_aggregation")
    print("== Fig 5/6: strategies x ranks ==")
    for strategy in STRATEGIES:
        for ranks in ranks_sweep:
            lay = synthetic_layout(ranks, per_rank)
            d = fresh_dir(f"agg_{strategy}_{ranks}")
            w = bench_write(lay, "aggregated", {"strategy": strategy}, d)
            r = bench_read(lay, "aggregated", {"strategy": strategy}, d)
            rep.add(fig="5-6", strategy=strategy, ranks=ranks,
                    per_rank_mb=per_rank >> 20, write_gbps=w["gbps"],
                    read_gbps=r["gbps"], files=w["files"],
                    write_reqs=w["io_requests"])
    print("== Fig 7/8: strategies x data size (4 ranks) ==")
    ranks = 2 if quick else 4
    for strategy in STRATEGIES:
        for size in size_sweep:
            lay = synthetic_layout(ranks, size)
            d = fresh_dir(f"aggsz_{strategy}_{size >> 20}")
            w = bench_write(lay, "aggregated", {"strategy": strategy}, d)
            r = bench_read(lay, "aggregated", {"strategy": strategy}, d)
            rep.add(fig="7-8", strategy=strategy, ranks=ranks,
                    per_rank_mb=size >> 20, write_gbps=w["gbps"],
                    read_gbps=r["gbps"])
    return rep.save()


if __name__ == "__main__":
    import sys
    run(full_scale="--full-scale" in sys.argv, quick="--quick" in sys.argv)
