"""Paper Figs 11–12, 15–16: C/R engines vs the ideal aggregated baseline on
the synthetic workload (single aggregated file where the engine supports it).

aggregated = the paper's ideal liburing baseline (ours, productionized)
datastates = DataStates-LLM-faithful     snapshot = TorchSnapshot-faithful
torchsave  = default torch.save
"""

from __future__ import annotations

from benchmarks.common import Report, fresh_dir, synthetic_layout
from benchmarks.crbench import bench_read, bench_write

ENGINES = ["aggregated", "datastates", "snapshot", "torchsave"]


def run(full_scale: bool = False, quick: bool = False):
    per_rank = (8 << 30) if full_scale else (512 << 20)
    ranks_sweep = [1, 2, 4]
    if quick:
        per_rank = 128 << 20
        ranks_sweep = [1, 2]
    # snapshot chunking at paper scale is 512MB; scale with data volume
    chunk = (512 << 20) if full_scale else (32 << 20)

    rep = Report("bench_engines")
    for engine in ENGINES:
        for ranks in ranks_sweep:
            lay = synthetic_layout(ranks, per_rank)
            d = fresh_dir(f"eng_{engine}_{ranks}")
            cfg = {"chunk_bytes": chunk}
            w = bench_write(lay, engine, cfg, d)
            r = bench_read(lay, engine, cfg, d)
            rep.add(engine=engine, ranks=ranks, per_rank_mb=per_rank >> 20,
                    write_gbps=w["gbps"], read_gbps=r["gbps"],
                    files=w["files"], write_reqs=w["io_requests"],
                    read_reqs=r["io_requests"])
    return rep.save()


if __name__ == "__main__":
    import sys
    run(full_scale="--full-scale" in sys.argv, quick="--quick" in sys.argv)
