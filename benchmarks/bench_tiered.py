"""Tiered transfer engine: level-0 → level-1 flush and level-1 → level-0
restore prefetch through the io_engine stack vs the buffered shutil baseline
(DESIGN.md §8).

Writes the usual results/bench_tiered.json detail AND a repo-root
``BENCH_tiered.json`` summary so the flush/prefetch trajectory is tracked
across PRs.
"""

from __future__ import annotations

import os
import shutil
import time

import numpy as np

from benchmarks.common import Report, fresh_dir, write_summary
from repro.core import CheckpointManager, MultiLevelCheckpointer
from repro.core.multilevel import _default_copy
from repro.core.uring import probe_io_uring



def _state(total_bytes: int, rng) -> dict:
    """LLM-ish composition: one dominant tensor + medium shards + small."""
    big = int(total_bytes * 0.75)
    med = int(total_bytes * 0.2) // 8
    out = {"params/embed": rng.integers(0, 255, size=(big,), dtype=np.uint8)}
    for i in range(8):
        out[f"params/layer{i}"] = rng.integers(0, 255, size=(med,),
                                               dtype=np.uint8)
    for i in range(24):
        out[f"meta/small{i}"] = rng.integers(0, 255, size=(3000 + 171 * i,),
                                             dtype=np.uint8)
    return out


def _seed_local(local: str, state) -> int:
    with CheckpointManager(local, async_save=False) as mgr:
        mgr.save(1, state, rank=0, num_ranks=1)
    step_dir = os.path.join(local, "step_00000001")
    return sum(os.path.getsize(os.path.join(root, n))
               for root, _d, names in os.walk(step_dir) for n in names)


def _bench_flush(local: str, remote: str, mode: str, reps: int = 2,
                 **ml_kw) -> dict:
    ml = MultiLevelCheckpointer(local, remote, **ml_kw)
    best = None
    try:
        for _ in range(reps):
            shutil.rmtree(remote, ignore_errors=True)
            os.makedirs(remote)
            os.sync()   # don't time the previous run's writeback
            t0 = time.perf_counter()
            s = ml.flush_to_remote(1)
            wall = time.perf_counter() - t0
            row = {"op": "flush", "mode": mode, "bytes": s.bytes,
                   "wall_s": wall, "write_gbps": s.bytes / wall / 1e9,
                   "files": s.files, "extents": s.extents,
                   "hedged": s.hedged, "backend": s.backend or "shutil",
                   "tier0_read_gbps": s.read_gbps,
                   "tier1_write_gbps": s.write_gbps}
            if best is None or row["write_gbps"] > best["write_gbps"]:
                best = row
        return best
    finally:
        ml.close()


def _bench_prefetch(remote: str, scratch: str, mode: str, **ml_kw) -> dict:
    """Node-loss restore: level-1 extents prefetched into a fresh level 0."""
    shutil.rmtree(scratch, ignore_errors=True)
    os.makedirs(scratch)
    ml = MultiLevelCheckpointer(scratch, remote, **ml_kw)
    try:
        os.sync()
        t0 = time.perf_counter()
        ml.restore(step=1)
        wall = time.perf_counter() - t0
        nbytes = ml.local.last_restore_metrics.total_bytes
        return {"op": "prefetch_restore", "mode": mode, "bytes": nbytes,
                "wall_s": wall, "read_gbps": nbytes / wall / 1e9,
                "promoted": os.path.exists(
                    os.path.join(scratch, "step_00000001", "manifest.json"))}
    finally:
        ml.close()


def run(full_scale: bool = False, quick: bool = False):
    total = (2 << 30) if full_scale else (32 << 20) if quick else (256 << 20)
    base = fresh_dir("tiered")
    local = os.path.join(base, "level0")
    rng = np.random.default_rng(7)
    nbytes = _seed_local(local, _state(total, rng))
    print(f"  seeded level-0 checkpoint: {nbytes >> 20} MB")

    backends = ["threadpool", "posix"] + (["uring"] if probe_io_uring() else [])
    rep = Report("bench_tiered")
    flush_rows = []
    row = _bench_flush(local, os.path.join(base, "r_shutil"), "shutil",
                       copy_fn=_default_copy)
    rep.add(**row)
    flush_rows.append(row)
    for b in backends:
        row = _bench_flush(local, os.path.join(base, f"r_{b}"), f"tiered-{b}",
                           transfer_backend=b)
        rep.add(**row)
        flush_rows.append(row)

    # restore prefetch from the fastest tiered remote (node-loss recovery)
    best_backend = max(flush_rows[1:],
                       key=lambda r: r["write_gbps"])["backend"]
    pf = _bench_prefetch(os.path.join(base, f"r_{best_backend}"),
                         os.path.join(base, "level0_fresh"),
                         f"tiered-{best_backend}")
    rep.add(**pf)

    out = rep.save()
    shutil_gbps = flush_rows[0]["write_gbps"]
    tiered = {r["mode"]: round(r["write_gbps"], 4) for r in flush_rows[1:]}
    best_mode, best_gbps = max(tiered.items(), key=lambda kv: kv[1])
    summary = {
        "bytes": nbytes,
        "flush_gbps": {"shutil": round(shutil_gbps, 4), **tiered},
        "best": {"mode": best_mode, "gbps": best_gbps,
                 "speedup_vs_shutil": round(best_gbps / shutil_gbps, 3)
                 if shutil_gbps else None},
        "prefetch_restore_gbps": round(pf["read_gbps"], 4),
        "prefetch_promoted": pf["promoted"],
    }
    summary_path = write_summary("tiered", summary)
    print(f"  summary -> {summary_path}: best {best_mode} "
          f"{best_gbps:.2f} GB/s ({summary['best']['speedup_vs_shutil']}x "
          f"vs shutil)")
    return out


if __name__ == "__main__":
    import sys
    from benchmarks.common import trace_from_argv
    trace_from_argv()
    run(full_scale="--full-scale" in sys.argv, quick="--quick" in sys.argv)
