"""Roofline analysis over the dry-run artifacts (results/dryrun_all.json).

Per (arch × shape × mesh) cell, derive the three per-device roofline terms
(TPU v5e constants):

    compute    = FLOPs / 197e12          (bf16 peak per chip)
    memory     = bytes / 819e9           (HBM bandwidth)
    collective = collective_bytes / 50e9 (ICI per-link)

ACCOUNTING. XLA's ``cost_analysis()`` counts while-loop bodies ONCE, so the
compiled numbers undercount scanned programs (layer scan × grad-accumulation
scan) by their trip counts. We therefore report ANALYTIC terms — the standard
MFU practice (parameter/activation traffic and 6·N·D-style FLOPs are exact
closed forms) — and scale the HLO-parsed collective volume by the known scan
trip counts (collectives live in the layer-scan body: TP all-gathers/
reduce-scatters per layer per microbatch; DP gradient reduce-scatter per
microbatch). The raw counted-once program stats stay in dryrun_all.json.
"""

from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # B/s
ICI_BW = 50e9           # B/s per link

DRYRUN_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun_all.json")

SHAPES = {  # (seq_len, global_batch)
    "train_4k": (4096, 256),
    "prefill_32k": (32768, 32),
    "decode_32k": (32768, 128),
    "long_500k": (524288, 1),
}


def _mesh_sizes(mesh: str):
    if mesh == "2x16x16":
        return 512, 32, 16   # devices, dp, tp
    return 256, 16, 16


def _microbatches(batch: int, dp: int) -> int:
    return max(1, min(16, batch // dp))


def analytic_terms(rec: dict, dp: int | None = None, tp: int | None = None,
                   M: int | None = None, seq_parallel: bool = False) -> dict:
    """Closed-form per-device FLOPs / HBM bytes / collective bytes.

    ``dp``/``tp``/``M``/``seq_parallel`` override the recorded mesh split for
    hillclimb what-if evaluation (same formulas, different parallelism)."""
    from repro.configs import get_config
    cfg = get_config(rec["arch"])
    S, B = SHAPES[rec["shape"]]
    n_dev, dp0, tp0 = _mesh_sizes(rec["mesh"])
    dp = dp or dp0
    tp = tp or tp0
    mode = rec["mode"]
    N_active = rec["model"]["active_params"]
    N_total = rec["model"]["params"]

    # ---- FLOPs ----
    tokens = B * S if mode in ("train", "prefill") else B * 1
    matmul_flops = (6 if mode == "train" else 2) * N_active * tokens
    # causal attention scores+values: 2 ops × 2 matmuls × (S²/2) × heads×dim
    n_attn = sum(1 for k in cfg.block_pattern if k == "attn") \
        * cfg.num_layers // len(cfg.block_pattern)
    n_local = sum(1 for k in cfg.block_pattern if k == "attn_local") \
        * cfg.num_layers // len(cfg.block_pattern)
    if mode in ("train", "prefill"):
        ctx_g, ctx_l = S / 2, min(cfg.sliding_window or S, S)
    else:
        ctx_g, ctx_l = S, min(cfg.sliding_window or S, S)
    attn_flops = (2 * 2 * cfg.q_dim * tokens
                  * (n_attn * ctx_g + n_local * ctx_l))
    if mode == "train":
        attn_flops *= 3   # fwd + 2x bwd
    flops_dev = (matmul_flops + attn_flops) / n_dev

    # ---- HBM bytes ----
    pbytes = N_total * 2                      # bf16 params
    if M is None:
        M = _microbatches(B, dp) if mode == "train" else 1
    if mode == "train":
        # per step: local param shard read x(fwd+bwd)x microbatches (FSDP),
        # grads rw, mu/nu fp32 read+write
        param_traffic = pbytes / (dp * tp) * (2 * M + 2) + \
            N_total * 4 / (dp * tp) * 6
        act = 2 * tokens * cfg.d_model * 2 / n_dev * cfg.num_layers * 4
        mem_dev = param_traffic + act
    elif mode == "prefill":
        act = 2 * tokens * cfg.d_model * 2 / n_dev * cfg.num_layers * 2
        mem_dev = pbytes / tp + act
    else:
        # decode: every param + the whole KV/recurrent cache read once
        cache = rec["per_device"].get("argument_bytes", 0)
        mem_dev = pbytes / tp + cache
    # ---- collectives: closed forms (ring-algorithm per-device traffic) ----
    L = cfg.num_layers
    d = cfg.d_model
    if mode == "train":
        tokens_mb_local = B // M // dp * S          # tokens/microbatch/device
        ag_param = 2 * M * (pbytes / tp) * (dp - 1) / dp      # FSDP fwd+bwd
        rs_grad = M * (4 * N_total / tp) * (dp - 1) / dp      # ZeRO-2
        # TP: 2 all-reduces/layer (attn-out, ffn-out), x2 in bwd; AR ring
        # traffic = 2x payload x (tp-1)/tp. Megatron-style sequence
        # parallelism replaces each AR with RS+AG = 1x payload: halves it.
        ar_factor = 1.0 if seq_parallel else 2.0
        tp_act = (L * M * 4 * (ar_factor * tokens_mb_local * d * 2)
                  * (tp - 1) / tp)
        if cfg.is_moe:  # dispatch/combine all-to-alls fwd+bwd
            tp_act += L * M * 4 * (tokens_mb_local * d * 2) * (tp - 1) / tp
        coll_dev = ag_param + rs_grad + tp_act
    elif mode == "prefill":
        tokens_local = B * S // dp
        coll_dev = L * 2 * (2 * tokens_local * d * 2) * (tp - 1) / tp
        if cfg.is_moe:
            coll_dev += L * 2 * (tokens_local * d * 2) * (tp - 1) / tp
    else:
        b_local = max(B // dp, 1)
        coll_dev = L * 4 * (b_local * d * 2) * (tp - 1) / tp
    return {"flops_dev": flops_dev, "mem_dev": mem_dev, "coll_dev": coll_dev,
            "model_flops_dev": matmul_flops / n_dev}


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    pd = rec["per_device"]
    a = analytic_terms(rec)
    terms = {
        "compute_s": a["flops_dev"] / PEAK_FLOPS,
        "memory_s": a["mem_dev"] / HBM_BW,
        "collective_s": a["coll_dev"] / ICI_BW,
    }
    bottleneck = max(terms, key=terms.get)
    bound = max(terms.values())
    hlo_flops_once = max(pd["flops"], 1.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **{k: round(v, 6) for k, v in terms.items()},
        "bottleneck": bottleneck.replace("_s", ""),
        "roofline_mfu": round((a["model_flops_dev"] / PEAK_FLOPS) / bound, 4)
        if bound else 0.0,
        "useful_flops_frac": round(a["model_flops_dev"] / a["flops_dev"], 3),
        "peak_gb": round(pd.get("tpu_adjusted_peak_bytes",
                                pd["peak_hbm_bytes"]) / 1e9, 2),
        "raw_peak_gb": round(pd["peak_hbm_bytes"] / 1e9, 2),
        "collective_mb": round(a["coll_dev"] / 1e6, 1),
        "hlo_flops_counted_once": hlo_flops_once,
    }


def run(full_scale: bool = False, quick: bool = False):
    if not os.path.exists(DRYRUN_JSON):
        print("no dry-run results; run: python -m repro.launch.dryrun --all "
              "--both-meshes --out results/dryrun_all.json")
        return None
    recs = json.load(open(DRYRUN_JSON))
    rows = [a for a in (analyze(r) for r in recs) if a]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} {'compute':>9s} "
           f"{'memory':>9s} {'collect':>9s} {'bound':>11s} {'mfu':>7s} "
           f"{'useful':>7s} {'peakGB':>7s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['compute_s']:9.5f} {r['memory_s']:9.5f} "
              f"{r['collective_s']:9.5f} {r['bottleneck']:>11s} "
              f"{r['roofline_mfu']:7.3f} {r['useful_flops_frac']:7.3f} "
              f"{r['peak_gb']:7.2f}")
    out = os.path.join(os.path.dirname(DRYRUN_JSON), "roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\n{len(rows)} cells -> {out}")
    return out


if __name__ == "__main__":
    run()
