"""Paper Fig 3 + DESIGN.md §9: checkpoint/restore overhead in the loop, and
the save-path mode comparison (blocking vs legacy-async vs pipelined).

Part 1 (mode comparison, always run; the §9 acceptance experiment): saves a
multi-tensor state through the three manager modes and records the best-of-N
``blocking_seconds`` per mode into a repo-root ``BENCH_pipeline.json``. The
comparison is copy-bound — legacy async blocks for a full host copy of every
shard, the pipelined save returns after submission — so it is stable on a
noisy disk.

Part 2 (trainer sweep, skipped with ``--smoke``): trains a reduced model and
measures per-iteration time with each engine in the loop, plus restore time —
the end-to-end framing of the paper's motivating experiment.
"""

from __future__ import annotations

import os
import shutil
import sys
import time

import numpy as np

from benchmarks.common import Report, SCRATCH, fresh_dir, write_summary

MODES = [
    ("blocking", dict(async_save=False, streaming=True)),
    ("legacy-async", dict(async_save=True, streaming=False)),
    ("pipelined", dict(async_save=True, streaming=True)),
]


def _mode_state(n_tensors: int, mb_per_tensor: int):
    import jax.numpy as jnp
    rng = np.random.default_rng(7)
    elems = mb_per_tensor * (1 << 20) // 4
    return {"params": {
        f"w{i}": jnp.asarray(rng.standard_normal(elems).astype(np.float32))
        for i in range(n_tensors)}}


def run_mode_comparison(rep: Report, smoke: bool = False) -> dict:
    from repro.core import CheckpointManager

    n_tensors = 16
    mb = 2 if smoke else 6
    reps = 5
    state = _mode_state(n_tensors, mb)
    total = n_tensors * mb << 20

    out = {"state_bytes": total, "tensors": n_tensors, "reps": reps,
           "modes": {}}
    for name, kw in MODES:
        d = fresh_dir(f"mode_{name.replace('-', '_')}")
        best_block, best_e2e = float("inf"), float("inf")
        with CheckpointManager(d, keep=2, **kw) as mgr:
            mgr.save(0, state)     # warm: pool buffers, file prealloc, jit
            mgr.wait()
            for r in range(1, reps + 1):
                os.sync()          # writeback from the previous rep/mode
                m = mgr.save(r, state)
                mgr.wait()         # e2e is filled once the flush commits
                best_block = min(best_block, m.blocking_seconds)
                best_e2e = min(best_e2e, m.end_to_end_seconds)
        out["modes"][name] = {"blocking_seconds": round(best_block, 6),
                              "end_to_end_seconds": round(best_e2e, 6)}
        rep.add(config=f"mode-{name}", blocking_s=best_block,
                end_to_end_s=best_e2e, state_mb=total >> 20)

    legacy = out["modes"]["legacy-async"]["blocking_seconds"]
    piped = out["modes"]["pipelined"]["blocking_seconds"]
    out["pipelined_vs_legacy_blocking_speedup"] = round(
        legacy / piped if piped else float("inf"), 2)
    out["pipelined_wins"] = piped < legacy
    write_summary("pipeline", out)
    print(f"  -> BENCH_pipeline.json: pipelined {piped * 1e3:.2f} ms vs "
          f"legacy-async {legacy * 1e3:.2f} ms blocking "
          f"({out['pipelined_vs_legacy_blocking_speedup']}x)")
    return out


def run_trainer_sweep(rep: Report, quick: bool = False) -> None:
    from repro.configs import get_config
    from repro.core import CheckpointManager
    from repro.data import DataConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config("stablelm-3b").scaled_down(
        layers=2 if quick else 4, width_div=16 if quick else 8, vocab=2048)
    steps = 12 if quick else 30
    ckpt_every = 4 if quick else 10
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)

    baseline_wall = None
    for engine, async_ in [(None, False), ("aggregated", True),
                           ("aggregated", False), ("datastates", False),
                           ("snapshot", False), ("torchsave", False)]:
        d = fresh_dir(f"train_{engine}_{async_}")
        tcfg = TrainerConfig(steps=steps,
                             ckpt_every=ckpt_every if engine else 0,
                             ckpt_dir=d, ckpt_engine=engine or "aggregated",
                             async_ckpt=async_, log_every=0)
        t = Trainer(cfg, tcfg, data_cfg=data)
        out = t.run()
        label = "no-ckpt" if engine is None else \
            f"{engine}{'-async' if async_ else ''}"
        wall = out["wall_seconds"]
        if engine is None:
            baseline_wall = wall
        n_ckpts = steps // ckpt_every if engine else 0
        over = (wall - baseline_wall) / n_ckpts if n_ckpts else 0.0
        restore_s = 0.0
        if engine:
            t0 = time.perf_counter()
            with CheckpointManager(d, engine=engine or "aggregated") as mgr:
                mgr.restore(state_template={
                    "train": out["state"],
                    "data": {"data_step": 0}})
            restore_s = time.perf_counter() - t0
        t.close()
        rep.add(config=label, wall_s=wall,
                per_ckpt_overhead_s=over,
                ckpt_blocking_s=out["ckpt_blocking_seconds"],
                ckpt_blocking_reported_s=out["ckpt_blocking_reported_s"],
                restore_s=restore_s)


def run(full_scale: bool = False, quick: bool = False, smoke: bool = False):
    rep = Report("bench_train_overhead")
    modes = run_mode_comparison(rep, smoke=smoke)
    if not smoke:
        run_trainer_sweep(rep, quick=quick)
    path = rep.save()
    if smoke and not modes["pipelined_wins"]:
        print("SMOKE FAIL: pipelined blocking_seconds not below legacy-async",
              file=sys.stderr)
        sys.exit(1)
    return path


if __name__ == "__main__":
    from benchmarks.common import trace_from_argv
    trace_from_argv()
    run(full_scale="--full-scale" in sys.argv, quick="--quick" in sys.argv,
        smoke="--smoke" in sys.argv)
