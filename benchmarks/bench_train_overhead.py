"""Paper Fig 3: checkpoint/restore overhead inside a real training loop.

Trains a reduced model and measures per-iteration time with each engine in
the loop (sync + async), plus restore time — the end-to-end framing of the
paper's motivating experiment.
"""

from __future__ import annotations

import shutil
import time

import numpy as np

from benchmarks.common import Report, SCRATCH, fresh_dir


def run(full_scale: bool = False, quick: bool = False):
    import jax
    from repro.configs import get_config
    from repro.core import CheckpointManager
    from repro.data import DataConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config("stablelm-3b").scaled_down(
        layers=2 if quick else 4, width_div=16 if quick else 8, vocab=2048)
    steps = 12 if quick else 30
    ckpt_every = 4 if quick else 10
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)

    rep = Report("bench_train_overhead")
    baseline_wall = None
    for engine, async_ in [(None, False), ("aggregated", True),
                           ("aggregated", False), ("datastates", False),
                           ("snapshot", False), ("torchsave", False)]:
        d = fresh_dir(f"train_{engine}_{async_}")
        tcfg = TrainerConfig(steps=steps,
                             ckpt_every=ckpt_every if engine else 0,
                             ckpt_dir=d, ckpt_engine=engine or "aggregated",
                             async_ckpt=async_, log_every=0)
        t = Trainer(cfg, tcfg, data_cfg=data)
        out = t.run()
        label = "no-ckpt" if engine is None else \
            f"{engine}{'-async' if async_ else ''}"
        wall = out["wall_seconds"]
        if engine is None:
            baseline_wall = wall
        n_ckpts = steps // ckpt_every if engine else 0
        over = (wall - baseline_wall) / n_ckpts if n_ckpts else 0.0
        restore_s = 0.0
        if engine:
            t0 = time.perf_counter()
            with CheckpointManager(d, engine=engine or "aggregated") as mgr:
                mgr.restore(state_template={
                    "train": out["state"],
                    "data": {"data_step": 0}})
            restore_s = time.perf_counter() - t0
        t.close()
        rep.add(config=label, wall_s=wall,
                per_ckpt_overhead_s=over,
                ckpt_blocking_s=out["ckpt_blocking_seconds"],
                restore_s=restore_s)
    return rep.save()


if __name__ == "__main__":
    import sys
    run(full_scale="--full-scale" in sys.argv, quick="--quick" in sys.argv)
