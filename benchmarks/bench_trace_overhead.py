"""Tracer overhead gate + the §17 observability acceptance experiment.

Three checks, all gated under ``--smoke`` (``make verify`` / CI):

1. **Overhead**: the span tracer must cost <= 5% of save wall time when
   ENABLED (median-of-N blocking saves, traced vs untraced) — and the
   disabled no-op fast path is free by construction (module-global None
   check, shared no-op span singleton).
2. **Timeline**: a pipelined ~96 MB save and a streaming restore each
   export a Perfetto ``trace.json`` whose per-tier tracks show stage
   overlap — a ``snapshot``/``read.stall`` span concurrent with an
   ``io.write``/``io.read`` span on another track (the whole point of
   the pipelined paths).
3. **Attribution**: ``trace.stall_report()`` decomposes the save root
   span into {compute, d2h, stage_wait, level0_write, ...} and the
   categories sum to the root wall within 5%.

Artifacts: ``BENCH_trace_overhead.json`` plus ``TRACE_save.json`` /
``TRACE_restore.json`` (repo root; load in ui.perfetto.dev).
"""

from __future__ import annotations

import os
import statistics
import sys

import numpy as np

from benchmarks.common import REPO_ROOT, Report, fresh_dir, write_summary
from repro.core import CheckpointManager, EngineConfig, trace

STATE_MB = 96
N_TENSORS = 12
REPS = 7
OVERHEAD_GATE = 0.05
STALL_SUM_TOL = 0.05


def _state(total_mb: int):
    rng = np.random.default_rng(7)
    elems = total_mb * (1 << 20) // 4 // N_TENSORS
    return {f"w{i}": rng.standard_normal(elems).astype(np.float32)
            for i in range(N_TENSORS)}


def _interleaved_walls(d: str, state, reps: int) -> dict[bool, list[float]]:
    """Traced and untraced saves alternate rep by rep on one manager so
    page-cache / writeback drift hits both modes equally; min-of-N per
    mode isolates the tracer's cost from disk noise."""
    walls: dict[bool, list[float]] = {False: [], True: []}
    step = 1
    with CheckpointManager(d, keep=2, async_save=False,
                           streaming=True) as mgr:
        trace.disable()
        mgr.save(0, state)                     # warm: pool, prealloc, jit
        for _ in range(reps):
            for on in (False, True):
                (trace.enable if on else trace.disable)()
                os.sync()
                t0 = trace.clock()
                mgr.save(step, state)
                walls[on].append(trace.clock() - t0)
                step += 1
        trace.disable()
    return walls


def _overlaps(events, name_a: str, name_b: str) -> bool:
    """Any span named ``name_a`` concurrent with any span ``name_b``?"""
    a = [e for e in events if e.kind == "span" and e.name == name_a]
    b = [e for e in events if e.kind == "span" and e.name == name_b]
    return any(x.t0 < y.t1 and y.t0 < x.t1 for x in a for y in b)


def run(smoke: bool = False) -> dict:
    rep = Report("trace_overhead")
    mb = 24 if smoke else STATE_MB
    state = _state(mb)
    out = {"state_bytes": mb << 20, "reps": REPS,
           "overhead_gate": OVERHEAD_GATE}

    # -------------------------------------------------- 1. overhead gate
    # median of PAIRED diffs: each traced save is compared against its
    # immediate untraced neighbour, so slow-disk excursions hit both sides
    # of a pair and cancel; a lone outlier can't swing the median
    walls = _interleaved_walls(fresh_dir("trace_overhead"), state, REPS)
    off_s = min(walls[False])
    on_s = min(walls[True])
    diffs = [on - off for off, on in zip(walls[False], walls[True])]
    overhead = statistics.median(diffs) / off_s
    out["save_wall_untraced_s"] = round(off_s, 6)
    out["save_wall_traced_s"] = round(on_s, 6)
    out["overhead_frac"] = round(overhead, 4)
    out["overhead_ok"] = bool(overhead <= OVERHEAD_GATE)
    rep.add(config="overhead", untraced_s=off_s, traced_s=on_s,
            overhead_frac=overhead)
    print(f"  save wall: untraced {off_s * 1e3:.2f} ms, traced "
          f"{on_s * 1e3:.2f} ms -> overhead {overhead * 100:+.2f}% "
          f"(gate {OVERHEAD_GATE * 100:.0f}%)")

    # ---------------------------- 2. save timeline + 3. stall attribution
    # small staging batches: writes stream out WHILE later tensors are
    # still snapshotting, so the timeline shows the pipelined overlap even
    # at smoke scale
    d = fresh_dir("trace_timeline")
    cfg = EngineConfig(coalesce_bytes=4 << 20)
    trace.enable()
    with CheckpointManager(d, keep=2, async_save=False, streaming=True,
                           config=cfg) as mgr:
        mgr.save(1, state)
    events = trace.drain()
    save_overlap = _overlaps(events, "snapshot", "io.write")
    trace.export_perfetto(os.path.join(REPO_ROOT, "TRACE_save.json"))
    stall = trace.stall_report(root="save")
    trace.disable()
    assert stall is not None
    stall_sum = sum(stall.attribution.values())
    stall_err = abs(stall_sum - stall.wall) / stall.wall
    out["save_overlap"] = bool(save_overlap)
    out["stall_report"] = {k: round(v, 6)
                           for k, v in stall.attribution.items()}
    out["stall_wall_s"] = round(stall.wall, 6)
    out["stall_sum_err"] = round(stall_err, 6)
    out["stall_ok"] = bool(stall_err <= STALL_SUM_TOL)
    print("  " + stall.render().replace("\n", "\n  "))

    trace.enable()
    with CheckpointManager(d, keep=2, streaming=True, config=cfg) as mgr:
        mgr.restore(step=1)
    events = trace.drain()
    restore_overlap = (_overlaps(events, "decode", "io.read")
                       or _overlaps(events, "assemble", "io.read")
                       or _overlaps(events, "read.stall", "io.read"))
    trace.export_perfetto(os.path.join(REPO_ROOT, "TRACE_restore.json"))
    trace.disable()
    out["restore_overlap"] = bool(restore_overlap)
    rep.add(config="timeline", save_overlap=save_overlap,
            restore_overlap=restore_overlap, stall_sum_err=stall_err)

    rep.save()
    write_summary("trace_overhead", out)

    failures = []
    if not out["overhead_ok"]:
        failures.append(
            f"tracer overhead {overhead * 100:.2f}% > "
            f"{OVERHEAD_GATE * 100:.0f}% of save wall")
    if not save_overlap:
        failures.append("save trace shows no snapshot/io.write overlap")
    if not restore_overlap:
        failures.append("restore trace shows no stage/io.read overlap")
    if not out["stall_ok"]:
        failures.append(
            f"stall attribution off by {stall_err * 100:.2f}% of wall")
    if failures:
        print("TRACE GATE FAILURES:\n  - " + "\n  - ".join(failures))
        sys.exit(1)
    print(f"  trace gate OK: overhead {overhead * 100:+.2f}%, overlap "
          f"save/restore, stall sums to wall "
          f"(err {stall_err * 100:.2f}%)")
    return out


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
