"""Content-addressed delta checkpointing sweep (DESIGN.md §12).

Sweeps dirty fraction ∈ {100%, 50%, 10%, 1%} × layout ∈ {file-per-tensor,
file-per-rank, single-file} through a ``delta=True`` CheckpointManager: step
0 is the full save (every chunk dirty by construction), then each following
step mutates a contiguous ``frac`` of every tensor's rows and saves again.
Recorded per cell: logical bytes actually written (``SaveMetrics.
written_bytes``), the written fraction vs the full save, end-to-end save
seconds, and the worker-side fingerprint/diff seconds plus D2H traffic —
the paper's *volume* axis should scale with the dirty fraction while
restore stays bit-identical. A ``baseline_blake2b`` cell re-runs the
1%-dirty single-file point with ``device_fingerprint=False`` so the json
carries the fp128-vs-blake2b speedup in one file (DESIGN.md §14).

``--smoke`` shrinks the state and gates on the §12/§14 acceptance criteria:
  · the 1%-dirty single-file save writes ≤ 10% of the full save's bytes,
  · the streaming restore of the delta step is bit-identical to a full
    (non-delta) save's restore of the same state,
  · after retention drops old steps, the refcount GC reaps unreferenced
    packs but every kept step still restores bit-exactly,
  · fp128 and blake2b produce the SAME dirty set (chunk counts + written
    bytes) over the same mutation schedule, with bit-identical restores,
  · on a device-held (jax) state, ``d2h_bytes`` never exceeds the dirty
    bytes plus the 16 B/chunk digest-table overhead — clean bytes stay
    on device.
Exits nonzero on any violation — wired into ``make verify`` and CI.
"""

from __future__ import annotations

import os
import shutil
import sys

import numpy as np

from benchmarks.common import Report, fresh_dir, write_summary

FRACTIONS = (1.0, 0.5, 0.1, 0.01)
LAYOUTS = [
    ("file-per-tensor", "file_per_tensor"),
    ("file-per-rank", "file_per_process"),
    ("single-file", "single_file"),
]


def _state(n_tensors: int, rows: int, cols: int) -> dict:
    rng = np.random.default_rng(12)
    return {"params": {
        f"w{i}": rng.standard_normal((rows, cols)).astype(np.float32)
        for i in range(n_tensors)}, "step": 0}


def _total_bytes(state) -> int:
    return sum(a.nbytes for a in state["params"].values())


def _mutate(state, frac: float, rep: int) -> None:
    """Touch a contiguous ``frac`` of every tensor's rows, offset per rep so
    consecutive saves dirty different chunks."""
    for a in state["params"].values():
        rows = a.shape[0]
        n = max(1, int(rows * frac))
        off = (rep * 7919) % max(rows - n, 1)
        a[off:off + n] += 1.0
    state["step"] = rep


def run_sweep(rep_log: Report, smoke: bool) -> dict:
    from repro.core import CheckpointManager, EngineConfig

    # tensors must dwarf the chunk grid for the 1% cell to be meaningful:
    # a 1% contiguous span can dirty at most span//chunk + 2 chunks
    n_tensors = 4
    rows = 2048 if smoke else 6144
    cols = 1024
    reps = 2 if smoke else 3
    out = {"chunk_bytes": 256 << 10, "reps": reps, "cells": {}}

    for label, strategy in LAYOUTS:
        for frac in FRACTIONS:
            state = _state(n_tensors, rows, cols)
            total = _total_bytes(state)
            out["state_bytes"] = total
            d = fresh_dir(f"delta_{strategy}_{int(frac * 100)}")
            cfg = EngineConfig(strategy=strategy)
            with CheckpointManager(d, config=cfg, delta=True,
                                   keep=None) as mgr:
                full = mgr.save(0, state)
                best_written, best_s, best_hash = float("inf"), \
                    float("inf"), float("inf")
                best_fp, best_diff, d2h = float("inf"), float("inf"), 0
                for r in range(1, reps + 1):
                    _mutate(state, frac, r)
                    os.sync()
                    m = mgr.save(r, state)
                    best_written = min(best_written, m.written_bytes)
                    best_s = min(best_s, m.end_to_end_seconds)
                    best_hash = min(best_hash, m.hash_seconds)
                    best_fp = min(best_fp, m.fingerprint_seconds)
                    best_diff = min(best_diff, m.diff_seconds)
                    d2h = max(d2h, m.d2h_bytes)
            wf = best_written / full.written_bytes
            out["cells"][f"{int(frac * 100)}%x{label}"] = {
                "dirty_fraction": frac, "layout": label,
                "full_written_bytes": full.written_bytes,
                "written_bytes": best_written,
                "written_fraction": round(wf, 4),
                "save_seconds": round(best_s, 6),
                "hash_seconds": round(best_hash, 6),
                "fingerprint_seconds": round(best_fp, 6),
                "diff_seconds": round(best_diff, 6),
                "d2h_bytes": d2h}
            rep_log.add(config=f"{int(frac * 100)}%-{label}",
                        written_mb=best_written / 1e6, written_frac=wf,
                        save_s=best_s, hash_s=best_hash,
                        fp_s=best_fp, diff_s=best_diff,
                        state_mb=total >> 20)

    # blake2b baseline at the acceptance point (1% dirty, single-file):
    # same schedule with device_fingerprint=False, so one json carries the
    # digest-engine speedup
    state = _state(n_tensors, rows, cols)
    d = fresh_dir("delta_blake2b_baseline")
    with CheckpointManager(d, config=EngineConfig(strategy="single_file"),
                           delta=True, keep=None,
                           device_fingerprint=False) as mgr:
        mgr.save(0, state)
        base_hash, base_s = float("inf"), float("inf")
        for r in range(1, reps + 1):
            _mutate(state, 0.01, r)
            m = mgr.save(r, state)
            base_hash = min(base_hash, m.hash_seconds)
            base_s = min(base_s, m.end_to_end_seconds)
    fp_cell = out["cells"]["1%xsingle-file"]
    speedup = base_hash / max(fp_cell["hash_seconds"], 1e-9)
    out["baseline_blake2b"] = {
        "dirty_fraction": 0.01, "layout": "single-file",
        "hash_seconds": round(base_hash, 6),
        "save_seconds": round(base_s, 6)}
    out["fingerprint_speedup"] = round(speedup, 2)
    rep_log.add(config="1%-single-file-blake2b", hash_s=base_hash,
                save_s=base_s, speedup=speedup)
    write_summary("delta", out)
    print(f"  -> BENCH_delta.json: {len(out['cells'])} cells, "
          f"{out['state_bytes'] >> 20} MB state, fp128 hash+diff "
          f"{speedup:.1f}x faster than blake2b")
    return out


def check_gates(smoke: bool) -> list[str]:
    """The §12 acceptance experiment (always run; sized small)."""
    from repro.core import CheckpointManager, EngineConfig

    errors: list[str] = []
    state = _state(4, 2048, 1024)          # 32 MB, 128 chunks of 256 KiB
    # fresh_dir purges the whole scratch: one call, then a sibling dir
    d = fresh_dir("delta_gate")
    d_full = os.path.join(os.path.dirname(d), "delta_gate_full")
    os.makedirs(d_full, exist_ok=True)

    cfg = EngineConfig(strategy="single_file")
    with CheckpointManager(d, config=cfg, delta=True, keep=2) as mgr:
        mgr.delta_gc_grace_s = 0.0
        full = mgr.save(0, state)
        _mutate(state, 0.01, 1)
        m1 = mgr.save(1, state)
        ratio = m1.written_bytes / full.written_bytes
        if ratio > 0.10:
            errors.append(f"1%-dirty save wrote {ratio:.1%} of full bytes "
                          f"(gate: <=10%)")
        # bit-identity: delta-step restore == full-save restore of same state
        with CheckpointManager(d_full, config=EngineConfig(
                strategy="single_file")) as ref:
            ref.save(1, state)
            want = ref.restore(step=1)
        got = mgr.restore(step=1)
        for k in state["params"]:
            if not np.array_equal(got["params"][k], want["params"][k]):
                errors.append(f"delta restore of {k} differs from "
                              f"full-save restore")
        # retention GC: roll old steps out; kept steps must stay restorable
        for r in range(2, 5):
            _mutate(state, 0.01, r)
            mgr.save(r, state)
        kept = mgr.all_steps()
        if kept != [3, 4]:
            errors.append(f"keep=2 retained {kept}")
        gc = mgr.last_gc_stats
        if gc is None or gc.kept == 0:
            errors.append("refcount GC never ran or pinned nothing")
        try:
            out = mgr.restore(step=kept[-1])
            for k, v in state["params"].items():
                if not np.array_equal(out["params"][k], v):
                    errors.append(f"post-GC restore of {k} not bit-identical")
        except Exception as e:  # noqa: BLE001 - gate must report, not die
            errors.append(f"post-GC restore failed: {e!r}")
    shutil.rmtree(d, ignore_errors=True)
    shutil.rmtree(d_full, ignore_errors=True)
    errors += _check_fingerprint_gates()
    return errors


def _check_fingerprint_gates() -> list[str]:
    """§14 gates: fp128 dirty-set parity with blake2b, and D2H avoidance
    on a device-held state (clean bytes never cross)."""
    import jax.numpy as jnp

    from repro.core import CheckpointManager, EngineConfig

    errors: list[str] = []
    state_fp = _state(4, 2048, 1024)       # 32 MB, 128 chunks of 256 KiB
    d_fp = fresh_dir("delta_gate_fp128")
    d_bl = os.path.join(os.path.dirname(d_fp), "delta_gate_blake2b")
    os.makedirs(d_bl, exist_ok=True)
    cfg = dict(config=EngineConfig(strategy="single_file"), delta=True,
               keep=None)

    # 1. dirty-set parity: identical mutation schedule through both digest
    #    engines must mark the same chunks dirty and restore bit-identically
    state_bl = _state(4, 2048, 1024)
    with CheckpointManager(d_fp, **cfg) as m_fp, \
            CheckpointManager(d_bl, device_fingerprint=False,
                              **cfg) as m_bl:
        for r in range(3):
            if r:
                _mutate(state_fp, 0.01, r)
                _mutate(state_bl, 0.01, r)
            a = m_fp.save(r, state_fp)
            b = m_bl.save(r, state_bl)
            if (a.chunks_total, a.chunks_dirty) != (b.chunks_total,
                                                    b.chunks_dirty):
                errors.append(
                    f"dirty-set parity: step {r} fp128 marked "
                    f"{a.chunks_dirty}/{a.chunks_total} dirty, blake2b "
                    f"{b.chunks_dirty}/{b.chunks_total}")
            if a.written_bytes != b.written_bytes:
                errors.append(f"dirty-set parity: step {r} wrote "
                              f"{a.written_bytes} (fp128) vs "
                              f"{b.written_bytes} (blake2b) bytes")
        got = m_fp.restore(step=2)
        want = m_bl.restore(step=2)
        for k in state_fp["params"]:
            if not np.array_equal(got["params"][k], want["params"][k]):
                errors.append(f"fp128 restore of {k} differs from blake2b")

    # 2. D2H avoidance: device-held state; traffic = digest tables
    #    (16 B/chunk) + dirty gathers only, never the clean bytes
    d_dev = os.path.join(os.path.dirname(d_fp), "delta_gate_device")
    os.makedirs(d_dev, exist_ok=True)
    dev = {"params": {k: jnp.asarray(v)
                      for k, v in _state(4, 2048, 1024)["params"].items()},
           "step": 0}
    with CheckpointManager(d_dev, **cfg) as mgr:
        m0 = mgr.save(0, dev)
        if m0.d2h_bytes <= 0:
            errors.append("device-state save reported zero d2h_bytes")
        host = {"params": {k: np.asarray(v).copy()
                           for k, v in dev["params"].items()}, "step": 0}
        _mutate(host, 0.01, 1)
        dev = {"params": {k: jnp.asarray(v)
                          for k, v in host["params"].items()}, "step": 1}
        m1 = mgr.save(1, dev)
        budget = m1.written_bytes + 16 * m1.chunks_total + (64 << 10)
        if m1.d2h_bytes > budget:
            errors.append(
                f"D2H gate: {m1.d2h_bytes} bytes crossed for a 1%-dirty "
                f"device save (budget {budget} = written + digest tables)")
        got = mgr.restore(step=1)
        for k, v in host["params"].items():
            if not np.array_equal(got["params"][k], v):
                errors.append(f"device-state delta restore of {k} not "
                              f"bit-identical")
    for p in (d_fp, d_bl, d_dev):
        shutil.rmtree(p, ignore_errors=True)
    return errors


def run(smoke: bool = False):
    rep = Report("bench_delta")
    run_sweep(rep, smoke=smoke)
    errors = check_gates(smoke)
    path = rep.save()
    for e in errors:
        print(f"SMOKE FAIL: {e}", file=sys.stderr)
    if errors:
        sys.exit(1)
    print("  delta gates: 1%-dirty <=10% bytes, bit-identical restore, "
          "refcount GC keeps every referenced chunk, fp128==blake2b dirty "
          "set, d2h <= dirty bytes + digest tables")
    return path


if __name__ == "__main__":
    from benchmarks.common import trace_from_argv
    trace_from_argv()
    run(smoke="--smoke" in sys.argv)
