"""Content-addressed delta checkpointing sweep (DESIGN.md §12).

Sweeps dirty fraction ∈ {100%, 50%, 10%, 1%} × layout ∈ {file-per-tensor,
file-per-rank, single-file} through a ``delta=True`` CheckpointManager: step
0 is the full save (every chunk dirty by construction), then each following
step mutates a contiguous ``frac`` of every tensor's rows and saves again.
Recorded per cell: logical bytes actually written (``SaveMetrics.
written_bytes``), the written fraction vs the full save, end-to-end save
seconds, and the worker-side hash/diff seconds — the paper's *volume* axis
should scale with the dirty fraction while restore stays bit-identical.

``--smoke`` shrinks the state and gates on the §12 acceptance criteria:
  · the 1%-dirty single-file save writes ≤ 10% of the full save's bytes,
  · the streaming restore of the delta step is bit-identical to a full
    (non-delta) save's restore of the same state,
  · after retention drops old steps, the refcount GC reaps unreferenced
    packs but every kept step still restores bit-exactly.
Exits nonzero on any violation — wired into ``make verify`` and CI.
"""

from __future__ import annotations

import os
import shutil
import sys

import numpy as np

from benchmarks.common import Report, fresh_dir, write_summary

FRACTIONS = (1.0, 0.5, 0.1, 0.01)
LAYOUTS = [
    ("file-per-tensor", "file_per_tensor"),
    ("file-per-rank", "file_per_process"),
    ("single-file", "single_file"),
]


def _state(n_tensors: int, rows: int, cols: int) -> dict:
    rng = np.random.default_rng(12)
    return {"params": {
        f"w{i}": rng.standard_normal((rows, cols)).astype(np.float32)
        for i in range(n_tensors)}, "step": 0}


def _total_bytes(state) -> int:
    return sum(a.nbytes for a in state["params"].values())


def _mutate(state, frac: float, rep: int) -> None:
    """Touch a contiguous ``frac`` of every tensor's rows, offset per rep so
    consecutive saves dirty different chunks."""
    for a in state["params"].values():
        rows = a.shape[0]
        n = max(1, int(rows * frac))
        off = (rep * 7919) % max(rows - n, 1)
        a[off:off + n] += 1.0
    state["step"] = rep


def run_sweep(rep_log: Report, smoke: bool) -> dict:
    from repro.core import CheckpointManager, EngineConfig

    # tensors must dwarf the chunk grid for the 1% cell to be meaningful:
    # a 1% contiguous span can dirty at most span//chunk + 2 chunks
    n_tensors = 4
    rows = 2048 if smoke else 6144
    cols = 1024
    reps = 2 if smoke else 3
    out = {"chunk_bytes": 256 << 10, "reps": reps, "cells": {}}

    for label, strategy in LAYOUTS:
        for frac in FRACTIONS:
            state = _state(n_tensors, rows, cols)
            total = _total_bytes(state)
            out["state_bytes"] = total
            d = fresh_dir(f"delta_{strategy}_{int(frac * 100)}")
            cfg = EngineConfig(strategy=strategy)
            with CheckpointManager(d, config=cfg, delta=True,
                                   keep=None) as mgr:
                full = mgr.save(0, state)
                best_written, best_s, best_hash = float("inf"), \
                    float("inf"), float("inf")
                for r in range(1, reps + 1):
                    _mutate(state, frac, r)
                    os.sync()
                    m = mgr.save(r, state)
                    best_written = min(best_written, m.written_bytes)
                    best_s = min(best_s, m.end_to_end_seconds)
                    best_hash = min(best_hash, m.hash_seconds)
            wf = best_written / full.written_bytes
            out["cells"][f"{int(frac * 100)}%x{label}"] = {
                "dirty_fraction": frac, "layout": label,
                "full_written_bytes": full.written_bytes,
                "written_bytes": best_written,
                "written_fraction": round(wf, 4),
                "save_seconds": round(best_s, 6),
                "hash_seconds": round(best_hash, 6)}
            rep_log.add(config=f"{int(frac * 100)}%-{label}",
                        written_mb=best_written / 1e6, written_frac=wf,
                        save_s=best_s, hash_s=best_hash,
                        state_mb=total >> 20)
    write_summary("delta", out)
    print(f"  -> BENCH_delta.json: {len(out['cells'])} cells, "
          f"{out['state_bytes'] >> 20} MB state")
    return out


def check_gates(smoke: bool) -> list[str]:
    """The §12 acceptance experiment (always run; sized small)."""
    from repro.core import CheckpointManager, EngineConfig

    errors: list[str] = []
    state = _state(4, 2048, 1024)          # 32 MB, 128 chunks of 256 KiB
    # fresh_dir purges the whole scratch: one call, then a sibling dir
    d = fresh_dir("delta_gate")
    d_full = os.path.join(os.path.dirname(d), "delta_gate_full")
    os.makedirs(d_full, exist_ok=True)

    cfg = EngineConfig(strategy="single_file")
    with CheckpointManager(d, config=cfg, delta=True, keep=2) as mgr:
        mgr.delta_gc_grace_s = 0.0
        full = mgr.save(0, state)
        _mutate(state, 0.01, 1)
        m1 = mgr.save(1, state)
        ratio = m1.written_bytes / full.written_bytes
        if ratio > 0.10:
            errors.append(f"1%-dirty save wrote {ratio:.1%} of full bytes "
                          f"(gate: <=10%)")
        # bit-identity: delta-step restore == full-save restore of same state
        with CheckpointManager(d_full, config=EngineConfig(
                strategy="single_file")) as ref:
            ref.save(1, state)
            want = ref.restore(step=1)
        got = mgr.restore(step=1)
        for k in state["params"]:
            if not np.array_equal(got["params"][k], want["params"][k]):
                errors.append(f"delta restore of {k} differs from "
                              f"full-save restore")
        # retention GC: roll old steps out; kept steps must stay restorable
        for r in range(2, 5):
            _mutate(state, 0.01, r)
            mgr.save(r, state)
        kept = mgr.all_steps()
        if kept != [3, 4]:
            errors.append(f"keep=2 retained {kept}")
        gc = mgr.last_gc_stats
        if gc is None or gc.kept == 0:
            errors.append("refcount GC never ran or pinned nothing")
        try:
            out = mgr.restore(step=kept[-1])
            for k, v in state["params"].items():
                if not np.array_equal(out["params"][k], v):
                    errors.append(f"post-GC restore of {k} not bit-identical")
        except Exception as e:  # noqa: BLE001 - gate must report, not die
            errors.append(f"post-GC restore failed: {e!r}")
    shutil.rmtree(d, ignore_errors=True)
    shutil.rmtree(d_full, ignore_errors=True)
    return errors


def run(smoke: bool = False):
    rep = Report("bench_delta")
    run_sweep(rep, smoke=smoke)
    errors = check_gates(smoke)
    path = rep.save()
    for e in errors:
        print(f"SMOKE FAIL: {e}", file=sys.stderr)
    if errors:
        sys.exit(1)
    print("  delta gates: 1%-dirty <=10% bytes, bit-identical restore, "
          "refcount GC keeps every referenced chunk")
    return path


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
