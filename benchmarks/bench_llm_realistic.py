"""Paper Figs 17–18: realistic LLM checkpoint layouts.

Fig 17: aggregation strategies on bloom-3b / llama-7b / llama-13b layouts.
Fig 18: engines on the same layouts (single aggregated file).

The layouts reproduce the paper's heterogeneous compositions (one multi-GB
optimizer shard + hundreds of KB..MB objects per rank — Fig 4), which is
exactly where uncoalesced I/O collapses.
"""

from __future__ import annotations

from benchmarks.common import Report, fresh_dir, llm_layout
from benchmarks.crbench import bench_read, bench_write

MODELS = [("bloom-3b", 4), ("llama-7b", 8), ("llama-13b", 16)]
STRATEGIES = ["file_per_tensor", "file_per_process", "single_file"]
ENGINES = ["aggregated", "datastates", "snapshot", "torchsave"]


def run(full_scale: bool = False, quick: bool = False):
    # paper scale: full checkpoints (42 GB for 3B over 4 ranks). Scaled:
    scale = 1.0 if full_scale else 1 / 16
    models = MODELS if not quick else [("bloom-3b", 2)]
    if quick:
        scale = 1 / 64

    rep = Report("bench_llm_realistic")
    print("== Fig 17: strategies x model layouts ==")
    for model, ranks in models:
        ranks = min(ranks, 4)   # 4 procs/node, single node (paper figs 13-18)
        for strategy in STRATEGIES:
            lay = llm_layout(model, ranks, scale)
            d = fresh_dir(f"llm_{model}_{strategy}")
            w = bench_write(lay, "aggregated", {"strategy": strategy}, d)
            r = bench_read(lay, "aggregated", {"strategy": strategy}, d)
            rep.add(fig="17", model=model, ranks=ranks, strategy=strategy,
                    total_mb=lay.total_bytes >> 20, write_gbps=w["gbps"],
                    read_gbps=r["gbps"], files=w["files"])
    print("== Fig 18: engines x model layouts (single aggregated file) ==")
    chunk = (512 << 20) if full_scale else (32 << 20)
    for model, ranks in models:
        ranks = min(ranks, 4)
        for engine in ENGINES:
            lay = llm_layout(model, ranks, scale)
            d = fresh_dir(f"llme_{model}_{engine}")
            w = bench_write(lay, engine, {"chunk_bytes": chunk}, d)
            r = bench_read(lay, engine, {"chunk_bytes": chunk}, d)
            rep.add(fig="18", model=model, ranks=ranks, engine=engine,
                    total_mb=lay.total_bytes >> 20, write_gbps=w["gbps"],
                    read_gbps=r["gbps"], write_reqs=w["io_requests"],
                    read_reqs=r["io_requests"])
    return rep.save()


if __name__ == "__main__":
    import sys
    run(full_scale="--full-scale" in sys.argv, quick="--quick" in sys.argv)
