"""Paper Figs 9–10: O_DIRECT × backend (liburing vs POSIX), single aggregated
file, write and cold-read throughput across data sizes."""

from __future__ import annotations

from benchmarks.common import Report, fresh_dir, synthetic_layout
from benchmarks.crbench import bench_read, bench_write


def run(full_scale: bool = False, quick: bool = False):
    sizes = ([256 << 20, 1 << 30, 4 << 30, 8 << 30] if full_scale
             else [64 << 20, 256 << 20, 1 << 30])
    ranks = 4
    if quick:
        sizes = [64 << 20, 256 << 20]
        ranks = 2

    rep = Report("bench_odirect")
    for backend in ["uring", "posix"]:
        for direct in [True, False]:
            for size in sizes:
                lay = synthetic_layout(ranks, size)
                d = fresh_dir(f"od_{backend}_{direct}_{size >> 20}")
                cfg = {"strategy": "single_file", "backend": backend,
                       "direct": direct}
                w = bench_write(lay, "aggregated", cfg, d)
                r = bench_read(lay, "aggregated", cfg, d)
                rep.add(backend=backend, o_direct=direct,
                        per_rank_mb=size >> 20, write_gbps=w["gbps"],
                        read_gbps=r["gbps"])
    return rep.save()


if __name__ == "__main__":
    import sys
    run(full_scale="--full-scale" in sys.argv, quick="--quick" in sys.argv)
