"""Benchmark runner — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick|--full-scale] [--only X]

Prints a ``name,us_per_call,derived`` CSV summary at the end; per-figure
detail lands in results/*.json (consumed by EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import time

MODULES = [
    "bench_aggregation",     # Figs 5-8
    "bench_odirect",         # Figs 9-10
    "bench_engines",         # Figs 11-12, 15-16
    "bench_restore_alloc",   # Figs 13-14
    "bench_llm_realistic",   # Figs 17-18
    "bench_tiered",          # §8 tiered flush/prefetch vs shutil baseline
    "bench_train_overhead",  # Fig 3
    "io_hillclimb",          # §Perf I/O hypothesis loop
    "roofline",              # §Roofline from the dry-run
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes (CI-friendly)")
    ap.add_argument("--full-scale", action="store_true",
                    help="paper-scale sizes (needs ~80GB disk + hours)")
    ap.add_argument("--only", default="",
                    help="comma-separated module suffixes")
    ap.add_argument("--refresh", action="store_true",
                    help="re-measure even when results/<module>.json exists")
    args = ap.parse_args()

    from benchmarks.common import RESULTS_DIR
    only = {m.strip() for m in args.only.split(",") if m.strip()}
    csv_rows = [("name", "us_per_call", "derived")]
    for name in MODULES:
        if only and not any(name.endswith(o) or o in name for o in only):
            continue
        print(f"\n===== {name} =====", flush=True)
        cached = os.path.join(RESULTS_DIR, f"{name}.json")
        t0 = time.perf_counter()
        if name != "roofline" and not args.refresh and os.path.exists(cached):
            print(f"  (summarizing existing {cached}; --refresh re-measures)")
            for r in json.load(open(cached)):
                print("  " + " ".join(f"{k}={v}" for k, v in r.items()))
            out_path = cached
        else:
            mod = importlib.import_module(f"benchmarks.{name}")
            out_path = mod.run(full_scale=args.full_scale, quick=args.quick)
        elapsed = time.perf_counter() - t0
        derived = ""
        if out_path and os.path.exists(out_path):
            rows = json.load(open(out_path))
            if rows and "write_gbps" in rows[0]:
                best = max(r.get("write_gbps", 0) for r in rows)
                derived = f"best_write={best:.2f}GB/s"
            elif rows and "read_gbps" in rows[0]:
                best = max(r.get("read_gbps", 0) for r in rows)
                derived = f"best_read={best:.2f}GB/s"
            elif rows and "roofline_mfu" in rows[0]:
                avg = sum(r["roofline_mfu"] for r in rows) / len(rows)
                derived = f"mean_roofline_mfu={avg:.3f}"
            elif rows and "wall_s" in rows[0]:
                derived = f"rows={len(rows)}"
        csv_rows.append((name, f"{elapsed * 1e6:.0f}", derived))

    print("\n=== summary CSV ===")
    for r in csv_rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
