"""Shared benchmark machinery: multi-rank process harness, cache control,
and the realistic LLM checkpoint layout generator (paper Fig 4).

Scale note (DESIGN.md §7): Polaris ranks flush 8 GB each to a 650 GB/s Lustre
PFS; this container has one ~0.65 GB/s filesystem and one core. Default sizes
are 1/16 of the paper's; ``--full-scale`` restores them. Process counts follow
the paper's 4-per-node.
"""

from __future__ import annotations

import datetime
import json
import multiprocessing as mp
import os
import shutil
import socket
import subprocess
import time
from dataclasses import dataclass, field

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(REPO_ROOT, "results")
SCRATCH = os.environ.get("REPRO_BENCH_DIR", "/root/bench_scratch")


def _git_revision() -> str:
    try:
        return subprocess.run(
            ["git", "-C", REPO_ROOT, "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            check=True).stdout.strip()
    except Exception:
        return "unknown"


def run_meta() -> dict:
    """Provenance stamped into every summary: which commit, where, when —
    so BENCH_*.json trajectories are comparable across PRs and hosts."""
    return {
        "git_revision": _git_revision(),
        "hostname": socket.gethostname(),
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
                        .isoformat(timespec="seconds"),
    }


def write_summary(tag: str, payload: dict) -> str:
    """THE one code path for tracked benchmark summaries.

    Every bench emits two artifacts: the per-row log (``Report.save`` →
    ``results/<name>.json``) and a curated summary tracked at the repo root
    as ``BENCH_<tag>.json`` so trajectories survive scratch cleanup. The
    benches used to hand-roll the latter; route them all through here.
    Every summary is stamped with ``meta`` provenance (``run_meta``).
    """
    path = os.path.join(REPO_ROOT, f"BENCH_{tag}.json")
    with open(path, "w") as f:
        json.dump({"meta": run_meta(), **payload}, f, indent=1)
    maybe_export_trace(tag)
    return path


def maybe_export_trace(tag: str) -> str | None:
    """When the bench ran with ``--trace`` (tracer enabled), drop the
    Perfetto timeline next to the summary: ``BENCH_<tag>.trace.json``.
    Load it in ui.perfetto.dev to see per-tier stage overlap."""
    from repro.core import trace
    if not trace.is_enabled():
        return None
    path = os.path.join(REPO_ROOT, f"BENCH_{tag}.trace.json")
    trace.export_perfetto(path)
    return path


def trace_from_argv(argv=None) -> bool:
    """Shared ``--trace`` flag: span tracer on for the whole bench run;
    ``write_summary`` then drops a Perfetto timeline beside each
    ``BENCH_<tag>.json``. Returns whether tracing was enabled."""
    import sys
    on = "--trace" in (sys.argv if argv is None else argv)
    if on:
        from repro.core import trace
        trace.enable()
    return on


def drop_caches() -> bool:
    """Drop the page cache so reads are cold (needs root; returns success)."""
    try:
        os.sync()
        with open("/proc/sys/vm/drop_caches", "w") as f:
            f.write("3")
        return True
    except OSError:
        return False


def fresh_dir(name: str) -> str:
    """Scratch dir for one benchmark config. Purges ALL earlier configs'
    data first — accumulated checkpoints otherwise exhaust the disk."""
    os.makedirs(SCRATCH, exist_ok=True)
    for entry in os.listdir(SCRATCH):
        shutil.rmtree(os.path.join(SCRATCH, entry), ignore_errors=True)
    d = os.path.join(SCRATCH, name)
    os.makedirs(d, exist_ok=True)
    return d


# ---------------------------------------------------------------- layouts
@dataclass
class Layout:
    """A per-rank list of object sizes modeling a checkpoint composition."""
    name: str
    ranks: int
    sizes_per_rank: list[list[int]]

    @property
    def total_bytes(self) -> int:
        return sum(sum(s) for s in self.sizes_per_rank)


def synthetic_layout(ranks: int, per_rank_bytes: int,
                     region_bytes: int = 64 << 20) -> Layout:
    """Paper §3.3: one large host buffer per rank, submitted as 64 MB regions."""
    sizes = []
    for _ in range(ranks):
        n, rem = divmod(per_rank_bytes, region_bytes)
        s = [region_bytes] * n + ([rem] if rem else [])
        sizes.append(s)
    return Layout("synthetic", ranks, sizes)


def llm_layout(model: str, ranks: int, scale: float = 1.0) -> Layout:
    """Realistic checkpoint compositions (paper Fig 4): heterogeneous object
    sizes from KB metadata headers to GB optimizer shards.

    Models: bloom-3b (4 ranks), llama-7b (8), llama-13b (16) following the
    paper, plus layouts derived from our assigned arch configs."""
    rng = np.random.default_rng(hash(model) % 2**31)
    presets = {
        # (big objects per rank, big size, medium count, medium size,
        #  small count, small range)
        "bloom-3b": (1, 8 << 30, 12, 300 << 20, 60, (4 << 10, 5 << 20)),
        "llama-7b": (1, 6 << 30, 16, 250 << 20, 90, (4 << 10, 5 << 20)),
        "llama-13b": (1, 5 << 30, 20, 200 << 20, 140, (4 << 10, 5 << 20)),
    }
    if model in presets:
        nb, bs, nm, ms, ns, (lo, hi) = presets[model]
        sizes = []
        for _ in range(ranks):
            s = [int(bs * scale)] * nb
            s += [int(ms * scale * rng.uniform(0.5, 1.5)) for _ in range(nm)]
            s += [int(rng.uniform(lo, hi)) for _ in range(ns)]
            sizes.append(s)
        return Layout(model, ranks, sizes)
    # derive from an assigned architecture's actual tensor inventory
    from repro.configs import get_config
    from repro.train.steps import init_train_state
    import jax
    cfg = get_config(model)
    shapes = jax.eval_shape(
        lambda: init_train_state(jax.random.key(0), cfg))
    leaves = jax.tree_util.tree_leaves(shapes)
    all_sizes = [int(np.prod(l.shape) * l.dtype.itemsize) for l in leaves]
    per_rank = [max(64, int(s * scale / ranks)) for s in all_sizes]
    return Layout(model, ranks, [list(per_rank) for _ in range(ranks)])


# ------------------------------------------------------------ rank harness
def _rank_worker(fn, rank, args, barrier, q):
    try:
        barrier.wait(timeout=600)
        t0 = time.perf_counter()
        out = fn(rank, *args)
        q.put((rank, time.perf_counter() - t0, out, None))
    except Exception as e:  # pragma: no cover
        import traceback
        q.put((rank, 0.0, None, traceback.format_exc()))


def run_ranks(fn, ranks: int, *args) -> tuple[float, list]:
    """Run fn(rank, *args) in `ranks` processes, barrier-synchronized start.

    Returns (wall_seconds_of_slowest, per-rank outputs)."""
    if ranks == 1:
        t0 = time.perf_counter()
        out = fn(0, *args)
        return time.perf_counter() - t0, [out]
    ctx = mp.get_context("spawn")
    barrier = ctx.Barrier(ranks)
    q = ctx.Queue()
    procs = [ctx.Process(target=_rank_worker, args=(fn, r, args, barrier, q))
             for r in range(ranks)]
    for p in procs:
        p.start()
    results = [q.get(timeout=1200) for _ in procs]
    for p in procs:
        p.join()
    errs = [e for (_, _, _, e) in results if e]
    if errs:
        raise RuntimeError(errs[0])
    wall = max(t for (_, t, _, _) in results)
    outs = [o for (_, _, o, _) in sorted(results)]
    return wall, outs


# ------------------------------------------------------------------ output
class Report:
    def __init__(self, name: str):
        self.name = name
        self.rows: list[dict] = []

    def add(self, **row):
        row = {k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in row.items()}
        self.rows.append(row)
        print("  " + " ".join(f"{k}={v}" for k, v in row.items()), flush=True)

    def save(self):
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, f"{self.name}.json")
        with open(path, "w") as f:
            json.dump(self.rows, f, indent=1)
        return path
