"""Core C/R bench: write/read a Layout through an engine across N rank
processes, barrier-synchronized, reporting aggregate bandwidth."""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import Layout, drop_caches, run_ranks


def _write_rank(rank: int, layout_sizes, engine_name: str, cfg_kw: dict,
                ckpt_dir: str, rank_totals):
    from repro.core.engines import EngineConfig, SaveItem, make_cr_engine
    sizes = layout_sizes[rank]
    items = []
    for i, n in enumerate(sizes):
        a = np.empty(max(n, 1), np.uint8)
        a[:: max(n // 64, 1)] = (rank * 131 + i) % 251   # cheap non-zero fill
        items.append(SaveItem(f"r{rank}/o{i}", a[:n] if n else a[:0],
                              "uint8", (n,), ((0, n),)))
    eng = make_cr_engine(engine_name, EngineConfig(**cfg_kw))
    m = eng.save(ckpt_dir, items, step=0, rank=rank,
                 num_ranks=len(layout_sizes), rank_totals=rank_totals)
    with open(os.path.join(ckpt_dir, f"manifest_rank{rank}.json"), "wb") as f:
        f.write(m.dumps())
    s = eng.last_save_stats
    eng.close()
    return {"bytes": s.logical_bytes, "seconds": s.seconds,
            "io_requests": s.io_requests, "files": s.files,
            "alloc_s": s.alloc_seconds, "copy_s": s.copy_seconds}


def _read_rank(rank: int, layout_sizes, engine_name: str, cfg_kw: dict,
               ckpt_dir: str):
    from repro.core.engines import EngineConfig, ReadReq, make_cr_engine
    from repro.core.manifest import Manifest
    with open(os.path.join(ckpt_dir, f"manifest_rank{rank}.json"), "rb") as f:
        m = Manifest.loads(f.read())
    reqs = []
    for key, rec in m.tensors.items():
        sh = rec.shards[0]
        reqs.append(ReadReq(key, sh.path, sh.offset, sh.nbytes, obj=key))
    eng = make_cr_engine(engine_name, EngineConfig(**cfg_kw))
    out = eng.read(ckpt_dir, reqs)
    s = eng.last_restore_stats
    n = sum(v.nbytes for v in out.values())
    eng.close()
    return {"bytes": n, "seconds": s.seconds, "io_requests": s.io_requests,
            "alloc_s": s.alloc_seconds, "copy_s": s.copy_seconds}


def rank_totals_for(layout: Layout, cfg_kw: dict):
    from repro.core.aggregation import ObjectSpec, Strategy, rank_padded_total
    strat = Strategy.parse(cfg_kw.get("strategy", Strategy.SINGLE_FILE))
    if strat is not Strategy.SINGLE_FILE:
        return None
    return [rank_padded_total([ObjectSpec(f"r{r}/o{i}", n)
                               for i, n in enumerate(sizes)])
            for r, sizes in enumerate(layout.sizes_per_rank)]


def bench_write(layout: Layout, engine: str, cfg_kw: dict, ckpt_dir: str):
    cfg_kw = dict(cfg_kw)
    if layout.ranks > 1:
        cfg_kw["truncate"] = False   # shared-file mode: no cross-rank trunc
    totals = rank_totals_for(layout, cfg_kw)
    wall, outs = run_ranks(_write_rank, layout.ranks, layout.sizes_per_rank,
                           engine, cfg_kw, ckpt_dir, totals)
    total = sum(o["bytes"] for o in outs)
    return {"gbps": total / wall / 1e9, "wall_s": wall, "bytes": total,
            "io_requests": sum(o["io_requests"] for o in outs),
            "files": sum(o["files"] for o in outs),
            "alloc_s": max(o["alloc_s"] for o in outs),
            "copy_s": max(o["copy_s"] for o in outs)}


def bench_read(layout: Layout, engine: str, cfg_kw: dict, ckpt_dir: str,
               cold: bool = True):
    if cold:
        drop_caches()
    wall, outs = run_ranks(_read_rank, layout.ranks, layout.sizes_per_rank,
                           engine, cfg_kw, ckpt_dir)
    total = sum(o["bytes"] for o in outs)
    return {"gbps": total / wall / 1e9, "wall_s": wall, "bytes": total,
            "io_requests": sum(o["io_requests"] for o in outs),
            "alloc_s": max(o["alloc_s"] for o in outs),
            "copy_s": max(o["copy_s"] for o in outs)}
