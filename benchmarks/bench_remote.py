"""Object-store level-2 tier sweep (DESIGN.md §15).

Publishes a checkpoint step to the in-process ``SimObjectStore`` (latency,
per-request bandwidth, and stall pathologies dialed in via ``SimProfile``),
then sweeps the ranged-restore knobs — range size × window (parallelism) ×
hedge threshold — through the direct-to-pipeline stream restore
(``RemoteCheckpointer(restore_mode="stream")``), recording wall-clock,
effective GB/s, hedge counts, and the per-range time-to-first-completion
p50/p99. Three dedicated experiments ride along in the same json:

  · ``parallel_speedup``  — windowed ranged restore vs the same stack at
    window=1 (the single-stream baseline) on a latency+bandwidth profile,
  · ``stall_masking``     — a stall-heavy profile restored with and without
    hedging: the hedged tail (p99 range time) must be bounded by the hedge
    threshold, the unhedged tail by the store's stall time,
  · ``dedup_upload``      — a 96 MB delta step re-uploaded after a 1%
    mutation: over-the-wire bytes vs the full upload (chunkstore packs are
    deduped via HEAD, the manifest is PUT last).

``--smoke`` shrinks the sweep and gates on the §15 acceptance criteria:
  · parallel hedged ranged restore >= 2x the single-stream wall-clock,
  · with injected stalls, hedged p99 range time is bounded by the hedge
    threshold (not the stall time) while the unhedged tail hits the stall,
  · the 1%-dirty re-upload ships <= 10% of the full upload's wire bytes,
  · every remote restore is bit-identical to the saved state.
Exits nonzero on any violation — wired into ``make verify`` and CI.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks.common import Report, fresh_dir, write_summary

# latency + per-request bandwidth: parallelism pays, stalls are rare
SWEEP_PROFILE = dict(latency_s=0.004, jitter_s=0.002,
                     bandwidth_bytes_s=600e6, seed=7)
# the tail profile: 12% of range GETs stall for 0.6 s
STALL_PROFILE = dict(latency_s=0.002, jitter_s=0.001,
                     bandwidth_bytes_s=800e6, stall_prob=0.12, stall_s=0.6,
                     seed=11)
NO_HEDGE = 1e9


def _state(total_mb: int) -> dict:
    rng = np.random.default_rng(5)
    rows = (total_mb << 20) // 3 // 4096
    return {f"w{i}": rng.standard_normal((rows, 1024)).astype(np.float32)
            for i in range(3)}


def _mutate(state: dict, frac: float, rep: int) -> None:
    for a in state.values():
        rows = a.shape[0]
        n = max(1, int(rows * frac))
        off = (rep * 7919) % max(rows - n, 1)
        a[off:off + n] += 1.0


def _identical(got: dict, want: dict) -> bool:
    return all(np.array_equal(np.asarray(got[k]), v)
               for k, v in want.items())


def _publish(base: str, store, state: dict, *, name: str,
             **mgr_kw) -> "object":
    """Save + synchronously upload step 0; returns the checkpointer."""
    from repro.core import RemoteCheckpointer
    d = os.path.join(base, f"pub_{name}")
    os.makedirs(d, exist_ok=True)
    mgr = RemoteCheckpointer(d, store, upload_async=False, **mgr_kw)
    mgr.save(0, state)
    return mgr


def _stream_restore(base: str, store, cfg, step: int = 0):
    """One fresh-machine stream restore; returns (state, wall_s, RangeStats)."""
    from repro.core import RemoteCheckpointer
    import shutil
    import uuid
    d = os.path.join(base, f"v_{uuid.uuid4().hex[:8]}")
    os.makedirs(d, exist_ok=True)
    v = RemoteCheckpointer(d, store, remote=cfg, restore_mode="stream")
    t0 = time.perf_counter()
    out = v.restore(step=step)
    wall = time.perf_counter() - t0
    stats = v._rmgr.engine.last_range_stats
    v.close()
    shutil.rmtree(d, ignore_errors=True)
    return out, wall, stats


def run_sweep(rep_log: Report, smoke: bool) -> dict:
    from repro.core import RemoteConfig, SimObjectStore, SimProfile

    state = _state(24 if smoke else 192)
    total = sum(a.nbytes for a in state.values())
    base = fresh_dir("remote_sweep")
    store = SimObjectStore(os.path.join(base, "bucket"))
    pub = _publish(base, store, state, name="sweep")
    store.profile = SimProfile(**SWEEP_PROFILE)

    ranges = [1 << 20, 4 << 20] if smoke else [1 << 20, 4 << 20, 16 << 20]
    windows = [1, 4, 8] if smoke else [1, 4, 8, 16]
    hedges = [0.1] if smoke else [0.1, 0.5]
    out = {"state_bytes": total, "sweep_profile": SWEEP_PROFILE,
           "stall_profile": STALL_PROFILE, "cells": {}}
    for rb in ranges:
        for w in windows:
            for h in hedges:
                cfg = RemoteConfig(range_bytes=rb, window=w, hedge_after_s=h)
                got, wall, st = _stream_restore(base, store, cfg)
                cell = {"range_mb": rb >> 20, "window": w,
                        "hedge_after_s": h, "wall_s": round(wall, 4),
                        "gbps": round(total / wall / 1e9, 3),
                        "ranges": st.ranges, "hedged": st.hedged,
                        "hedge_wins": st.hedge_wins,
                        "p50_range_s": round(st.range_percentile(0.5), 4),
                        "p99_range_s": round(st.range_percentile(0.99), 4),
                        "bit_identical": _identical(got, state)}
                out["cells"][f"r{rb >> 20}MB_w{w}_h{h}"] = cell
                rep_log.add(config=f"r{rb >> 20}MB_w{w}_h{h}",
                            gbps=cell["gbps"], wall_s=wall,
                            hedged=st.hedged, p99_range_s=cell["p99_range_s"])
    pub.close()
    return out


def check_speedup(out: dict, errors: list, smoke: bool) -> None:
    """Parallel hedged ranged restore vs single-stream, same stack."""
    from repro.core import RemoteConfig, SimObjectStore, SimProfile

    state = _state(48 if smoke else 96)
    total = sum(a.nbytes for a in state.values())
    base = fresh_dir("remote_speedup")
    store = SimObjectStore(os.path.join(base, "bucket"))
    pub = _publish(base, store, state, name="speedup")
    store.profile = SimProfile(**SWEEP_PROFILE)

    single_cfg = RemoteConfig(range_bytes=1 << 20, window=1,
                              hedge_after_s=NO_HEDGE)
    par_cfg = RemoteConfig(range_bytes=1 << 20, window=8, hedge_after_s=0.1)
    got_s, wall_s, _ = _stream_restore(base, store, single_cfg)
    got_p, wall_p, st_p = _stream_restore(base, store, par_cfg)
    speedup = wall_s / wall_p
    out["parallel_speedup"] = {
        "state_bytes": total, "single_wall_s": round(wall_s, 4),
        "parallel_wall_s": round(wall_p, 4), "window": par_cfg.window,
        "speedup": round(speedup, 2),
        "parallel_gbps": round(total / wall_p / 1e9, 3)}
    if speedup < 2.0:
        errors.append(f"parallel ranged restore only {speedup:.2f}x the "
                      f"single-stream wall (gate: >=2x)")
    for name, got in (("single-stream", got_s), ("parallel", got_p)):
        if not _identical(got, state):
            errors.append(f"{name} remote restore is not bit-identical")
    pub.close()


def check_stall_masking(out: dict, errors: list, smoke: bool) -> None:
    """Injected stalls: the hedged completion tail must be bounded by the
    hedge threshold; without hedging it hits the store's stall time."""
    from repro.core import RemoteConfig, SimObjectStore, SimProfile

    state = _state(24)
    base = fresh_dir("remote_stall")
    store = SimObjectStore(os.path.join(base, "bucket"))
    pub = _publish(base, store, state, name="stall")
    store.profile = SimProfile(**STALL_PROFILE)

    stall_s = STALL_PROFILE["stall_s"]
    hedge = 0.08
    rb = 512 << 10            # ~48 ranges: plenty of stall samples
    base_cfg = dict(range_bytes=rb, window=8)
    unhedged_cfg = RemoteConfig(hedge_after_s=NO_HEDGE, **base_cfg)
    hedged_cfg = RemoteConfig(hedge_after_s=hedge, max_hedges=2, **base_cfg)
    got_u, wall_u, st_u = _stream_restore(base, store, unhedged_cfg)
    got_h, wall_h, st_h = _stream_restore(base, store, hedged_cfg)
    u_max = max(st_u.range_seconds, default=0.0)
    h_p99 = st_h.range_percentile(0.99)
    out["stall_masking"] = {
        "stall_s": stall_s, "hedge_after_s": hedge,
        "unhedged": {"wall_s": round(wall_u, 4),
                     "p99_range_s": round(st_u.range_percentile(0.99), 4),
                     "max_range_s": round(u_max, 4)},
        "hedged": {"wall_s": round(wall_h, 4),
                   "p99_range_s": round(h_p99, 4),
                   "max_range_s": round(max(st_h.range_seconds,
                                            default=0.0), 4),
                   "hedged": st_h.hedged, "hedge_wins": st_h.hedge_wins}}
    if not _identical(got_u, state) or not _identical(got_h, state):
        errors.append("stall-profile remote restore is not bit-identical")
    if u_max < 0.9 * stall_s:
        errors.append(f"stall profile never stalled the unhedged run "
                      f"(max range {u_max:.3f}s < stall {stall_s}s)")
    if st_h.hedged == 0:
        errors.append("hedged run under a stall profile issued no hedges")
    # the acceptance bound: the hedged tail is set by the hedge threshold
    # (up to max_hedges re-issues + a fast fetch), never by the stall
    bound = (1 + hedged_cfg.max_hedges) * hedge + 0.25
    if h_p99 > bound:
        errors.append(f"hedged p99 range time {h_p99:.3f}s exceeds the "
                      f"hedge-threshold bound {bound:.3f}s")
    if h_p99 >= 0.9 * stall_s:
        errors.append(f"hedged p99 range time {h_p99:.3f}s is at the stall "
                      f"time ({stall_s}s): stalls are not being masked")
    if wall_h > wall_u:
        errors.append(f"hedged restore wall {wall_h:.3f}s slower than "
                      f"unhedged {wall_u:.3f}s under stalls")
    pub.close()


def check_dedup_upload(out: dict, errors: list) -> None:
    """The §15 dedup gate, sized exactly as the acceptance criterion: a
    96 MB delta step mutated 1% dirty re-uploads <= 10% of the full wire
    bytes (chunkstore packs dedup via HEAD)."""
    from repro.core import SimObjectStore

    state = _state(96)
    total = sum(a.nbytes for a in state.values())
    base = fresh_dir("remote_dedup")
    store = SimObjectStore(os.path.join(base, "bucket"))
    mgr = _publish(base, store, state, name="dedup", delta=True,
                   delta_chunk_bytes=256 << 10)
    full_wire = store.bytes_in
    full_up = mgr.last_upload_stats
    _mutate(state, 0.01, 1)
    mgr.save(1, state)
    dirty_wire = store.bytes_in - full_wire
    up = mgr.last_upload_stats
    frac = dirty_wire / full_wire
    out["dedup_upload"] = {
        "state_bytes": total, "full_wire_bytes": full_wire,
        "dirty_wire_bytes": dirty_wire, "wire_fraction": round(frac, 4),
        "chunks_shipped": up.chunks_shipped,
        "chunks_skipped": up.chunks_skipped,
        "bytes_skipped": up.bytes_skipped,
        "full_chunks_shipped": full_up.chunks_shipped,
        "upload_seconds": round(up.seconds, 4)}
    if frac > 0.10:
        errors.append(f"1%-dirty re-upload moved {frac:.1%} of the full "
                      f"upload's wire bytes (gate: <=10%)")
    if up.chunks_skipped == 0:
        errors.append("dedup re-upload skipped zero chunkstore packs")
    # the delta step must stream-restore bit-exactly on a fresh machine
    from repro.core import RemoteConfig
    got, _, _ = _stream_restore(base, store, RemoteConfig(), step=1)
    if not _identical(got, state):
        errors.append("remote stream restore of the delta step is not "
                      "bit-identical")
    mgr.close()


def run(smoke: bool = False):
    rep = Report("bench_remote")
    errors: list[str] = []
    out = run_sweep(rep, smoke=smoke)
    check_speedup(out, errors, smoke)
    check_stall_masking(out, errors, smoke)
    check_dedup_upload(out, errors)
    write_summary("remote", out)
    sp = out["parallel_speedup"]
    sm = out["stall_masking"]
    dd = out["dedup_upload"]
    print(f"  -> BENCH_remote.json: {len(out['cells'])} cells; parallel "
          f"{sp['speedup']}x single-stream; hedged p99 "
          f"{sm['hedged']['p99_range_s']}s vs stall {sm['stall_s']}s; "
          f"1%-dirty upload {dd['wire_fraction']:.1%} of full wire bytes")
    path = rep.save()
    for e in errors:
        print(f"SMOKE FAIL: {e}", file=sys.stderr)
    if errors:
        sys.exit(1)
    print("  remote gates: parallel >=2x single-stream, hedged tail "
          "bounded by hedge threshold, 1%-dirty upload <=10% wire bytes, "
          "bit-identical restores")
    return path


if __name__ == "__main__":
    from benchmarks.common import trace_from_argv
    trace_from_argv()
    run(smoke="--smoke" in sys.argv)
