"""§Perf I/O hillclimb — hypothesis → change → measure → validate cycles on
the checkpoint write path (the paper's own metric: sustained write bandwidth
on a realistic LLM layout).

Each iteration states a hypothesis with napkin math BEFORE measuring; the
result records confirmed/refuted. Runs on the real filesystem (io_uring +
O_DIRECT, measured, not simulated).
"""

from __future__ import annotations

import json
import os
import statistics

from benchmarks.common import Report, fresh_dir, llm_layout
from benchmarks.crbench import bench_write

RANKS = 2          # keep CPU contention low on the 1-core host
SCALE = 1 / 16
REPS = 3


def measure(cfg_kw: dict, tag: str) -> float:
    vals = []
    for rep in range(REPS):
        lay = llm_layout("bloom-3b", RANKS, SCALE)
        d = fresh_dir(f"hc_{tag}_{rep}")
        w = bench_write(lay, "aggregated", cfg_kw, d)
        vals.append(w["gbps"])
    return statistics.median(vals)


ITERATIONS = [
    # (name, hypothesis, config-delta)
    ("baseline",
     "paper-faithful config: single_file + uring + O_DIRECT + qd64 + "
     "64MB coalesce. Expected ≈ raw-disk sequential rate minus staging "
     "overhead (probe measured 0.65 GB/s raw).",
     {}),
    ("coalesce_256MB",
     "H1: 4x larger coalesce groups -> fewer, larger writes. Disk is "
     "sequential-dominated; fewer request boundaries should gain 5-15% "
     "(paper: throughput grows to ~2GB batches).",
     {"coalesce_bytes": 256 << 20, "chunk_bytes": 256 << 20}),
    ("queue_depth_8",
     "H2a: shallow queue (8). Single disk, sequential stream -> depth "
     "beyond a few should not matter; expect ~flat (<5% change).",
     {"queue_depth": 8}),
    ("queue_depth_128",
     "H2b: deep queue (128). Same reasoning; expect flat.",
     {"queue_depth": 128}),
    ("buffered",
     "H3: drop O_DIRECT. Page-cache double buffering + writeback under "
     "fsync -> paper saw up to 4.8x write LOSS; our earlier probe saw "
     "~3.8x. Expect large regression.",
     {"direct": False}),
    ("posix_backend",
     "H4: POSIX backend (blocking sequential pwrite, O_DIRECT kept). "
     "Python's syscall overhead per 64MB request is small -> expect "
     "mild regression vs uring (no submit/compute overlap).",
     {"backend": "posix"}),
    ("sqpoll",
     "H5: SQPOLL kernel-side submission polling. Saves syscalls but the "
     "poller thread competes for the SINGLE core with staging memcpy -> "
     "expect regression here (would help on a many-core host).",
     {"sqpoll": True}),
    ("coalesce_1GB",
     "H6: 1GB coalesce (paper's ~2GB/rank saturation point, scaled). "
     "Beyond the disk's saturation batch, staging latency before first "
     "byte hits disk grows -> expect <=5% over 256MB.",
     {"coalesce_bytes": 1 << 30, "chunk_bytes": 256 << 20}),
]


def run(full_scale: bool = False, quick: bool = False):
    rep = Report("io_hillclimb")
    base = None
    best = (None, 0.0)
    for name, hypothesis, delta in ITERATIONS:
        cfg = {"strategy": "single_file", "backend": "uring", "direct": True,
               "queue_depth": 64, "coalesce_bytes": 64 << 20,
               "chunk_bytes": 64 << 20}
        cfg.update(delta)
        gbps = measure(cfg, name)
        if base is None:
            base = gbps
        delta_pct = (gbps - base) / base * 100
        rep.add(iteration=name, write_gbps=gbps, delta_vs_baseline_pct=delta_pct,
                hypothesis=hypothesis[:100])
        if gbps > best[1]:
            best = (name, gbps)
    rep.add(iteration="BEST", write_gbps=best[1],
            delta_vs_baseline_pct=(best[1] - base) / base * 100,
            hypothesis=f"winner: {best[0]}")
    return rep.save()


if __name__ == "__main__":
    run()
