PY ?= python

.PHONY: verify test bench-smoke bench-restore-smoke

# The ROADMAP tier-1 gate plus the save- and restore-path smoke benchmarks:
# regressions in the test suite, pipelined blocking time, or streaming
# restore (wall-clock, staging bound, bit-identity) fail loudly.
verify: test bench-smoke bench-restore-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_train_overhead --smoke

bench-restore-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_restore_alloc --smoke
