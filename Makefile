PY ?= python

.PHONY: verify test bench-smoke

# The ROADMAP tier-1 gate plus the save-path smoke benchmark: regressions in
# either the test suite or pipelined blocking time fail loudly.
verify: test bench-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_train_overhead --smoke
