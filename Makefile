PY ?= python

.PHONY: verify test lint lint-baseline chaos bench-smoke \
	bench-restore-smoke bench-concurrency-smoke bench-delta-smoke \
	bench-remote-smoke bench-trace-smoke

# The ROADMAP tier-1 gate plus the chaos gate and the save-, restore-,
# concurrency, and delta smoke benchmarks: regressions in the test suite,
# crash/corruption invariants under injected faults (incl. crashes in the
# fingerprint-diff -> D2H gather window), pipelined blocking time,
# streaming restore (wall-clock, staging bound, bit-identity), the
# multi-writer commit protocol (one committed dir, merged manifest,
# elastic bit-identity), delta checkpointing (1%-dirty save writes
# <=10% of full bytes, bit-identical restore, refcount GC, fp128==blake2b
# dirty sets, d2h_bytes <= dirty bytes + digest tables), or the remote
# object tier (parallel hedged ranged restore >=2x single-stream, hedged
# tail bounded by the hedge threshold, 1%-dirty dedup upload <=10% wire
# bytes, bit-identical remote restores), or the tracing gate (tracer
# overhead <=5% of save wall, Perfetto timelines show pipelined stage
# overlap, stall attribution sums to the root wall) fail loudly.
verify: lint test chaos bench-smoke bench-restore-smoke \
	bench-concurrency-smoke bench-delta-smoke bench-remote-smoke \
	bench-trace-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# crlint (DESIGN.md §16): durability/concurrency invariant static analysis
# over the checkpoint stack. Zero-new-findings gate: anything not in
# crlint_baseline.txt fails the build.
lint:
	PYTHONPATH=src $(PY) -m repro.analysis.crlint src/repro

# Accept the current findings into the baseline (prints a diff-stat).
# Review the diff before committing — shrinking is progress, growth needs
# a reason in the PR.
lint-baseline:
	PYTHONPATH=src $(PY) -m repro.analysis.crlint src/repro --write-baseline

# Seeded fault-injection campaign (DESIGN.md §13): >=200 faults per fixed
# seed across the delta x multiwriter x multilevel matrix, zero invariant
# violations, < 60 s. CHAOS_ITERS=N appends N extra random-seed campaigns
# (nightly soak; each seed is printed for reproduction).
chaos:
	PYTHONPATH=src $(PY) tests/chaos/campaign.py

bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_train_overhead --smoke

bench-restore-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_restore_alloc --smoke

bench-concurrency-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_concurrency --smoke

bench-delta-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_delta --smoke

bench-remote-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_remote --smoke

bench-trace-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_trace_overhead --smoke
