PY ?= python

.PHONY: verify test bench-smoke bench-restore-smoke bench-concurrency-smoke

# The ROADMAP tier-1 gate plus the save-, restore-, and concurrency smoke
# benchmarks: regressions in the test suite, pipelined blocking time,
# streaming restore (wall-clock, staging bound, bit-identity), or the
# multi-writer commit protocol (one committed dir, merged manifest,
# elastic bit-identity) fail loudly.
verify: test bench-smoke bench-restore-smoke bench-concurrency-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_train_overhead --smoke

bench-restore-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_restore_alloc --smoke

bench-concurrency-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_concurrency --smoke
