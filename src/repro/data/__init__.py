from .pipeline import DataConfig, PipelineState, SyntheticPipeline

__all__ = ["DataConfig", "PipelineState", "SyntheticPipeline"]
