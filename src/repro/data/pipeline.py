"""Deterministic, shardable, checkpointable synthetic data pipeline.

Each (step, host) pair maps to an independent counter-based PRNG stream, so:
  · any host can regenerate any step (restart determinism — the pipeline
    state that must be checkpointed is just the step counter),
  · elastic restarts onto a different host count re-partition the global
    batch without replaying data,
  · no host ever materializes another host's shard.

Batches model a language-modeling token stream with structure (Zipf-ish
unigram + short-range repetition) so losses actually decrease during the
end-to-end example runs.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_len: int = 0
    frontend_dim: int = 0


@dataclass
class PipelineState:
    """The only thing the checkpoint needs to capture."""
    step: int = 0


class SyntheticPipeline:
    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 host_count: int = 1, state: PipelineState | None = None):
        if cfg.global_batch % host_count:
            raise ValueError("global_batch must divide host_count")
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        self.state = state or PipelineState()

    # -- deterministic per-(step,host) generation ---------------------------
    def _rng(self, step: int) -> np.random.Generator:
        seq = np.random.SeedSequence(
            [self.cfg.seed, step, self.host_index, 0xC0FFEE])
        return np.random.default_rng(seq)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        B, S = self.local_batch, cfg.seq_len
        # Zipf-ish unigram distribution with banded repetition
        base = rng.zipf(1.3, size=(B, S)).astype(np.int64)
        tokens = (base % (cfg.vocab_size - 2)) + 1
        # inject copy structure: second half repeats first half shifted
        half = S // 2
        if half > 4:
            tokens[:, half:half * 2] = tokens[:, :half]
        tokens = tokens.astype(np.int32)
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if cfg.frontend_len:
            out["frontend_embeds"] = rng.standard_normal(
                (B, cfg.frontend_len, cfg.frontend_dim)).astype(np.float32)
        return out

    def __iter__(self):
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    # -- checkpoint integration ---------------------------------------------
    def state_dict(self) -> dict:
        return {"data_step": self.state.step}

    def load_state_dict(self, d: dict) -> None:
        self.state.step = int(d["data_step"])
