"""Model configuration covering every assigned architecture family.

One frozen dataclass drives dense / MoE / SSM / hybrid / VLM / audio decoder
stacks. Layer heterogeneity (gemma2 local↔global alternation, recurrentgemma's
RG-LRU:attention 1:2 pattern, xLSTM's sLSTM/mLSTM mix) is expressed as a
``block_pattern`` that tiles across ``num_layers`` and is scanned group-wise
(stacked params per pattern period) to keep HLO size and compile time bounded.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field

# block kinds
ATTN = "attn"            # global attention
ATTN_LOCAL = "attn_local"
MLSTM = "mlstm"
SLSTM = "slstm"
RGLRU = "rglru"

ATTENTION_KINDS = (ATTN, ATTN_LOCAL)
RECURRENT_KINDS = (MLSTM, SLSTM, RGLRU)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    router_aux_coef: float = 0.01
    moe_capacity_factor: float = 1.25

    # --- attention variants ---
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen2.5
    attn_softcap: float = 0.0        # gemma2: 50.0
    final_softcap: float = 0.0       # gemma2: 30.0
    sliding_window: int = 0          # local-attention window
    rope_theta: float = 10_000.0

    # --- block pattern (tiles over num_layers); () -> all-ATTN ---
    block_pattern: tuple[str, ...] = ()

    # --- misc ---
    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # modality frontend stub: precomputed embeddings projected into d_model
    frontend: str = ""               # "" | audio_frames | vision_patches
    frontend_dim: int = 0            # incoming embedding dim
    frontend_len: int = 0            # prefix length supplied by the stub
    # recurrent block sizing
    lru_dim: int = 0                 # 0 -> d_model (RG-LRU width)
    proj_factor: float = 2.0         # xLSTM up-projection factor

    # --- training ---
    dtype: str = "bfloat16"
    remat: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.block_pattern:
            object.__setattr__(self, "block_pattern", (ATTN,))
        if self.num_layers % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers {self.num_layers} not divisible by "
                f"block pattern period {len(self.block_pattern)}")
        if self.lru_dim == 0:
            object.__setattr__(self, "lru_dim", self.d_model)

    # ---- derived ----
    @property
    def layers_per_group(self) -> int:
        return len(self.block_pattern)

    @property
    def num_groups(self) -> int:
        return self.num_layers // self.layers_per_group

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if no block requires a full-length global KV cache."""
        return all(k != ATTN for k in self.block_pattern)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        n = self.vocab_size * self.d_model          # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model     # head
        if self.frontend:
            n += self.frontend_dim * self.d_model   # frontend projector
        per_pattern = 0
        for kind in self.block_pattern:
            per_pattern += self._block_params(kind)
        n += per_pattern * self.num_groups
        n += self.d_model                            # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        full_experts = self._moe_ffn_params()
        active = full_experts * self.experts_per_token // self.num_experts
        dense_rest = self.param_count() - full_experts * self.num_layers // \
            self.layers_per_group * self.layers_per_group
        # simpler: subtract all expert params, add back active fraction
        total = self.param_count()
        expert_total = full_experts * self.num_layers
        return total - expert_total + active * self.num_layers

    def _moe_ffn_params(self) -> int:
        return self.num_experts * 3 * self.d_model * self.moe_d_ff

    def _block_params(self, kind: str) -> int:
        d, dff = self.d_model, self.d_ff
        if kind in ATTENTION_KINDS:
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                attn += self.q_dim + 2 * self.kv_dim
            if self.qk_norm:
                attn += 2 * self.head_dim
            ffn = (self._moe_ffn_params() + self.num_experts * d  # router
                   if self.is_moe else 3 * d * dff)
            return attn + ffn + 2 * d  # two norms
        if kind == RGLRU:
            r = self.lru_dim
            block = 2 * d * r + r * d       # in (x,gate) + out proj
            block += 3 * r                  # Λ, input-gate, conv-ish mixing
            ffn = 3 * d * dff
            return block + ffn + 2 * d
        if kind == MLSTM:
            up = int(self.proj_factor * d)
            inner = 2 * d * up + up * d     # up (x2) + down
            inner += 3 * up * up // max(self.num_heads, 1)  # q,k,v per head (approx)
            inner += 2 * up                 # gates
            return inner + d
        if kind == SLSTM:
            inner = 4 * d * d + 4 * d * d   # 4 gates, input+recurrent
            ffn_up = int(self.proj_factor * d)
            return inner + 2 * d * ffn_up + d
        raise ValueError(kind)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def scaled_down(self, layers: int = 2, width_div: int = 8,
                    vocab: int = 512) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        period = len(self.block_pattern)
        layers = max(layers, period)
        layers -= layers % period
        d_model = max(64, self.d_model // width_div)
        n_heads = max(1, self.num_heads // width_div)
        n_kv = max(1, min(self.num_kv_heads, n_heads))
        hd = max(16, d_model // n_heads)
        d_model = hd * n_heads
        return self.replace(
            num_layers=layers, d_model=d_model, num_heads=n_heads,
            num_kv_heads=n_kv, head_dim=hd,
            d_ff=max(32, self.d_ff // width_div) if self.d_ff else 0,
            vocab_size=vocab,
            num_experts=min(self.num_experts, 8) if self.is_moe else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.is_moe else 0,
            moe_d_ff=max(32, self.moe_d_ff // width_div) if self.is_moe else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            frontend_dim=min(self.frontend_dim, 64) if self.frontend else 0,
            frontend_len=min(self.frontend_len, 8) if self.frontend else 0,
            lru_dim=max(32, self.lru_dim // width_div),
            remat=False,
        )


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}
