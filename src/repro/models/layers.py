"""Functional layer library: init/apply pairs over plain dict pytrees.

Covers every assigned architecture's needs: RMSNorm, rotary embeddings, GQA
attention (qk-norm, qkv-bias, logit softcap, sliding window, KV cache), gated
MLP, capacity-based top-k MoE (expert-parallel friendly), RG-LRU, mLSTM and
sLSTM blocks. All matmul compute runs in ``cfg.dtype`` (bf16 by default) with
fp32 softmax/normalization/recurrence states.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, scale: float | None = None):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(cfg: ModelConfig, dim: int | None = None):
    return {"scale": jnp.ones((dim or cfg.d_model,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA + variants), with optional KV cache
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": dense_init(ks[0], (d, qd)),
        "wk": dense_init(ks[1], (d, kvd)),
        "wv": dense_init(ks[2], (d, kvd)),
        "wo": dense_init(ks[3], (qd, d)),
        "norm1": rmsnorm_init(cfg),
        "norm2": rmsnorm_init(cfg),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), jnp.float32)
        p["bk"] = jnp.zeros((kvd,), jnp.float32)
        p["bv"] = jnp.zeros((kvd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg, cfg.head_dim)
        p["k_norm"] = rmsnorm_init(cfg, cfg.head_dim)
    return p


def _softcap(logits, cap: float):
    if cap > 0:
        logits = cap * jnp.tanh(logits / cap)
    return logits


def attention_scores(q, k, v, mask, cfg: ModelConfig):
    """q: (B,Sq,H,D), k/v: (B,Skv,KV,D); returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32)
    logits = logits / np.sqrt(D)
    logits = _softcap(logits, cfg.attn_softcap)
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


def causal_mask(Sq: int, Skv: int, q_offset, window: int = 0):
    """(1, Sq, Skv) bool; window>0 limits lookback (local attention)."""
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m[None, :, :]


ATTN_CHUNK = 1024  # query-chunk size for memory-bounded attention


def chunked_attention(q, k, v, cfg: ModelConfig, window: int,
                      chunk: int = ATTN_CHUNK):
    """Causal attention with O(S·chunk) live memory via a query-chunk scan.

    The (B, chunk, Skv) logit tile is the only quadratic-ish intermediate —
    this is the XLA-level analogue of flash attention's tiling and what makes
    the 4k/32k dry-run cells fit per-device HBM (see DESIGN.md).
    """
    B, S, H, D = q.shape
    if S <= chunk:
        return attention_scores(q, k, v, causal_mask(S, S, 0, window), cfg)
    pad = (-S) % chunk   # frontend prefixes make S non-chunk-divisible
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = (S + pad) // chunk
    qs = q.reshape(B, nq, chunk, H, D).transpose(1, 0, 2, 3, 4)
    offs = jnp.arange(nq) * chunk

    def body(_, xs):
        qc, off = xs
        mask = causal_mask(chunk, S, off, window)
        return None, attention_scores(qc, k, v, mask, cfg)

    _, outs = jax.lax.scan(body, None, (qs, offs))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S + pad, H, D)
    return out[:, :S]


def attention_apply(params, x, cfg: ModelConfig, *, positions, local: bool,
                    cache=None):
    """Pre-norm attention block with residual. cache: dict(k,v,pos) or None."""
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    B, S, _ = h.shape
    q = h @ params["wq"].astype(h.dtype)
    k = h @ params["wk"].astype(h.dtype)
    v = h @ params["wv"].astype(h.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(h.dtype)
        k = k + params["bk"].astype(h.dtype)
        v = v + params["bv"].astype(h.dtype)
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    window = cfg.sliding_window if local else 0
    new_cache = None
    if cache is None:
        out = chunked_attention(q, k, v, cfg, window)
    else:
        # decode: S == 1; insert into cache ring/linear buffer, attend over it.
        # Slot validity/positions are ANALYTIC (no stored kpos array): for the
        # ring buffer, slot s holds position pos - ((pos - s) mod W); for the
        # linear buffer, slot s holds position s.
        ck, cv, pos = cache["k"], cache["v"], cache["pos"]
        W = ck.shape[1]
        slot = pos % W if window > 0 else jnp.minimum(pos, W - 1)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, slot, 0, 0))
        slots = jnp.arange(W, dtype=jnp.int32)[None, :]        # (1, W)
        cur = positions[:, :1]                                 # (B, 1)
        if window > 0:
            kpos = cur - jnp.remainder(cur - slots, W)
        else:
            kpos = jnp.broadcast_to(slots, (cur.shape[0], W))
        valid = (kpos >= 0) & (kpos <= cur)
        if window > 0:
            valid &= kpos > cur - window
        mask = valid[:, None, :]
        out = attention_scores(q, ck, cv, mask, cfg)
        new_cache = {"k": ck, "v": cv, "pos": pos + 1}
    out = out.reshape(B, S, cfg.q_dim) @ params["wo"].astype(x.dtype)
    return x + out, new_cache


def attention_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                         local: bool):
    W = min(cfg.sliding_window, max_len) if (local and cfg.sliding_window) \
        else max_len
    dt = _dtype(cfg)
    return {
        "k": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.head_dim), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {"wg": dense_init(ks[0], (d, f)),
            "wu": dense_init(ks[1], (d, f)),
            "wd": dense_init(ks[2], (f, d))}


def mlp_apply(params, x, cfg: ModelConfig):
    a = act_fn(cfg.act)
    h = a(x @ params["wg"].astype(x.dtype)) * (x @ params["wu"].astype(x.dtype))
    return h @ params["wd"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, capacity-based dropping, EP-shardable)
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    return {
        "router": dense_init(ks[0], (d, E), scale=0.02),
        "wg": dense_init(ks[1], (E, d, f)),
        "wu": dense_init(ks[2], (E, d, f)),
        "wd": dense_init(ks[3], (E, f, d)),
    }


def moe_apply(params, x, cfg: ModelConfig, capacity_factor: float | None = None):
    """x: (B,S,d) -> (B,S,d), aux_loss. Dropping implementation (GShard-style)
    with scatter dispatch into an (E, C, d) buffer — expert dim shards over the
    'model' mesh axis (expert parallelism)."""
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gates, idx = jax.lax.top_k(probs, k)                         # (T, k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    # capacity floor protects tiny (decode) batches from pathological drops
    C = max(int(np.ceil(T * k / E * capacity_factor)), min(T, 4 * k))
    e_flat = idx.reshape(-1)                                     # (T*k,)
    g_flat = gates.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(T), k)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)          # (T*k, E)
    pos = (jnp.cumsum(onehot, axis=0) - 1)
    pos = jnp.sum(pos * onehot, axis=-1)                         # (T*k,)
    keep = pos < C
    pos = jnp.where(keep, pos, C - 1)

    buf = jnp.zeros((E, C, d), x.dtype)
    contrib = jnp.where(keep[:, None], xt[t_flat], 0)
    buf = buf.at[e_flat, pos].add(contrib)

    a = act_fn(cfg.act)
    h = a(jnp.einsum("ecd,edf->ecf", buf, params["wg"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["wu"].astype(x.dtype))
    h = jnp.einsum("ecf,efd->ecd", h, params["wd"].astype(x.dtype))

    y = h[e_flat, pos] * g_flat[:, None].astype(x.dtype)
    y = jnp.where(keep[:, None], y, 0)
    out = jnp.zeros((T, d), x.dtype).at[t_flat].add(y)
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Transformer block = attention + (MLP | MoE)
# ---------------------------------------------------------------------------

def transformer_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = attention_init(k1, cfg)
    if cfg.is_moe:
        p["moe"] = moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k2, cfg)
    return p


def transformer_block_apply(params, x, cfg: ModelConfig, *, positions,
                            local: bool, cache=None):
    x, new_cache = attention_apply(params, x, cfg, positions=positions,
                                   local=local, cache=cache)
    h = rmsnorm(params["norm2"], x, cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moe_apply(params["moe"], h, cfg)
    else:
        y, aux = mlp_apply(params["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    return x + y, new_cache, aux


# ---------------------------------------------------------------------------
# RG-LRU block (RecurrentGemma) — gated linear recurrence + gated MLP
# ---------------------------------------------------------------------------

def rglru_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 7)
    d, r = cfg.d_model, cfg.lru_dim
    return {
        "wx": dense_init(ks[0], (d, r)),
        "wgate": dense_init(ks[1], (d, r)),
        "wout": dense_init(ks[2], (r, d)),
        # recurrence parameters (per-channel)
        "a_param": jnp.full((r,), 4.0, jnp.float32),    # Λ via softplus-ish
        "w_input_gate": dense_init(ks[3], (d, r), scale=0.02),
        "b_input_gate": jnp.zeros((r,), jnp.float32),
        "w_a_gate": dense_init(ks[4], (d, r), scale=0.02),
        "b_a_gate": jnp.zeros((r,), jnp.float32),
        "norm1": rmsnorm_init(cfg),
        "norm2": rmsnorm_init(cfg),
        "mlp": {"wg": dense_init(ks[5], (d, cfg.d_ff)),
                "wu": dense_init(ks[6], (d, cfg.d_ff)),
                "wd": dense_init(jax.random.fold_in(key, 9),
                                 (cfg.d_ff, d))},
    }


def _rglru_coeffs(params, u):
    """u: (...,d_model) pre-norm input. Returns (a, bx) fp32 of lru_dim."""
    c = 8.0
    ig = jax.nn.sigmoid((u @ params["w_input_gate"].astype(u.dtype)
                         ).astype(jnp.float32) + params["b_input_gate"])
    ag = jax.nn.sigmoid((u @ params["w_a_gate"].astype(u.dtype)
                         ).astype(jnp.float32) + params["b_a_gate"])
    log_a = -c * ag * jax.nn.softplus(params["a_param"])
    a = jnp.exp(log_a)
    x = (u @ params["wx"].astype(u.dtype)).astype(jnp.float32)
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-8))
    return a, beta * ig * x


def rglru_apply(params, x, cfg: ModelConfig, *, positions=None, local=False,
                cache=None):
    """Parallel (associative-scan) for sequences; recurrent for decode."""
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    a, bx = _rglru_coeffs(params, h)                  # (B,S,r) fp32
    if cache is None:
        # first-order linear recurrence via associative scan over S
        def comb(l, r_):
            al, bl = l
            ar, br = r_
            return al * ar, br + ar * bl
        aa, hh = jax.lax.associative_scan(comb, (a, bx), axis=1)
        new_cache = None
    else:
        h_prev = cache["h"]                            # (B,1,r)
        hh = a * h_prev + bx
        new_cache = {"h": hh}
    gate = jax.nn.silu((h @ params["wgate"].astype(h.dtype)))
    y = (hh.astype(x.dtype) * gate) @ params["wout"].astype(x.dtype)
    x = x + y
    # MLP half
    h2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
    act = act_fn(cfg.act)
    m = act(h2 @ params["mlp"]["wg"].astype(x.dtype)) * \
        (h2 @ params["mlp"]["wu"].astype(x.dtype))
    x = x + m @ params["mlp"]["wd"].astype(x.dtype)
    return x, new_cache


def rglru_cache_init(cfg: ModelConfig, batch: int):
    return {"h": jnp.zeros((batch, 1, cfg.lru_dim), jnp.float32)}


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM) — matrix memory; chunked-parallel for sequences
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    up = int(cfg.proj_factor * d)
    hd = up // cfg.num_heads
    return {
        "w_up1": dense_init(ks[0], (d, up)),
        "w_up2": dense_init(ks[1], (d, up)),
        "w_down": dense_init(ks[2], (up, d)),
        "wq": dense_init(ks[3], (up, up)),
        "wk": dense_init(ks[4], (up, up)),
        "wv": dense_init(ks[5], (up, up)),
        "w_igate": dense_init(ks[6], (up, cfg.num_heads), scale=0.02),
        "b_igate": jnp.zeros((cfg.num_heads,), jnp.float32),
        "w_fgate": dense_init(ks[7], (up, cfg.num_heads), scale=0.02),
        "b_fgate": jnp.full((cfg.num_heads,), 3.0, jnp.float32),
        "norm1": rmsnorm_init(cfg),
        "out_norm": rmsnorm_init(cfg, hd),
    }


def _mlstm_qkv(params, h, cfg):
    B, S, up = h.shape
    H = cfg.num_heads
    hd = up // H
    q = (h @ params["wq"].astype(h.dtype)).reshape(B, S, H, hd)
    k = (h @ params["wk"].astype(h.dtype)).reshape(B, S, H, hd) / np.sqrt(hd)
    v = (h @ params["wv"].astype(h.dtype)).reshape(B, S, H, hd)
    logi = (h @ params["w_igate"].astype(h.dtype)).astype(jnp.float32) \
        + params["b_igate"]                              # (B,S,H)
    logf = jax.nn.log_sigmoid(
        (h @ params["w_fgate"].astype(h.dtype)).astype(jnp.float32)
        + params["b_fgate"])                             # (B,S,H)
    return q, k, v, logi, logf


def _mlstm_intra(q, k, v, logi, logf):
    """Unnormalized intra-chunk mLSTM pieces (for exact chunkwise merging).

    Returns (num (B,S,H,D), den (B,S,H), m_intra (B,S,H), F (B,S,H)) where
    num/den carry stabilizer exp(·−m_intra) and F is the in-chunk cumulative
    log-forget."""
    F = jnp.cumsum(logf, axis=1)                          # (B,S,H)
    # log decay matrix: D[t,s] = F_t - F_s + i_s  for s <= t
    dmat = F[:, :, None, :] - F[:, None, :, :] + logi[:, None, :, :]
    S = q.shape[1]
    tri = jnp.tril(jnp.ones((S, S), bool))
    dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)   # (B,T,S,H)
    m = jnp.max(dmat, axis=2)                             # (B,T,H)
    dexp = jnp.exp(dmat - m[:, :, None, :])               # (B,T,S,H)
    scores = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    w = scores * dexp
    num = jnp.einsum("btsh,bshd->bthd", w, v.astype(jnp.float32))
    den = jnp.sum(w, axis=2)                              # (B,T,H)
    return num, den, m, F


def mlstm_sequence(q, k, v, logi, logf):
    """Stabilized quadratic-parallel mLSTM over a (chunk of) sequence.

    q,k,v: (B,S,H,D); logi,logf: (B,S,H). Returns (B,S,H,D).
    Matches the xLSTM paper's parallel formulation.
    """
    num, den, m, _ = _mlstm_intra(q, k, v, logi, logf)
    norm = jnp.maximum(jnp.abs(den), jnp.exp(-m))
    return num / (norm[..., None] + 1e-6)


def mlstm_apply(params, x, cfg: ModelConfig, *, positions=None, local=False,
                cache=None, chunk: int = 256):
    h0 = rmsnorm(params["norm1"], x, cfg.norm_eps)
    u1 = h0 @ params["w_up1"].astype(x.dtype)
    u2 = jax.nn.silu(h0 @ params["w_up2"].astype(x.dtype))
    q, k, v, logi, logf = _mlstm_qkv(params, u1, cfg)
    B, S, H, D = q.shape
    if cache is None:
        # NOTE: O(S·chunk) memory via chunking would be the production path;
        # the quadratic parallel form is used for S <= chunk and the
        # recurrent scan for longer sequences (TPU adaptation of the paper's
        # chunkwise formulation).
        if S <= chunk:
            out = mlstm_sequence(q, k, v, logi, logf)
        else:
            out = _mlstm_chunked(q, k, v, logi, logf, chunk)
        new_cache = None
    else:
        Cst, Nst, Mst = cache["C"], cache["N"], cache["M"]  # (B,H,D,D),(B,H,D),(B,H)
        lf, li = logf[:, 0], logi[:, 0]                     # (B,H)
        m_new = jnp.maximum(lf + Mst, li)
        alpha = jnp.exp(lf + Mst - m_new)[..., None]
        beta = jnp.exp(li - m_new)[..., None]
        k1, v1, q1 = k[:, 0], v[:, 0], q[:, 0]               # (B,H,D)
        Cst = Cst * alpha[..., None] + \
            beta[..., None] * k1[..., :, None] * v1[..., None, :]
        Nst = Nst * alpha + beta * k1
        qf = q1.astype(jnp.float32)
        num = jnp.einsum("bhd,bhde->bhe", qf, Cst)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, Nst)),
                          jnp.exp(-m_new))
        out = (num / (den[..., None] + 1e-6))[:, None]   # (B,1,H,D)
        new_cache = {"C": Cst, "N": Nst, "M": m_new}
    out = rmsnorm(params["out_norm"], out, cfg.norm_eps)
    out = out.reshape(B, S, H * D).astype(x.dtype) * u2
    return x + out @ params["w_down"].astype(x.dtype), new_cache


def _mlstm_chunked(q, k, v, logi, logf, chunk: int):
    """EXACT chunkwise mLSTM: quadratic within chunks, recurrent stabilized
    (C, N, M) state across chunks — the TPU-friendly O(S·chunk) form.

    State convention: C = Σ_s k_s v_sᵀ exp(F_end − F_s + i_s − M) (N likewise)
    where M is the running max-exponent at the chunk boundary.
    """
    B, S, H, D = q.shape
    nC = S // chunk
    assert S % chunk == 0, "sequence must be chunk-divisible"
    qs = q.reshape(B, nC, chunk, H, D).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nC, chunk, H, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nC, chunk, H, D).transpose(1, 0, 2, 3, 4)
    lis = logi.reshape(B, nC, chunk, H).transpose(1, 0, 2, 3)
    lfs = logf.reshape(B, nC, chunk, H).transpose(1, 0, 2, 3)
    NEG = -1e30   # log(0) stand-in that survives arithmetic

    def step(carry, xs):
        C, N, M = carry                      # (B,H,D,D), (B,H,D), (B,H)
        qc, kc, vc, lic, lfc = xs            # (B,chunk,H,*)
        num_i, den_i, m_i, F = _mlstm_intra(qc, kc, vc, lic, lfc)
        m_i = jnp.maximum(m_i, NEG)
        qf = qc.astype(jnp.float32)
        # per-position exponent of the carry-state contribution
        m_state = F + M[:, None, :]                         # (B,c,H)
        m_tot = jnp.maximum(m_i, m_state)
        a_i = jnp.exp(m_i - m_tot)                          # (B,c,H)
        a_s = jnp.exp(m_state - m_tot)
        num = num_i * a_i[..., None] + \
            a_s[..., None] * jnp.einsum("bchd,bhde->bche", qf, C)
        den = den_i * a_i + a_s * jnp.einsum("bchd,bhd->bch", qf, N)
        out = num / (jnp.maximum(jnp.abs(den), jnp.exp(-m_tot))[..., None]
                     + 1e-6)
        # state update to the chunk end, re-stabilized at M_new
        Ftot = F[:, -1]                                     # (B,H)
        m_new_local = jnp.max(Ftot[:, None, :] - F + lic, axis=1)  # (B,H)
        M_new = jnp.maximum(M + Ftot, m_new_local)
        dk = jnp.exp(Ftot[:, None, :] - F + lic - M_new[:, None, :])
        kc_f = kc.astype(jnp.float32) * dk[..., None]
        scale_old = jnp.exp(M + Ftot - M_new)
        C = C * scale_old[..., None, None] + \
            jnp.einsum("bchd,bche->bhde", kc_f, vc.astype(jnp.float32))
        N = N * scale_old[..., None] + jnp.sum(kc_f, axis=1)
        return (C, N, M_new), out

    C0 = jnp.zeros((B, H, D, D), jnp.float32)
    N0 = jnp.zeros((B, H, D), jnp.float32)
    M0 = jnp.full((B, H), NEG, jnp.float32)
    (_, _, _), outs = jax.lax.scan(step, (C0, N0, M0),
                                   (qs, ks, vs, lis, lfs))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)


def mlstm_cache_init(cfg: ModelConfig, batch: int):
    up = int(cfg.proj_factor * cfg.d_model)
    hd = up // cfg.num_heads
    return {"C": jnp.zeros((batch, cfg.num_heads, hd, hd), jnp.float32),
            "N": jnp.zeros((batch, cfg.num_heads, hd), jnp.float32),
            "M": jnp.zeros((batch, cfg.num_heads), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — scalar memory, sequential scan
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    up = int(cfg.proj_factor * d)
    p = {"norm1": rmsnorm_init(cfg)}
    for i, g in enumerate(("i", "f", "z", "o")):
        p[f"w_{g}"] = dense_init(ks[i], (d, d))
        p[f"r_{g}"] = dense_init(ks[4 + i], (d, d), scale=0.02)
        p[f"b_{g}"] = (jnp.full((d,), 1.0, jnp.float32) if g == "f"
                       else jnp.zeros((d,), jnp.float32))
    p["w_up"] = dense_init(ks[8], (d, up))
    p["w_down"] = dense_init(ks[9], (up, d))
    return p


def _slstm_step(params, carry, x_t):
    """x_t: (B,d) fp32 pre-activations base; carry: (c,n,m,h)."""
    c, n, m, h = carry
    pre = lambda g: (x_t @ params[f"w_{g}"] + h @ params[f"r_{g}"]
                     + params[f"b_{g}"])
    it, ft = pre("i"), pre("f")
    zt = jnp.tanh(pre("z"))
    ot = jax.nn.sigmoid(pre("o"))
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(logf + m - m_new)
    c = f_ * c + i_ * zt
    n = f_ * n + i_
    h = ot * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h), h


def slstm_apply(params, x, cfg: ModelConfig, *, positions=None, local=False,
                cache=None):
    h0 = rmsnorm(params["norm1"], x, cfg.norm_eps).astype(jnp.float32)
    B, S, d = h0.shape
    w = {k: v.astype(jnp.float32) for k, v in params.items()
         if k.startswith(("w_", "r_", "b_")) and not k.endswith(("up", "down"))}
    w["w_up"], w["w_down"] = params["w_up"], params["w_down"]
    if cache is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        carry0 = (c0, c0, c0, c0)
        (cN, nN, mN, hN), hs = jax.lax.scan(
            lambda c, xt: _slstm_step(w, c, xt),
            carry0, h0.transpose(1, 0, 2))
        out = hs.transpose(1, 0, 2)
        new_cache = None
    else:
        carry = (cache["c"], cache["n"], cache["m"], cache["h"])
        carry, out = _slstm_step(w, carry, h0[:, 0])
        out = out[:, None, :]
        new_cache = dict(zip(("c", "n", "m", "h"), carry))
    up = jax.nn.gelu(out.astype(x.dtype) @ params["w_up"].astype(x.dtype))
    return x + up @ params["w_down"].astype(x.dtype), new_cache


def slstm_cache_init(cfg: ModelConfig, batch: int):
    z = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return {"c": z, "n": z, "m": z, "h": z}
