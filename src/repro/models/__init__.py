from .config import (ALL_SHAPES, ATTN, ATTN_LOCAL, MLSTM, RGLRU, SLSTM,
                     SHAPES_BY_NAME, ModelConfig, ShapeConfig)
from .transformer import (cast_params, decode_step, forward, init_cache,
                          init_params)

__all__ = ["ALL_SHAPES", "ATTN", "ATTN_LOCAL", "MLSTM", "RGLRU", "SLSTM",
           "SHAPES_BY_NAME", "ModelConfig", "ShapeConfig", "cast_params",
           "decode_step", "forward", "init_cache", "init_params"]
