"""Decoder-LM assembly: embeddings, scanned heterogeneous blocks, head.

Layers are scanned GROUP-wise: the block pattern (e.g. recurrentgemma's
(rglru, rglru, attn_local)) forms one group whose params are stacked across
``num_groups`` repetitions, and ``jax.lax.scan`` iterates groups. This keeps
the lowered HLO O(pattern) instead of O(num_layers) — essential for the
512-device dry-run compiles — and is remat-friendly (one policy per group).

Modality frontends (audio frames / vision patches) are STUBS per the
assignment: ``frontend_embeds`` arrive precomputed and a learned projector
maps them into d_model as a prefix to the token embeddings.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .config import (ATTN, ATTN_LOCAL, MLSTM, RGLRU, SLSTM, ATTENTION_KINDS,
                     ModelConfig)

BLOCK_INIT = {
    ATTN: L.transformer_block_init,
    ATTN_LOCAL: L.transformer_block_init,
    MLSTM: L.mlstm_init,
    SLSTM: L.slstm_init,
    RGLRU: L.rglru_init,
}


def init_params(key, cfg: ModelConfig):
    """Full parameter pytree. Per-group block params stacked on axis 0."""
    keys = jax.random.split(key, 4 + cfg.num_layers)
    params = {
        "embed": L.dense_init(keys[0], (cfg.vocab_size, cfg.d_model), 0.02),
        "final_norm": L.rmsnorm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(keys[1], (cfg.d_model, cfg.vocab_size))
    if cfg.frontend:
        params["frontend_proj"] = L.dense_init(
            keys[2], (cfg.frontend_dim, cfg.d_model))

    blocks = []
    ki = iter(keys[4:])
    for g in range(cfg.num_groups):
        group = {}
        for j, kind in enumerate(cfg.block_pattern):
            group[f"b{j}_{kind}"] = BLOCK_INIT[kind](next(ki), cfg)
        blocks.append(group)
    params["blocks"] = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *blocks)
    return params


def cast_params(params, dtype):
    """Cast matmul weights to compute dtype; keep norms/gates fp32."""
    def cast(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("scale",) or name.startswith("b_") or \
                name in ("a_param",):
            return x
        return x.astype(dtype)
    return jax.tree_util.tree_map_with_path(cast, params)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

CACHE_INIT = {
    ATTN: lambda cfg, b, m: L.attention_cache_init(cfg, b, m, local=False),
    ATTN_LOCAL: lambda cfg, b, m: L.attention_cache_init(cfg, b, m, local=True),
    MLSTM: lambda cfg, b, m: L.mlstm_cache_init(cfg, b),
    SLSTM: lambda cfg, b, m: L.slstm_cache_init(cfg, b),
    RGLRU: lambda cfg, b, m: L.rglru_cache_init(cfg, b),
}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked (per-group) decode caches matching the scan structure."""
    groups = []
    for g in range(cfg.num_groups):
        group = {}
        for j, kind in enumerate(cfg.block_pattern):
            group[f"b{j}_{kind}"] = CACHE_INIT[kind](cfg, batch, max_len)
        groups.append(group)
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *groups)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _apply_block(kind: str, params, x, cfg, positions, cache):
    if kind in ATTENTION_KINDS:
        x, nc, aux = L.transformer_block_apply(
            params, x, cfg, positions=positions,
            local=(kind == ATTN_LOCAL), cache=cache)
        return x, nc, aux
    fn = {MLSTM: L.mlstm_apply, SLSTM: L.slstm_apply, RGLRU: L.rglru_apply}[kind]
    x, nc = fn(params, x, cfg, positions=positions,
               local=(kind == ATTN_LOCAL), cache=cache)
    return x, nc, jnp.zeros((), jnp.float32)


def _group_fn(cfg: ModelConfig, decode: bool, act_sharding=None):
    def group(carry, scanned):
        x, positions = carry
        gparams = scanned["params"]
        gcache = scanned.get("cache")
        new_cache = {}
        aux_total = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(cfg.block_pattern):
            name = f"b{j}_{kind}"
            cache_j = gcache[name] if gcache is not None else None
            x, nc, aux = _apply_block(kind, gparams[name], x, cfg,
                                      positions, cache_j)
            if act_sharding is not None:
                # pin the residual stream layout (batch over DP) so the scan's
                # saved carries stay batch-sharded instead of whatever GSPMD
                # propagates from the params
                x = jax.lax.with_sharding_constraint(x, act_sharding)
            aux_total = aux_total + aux
            if nc is not None:
                new_cache[name] = nc
        out = {"aux": aux_total}
        if decode:
            out["cache"] = new_cache
        return (x, positions), out
    return group


def _embed(params, cfg: ModelConfig, tokens, frontend_embeds=None):
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    if cfg.family in ("vlm", "audio") and cfg.frontend and \
            frontend_embeds is not None:
        proj = frontend_embeds.astype(x.dtype) @ \
            params["frontend_proj"].astype(x.dtype)
        x = jnp.concatenate([proj, x], axis=1)
    if cfg.attn_softcap:      # gemma-style embedding scaling
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    return x


def _unembed(params, cfg: ModelConfig, x):
    h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].astype(h.dtype).T
    else:
        logits = h @ params["head"].astype(h.dtype)
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def forward(params, cfg: ModelConfig, tokens, frontend_embeds=None,
            return_hidden: bool = False, act_sharding=None):
    """Training/prefill forward: tokens (B,S) -> logits (B,S_total,V), aux.

    ``return_hidden=True`` skips the unembed (the training loss computes it
    chunk-wise to bound fp32 logit memory). ``act_sharding`` pins the
    residual-stream layout at production scale."""
    x = _embed(params, cfg, tokens, frontend_embeds)
    if act_sharding is not None:
        x = jax.lax.with_sharding_constraint(x, act_sharding)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    group = _group_fn(cfg, decode=False, act_sharding=act_sharding)
    if cfg.remat:
        group = jax.checkpoint(group,
                               policy=jax.checkpoint_policies.nothing_saveable)
    (x, _), outs = jax.lax.scan(group, (x, positions),
                                {"params": params["blocks"]})
    aux = jnp.sum(outs["aux"])
    if return_hidden:
        return x, aux
    return _unembed(params, cfg, x), aux


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """One-token decode: tokens (B,1), pos (B,1) absolute positions.

    cache is the stacked per-group cache from ``init_cache``. Returns
    (logits (B,1,V), new_cache).
    """
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    if cfg.attn_softcap:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    group = _group_fn(cfg, decode=True)
    (x, _), outs = jax.lax.scan(group, (x, pos),
                                {"params": params["blocks"], "cache": cache})
    logits = _unembed(params, cfg, x)
    return logits, outs["cache"]
