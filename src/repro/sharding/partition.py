"""Partition rules: DP × TP (× pod) with EP for MoE and ZeRO-1 moments.

Name-based rules map every parameter path to a PartitionSpec, with
divisibility guards (e.g. qwen2.5's 2 KV heads can't split 16 ways — they
replicate; internvl2's 92553 vocab shards on d_model instead). Stacked
per-group block params get a leading None for the scan axis.

DP axes: ("pod", "data") when the pod axis exists, else ("data",).
TP/EP axis: "model".
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


class Partitioner:
    def __init__(self, cfg: ModelConfig, mesh: Mesh, zero1: bool = True,
                 fsdp: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.zero1 = zero1
        self.fsdp = fsdp   # additionally shard params over 'data' (ZeRO-3)
        self.model = axis_size(mesh, "model")
        self.dp = dp_axes(mesh)
        self.dp_size = int(np.prod([axis_size(mesh, a) for a in self.dp]))

    # ------------------------------------------------------------- params
    def param_spec(self, path: tuple[str, ...], shape: tuple[int, ...]) -> P:
        name = path[-1]
        stacked = "blocks" in path      # leading scan axis
        lead = (None,) if stacked else ()
        body = shape[1:] if stacked else shape
        m = self.model

        def guard(spec_entries):
            # verify each sharded dim divides; else replicate that entry
            out = []
            for dim, e in zip(body, spec_entries):
                out.append(e if (e is None or _div(dim, m)) else None)
            return P(*lead, *out)

        if name == "embed":
            return (P("model", None) if _div(shape[0], m)
                    else guard((None, "model")))
        if name == "head":
            return guard((None, "model"))
        if name == "frontend_proj":
            return guard((None, "model"))
        if name in ("wq", "wk", "wv", "w_up1", "w_up2", "wg", "wu", "wx",
                    "wgate", "w_input_gate", "w_a_gate", "w_up",
                    "w_i", "w_f", "w_z", "w_o", "r_i", "r_f", "r_z", "r_o"):
            if len(body) == 3:   # MoE expert-stacked (E, d, f): EP on experts
                return guard(("model", None, None))
            return guard((None, "model"))
        if name in ("wo", "wd", "w_down", "wout"):
            if len(body) == 3:   # MoE (E, f, d)
                return guard(("model", None, None))
            return guard(("model", None))
        if name == "router":
            return guard((None, None))
        if name in ("bq", "bk", "bv", "a_param", "b_input_gate", "b_a_gate"):
            return guard(("model",))
        if name in ("b_i", "b_f", "b_z", "b_o", "b_igate", "b_fgate",
                    "w_igate", "w_fgate"):
            return guard(tuple(None for _ in body))
        if name == "scale":
            return P(*lead, *(None for _ in body))
        # default: replicate
        return P(*lead, *(None for _ in body))

    def _fsdp_spec(self, pspec: P, shape: tuple[int, ...],
                   stacked: bool) -> P:
        """ZeRO-3: add 'data' to the first unsharded divisible dim, skipping
        the leading layer-stack dim (sharding the scan axis would force a
        full-stack gather every scan iteration)."""
        if not self.fsdp or "data" not in self.mesh.axis_names:
            return pspec
        entries = list(pspec) + [None] * (len(shape) - len(pspec))
        dsize = axis_size(self.mesh, "data")
        start = 1 if stacked else 0
        for i in range(start, len(shape)):
            if entries[i] is None and _div(shape[i], dsize) \
                    and shape[i] >= dsize:
                entries[i] = "data"
                return P(*entries)
        return pspec

    def param_shardings(self, params_shape):
        """Pytree of NamedShardings matching a params (shape-)pytree."""
        def one(path, leaf):
            names = tuple(
                p.key if hasattr(p, "key") else str(p) for p in path)
            spec = self.param_spec(names, tuple(leaf.shape))
            spec = self._fsdp_spec(spec, tuple(leaf.shape),
                                   stacked="blocks" in names)
            return NamedSharding(self.mesh, spec)
        return jax.tree_util.tree_map_with_path(one, params_shape)

    # ------------------------------------------------------------ optimizer
    def zero1_spec(self, pspec: P, shape: tuple[int, ...]) -> P:
        """Add 'data' sharding to the first unsharded, divisible dim."""
        if not self.zero1 or "data" not in self.mesh.axis_names:
            return pspec
        entries = list(pspec) + [None] * (len(shape) - len(pspec))
        dsize = axis_size(self.mesh, "data")
        for i, (dim, e) in enumerate(zip(shape, entries)):
            if e is None and _div(dim, dsize) and dim >= dsize:
                entries[i] = "data"
                return P(*entries)
        return pspec

    def opt_shardings(self, params_shape):
        def one(path, leaf):
            names = tuple(
                p.key if hasattr(p, "key") else str(p) for p in path)
            shape = tuple(leaf.shape)
            ps = self.param_spec(names, shape)
            if self.fsdp:   # ZeRO-3: moments follow the fsdp param sharding
                ps = self._fsdp_spec(ps, shape, stacked="blocks" in names)
            else:           # ZeRO-1: shard moments over data
                ps = self.zero1_spec(ps, shape)
            return NamedSharding(self.mesh, ps)
        moments = jax.tree_util.tree_map_with_path(one, params_shape)
        return {"mu": moments, "nu": moments,
                "count": NamedSharding(self.mesh, P())}

    # ------------------------------------------------------------ activations
    def batch_spec(self) -> P:
        return P(self.dp,)

    def tokens_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.dp, None))

    def frontend_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.dp, None, None))

    def activation_spec(self) -> P:
        return P(self.dp, None, None)

    def cache_shardings(self, cache_shape):
        """Decode caches: batch over DP; KV-head dim over model if divisible."""
        def one(path, leaf):
            shape = tuple(leaf.shape)
            # stacked leading group axis, then batch
            entries: list = [None]  # group axis
            if len(shape) >= 2:
                entries.append(self.dp)
            for dim in shape[2:]:
                if dim == self.cfg.num_kv_heads and \
                        _div(self.cfg.num_kv_heads, self.model):
                    entries.append("model")
                elif dim == self.cfg.num_heads and \
                        _div(self.cfg.num_heads, self.model):
                    entries.append("model")
                else:
                    entries.append(None)
            # scalar leaves (e.g. pos)
            entries = entries[:len(shape)]
            return NamedSharding(self.mesh, P(*entries))
        return jax.tree_util.tree_map(one, cache_shape)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())
