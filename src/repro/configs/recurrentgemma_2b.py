"""recurrentgemma-2b — RG-LRU + local attention hybrid (Griffin), 1:2 ratio.

[arXiv:2402.19427; hf]  26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000. Pattern: two RG-LRU blocks per local-attention block
(26 layers = 2 groups of a 13-block pattern carrying 9 recurrent + 4 local
attention, reproducing the paper's (R,R,A) tiling over 26 layers).
Sub-quadratic (window-bounded cache): runs the long_500k cell.
"""

from repro.models.config import ATTN_LOCAL, RGLRU, ModelConfig

_PATTERN = (RGLRU, RGLRU, ATTN_LOCAL) * 4 + (RGLRU,)   # 13 blocks, x2 groups

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=_PATTERN,
    sliding_window=2048,
    lru_dim=2560,
    act="gelu",
    tie_embeddings=True,
)
