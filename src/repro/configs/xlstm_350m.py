"""xlstm-350m — sLSTM + mLSTM recurrent LM (attention-free).

[arXiv:2405.04517; unverified]  24L d_model=1024 4H d_ff=0 vocab=50304.
Alternating mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar memory,
sequential) blocks; d_ff=0 means blocks carry their own up/down projections
(proj_factor=2). Sub-quadratic: runs the long_500k cell.
"""

from repro.models.config import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=(MLSTM, SLSTM),
    proj_factor=2.0,
    tie_embeddings=True,
)
