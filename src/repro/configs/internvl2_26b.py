"""internvl2-26b — VLM: InternViT frontend + InternLM2 decoder backbone.

[arXiv:2404.16821; hf]  48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553. The InternViT-6B vision tower is a STUB per the assignment:
``input_specs`` supplies precomputed patch embeddings (3200-dim) projected
into the LM as a 256-token prefix.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    frontend="vision_patches",
    frontend_dim=3200,    # InternViT-6B feature width
    frontend_len=256,     # patches per image after pixel-shuffle
)
