"""Architecture registry: ``--arch <id>`` resolves here.

Every assigned architecture is a selectable config; ``get_config(id)`` returns
the full-size ModelConfig and ``get_config(id).scaled_down()`` the reduced
same-family smoke-test config.
"""

from __future__ import annotations

import importlib

from repro.models.config import (ALL_SHAPES, SHAPES_BY_NAME, ModelConfig,
                                 ShapeConfig)

_MODULES = {
    "musicgen-large": "musicgen_large",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "stablelm-3b": "stablelm_3b",
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen3-32b": "qwen3_32b",
    "gemma2-9b": "gemma2_9b",
    "internvl2-26b": "internvl2_26b",
    "xlstm-350m": "xlstm_350m",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def cells(arch_id: str) -> list[tuple[ModelConfig, ShapeConfig, bool]]:
    """All (config, shape, applicable) dry-run cells for one arch.

    ``applicable`` is False for long_500k on pure full-attention archs
    (needs sub-quadratic attention — see DESIGN.md §6).
    """
    cfg = get_config(arch_id)
    out = []
    for shape in ALL_SHAPES:
        applicable = True
        if shape.name == "long_500k" and not cfg.sub_quadratic:
            applicable = False
        out.append((cfg, shape, applicable))
    return out
