"""musicgen-large — decoder-only LM over EnCodec audio tokens.

[arXiv:2306.05284; hf]  48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048.
The EnCodec/conditioning frontend is a STUB: ``input_specs`` supplies
precomputed frame embeddings that a learned projector prefixes to the token
stream (assignment: "modality frontend is a STUB").
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    act="gelu",
    frontend="audio_frames",
    frontend_dim=768,     # conditioning embedding width (T5-style)
    frontend_len=64,      # prefix frames
)
