"""gemma2-9b — dense LM with local/global alternating attention + softcaps.

[arXiv:2408.00118; hf]  42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000. Attention logit softcap 50, final logit softcap 30,
4096-token sliding window on local layers, tied embeddings, GeGLU.
"""

from repro.models.config import ATTN, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    block_pattern=(ATTN_LOCAL, ATTN),
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
)
