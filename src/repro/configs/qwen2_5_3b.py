"""qwen2.5-3b — dense decoder LM with strong GQA (kv=2) and QKV bias.

[hf:Qwen/Qwen2.5-0.5B; hf]  36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
