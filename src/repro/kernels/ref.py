"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_blocks_ref(x):
    """x: (R, C) -> (int8 (R, C), f32 scales (R,)); one group per row."""
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=1)
    # reciprocal multiply, matching the kernel (see _quant_kernel)
    scale = jnp.where(absmax > 0, absmax * jnp.float32(1.0 / 127.0), 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_blocks_ref(q, scales, out_dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scales[:, None].astype(jnp.float32)
            ).astype(out_dtype)


def rglru_scan_ref(a, b):
    """First-order linear recurrence h_t = a_t * h_{t-1} + b_t, h_0 = 0.

    Uses jax.lax.associative_scan — the XLA path the kernel replaces.
    """
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl
    _, h = jax.lax.associative_scan(comb, (a.astype(jnp.float32),
                                           b.astype(jnp.float32)), axis=1)
    return h
