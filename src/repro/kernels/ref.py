"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_blocks_ref(x):
    """x: (R, C) -> (int8 (R, C), f32 scales (R,)); one group per row."""
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=1)
    # reciprocal multiply, matching the kernel (see _quant_kernel)
    scale = jnp.where(absmax > 0, absmax * jnp.float32(1.0 / 127.0), 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_blocks_ref(q, scales, out_dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scales[:, None].astype(jnp.float32)
            ).astype(out_dtype)


def fingerprint_chunks_ref(lanes, lengths):
    """Oracle for kernels.fingerprint.fingerprint_chunks.

    lanes: (n_chunks, CL) uint32; lengths: (n_chunks, 1) uint32 byte
    lengths of each chunk's digest domain -> (n_chunks, 4) uint32. One
    dot_general instead of the kernel's per-chunk multiply-sum — exact
    mod-2^32 arithmetic makes the association order irrelevant.
    """
    from .fingerprint import _LEN, _weights_jnp
    d = jax.lax.dot_general(lanes.astype(jnp.uint32),
                            _weights_jnp(lanes.shape[1]),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.uint32)
    return d + (lengths.reshape(-1, 1).astype(jnp.uint32)
                * jnp.asarray(_LEN, jnp.uint32))


def quantize_fingerprint_blocks_ref(x, chunk_bytes):
    """Oracle for kernels.fingerprint.quantize_fingerprint_blocks:
    quantize (R, LANE_COLS) rows and digest the int8 q-stream on the
    ``chunk_bytes`` grid. Returns (q, scales, digests)."""
    from .fingerprint import _digest_lane_stream, lanes_u32
    q, s = quantize_blocks_ref(x)
    nbytes = q.shape[0] * q.shape[1]
    d = _digest_lane_stream(lanes_u32(q.reshape(-1)), nbytes, chunk_bytes)
    return q, s, d


def rglru_scan_ref(a, b):
    """First-order linear recurrence h_t = a_t * h_{t-1} + b_t, h_0 = 0.

    Uses jax.lax.associative_scan — the XLA path the kernel replaces.
    """
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl
    _, h = jax.lax.associative_scan(comb, (a.astype(jnp.float32),
                                           b.astype(jnp.float32)), axis=1)
    return h
