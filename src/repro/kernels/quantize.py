"""Pallas TPU kernel: blockwise int8 quantization for checkpoint compression.

The paper's roofline is storage bandwidth: every checkpoint byte rides the
host→PFS link. Quantizing optimizer moments (bf16/f32 → int8 + per-row fp32
scales) halves/quarters flush volume at negligible compute cost — but the
quantize pass itself must not become a host bottleneck, hence a fused
absmax+scale+round kernel tiled for VMEM.

Layout: input is viewed as (rows, LANE_COLS) with one quantization group per
row. Tiles of (ROW_BLK, LANE_COLS) stream through VMEM; LANE_COLS is a
multiple of 128 (VPU lane width), ROW_BLK=8 matches the fp32 sublane count.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLK = 8
LANE_COLS = 512     # 4 × 128 lanes per row-group


def quant_rows(x):
    """Shared per-row quantize math: (rows, C) -> (int8 q, f32 scales).

    Row-independent, so any tiling of the row axis gives identical bits —
    the quantize kernel, the fused quantize+fingerprint kernel
    (kernels/fingerprint.py) and the jnp oracle all call this.
    """
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=1)
    # multiply by the f32 reciprocal (not a / 127.0): XLA strength-reduces
    # constant divides to reciprocal multiplies, so spelling it out keeps
    # compiled and eager (oracle) paths bit-identical at round-half points
    scale = jnp.where(absmax > 0, absmax * jnp.float32(1.0 / 127.0), 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def _quant_kernel(x_ref, q_ref, s_ref):
    q, s = quant_rows(x_ref[...])                        # (ROW_BLK, LANE_COLS)
    q_ref[...] = q
    s_ref[...] = s


def _dequant_kernel(q_ref, s_ref, o_ref, *, out_dtype):
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    o_ref[...] = (q * s[:, None]).astype(out_dtype)


def quantize_blocks(x, *, interpret: bool = False):
    """x: (R, LANE_COLS) — R % ROW_BLK == 0. Returns (int8 q, f32 scales)."""
    R, C = x.shape
    assert C == LANE_COLS and R % ROW_BLK == 0, (R, C)
    grid = (R // ROW_BLK,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROW_BLK, C), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROW_BLK, C), lambda i: (i, 0)),
                   pl.BlockSpec((ROW_BLK,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.int8),
                   jax.ShapeDtypeStruct((R,), jnp.float32)],
        interpret=interpret,
    )(x)


def dequantize_blocks(q, scales, out_dtype=jnp.bfloat16, *,
                      interpret: bool = False):
    R, C = q.shape
    assert C == LANE_COLS and R % ROW_BLK == 0
    grid = (R // ROW_BLK,)
    kernel = functools.partial(_dequant_kernel, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROW_BLK, C), lambda i: (i, 0)),
                  pl.BlockSpec((ROW_BLK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((ROW_BLK, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), out_dtype),
        interpret=interpret,
    )(q, scales)
