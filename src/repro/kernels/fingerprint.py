"""Pallas chunk-fingerprint kernel + bit-identical host/oracle twins.

On-device dirty detection (DESIGN.md §14): the delta path's blake2b chunk
hash ran on the host, so every payload byte crossed the device→host link
just to discover it was clean. This module computes a per-chunk 128-bit
non-cryptographic digest (kind ``fp128``) *where the bytes already live*
— as a Pallas kernel on TPU, as one jitted XLA pass on other backends,
and as a vectorized numpy fallback for host-resident arrays — so the
delta diff can run before any D2H copy and only dirty chunks ever cross
the link.

Digest spec (``fp128`` / version 1) — chosen so one integer matmul
computes it and a TPU VPU can reproduce it (no 64-bit lanes on TPU):

  lanes     the chunk's bytes, zero-padded to a multiple of 4, viewed as
            little-endian uint32 words ``v_0 .. v_{L-1}``.
  weights   ``w_k[i] = fmix32((i+1) ^ SEED_k) | 1`` for four fixed seeds
            (murmur3's finalizer; forcing odd weights makes any
            single-lane difference unconditionally detectable, since an
            odd multiplier is invertible mod 2^32).
  digest    ``d_k = (sum_i v_i * w_k[i] + n * LEN_k)  mod 2^32`` where
            ``n`` is the chunk's byte length (folds ragged tails apart
            from zero-padded full chunks). Serialized as 32 hex chars
            (``%08x`` per accumulator) — same width as blake2b-128.

All three implementations are bit-identical by construction: uint32
multiply-accumulate is exact mod 2^32 in any association order, so a
numpy ``lanes @ W`` matmul, an XLA ``dot_general`` and the kernel's
per-chunk multiply-sum agree word for word (property-tested in
tests/test_fingerprint.py). The host path is ~1 memory pass (a
``(chunks, lanes) @ (lanes, 4)`` uint32 matmul) — ~3x cheaper than
the per-chunk blake2b loop it replaces on the same buffer, and ~5x
vs the PR-5 recorded hash pass (which also paid per-chunk Python
slicing).

The fused ``quantize_fingerprint_blocks`` kernel extends the int8
quantize kernel (kernels/quantize.py) so quant + digest of the quantized
stream is one pass over the shard in VMEM: the digest domain there is
the *packed* representation (int8 q rows then f32 scales), which is what
actually gets written — see core/delta.py for the packed-payload chunk
grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .quantize import LANE_COLS, quant_rows

DIGEST_KIND = "fp128"
LANE_BYTES = 4

# four independent weight streams (xxhash/murmur-lineage odd constants)
_SEEDS = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F)
# per-accumulator length-fold multipliers (odd, so length always lands)
_LEN = (0x165667B1, 0xD3A2646D, 0x9E3779B9, 0x27D4EB2F)
_M1, _M2 = 0x85EBCA6B, 0xC2B2AE35


def lanes_per_chunk(chunk_bytes: int) -> int:
    return -(-chunk_bytes // LANE_BYTES)


def _fmix32_np(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32)
    x ^= x >> 16
    x = x * np.uint32(_M1)
    x ^= x >> 13
    x = x * np.uint32(_M2)
    x ^= x >> 16
    return x


def _fmix32_jnp(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_M2)
    x = x ^ (x >> 16)
    return x


@functools.lru_cache(maxsize=64)
def _weights_host(n_lanes: int) -> np.ndarray:
    """(n_lanes, 4) uint32 weight matrix, cached per lane count.

    Weights depend only on the lane index, so ``_weights_host(a)`` is a
    prefix of ``_weights_host(b)`` for a < b — ragged tail chunks reuse
    the full-chunk matrix truncated to their lane count."""
    i = np.arange(1, n_lanes + 1, dtype=np.uint32)
    return np.stack(
        [_fmix32_np(i ^ np.uint32(s)) | np.uint32(1) for s in _SEEDS],
        axis=1)


def _weights_jnp(n_lanes: int):
    i = jnp.arange(1, n_lanes + 1, dtype=jnp.uint32)
    return jnp.stack(
        [_fmix32_jnp(i ^ jnp.uint32(s)) | jnp.uint32(1) for s in _SEEDS],
        axis=1)


# ------------------------------------------------------------------ host path
def fingerprint_chunks_host(payload: np.ndarray,
                            chunk_bytes: int) -> np.ndarray:
    """Digest every chunk of a host payload: (n_chunks, 4) uint32.

    One uint32 matmul over the full-chunk body (zero-copy view when the
    grid is lane-aligned), a short padded loop for the ragged tail —
    ~1 memory pass total, which is the point of replacing blake2b.
    """
    payload = np.ascontiguousarray(payload).reshape(-1).view(np.uint8)
    n = payload.nbytes
    nc = -(-n // chunk_bytes) if n else 0
    out = np.zeros((nc, 4), np.uint32)
    if nc == 0:
        return out
    cl = lanes_per_chunk(chunk_bytes)
    w = _weights_host(cl)
    body = n // chunk_bytes if chunk_bytes % LANE_BYTES == 0 else 0
    if body:
        lanes = payload[:body * chunk_bytes].view(np.uint32) \
            .reshape(body, cl)
        np.matmul(lanes, w, out=out[:body])
    for j in range(body, nc):
        pos = j * chunk_bytes
        m = min(chunk_bytes, n - pos)
        lanes_n = -(-m // LANE_BYTES)
        buf = np.zeros(lanes_n * LANE_BYTES, np.uint8)
        buf[:m] = payload[pos:pos + m]
        out[j] = buf.view(np.uint32) @ w[:lanes_n]
    lens = np.full(nc, chunk_bytes, np.uint32)
    lens[-1] = n - (nc - 1) * chunk_bytes
    out += lens[:, None] * np.asarray(_LEN, np.uint32)
    return out


def digest_hex(d) -> str:
    """One digest row -> 32 hex chars (blake2b-128 width)."""
    return "%08x%08x%08x%08x" % tuple(int(v) for v in d)


def digests_hex(d: np.ndarray) -> list[str]:
    return [digest_hex(row) for row in np.asarray(d)]


def digest_bytes(data) -> str:
    """fp128 of one standalone chunk (domain = exactly these bytes).

    Matches the per-chunk digest whenever the chunk's digest domain is
    its written byte span — used by the store scrubber to content-verify
    fp128 references that carry no CRC."""
    a = np.frombuffer(data, np.uint8) if not isinstance(data, np.ndarray) \
        else data.reshape(-1).view(np.uint8)
    if a.nbytes == 0:
        return digest_hex(np.zeros(4, np.uint32))
    return digest_hex(fingerprint_chunks_host(a, a.nbytes)[0])


# -------------------------------------------------------------- device lanes
def lanes_u32(flat):
    """1-D device array (itemsize 1/2/4) -> little-endian uint32 lanes.

    Built arithmetically from same-width bitcasts: XLA's
    ``bitcast_convert_type`` is only byte-order-defined at equal widths,
    so wider lanes are assembled as ``b0 | b1<<8 | ...`` — bit-identical
    to the host's ``view(np.uint32)`` on little-endian layouts."""
    isz = np.dtype(flat.dtype).itemsize
    if isz == 4:
        return jax.lax.bitcast_convert_type(flat, jnp.uint32)
    if isz == 2:
        u = jax.lax.bitcast_convert_type(flat, jnp.uint16) \
            .astype(jnp.uint32)
        if u.shape[0] % 2:
            u = jnp.pad(u, (0, 1))
        u = u.reshape(-1, 2)
        return u[:, 0] | (u[:, 1] << 16)
    if isz == 1:
        u = jax.lax.bitcast_convert_type(flat, jnp.uint8) \
            .astype(jnp.uint32)
        if u.shape[0] % 4:
            u = jnp.pad(u, (0, 4 - u.shape[0] % 4))
        u = u.reshape(-1, 4)
        return u[:, 0] | (u[:, 1] << 8) | (u[:, 2] << 16) | (u[:, 3] << 24)
    raise ValueError(f"unsupported itemsize {isz} for device fingerprint")


def _digest_lane_stream(lanes, nbytes: int, chunk_bytes: int):
    """Trace-time core: flat lane vector -> (n_chunks, 4) uint32 digests.

    Requires ``chunk_bytes % 4 == 0`` so per-chunk lane domains tile the
    global lane stream (the delta planner falls back to the host path
    otherwise)."""
    assert chunk_bytes % LANE_BYTES == 0
    cl = chunk_bytes // LANE_BYTES
    nc = -(-nbytes // chunk_bytes)
    lanes = jnp.pad(lanes, (0, nc * cl - lanes.shape[0])).reshape(nc, cl)
    d = jax.lax.dot_general(lanes, _weights_jnp(cl),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.uint32)
    lens = jnp.full((nc,), chunk_bytes, jnp.uint32) \
        .at[-1].set(nbytes - (nc - 1) * chunk_bytes)
    return d + lens[:, None] * jnp.asarray(_LEN, jnp.uint32)


@functools.partial(jax.jit, static_argnames=("chunk_bytes",))
def _fp_device_jit(flat, chunk_bytes: int):
    nbytes = flat.shape[0] * np.dtype(flat.dtype).itemsize
    return _digest_lane_stream(lanes_u32(flat), nbytes, chunk_bytes)


@functools.partial(jax.jit, static_argnames=("chunk_bytes",))
def _fp_prep_jit(flat, chunk_bytes: int):
    """Kernel prologue: lanes padded + reshaped to the chunk grid."""
    nbytes = flat.shape[0] * np.dtype(flat.dtype).itemsize
    cl = chunk_bytes // LANE_BYTES
    nc = -(-nbytes // chunk_bytes)
    lanes = lanes_u32(flat)
    lanes = jnp.pad(lanes, (0, nc * cl - lanes.shape[0])).reshape(nc, cl)
    lens = jnp.full((nc, 1), chunk_bytes, jnp.uint32) \
        .at[-1, 0].set(nbytes - (nc - 1) * chunk_bytes)
    return lanes, lens


def fingerprint_digests(flat, chunk_bytes: int) -> np.ndarray:
    """Device dispatch: digest a 1-D device array's byte image.

    TPU runs the Pallas kernel over the lane grid; other backends run the
    jitted oracle (one XLA uint32 matmul). Either way only the
    (n_chunks, 4) digest table — 16 bytes per 256 KiB chunk — comes back
    to the host."""
    if jax.default_backend() == "tpu":
        lanes, lens = _fp_prep_jit(flat, chunk_bytes)
        return np.asarray(fingerprint_chunks(lanes, lens))
    return np.asarray(_fp_device_jit(flat, chunk_bytes))


# ------------------------------------------------------------- Pallas kernels
def _fp_kernel(lanes_ref, len_ref, d_ref):
    lanes = lanes_ref[...]                                 # (1, CL) uint32
    pos = jax.lax.broadcasted_iota(jnp.uint32, lanes.shape, 1) \
        + jnp.uint32(1)
    n = len_ref[0, 0]
    acc = []
    for s, ln in zip(_SEEDS, _LEN):
        w = _fmix32_jnp(pos ^ jnp.uint32(s)) | jnp.uint32(1)
        acc.append(jnp.sum(lanes * w, dtype=jnp.uint32)
                   + n * jnp.uint32(ln))
    d_ref[0, :] = jnp.stack(acc)


def fingerprint_chunks(lanes, lengths, *, interpret: bool = False):
    """lanes: (n_chunks, CL) uint32; lengths: (n_chunks, 1) uint32 byte
    length of each chunk's digest domain. Returns (n_chunks, 4) uint32.
    One chunk per grid step: a 256 KiB chunk is a 64Ki-lane block
    (256 KiB of VMEM) with weights regenerated from iota in-register."""
    nc, cl = lanes.shape
    return pl.pallas_call(
        _fp_kernel,
        grid=(nc,),
        in_specs=[pl.BlockSpec((1, cl), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, 4), jnp.uint32),
        interpret=interpret,
    )(lanes, lengths)


def _quant_fp_kernel(x_ref, q_ref, s_ref, d_ref, *, rows, chunk_bytes):
    q, scale = quant_rows(x_ref[...])            # (rows, LANE_COLS)
    q_ref[...] = q
    s_ref[...] = scale
    # lanes of the packed int8 stream this block contributes: row-major
    # q bytes, 4 per lane, little-endian — identical to the host view of
    # the packed payload's q region
    b = (q.astype(jnp.int32) & 0xFF).astype(jnp.uint32) \
        .reshape(rows, LANE_COLS // 4, 4)
    lanes = (b[..., 0] | (b[..., 1] << 8) | (b[..., 2] << 16)
             | (b[..., 3] << 24)).reshape(1, rows * (LANE_COLS // 4))
    pos = jax.lax.broadcasted_iota(jnp.uint32, lanes.shape, 1) \
        + jnp.uint32(1)
    acc = []
    for s, ln in zip(_SEEDS, _LEN):
        w = _fmix32_jnp(pos ^ jnp.uint32(s)) | jnp.uint32(1)
        acc.append(jnp.sum(lanes * w, dtype=jnp.uint32)
                   + jnp.uint32(chunk_bytes) * jnp.uint32(ln))
    d_ref[0, :] = jnp.stack(acc)


def quantize_fingerprint_blocks(x, chunk_bytes: int, *,
                                interpret: bool = False):
    """Fused quantize + fingerprint: one VMEM pass per digest chunk.

    x: (R, LANE_COLS) f32 rows to quantize, where ``chunk_bytes`` int8
    bytes = ``chunk_bytes // LANE_COLS`` quantized rows and R covers
    whole chunks (``R*LANE_COLS % chunk_bytes == 0``). Returns
    ``(q int8 (R, LANE_COLS), scales f32 (R,), digests uint32 (nc, 4))``
    where digest j covers q-stream bytes [j*chunk_bytes, (j+1)*chunk_bytes)
    — the quantized payload never leaves VMEM unfingerprinted, so clean
    chunks are known before any D2H copy."""
    R, C = x.shape
    assert C == LANE_COLS, (R, C)
    assert chunk_bytes % C == 0, (chunk_bytes, C)
    rows = chunk_bytes // C
    assert R % rows == 0, (R, rows)
    nc = R // rows
    kernel = functools.partial(_quant_fp_kernel, rows=rows,
                               chunk_bytes=chunk_bytes)
    return pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[pl.BlockSpec((rows, C), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, C), lambda i: (i, 0)),
                   pl.BlockSpec((rows,), lambda i: (i,)),
                   pl.BlockSpec((1, 4), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.int8),
                   jax.ShapeDtypeStruct((R,), jnp.float32),
                   jax.ShapeDtypeStruct((nc, 4), jnp.uint32)],
        interpret=interpret,
    )(x)


# ------------------------------------------- fused quant+digest (device path)
@functools.partial(jax.jit, static_argnames=("chunk_bytes",))
def _quant_fp_ref_jit(padded, chunk_bytes: int):
    """XLA-fused oracle: quantize + digest the packed qs-stream
    (q int8 rows then f32 scales — the packed payload minus its header)
    in one compiled pass. Bit-identical to the Pallas kernels."""
    q, s = quant_rows(padded)
    rows = q.shape[0]
    qlanes = lanes_u32(q.reshape(-1))
    slanes = jax.lax.bitcast_convert_type(s, jnp.uint32)
    lanes = jnp.concatenate([qlanes, slanes])
    nbytes = rows * LANE_COLS + rows * 4
    return q, s, _digest_lane_stream(lanes, nbytes, chunk_bytes)


def quant_fingerprint(padded, chunk_bytes: int):
    """Quantize ``padded`` (R, LANE_COLS) f32 on device and digest the
    packed qs-stream on the ``chunk_bytes`` grid. Returns device
    ``(q, s)`` plus the host digest table (n_chunks, 4) uint32.

    TPU: the fused Pallas kernel covers every chunk made purely of q
    bytes (quant + digest in one VMEM pass); the ragged tail (q remainder
    + the scales region) is digested from jit-assembled lanes. Other
    backends run the whole thing as one jitted XLA program."""
    if jax.default_backend() != "tpu" or chunk_bytes % LANE_COLS != 0:
        q, s, d = _quant_fp_ref_jit(padded, chunk_bytes)
        return q, s, np.asarray(d)
    R = padded.shape[0]
    qbytes = R * LANE_COLS
    body = qbytes // chunk_bytes
    body_rows = body * (chunk_bytes // LANE_COLS)
    if body_rows == 0:
        q, s, d = _quant_fp_ref_jit(padded, chunk_bytes)
        return q, s, np.asarray(d)
    qb, sb, db = quantize_fingerprint_blocks(padded[:body_rows], chunk_bytes)
    from .quantize import quantize_blocks
    if body_rows < R:
        qt, st = quantize_blocks(padded[body_rows:])
        q = jnp.concatenate([qb, qt])
        s = jnp.concatenate([sb, st])
    else:
        q, s = qb, sb
    dt = _quant_tail_digests_jit(q, s, chunk_bytes, body)
    return q, s, np.concatenate([np.asarray(db), np.asarray(dt)])


@functools.partial(jax.jit, static_argnames=("chunk_bytes", "body"))
def _quant_tail_digests_jit(q, s, chunk_bytes: int, body: int):
    rows = q.shape[0]
    lanes = jnp.concatenate([lanes_u32(q.reshape(-1)),
                             jax.lax.bitcast_convert_type(s, jnp.uint32)])
    nbytes = rows * LANE_COLS + rows * 4
    cl = chunk_bytes // LANE_BYTES
    return _digest_lane_stream(lanes, nbytes, chunk_bytes)[body:]
