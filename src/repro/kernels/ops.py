"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernels execute via the Pallas
interpreter on CPU for validation; on TPU they compile to Mosaic).
Arbitrary-shaped tensors are padded/reshaped to the kernels' tile layout here
so callers never deal with lane alignment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize as Q
from . import rglru as R


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------- quantize
@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_tensor(x, *, interpret: bool | None = None):
    """Quantize any tensor to (int8 payload, f32 scales, meta) blockwise."""
    interpret = _default_interpret() if interpret is None else interpret
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = Q.LANE_COLS
    rows = -(-n // cols)
    rows_pad = -(-rows // Q.ROW_BLK) * Q.ROW_BLK
    padded = jnp.zeros((rows_pad * cols,), jnp.float32).at[:n].set(
        flat.astype(jnp.float32)).reshape(rows_pad, cols)
    q, s = Q.quantize_blocks(padded, interpret=interpret)
    return q, s


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "interpret"))
def dequantize_tensor(q, s, shape, dtype=jnp.bfloat16, *,
                      interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    full = Q.dequantize_blocks(q, s, out_dtype=dtype, interpret=interpret)
    n = int(np.prod(shape))
    return full.reshape(-1)[:n].reshape(shape)


# ------------------------------------------------------------------ rglru
@functools.partial(jax.jit, static_argnames=("interpret",))
def rglru_scan(a, b, *, interpret: bool | None = None):
    """Padded/tiled entry to the fused RG-LRU scan kernel."""
    interpret = _default_interpret() if interpret is None else interpret
    B, S, Rr = a.shape
    Sp = -(-S // R.SEQ_CHUNK) * R.SEQ_CHUNK
    Rp = -(-Rr // R.FEAT_BLK) * R.FEAT_BLK
    if (Sp, Rp) != (S, Rr):
        pad = [(0, 0), (0, Sp - S), (0, Rp - Rr)]
        a = jnp.pad(a.astype(jnp.float32), pad)
        b = jnp.pad(b.astype(jnp.float32), pad)
    h = R.rglru_scan(a.astype(jnp.float32), b.astype(jnp.float32),
                     interpret=interpret)
    return h[:, :S, :Rr]
