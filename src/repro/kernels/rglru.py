"""Pallas TPU kernel: fused RG-LRU linear-recurrence scan.

The RG-LRU recurrence  h_t = a_t ⊙ h_{t-1} + b_t  is the compute spine of the
recurrentgemma blocks. XLA's associative_scan materializes log₂(S) full-size
intermediates in HBM; this kernel streams (CHUNK, 128)-tiles of (a, b) through
VMEM and carries h in a VMEM scratch register across sequence chunks, touching
HBM exactly once per element (memory-roofline optimal).

Grid: (batch, feature_blocks, seq_chunks) — the LAST axis iterates fastest
and sequentially on TPU, so the scratch carry is valid across seq chunks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FEAT_BLK = 128
SEQ_CHUNK = 256


def _rglru_kernel(a_ref, b_ref, o_ref, h_scr):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0]            # (SEQ_CHUNK, FEAT_BLK)
    b = b_ref[0]
    h0 = h_scr[...]         # (FEAT_BLK,)

    def body(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h
        return h

    h = jax.lax.fori_loop(0, a.shape[0], body, h0)
    h_scr[...] = h


def rglru_scan(a, b, *, interpret: bool = False):
    """a, b: (B, S, R) fp32 -> h: (B, S, R); h_0 = 0.

    S % SEQ_CHUNK == 0 and R % FEAT_BLK == 0 (pad upstream otherwise).
    """
    B, S, R = a.shape
    assert S % SEQ_CHUNK == 0 and R % FEAT_BLK == 0, (S, R)
    grid = (B, R // FEAT_BLK, S // SEQ_CHUNK)
    spec = pl.BlockSpec((1, SEQ_CHUNK, FEAT_BLK), lambda i, j, k: (i, k, j))
    return pl.pallas_call(
        _rglru_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, R), jnp.float32),
        scratch_shapes=[_vmem_scratch()],
        interpret=interpret,
    )(a, b)


def _vmem_scratch():
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM((FEAT_BLK,), jnp.float32)
