"""Concurrent multi-writer checkpointing: N ranks, one directory, one commit.

The paper frames checkpointing as "many processes, each managing numerous
tensors" contending for the PFS — yet a single ``CheckpointManager`` only
ever exercises one writer. This module runs the concurrency scenario the
engine stack was designed for, inside one process (DESIGN.md §11):

  · ``MultiWriterCheckpointer`` drives N writer ranks as threads, each with
    its OWN ``CheckpointManager``/engine pair sharing one checkpoint
    directory and one shared staging dir per step,
  · ``InProcessGroup`` is the process-group shim: a reusable barrier plus an
    allgather that carries the SINGLE_FILE ``rank_totals`` prefix-sum
    exchange (paper §3.6) — so N ranks write disjoint regions of one file,
  · ``CommitCoordinator`` implements the two-phase rank-0 commit
    (ByteCheckpoint's decoupled per-rank-plan/global-commit): every rank
    flushes + fsyncs its shards and writes ``MANIFEST.rank-{r}``, barriers;
    then rank 0 alone merges the on-disk rank manifests (validated,
    idempotent — ``Manifest.merge``), writes the global ``manifest.json``,
    and atomically publishes the step dir exactly once,
  · elastic restore: an N-rank checkpoint restores bit-identically onto an
    M-rank mesh — ``restore_sharded`` hands each reader rank its
    row-partition window, assembled from the saved shards it intersects by
    the existing ``WindowAssembler`` machinery.

Failure semantics: a rank failing before a barrier aborts the group — peers
unblock with ``MultiWriterAborted`` instead of hanging — and the step is
never published (the shared ``.tmp-*`` dir is owned by this process, so a
later manager's GC leaves it alone until the owner dies).

``delta=True`` composes per rank: each rank diffs its own shard windows
with the fp128 device fingerprint (DESIGN.md §14) against the prior
merged manifest's kind-matched index, so rank manifests carry the
digest-kind tag and the rank-0 merge preserves it into the v4 manifest.
"""

from __future__ import annotations

import os
import threading
import uuid
from dataclasses import dataclass, field, replace

import jax
import numpy as np

from . import faults, trace
from .aggregation import partition_spans
from .checkpoint import CheckpointManager, step_dir_name, write_owner
from .engines import EngineConfig
from .manifest import Manifest
from .serialization import LocalShard, path_str


class MultiWriterAborted(RuntimeError):
    """A peer rank failed; this rank's save was aborted, nothing committed."""


def _fanout(n: int, fn, name: str) -> tuple[list, list]:
    """Run ``fn(rank)`` on n threads; returns (results, exceptions) by rank."""
    outs: list = [None] * n
    errs: list[BaseException | None] = [None] * n

    def run(r: int) -> None:
        try:
            outs[r] = fn(r)
        except BaseException as e:
            errs[r] = e

    threads = [threading.Thread(target=run, args=(r,), name=f"{name}{r}")
               for r in range(n)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    return outs, errs


class InProcessGroup:
    """Barrier + allgather for N thread-ranks (the process-group shim)."""

    def __init__(self, num_ranks: int):
        if num_ranks < 1:
            raise ValueError("num_ranks must be >= 1")
        self.num_ranks = num_ranks
        self._barrier = threading.Barrier(num_ranks)
        self._vals: list = [None] * num_ranks

    def barrier(self) -> None:
        try:
            with trace.span("barrier", tier="commit",
                            attrs={"ranks": self.num_ranks}):
                self._barrier.wait()
        except threading.BrokenBarrierError:
            raise MultiWriterAborted(
                "a peer writer rank failed before the barrier") from None

    def allgather(self, value, rank: int, num_ranks: int | None = None
                  ) -> list:
        """Every rank contributes ``value``; all receive the rank-ordered
        list. Two barrier phases make the exchange reusable round after
        round (no rank may overwrite its slot before all peers read it)."""
        if num_ranks is not None and num_ranks != self.num_ranks:
            raise ValueError(
                f"allgather across {num_ranks} ranks on a "
                f"{self.num_ranks}-rank group")
        self._vals[rank] = value
        self.barrier()
        out = list(self._vals)
        self.barrier()
        return out

    def abort(self) -> None:
        """Break the barrier: peers blocked (or arriving) get
        ``MultiWriterAborted`` instead of hanging on a dead rank."""
        self._barrier.abort()

    def reset(self) -> None:
        self._barrier.reset()


class CommitCoordinator:
    """Two-phase rank-0 commit over a shared per-step staging dir.

    Phase 1 (every rank, from ``CheckpointManager._commit``): the rank's
    shards are already flushed + fsync'd into the shared tmp dir; write
    ``MANIFEST.rank-{r}``; barrier.
    Phase 2 (rank 0): load the rank manifests OFF DISK (the only channel a
    real multi-host rank 0 has), merge with validation + per-rank
    idempotency, write the global ``manifest.json``, publish the step dir
    with the manager's atomic displaced-aside rename — exactly once — and GC
    old steps. A second barrier releases the peers only after the publish,
    so every rank's ``save`` returns with the checkpoint durable.
    """

    def __init__(self, group: InProcessGroup):
        self.group = group
        self._lock = threading.Lock()
        # crlint: guarded-by(_lock)
        self._tmp: dict[int, str] = {}          # step -> shared staging dir
        self._err: BaseException | None = None

    def tmp_dir(self, directory: str, step: int) -> str:
        """The step's shared staging dir; first rank in creates + owns it."""
        with self._lock:
            tmp = self._tmp.get(step)
            if tmp is None:
                tmp = os.path.join(
                    directory,
                    f"{step_dir_name(step)}.tmp-mw-{uuid.uuid4().hex[:8]}")
                os.makedirs(tmp, exist_ok=True)
                write_owner(tmp)
                self._tmp[step] = tmp
            return tmp

    def discard(self, step: int) -> None:
        """Drop (and delete) a failed save's shared staging dir so a retry
        of the step starts clean instead of committing stale files."""
        with self._lock:
            tmp = self._tmp.pop(step, None)
        if tmp is not None:
            faults.rmtree(tmp, ignore_errors=True)

    def commit(self, mgr: CheckpointManager, manifest: Manifest, tmp: str,
               step: int, rank: int) -> None:
        with trace.span("commit.phase1", tier="commit",
                        attrs={"rank": rank, "step": step}):
            manifest.save_rank(tmp, rank)
            self.group.barrier()         # phase 1: all ranks durable
        if rank == 0:
            try:
                with trace.span("commit.merge", tier="commit",
                                attrs={"step": step}):
                    self._merge_publish(mgr, tmp, step)
            except BaseException as e:
                self._err = e
        self.group.barrier()             # phase 2: publish visible to all
        if self._err is not None:
            if rank == 0:
                raise self._err
            raise MultiWriterAborted("rank-0 commit failed") from self._err

    def _merge_publish(self, mgr: CheckpointManager, tmp: str,
                       step: int) -> None:
        merged = Manifest.load_rank(tmp, 0)
        for r in range(1, self.group.num_ranks):
            merged.merge(Manifest.load_rank(tmp, r), rank=r)
        merged.num_ranks = self.group.num_ranks
        saved = False
        if mgr.delta:
            # delta saves (§12): every rank's manifest described its fresh
            # chunks with step-dir-relative paths; rank 0 relocates the
            # shared data files into the chunkstore and rewrites the MERGED
            # manifest exactly once, before the only publish
            from .delta import publish_packs
            saved = publish_packs(merged, tmp, mgr.directory,
                                  step_dir_name(step))
        if not saved:
            merged.save(tmp)
        mgr._publish(tmp, step)
        mgr._gc_old()
        self._err = None
        # drop the staging entry only on success — on failure it stays
        # registered so _save_all's discard() can reclaim it
        with self._lock:
            self._tmp.pop(step, None)


@dataclass
class MultiSaveMetrics:
    """Aggregate view over the N concurrent rank saves."""
    step: int
    num_ranks: int
    total_bytes: int = 0
    blocking_seconds: float = 0.0    # caller stall (partition + submit)
    end_to_end_seconds: float = 0.0  # slowest rank, incl. the shared commit
    mode: str = "blocking"           # blocking | async
    per_rank: list = field(default_factory=list)   # SaveMetrics per rank

    @property
    def aggregate_gbps(self) -> float:
        """Aggregate write throughput: all ranks' bytes over the concurrent
        wall — the paper's under-contention number."""
        return (self.total_bytes / self.end_to_end_seconds / 1e9
                if self.end_to_end_seconds else 0.0)


def shard_state(state, num_ranks: int, *, snapshot: bool = False
                ) -> list:
    """Partition a global pytree row-wise into N per-rank pytrees.

    Tensor leaves whose leading dim holds ``num_ranks`` spans become
    ``LocalShard`` windows (one per rank, host-materialized now — this is
    the harness's D2H stage); short/0-d tensors are replicated (every rank
    saves the full window, restore dedupes identical windows like DP
    replicas). ``snapshot=True`` additionally deep-copies every payload so
    an async caller may mutate or donate its arrays the moment ``save``
    returns.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    per_rank: list[list] = [[] for _ in range(num_ranks)]
    for _path, leaf in flat:
        is_typed_prng = (isinstance(leaf, jax.Array) and jax.dtypes.
                         issubdtype(leaf.dtype, jax.dtypes.prng_key))
        if is_typed_prng:
            if snapshot:   # rebind off the (donatable) source buffer
                leaf = jax.random.wrap_key_data(
                    jax.numpy.array(jax.random.key_data(leaf)),
                    impl=str(jax.random.key_impl(leaf)))
            for lv in per_rank:
                lv.append(leaf)
            continue
        if not isinstance(leaf, (jax.Array, np.ndarray)):
            for lv in per_rank:
                lv.append(leaf)
            continue
        arr = np.asarray(leaf)
        if snapshot:
            arr = np.array(arr, copy=True)
        if arr.ndim == 0 or arr.shape[0] < num_ranks:
            for lv in per_rank:
                lv.append(arr)     # replicated: full window on every rank
            continue
        gs = tuple(arr.shape)
        for r, (lo, hi) in enumerate(partition_spans(gs[0], num_ranks)):
            idx = ((lo, hi),) + tuple((0, d) for d in gs[1:])
            per_rank[r].append(LocalShard(arr[lo:hi], idx, gs))
    return [jax.tree_util.tree_unflatten(treedef, lv) for lv in per_rank]


class MultiWriterCheckpointer:
    """Run N writer ranks concurrently (thread-per-rank) over one directory.

    ``save`` takes the GLOBAL state, partitions it across ranks
    (``shard_state``), and drives one blocking ``CheckpointManager.save``
    per rank thread through the shared two-phase commit. ``restore`` runs on
    rank 0's manager with full template/sharding support (any single reader
    can restore an N-rank checkpoint — that is the point of the merged
    manifest); ``restore_sharded`` materializes per-reader-rank windows on
    an M-rank mesh.
    """

    def __init__(self, directory: str, num_ranks: int, *,
                 engine: str = "aggregated",
                 config: EngineConfig | None = None,
                 async_save: bool = False, keep: int = 3,
                 verify_crc: bool = True, streaming: bool = True,
                 **mgr_kw):
        self.directory = os.path.abspath(directory)
        self.num_ranks = num_ranks
        self.async_save = async_save
        self.engine_name = engine
        self.group = InProcessGroup(num_ranks)
        self.coordinator = CommitCoordinator(self.group)
        base = config if config is not None else EngineConfig()
        self._base_config = replace(base)
        self.managers: list[CheckpointManager] = []
        for _r in range(num_ranks):
            cfg = replace(base)
            # ranks share files under SINGLE_FILE: nobody truncates a peer's
            # extents (the tmp dir is fresh per step, so nothing is stale)
            cfg.truncate = False
            mgr = CheckpointManager(
                directory, engine=engine, config=cfg, async_save=False,
                keep=keep, verify_crc=verify_crc, streaming=streaming,
                **mgr_kw)
            mgr.coordinator = self.coordinator
            mgr.allgather = self.group.allgather
            self.managers.append(mgr)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        self.last_save_metrics: MultiSaveMetrics | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state) -> MultiSaveMetrics:
        """Checkpoint the global ``state`` through N concurrent writers.

        The partition (and, async, a stable host snapshot of every payload)
        happens on the blocking path; with ``async_save`` the N rank flushes
        and the two-phase commit then drain on a driver thread."""
        self.wait()
        t0 = trace.clock()
        shards = shard_state(state, self.num_ranks,
                             snapshot=self.async_save)
        metrics = MultiSaveMetrics(
            step=step, num_ranks=self.num_ranks,
            mode="async" if self.async_save else "blocking")
        self.last_save_metrics = metrics
        if self.async_save:
            metrics.blocking_seconds = trace.clock() - t0
            self._error = None
            th = threading.Thread(
                target=self._run_guarded, args=(step, shards, metrics, t0),
                daemon=True, name=f"mw-driver-{step}")
            self._thread = th
            th.start()
        else:
            self._save_all(step, shards, metrics, t0)
            metrics.blocking_seconds = metrics.end_to_end_seconds
        return metrics

    def _run_guarded(self, step, shards, metrics, t0) -> None:
        try:
            self._save_all(step, shards, metrics, t0)
        except BaseException as e:
            self._error = e

    def _save_all(self, step, shards, metrics, t0) -> None:
        n = self.num_ranks

        def save_rank(r: int):
            try:
                return self.managers[r].save(
                    step, shards[r], rank=r, num_ranks=n)
            except BaseException:
                self.group.abort()   # unblock peers stuck on a barrier
                raise

        outs, errs = _fanout(n, save_rank, f"mw-rank-{step}")
        if any(errs):
            self.group.reset()       # repair the barrier for the next save
            self.coordinator.discard(step)   # stale staging must not commit
            primary = next((e for e in errs
                            if not isinstance(e, MultiWriterAborted)),
                           next(e for e in errs if e is not None))
            raise RuntimeError(
                f"multi-writer save of step {step} failed") from primary
        metrics.per_rank = [m for m in outs]
        metrics.total_bytes = sum(m.total_bytes for m in outs)
        metrics.end_to_end_seconds = trace.clock() - t0

    def wait_snapshotted(self) -> None:
        """No-op barrier: ``save`` partitions (async: deep-copies) every
        payload on the blocking path, so the snapshot is stable the moment
        it returns — callers may mutate or donate immediately."""

    def wait(self) -> None:
        """Block until an in-flight async multi-writer save committed."""
        th = self._thread
        if th is not None:
            th.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async multi-writer save failed") from err

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        return self.managers[0].all_steps()

    def latest_step(self) -> int | None:
        return self.managers[0].latest_step()

    @property
    def last_restore_metrics(self):
        return self.managers[0].last_restore_metrics

    def restore(self, state_template=None, *, step: int | None = None, **kw):
        """Single-reader restore of the merged checkpoint (full template /
        sharding / elastic-mesh support of ``CheckpointManager.restore``)."""
        self.wait()
        return self.managers[0].restore(state_template, step=step, **kw)

    def restore_sharded(self, num_ranks: int | None = None, *,
                        step: int | None = None):
        """Elastic N→M restore: M reader ranks, each materializing its
        row-partition windows from whatever saved shards intersect them
        (``WindowAssembler`` under the hood). Returns M pytrees whose tensor
        leaves are ``LocalShard``s (replicated leaves come back whole).
        Readers run concurrently — the restore-side contention scenario."""
        self.wait()
        m = num_ranks if num_ranks is not None else self.num_ranks
        outs, errs = _fanout(m, lambda r: self._restore_rank(r, m, step),
                             "mw-read-rank")
        for e in errs:
            if e is not None:
                raise e
        return outs

    def _restore_rank(self, rank: int, num_ranks: int, step: int | None):
        windows: dict[str, tuple] = {}   # key -> (window, global_shape)

        def window_fn(rec):
            gs = tuple(rec.global_shape)
            if len(gs) == 0 or gs[0] < num_ranks:
                w = tuple((0, d) for d in gs)    # replicated: full window
            else:
                lo, hi = partition_spans(gs[0], num_ranks)[rank]
                w = ((lo, hi),) + tuple((0, d) for d in gs[1:])
            windows[rec.key] = (w, gs)
            return [(w, None)]

        # reader ranks beyond the writer count get a fresh manager/engine
        # pair (M > N); writer ranks reuse their own (restores don't touch
        # the coordinator)
        if rank < len(self.managers):
            mgr, temp = self.managers[rank], False
        else:
            mgr, temp = CheckpointManager(
                self.directory, engine=self.engine_name,
                config=replace(self._base_config), async_save=False,
                verify_crc=self.managers[0].verify_crc,
                streaming=self.managers[0].streaming), True
        try:
            tree = mgr.restore(step=step, window_fn=window_fn)
        finally:
            if temp:
                mgr.close()
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        leaves = []
        for path, leaf in flat:
            info = windows.get(path_str(path))
            if info is None or not isinstance(leaf, np.ndarray):
                leaves.append(leaf)
                continue
            w, gs = info
            if w == tuple((0, d) for d in gs):
                leaves.append(leaf)              # replicated: whole tensor
            else:
                leaves.append(LocalShard(leaf, w, gs))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # --------------------------------------------------------------- plumbing
    def close(self) -> None:
        self.wait()
        for mgr in self.managers:
            mgr.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
