"""repro.core — the paper's contribution: PFS-aware LLM checkpoint/restore.

Layers (bottom-up):
  uring        raw io_uring syscall binding (the paper's liburing)
  io_engine    Uring / ThreadPool / Posix backends behind one request API
  buffers      aligned, pooled, reusable host buffers
  aggregation  file-per-tensor / file-per-process / single-file planners
  manifest     tensor→extent metadata with global shard indices
  engines      aggregated (ours) + datastates/snapshot/torchsave baselines
  delta        content-addressed chunk store: dirty-extent saves, refcount GC
  checkpoint   CheckpointManager: async save, atomic commit, elastic restore
  multiwriter  N concurrent writer ranks, two-phase rank-0 merge commit
  tiered       tier-to-tier transfer engine: extent-hedged flush + prefetch
  multilevel   local→PFS two-level flush with hedged straggler mitigation
  remote       object-store level-2 tier: hedged range reads, dedup upload,
               direct-to-pipeline remote restore
"""

from .aggregation import (ObjectSpec, Strategy, coalesce, partition_spans,
                          plan_layout)
from .buffers import AlignedBuffer, BufferPool, PAGE
from .checkpoint import CheckpointManager, SaveMetrics, RestoreMetrics
from .delta import (DeltaIndex, DeltaPlan, StoreGCStats, gc_store,
                    plan_delta)
from .engines import (AggregatedEngine, ChecksumError, CREngine,
                      DataStatesEngine, EngineConfig, ReadReq, ReadStream,
                      SaveItem, SaveSpec, SaveStream, SnapshotEngine,
                      TorchSaveEngine, make_cr_engine)
from .io_engine import (IOEngine, IORequest, PosixEngine, ThreadPoolEngine,
                        UringEngine, make_engine, open_for)
from .manifest import (ChunkRef, Manifest, ManifestError, ManifestMergeError,
                       ShardEntry, TensorRecord)
from .multilevel import FlushStats, MultiLevelCheckpointer
from .multiwriter import (CommitCoordinator, InProcessGroup, LocalShard,
                          MultiSaveMetrics, MultiWriterAborted,
                          MultiWriterCheckpointer, shard_state)
from .pipeline import (PendingPut, RestorePipeline, RestoreTask,
                       SnapshotPipeline, build_save_puts)
from .remote import (ObjectStore, RangeStats, RemoteCheckpointer,
                     RemoteConfig, RemoteError, RemotePrefetcher, RemoteTier,
                     RemoteTransferEngine, RemoteTransientError,
                     SimObjectStore, SimProfile, UploadStats)
from .tiered import RestorePrefetcher, TieredTransferEngine, TransferStats
from .uring import IoUring, probe_io_uring

__all__ = [
    "AggregatedEngine", "AlignedBuffer", "BufferPool", "CREngine",
    "CheckpointManager", "ChecksumError", "ChunkRef", "CommitCoordinator",
    "DataStatesEngine", "DeltaIndex", "DeltaPlan", "EngineConfig",
    "FlushStats", "IOEngine", "IORequest", "InProcessGroup", "IoUring",
    "LocalShard", "Manifest", "ManifestError", "ManifestMergeError",
    "MultiLevelCheckpointer", "MultiSaveMetrics", "MultiWriterAborted",
    "MultiWriterCheckpointer", "ObjectSpec", "ObjectStore", "PAGE",
    "PendingPut", "PosixEngine", "RangeStats", "ReadReq", "ReadStream",
    "RemoteCheckpointer", "RemoteConfig", "RemoteError", "RemotePrefetcher",
    "RemoteTier", "RemoteTransferEngine", "RemoteTransientError",
    "RestoreMetrics", "RestorePipeline", "RestorePrefetcher", "RestoreTask",
    "SaveItem", "SaveMetrics", "SaveSpec", "SaveStream", "ShardEntry",
    "SimObjectStore", "SimProfile", "SnapshotEngine", "SnapshotPipeline",
    "StoreGCStats", "Strategy", "TensorRecord", "ThreadPoolEngine",
    "TieredTransferEngine", "TorchSaveEngine", "TransferStats",
    "UploadStats", "UringEngine", "build_save_puts", "coalesce", "gc_store",
    "make_cr_engine", "make_engine", "open_for", "partition_spans",
    "plan_delta", "plan_layout", "probe_io_uring", "shard_state",
]
