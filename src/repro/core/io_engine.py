"""I/O engine abstraction: io_uring / thread-pool / blocking POSIX backends.

The paper benchmarks liburing against POSIX under checkpoint workloads; this
module is that axis. All engines consume the same ``IORequest`` stream so the
aggregation strategies and C/R engines above them are backend-agnostic.

- ``UringEngine``    — batched async submission via repro.core.uring (the paper's
                       subject). Supports registered ("fixed") buffers and deep
                       submission queues; completions reaped in batches.
- ``ThreadPoolEngine``— portability fallback: pread/pwrite on a worker pool (the
                       GIL is released inside the syscalls, so I/O overlaps).
- ``PosixEngine``    — the paper's POSIX baseline: sequential blocking pwrite /
                       pread in submission order, one syscall per object.
"""

from __future__ import annotations

import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor, wait
from dataclasses import dataclass, field

from . import faults, trace
from .buffers import AlignedBuffer, PAGE, align_up
from .uring import IoUring, probe_io_uring

OP_READ = "read"
OP_WRITE = "write"
OP_FSYNC = "fsync"


@dataclass
class IORequest:
    op: str
    fd: int
    offset: int = 0
    buffer: AlignedBuffer | None = None
    buf_offset: int = 0
    nbytes: int = 0
    user_data: int = 0
    buf_index: int | None = None  # registered-buffer slot (uring fixed ops)

    @property
    def addr(self) -> int:
        assert self.buffer is not None
        return self.buffer.address + self.buf_offset

    def view(self) -> memoryview:
        assert self.buffer is not None
        return self.buffer.view(self.buf_offset, self.nbytes)


@dataclass
class Completion:
    user_data: int
    nbytes: int
    error: BaseException | None = None   # set iff engine.capture_errors


@dataclass
class EngineStats:
    submissions: int = 0      # io_uring_enter / syscall batches
    ops: int = 0
    bytes_written: int = 0
    bytes_read: int = 0
    short_retries: int = 0
    max_inflight: int = 0

    def merge_op(self, op: str, nbytes: int) -> None:
        self.ops += 1
        if op == OP_WRITE:
            self.bytes_written += nbytes
        elif op == OP_READ:
            self.bytes_read += nbytes

    def as_dict(self) -> dict:
        """Flat dict for per-tier attribution in benchmark/flush reports."""
        return {"submissions": self.submissions, "ops": self.ops,
                "bytes_written": self.bytes_written,
                "bytes_read": self.bytes_read,
                "short_retries": self.short_retries,
                "max_inflight": self.max_inflight}


class IOEngine:
    """Base: synchronous convenience on top of submit/poll primitives."""

    name = "base"

    def __init__(self):
        self.stats = EngineStats()
        # True: a failed op is reported as Completion(error=...) instead of
        # raising from poll() — required by callers that hedge requests and
        # must tolerate one attempt failing while another succeeds
        self.capture_errors = False
        # trace track for this engine's submit→completion spans; owners
        # re-tag per role (tiered flush engines are "level1", remote "remote")
        self.tier = "level0"

    # --- async primitives (overridden) ---
    def submit(self, reqs: list[IORequest]) -> None:
        raise NotImplementedError

    def poll(self, min_n: int = 0,
             timeout_s: float | None = None) -> list[Completion]:
        """Reap completions. ``min_n`` > 0 blocks for at least that many;
        ``timeout_s`` bounds the block (hedging needs timed waits) — a timed
        poll may return fewer than ``min_n`` completions, including none."""
        raise NotImplementedError

    @property
    def inflight(self) -> int:
        raise NotImplementedError

    # --- sync convenience ---
    def run(self, reqs: list[IORequest], queue_depth: int = 64) -> list[Completion]:
        """Submit all requests with bounded queue depth; wait for everything."""
        out: list[Completion] = []
        i = 0
        n = len(reqs)
        while i < n or self.inflight:
            room = queue_depth - self.inflight
            if room > 0 and i < n:
                batch = reqs[i:i + room]
                self.submit(batch)
                i += len(batch)
            if self.inflight:
                out.extend(self.poll(min_n=1 if i >= n or self.inflight >= queue_depth else 0))
        out.extend(self.poll(min_n=0))  # drain engines that complete inline
        return out

    def fsync(self, fd: int, datasync: bool = True) -> None:
        with trace.span("io.fsync", tier=self.tier):
            if datasync:
                faults.fdatasync(fd)
            else:
                faults.fsync(fd)

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class UringEngine(IOEngine):
    """Kernel-accelerated batched async I/O (the paper's liburing)."""

    name = "uring"

    def __init__(self, entries: int = 256, sqpoll: bool = False,
                 fixed_buffers: list[AlignedBuffer] | None = None):
        super().__init__()
        self.ring = IoUring(entries=entries, sqpoll=sqpoll)
        self._pending: dict[int, IORequest] = {}
        self._t_submit: dict[int, float] = {}   # token -> clock() at submit
        self._backlog: list[Completion] = []
        self._next_token = 0
        self._fixed_index: dict[int, int] = {}
        if fixed_buffers:
            self.ring.register_buffers(fixed_buffers)
            self._fixed_index = {id(b): i for i, b in enumerate(fixed_buffers)}

    def _token(self) -> int:
        self._next_token += 1
        return self._next_token

    def _prep(self, r: IORequest, token: int) -> None:
        if r.op == OP_FSYNC:
            self.ring.prep_fsync(r.fd, user_data=token)
            return
        buf_index = r.buf_index
        if buf_index is None and r.buffer is not None:
            buf_index = self._fixed_index.get(id(r.buffer))
        if r.op == OP_WRITE:
            if buf_index is not None:
                self.ring.prep_write_fixed(r.fd, r.addr, r.nbytes, r.offset,
                                           token, buf_index)
            else:
                self.ring.prep_write(r.fd, r.addr, r.nbytes, r.offset, token)
        elif r.op == OP_READ:
            if buf_index is not None:
                self.ring.prep_read_fixed(r.fd, r.addr, r.nbytes, r.offset,
                                          token, buf_index)
            else:
                self.ring.prep_read(r.fd, r.addr, r.nbytes, r.offset, token)
        else:
            raise ValueError(r.op)

    def submit(self, reqs: list[IORequest]) -> None:
        traced = trace.is_enabled()
        for r in reqs:
            token = self._token()
            self._pending[token] = r
            if traced:
                self._t_submit[token] = trace.clock()
            self._prep(r, token)
        if reqs:
            self.ring.submit()
            self.stats.submissions += 1
            self.stats.max_inflight = max(self.stats.max_inflight,
                                          len(self._pending))

    @property
    def inflight(self) -> int:
        return len(self._pending)

    def poll(self, min_n: int = 0,
             timeout_s: float | None = None) -> list[Completion]:
        out: list[Completion] = []
        if self._backlog:
            out, self._backlog = self._backlog, []
            min_n = max(0, min_n - len(out))
            if not min_n:
                out.extend(self._reap(0))
                return out
        if min_n and timeout_s is not None:
            # timed wait: spin on non-blocking reaps until deadline.
            # min_n was already decremented by any backlog drained above,
            # so count only newly reaped completions against it.
            deadline = trace.clock() + timeout_s
            got = 0
            while got < min_n:
                new = self._reap(0)
                out.extend(new)
                got += len(new)
                if got >= min_n or trace.clock() >= deadline:
                    break
                time.sleep(0.0005)
            return out
        out.extend(self._reap(min_n))
        return out

    def _reap(self, min_n: int) -> list[Completion]:
        cqes = self.ring.wait_cqes(min_n) if min_n else self.ring.peek_cqes()
        out: list[Completion] = []
        for c in cqes:
            r = self._pending.pop(c.user_data)
            t0 = self._t_submit.pop(c.user_data, None)
            if t0 is not None:   # submit→completion pair on this tier's track
                trace.complete(f"io.{r.op}", t0, tier=self.tier,
                               nbytes=r.nbytes)
            if c.res < 0:
                err = OSError(-c.res,
                              f"{r.op} failed: {os.strerror(-c.res)} "
                              f"(fd={r.fd} off={r.offset} n={r.nbytes})")
                if self.capture_errors:
                    out.append(Completion(r.user_data, 0, err))
                    continue
                raise err
            if r.op != OP_FSYNC and c.res < r.nbytes:
                # short read/write: resubmit the remainder
                self.stats.short_retries += 1
                rem = IORequest(r.op, r.fd, r.offset + c.res, r.buffer,
                                r.buf_offset + c.res, r.nbytes - c.res,
                                r.user_data, r.buf_index)
                self.stats.merge_op(r.op, c.res)
                self.submit([rem])
                continue
            self.stats.merge_op(r.op, c.res if r.op != OP_FSYNC else 0)
            out.append(Completion(r.user_data, c.res))
        return out

    def fsync(self, fd: int, datasync: bool = True) -> None:
        token = self._token()
        self._pending[token] = IORequest(OP_FSYNC, fd, user_data=token)
        if trace.is_enabled():
            self._t_submit[token] = trace.clock()
        self.ring.prep_fsync(fd, user_data=token, datasync=datasync)
        self.ring.submit()
        self.stats.submissions += 1
        # Wait for this fsync; completions of other in-flight ops observed
        # while waiting are stashed for the next poll().
        while token in self._pending:
            done = self._reap(min_n=1)
            self._backlog.extend(c for c in done if c.user_data != token)

    def close(self) -> None:
        self.ring.close()


class ThreadPoolEngine(IOEngine):
    """pread/pwrite worker pool — async via OS threads (GIL released in I/O)."""

    name = "threadpool"

    def __init__(self, workers: int = 8):
        super().__init__()
        self.pool = ThreadPoolExecutor(max_workers=workers,
                                       thread_name_prefix="io")
        self._lock = threading.Lock()
        # crlint: guarded-by(_lock)
        self._futs: dict = {}

    @staticmethod
    def _do(r: IORequest) -> int:
        # syscalls route through the fault-injection shims (pass-through
        # when no FaultPlan is armed) — also the PosixEngine's data path
        if r.op == OP_WRITE:
            mv = r.view()
            total = 0
            while total < r.nbytes:
                total += faults.pwrite(r.fd, mv[total:], r.offset + total)
            return total
        elif r.op == OP_READ:
            # preadv fills the caller's (aligned) buffer — required for O_DIRECT
            mv = r.view()
            total = 0
            while total < r.nbytes:
                n = faults.preadv(r.fd, [mv[total:]], r.offset + total)
                if n == 0:
                    raise EOFError(f"pread hit EOF at {r.offset + total}")
                total += n
            return total
        elif r.op == OP_FSYNC:
            faults.fdatasync(r.fd)
            return 0
        raise ValueError(r.op)

    def _do_traced(self, r: IORequest) -> int:
        # runs on the io worker thread: the span lands in that thread's
        # ring, so worker-side I/O visibly overlaps the submitter's stages
        with trace.span(f"io.{r.op}", tier=self.tier, nbytes=r.nbytes):
            return self._do(r)

    def submit(self, reqs: list[IORequest]) -> None:
        with self._lock:
            for r in reqs:
                self._futs[self.pool.submit(self._do_traced, r)] = r
            self.stats.submissions += 1
            self.stats.max_inflight = max(self.stats.max_inflight,
                                          len(self._futs))

    @property
    def inflight(self) -> int:
        # crlint: allow(CRL003): racy len() read is the contract — callers
        # loop `while io.inflight: poll()`, and poll() re-checks under lock
        return len(self._futs)

    def poll(self, min_n: int = 0,
             timeout_s: float | None = None) -> list[Completion]:
        with self._lock:
            futs = list(self._futs)
        if not futs:
            return []
        done, _ = wait(futs, return_when="FIRST_COMPLETED" if min_n else "ALL_COMPLETED",
                       timeout=timeout_s if min_n else 0)
        out = []
        with self._lock:
            for f in done:
                r = self._futs.pop(f, None)
                if r is None:
                    continue
                try:
                    n = f.result()
                except BaseException as e:
                    if self.capture_errors:
                        out.append(Completion(r.user_data, 0, e))
                        continue
                    raise
                self.stats.merge_op(r.op, n)
                out.append(Completion(r.user_data, n))
        return out

    def close(self) -> None:
        self.pool.shutdown(wait=True)


class PosixEngine(IOEngine):
    """The paper's POSIX baseline: blocking, sequential, one syscall per op."""

    name = "posix"

    def __init__(self):
        super().__init__()
        self._done: list[Completion] = []

    def submit(self, reqs: list[IORequest]) -> None:
        for r in reqs:
            self.stats.submissions += 1
            try:
                with trace.span(f"io.{r.op}", tier=self.tier,
                                nbytes=r.nbytes):
                    n = ThreadPoolEngine._do(r)  # same loop, executed inline
            except BaseException as e:
                if self.capture_errors:
                    self._done.append(Completion(r.user_data, 0, e))
                    continue
                raise
            self.stats.merge_op(r.op, n)
            self._done.append(Completion(r.user_data, n))

    @property
    def inflight(self) -> int:
        return 0

    def poll(self, min_n: int = 0,
             timeout_s: float | None = None) -> list[Completion]:
        out, self._done = self._done, []
        return out


_ENGINES = {
    "uring": UringEngine,
    "threadpool": ThreadPoolEngine,
    "posix": PosixEngine,
}


def resolve_backend(name: str = "auto") -> str:
    """'auto' prefers io_uring, falls back to threads (single policy point)."""
    if name == "auto":
        return "uring" if probe_io_uring() else "threadpool"
    return name


def make_engine(name: str = "auto", **kw) -> IOEngine:
    """Engine factory."""
    return _ENGINES[resolve_backend(name)](**kw)


def open_for(path: str, mode: str, direct: bool = False,
             create_dirs: bool = True) -> int:
    """Open a file for engine I/O. mode in {'r','w','rw'}."""
    if create_dirs and mode != "r":
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flags = {"r": os.O_RDONLY, "w": os.O_CREAT | os.O_WRONLY | os.O_TRUNC,
             "rw": os.O_CREAT | os.O_RDWR}[mode]
    if direct:
        flags |= os.O_DIRECT
    try:
        return os.open(path, flags, 0o644)
    except OSError:
        if direct:  # filesystem without O_DIRECT: degrade gracefully
            return os.open(path, flags & ~os.O_DIRECT, 0o644)
        raise
