"""CheckpointManager — the framework-level checkpoint/restore API.

Implements the paper's full C/R pipeline for JAX pytrees:

  save:  tensor extraction + lean-object serialization  (§2 stage 1)
         → device-to-host transfer                      (§2 stage 2)
         → engine flush (async-capable)                 (§2 stage 3)
         → manifest + atomic commit                     (§2 stage 4)

Stages 2–4 run as a STREAMING pipeline (core.pipeline.SnapshotPipeline,
DESIGN.md §9): shards are declared by size, then snapshotted chunk-by-chunk
into pooled aligned buffers and flushed as each extent lands, so D2H,
quant-packing, CRC, and storage writes overlap instead of serializing.
Async saves return after submission — blocking time is planning, not
copying. ``streaming=False`` keeps the legacy full-copy path (benchmarks
compare the two).

  restore: manifest read → lean object → planned (coalesced) tensor reads
           → host-to-device with target sharding (elastic resharding).

The restore runs as the mirror-image STREAMING pipeline
(core.pipeline.RestorePipeline, DESIGN.md §10): extents surface from the
engine's ReadStream as they land and flow through dequantize → window
assembly → device_put per tensor while later tensors' reads are still in
flight, with CRCs verified inside the stream and peak host staging bounded
by ``EngineConfig.inflight_bytes``. ``streaming=False`` keeps the monolithic
read-everything-then-assemble path for A/B.

Versioned layout::

    <root>/step_00000100/manifest.json
                         data/...
    <root>/step_00000200/...

A step directory is valid iff its manifest exists (manifests are written last,
fsync'd, atomically renamed). Crash mid-save leaves a ``.tmp-*`` dir that is
garbage-collected, never restored from.
"""

from __future__ import annotations

import os
import re
import threading
import time
import uuid
from dataclasses import dataclass, field, replace

import jax
import numpy as np

from . import delta as delta_mod
from . import faults, trace
from .aggregation import ObjectSpec, Strategy, rank_padded_total
from .engines import (ChecksumError, EngineConfig, ReadReq, SaveItem,
                      make_cr_engine)
from .manifest import Manifest, ManifestError, crc32_of
from .pipeline import (RestorePipeline, RestoreTask, SnapshotPipeline,
                       build_save_puts, iter_host_shards)
from .resharding import assemble, dedupe_shards, normalize_index, plan_window
from .serialization import (LEAN_KEY, TensorStub, as_bytes_view,
                            deserialize_lean, extract_tensors, iter_stubs,
                            reinsert_tensors, serialize_lean, tensor_nbytes,
                            to_numpy_view)

_STEP_RE = re.compile(r"^step_(\d{8})$")
_ASIDE_RE = re.compile(r"^(step_\d{8})\.tmp-old-")

# in-flight ownership marker inside a .tmp-* dir: "<pid> <epoch>". A tmp dir
# whose owner process is alive is a LIVE save — a second manager (or rank)
# starting up must not GC it out from under the flush.
OWNER_NAME = ".owner.pid"
# ownerless tmp dirs younger than this are assumed mid-creation, not stale
TMP_GRACE_S = 300.0


def step_dir_name(step: int) -> str:
    return f"step_{step:08d}"


def replace_dir(tmp: str, final: str) -> None:
    """Atomically swap ``tmp`` in as ``final`` (the crash-safe publish).

    ``os.replace`` cannot rename over a non-empty dir, and a naive
    rmtree-then-replace leaves a window where a crash loses the PREVIOUS
    version. The old version is renamed aside (still ``.tmp-``-patterned,
    so aside dirs are GC-able), the new one renamed in — retried when a
    concurrent starter's ``_gc_tmp`` rolls a displaced version back in
    between — the parent dir fsync'd, and only then are the displaced
    copies deleted: every point of the sequence leaves a restorable
    version on disk."""
    asides = []
    for _attempt in range(5):
        if os.path.exists(final):
            aside = f"{final}.tmp-old-{uuid.uuid4().hex[:8]}"
            faults.replace(final, aside)
            asides.append(aside)
        try:
            # the publish sources (data files, then manifest) were fsync'd
            # by the engine and Manifest._write before any caller reaches
            # this leaf; only the dir fsync lives here
            # crlint: allow(CRL002): sources fsync'd upstream of this leaf
            faults.replace(tmp, final)
            break
        except (faults.InjectedCrash, faults.InjectedIOError):
            raise      # injected faults must not be absorbed by the retry
        except OSError:
            continue
    else:
        raise OSError(f"could not publish {tmp} over {final}")
    fd = os.open(os.path.dirname(final) or ".", os.O_RDONLY)
    try:
        faults.fsync(fd)
    finally:
        os.close(fd)
    for aside in asides:
        faults.rmtree(aside, ignore_errors=True)


def write_owner(tmp: str) -> None:
    import socket
    with open(os.path.join(tmp, OWNER_NAME), "w") as f:
        # crlint: allow(CRL006): pidfile epoch must be wall-clock (compared
        # against /proc btime by readers on other boots/hosts)
        f.write(f"{os.getpid()} {time.time():.3f} {socket.gethostname()}")


def _proc_start_time(pid: int) -> float | None:
    """Epoch seconds the process with ``pid`` started, via /proc (Linux).
    None when unknowable (no procfs, pid gone, unparsable)."""
    try:
        with open(f"/proc/{pid}/stat", "rb") as f:
            stat = f.read()
        btime = None
        with open("/proc/stat", "rb") as f:
            for line in f:
                if line.startswith(b"btime "):
                    btime = int(line.split()[1])
                    break
        if btime is None:
            return None
        # split after the last ')': the comm field may itself hold spaces
        fields = stat[stat.rindex(b")") + 2:].split()
        ticks = int(fields[19])           # starttime: overall field 22
        return btime + ticks / os.sysconf("SC_CLK_TCK")
    except (OSError, ValueError, IndexError):
        return None


def _dir_is_young(path: str) -> bool:
    try:
        # crlint: allow(CRL006): mtime comparison needs the wall clock
        return time.time() - os.path.getmtime(path) < TMP_GRACE_S
    except OSError:
        return False       # vanished concurrently


def tmp_in_flight(path: str) -> bool:
    """True when a .tmp-* dir belongs to a live in-flight save."""
    import socket
    try:
        with open(os.path.join(path, OWNER_NAME)) as f:
            parts = f.read().split()
        pid = int(parts[0])
        host = parts[2] if len(parts) > 2 else None
    except (OSError, ValueError, IndexError):
        # no/illegible owner record: fall back to age
        return _dir_is_young(path)
    if host is not None and host != socket.gethostname():
        # shared-FS dir owned by ANOTHER host: its pids mean nothing to this
        # kernel, so liveness is unknowable here — age is the only signal
        return _dir_is_young(path)
    if pid == os.getpid():
        return True        # another manager/rank in THIS process
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False       # owner died: stale, safe to reap
    except PermissionError:
        pass               # exists, owned by another user: check recycling
    # the pid is alive — but pids recycle. A process that STARTED after the
    # owner record was written cannot be the writer: the owner died and an
    # unrelated process inherited its pid. Only claim staleness when procfs
    # gives a definitive start time; otherwise stay conservative (spare).
    try:
        recorded = float(parts[1])
    except (ValueError, IndexError):
        recorded = None
    if recorded is not None:
        started = _proc_start_time(pid)
        if started is not None and started > recorded + 1.0:
            return False   # recycled pid: the recording save is long dead
    return True


def parse_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


@dataclass
class SaveMetrics:
    step: int
    total_bytes: int = 0
    written_bytes: int = 0         # bytes submitted to storage (< total when
    #                                delta saves skip clean chunks, §12)
    extract_seconds: float = 0.0   # tensor extraction + lean serialization
    fingerprint_seconds: float = 0.0  # delta: digest every chunk (worker-side)
    diff_seconds: float = 0.0      # delta: diff digests + build chunk refs
    d2h_seconds: float = 0.0       # device→host (staging copy when streaming)
    d2h_bytes: int = 0             # delta fp128: device bytes that crossed —
    #                                digest tables + dirty-chunk gathers only
    #                                (0 for host-resident sources, whose
    #                                "gathers" are free views)
    flush_seconds: float = 0.0     # engine write + fsync
    commit_seconds: float = 0.0
    blocking_seconds: float = 0.0  # time the training loop was stalled
    end_to_end_seconds: float = 0.0
    chunks_total: int = 0          # delta saves: chunk grid size
    chunks_dirty: int = 0          # delta saves: chunks actually written
    mode: str = "blocking"         # blocking | pipelined | legacy[-async]
    #                                (delta saves get a "delta-" prefix)

    @property
    def hash_seconds(self) -> float:
        """Back-compat: the PR-5 hash+diff wall, now split into
        ``fingerprint_seconds`` + ``diff_seconds``."""
        return self.fingerprint_seconds + self.diff_seconds

    @property
    def flush_gbps(self) -> float:
        return (self.total_bytes / self.flush_seconds / 1e9
                if self.flush_seconds else 0.0)


@dataclass
class RestoreMetrics:
    """Per-stage restore attribution.

    Streaming restores OVERLAP the stages, so the per-stage seconds no
    longer sum to ``end_to_end_seconds`` — ``read_seconds`` is the wall-clock
    span of the read stage (which runs under everything else), while
    ``read_stall_seconds`` is the time the consumer actually waited on
    extents. ``stage_seconds`` and ``overlap_seconds`` report both views.
    """
    step: int
    total_bytes: int = 0
    read_seconds: float = 0.0       # wall span of the read stage
    read_stall_seconds: float = 0.0  # consumer blocked waiting on extents
    decode_seconds: float = 0.0     # int8 → float dequantization
    assemble_seconds: float = 0.0
    h2d_seconds: float = 0.0
    prefetch_seconds: float = 0.0   # tier-1 → tier-0 extent staging
    end_to_end_seconds: float = 0.0
    peak_staged_bytes: int = 0      # max host bytes staged by the read stream
    mode: str = "monolithic"        # monolithic | streaming

    @property
    def stage_seconds(self) -> float:
        """Sum of the stage walls; exceeds end_to_end when stages overlap."""
        return (self.read_seconds + self.decode_seconds
                + self.assemble_seconds + self.h2d_seconds)

    @property
    def overlap_seconds(self) -> float:
        return max(0.0, self.stage_seconds - self.end_to_end_seconds)


class CheckpointManager:
    """Versioned, engine-pluggable, async-capable checkpointing for pytrees."""

    def __init__(self, directory: str, engine: str = "aggregated",
                 config: EngineConfig | None = None, *,
                 async_save: bool = False, keep: int | None = 3,
                 verify_crc: bool = True,
                 quantize_prefixes: tuple[str, ...] = (),
                 quantize_min_bytes: int = 1 << 16,
                 streaming: bool = True,
                 eager_snapshot: bool = False,
                 delta: bool = False,
                 delta_chunk_bytes: int = delta_mod.DEFAULT_CHUNK_BYTES,
                 device_fingerprint: bool = True):
        """``keep``: retain the newest N committed steps (N >= 1); ``None``
        retains every step. ``keep=0`` is rejected — it used to silently
        mean "keep everything", which is what ``None`` now says out loud.

        ``quantize_prefixes``: tensor keys starting with any of these are
        int8-packed on save (e.g. ("opt/mu", "opt/nu") halves AdamW-moment
        flush volume ~4x — see core.quant_codec).

        ``streaming``: route saves through the SnapshotPipeline (D2H, pack,
        CRC and writes overlap; async saves return after submission) and
        restores through the RestorePipeline (read, dequant, assembly and
        H2D overlap; host staging bounded by ``config.inflight_bytes``).
        ``streaming=False`` keeps the legacy full-copy paths on both sides.
        ``eager_snapshot``: async streaming saves copy ALL sources on the
        blocking path (for callers that donate device buffers before the
        pipeline drains); by default only in-place-mutable numpy sources are
        copied — JAX arrays are immutable, holding a reference is a snapshot.

        ``delta``: content-addressed delta checkpointing (DESIGN.md §12) —
        each tensor shard is chunked into ``delta_chunk_bytes`` extents and
        hashed on the pipeline worker; only chunks that changed since the
        previous step are written (into the shared ``chunkstore/``), clean
        chunks become manifest references. Requires ``streaming=True``.
        Caveat: the hash/diff pass holds host views of every tensor with a
        dirty chunk until its chunks are staged, so delta-save host
        residency tracks the dirty payload volume rather than the
        ``config.inflight_bytes`` staging bound (free for host-resident
        arrays, a real D2H copy per device array — same as a legacy save).

        ``device_fingerprint`` (delta saves only): fingerprint chunks with
        the on-device fp128 digest (Pallas kernel / jitted XLA pass /
        bit-identical numpy fallback — DESIGN.md §14) and D2H-copy only
        dirty chunks, instead of resolving every payload to the host and
        blake2b-hashing it there. Steps written by the two settings key
        the delta index with different digest kinds, so flipping the flag
        mid-run degrades to one full write — never a wrong delta.
        """
        self.directory = os.path.abspath(directory)
        os.makedirs(self.directory, exist_ok=True)
        self.engine_name = engine
        # copy on ingest: two managers sharing one config object must not
        # see each other's checksum/strategy mutations
        self.config = replace(config) if config is not None else EngineConfig()
        if verify_crc:
            self.config.checksum = True
        if keep is not None and keep < 1:
            raise ValueError(
                f"keep={keep} would delete every checkpoint as soon as it "
                f"commits; use keep=None to retain all steps, or keep >= 1")
        if delta and not streaming:
            raise ValueError("delta=True requires the streaming save path "
                             "(streaming=True)")
        if delta and delta_chunk_bytes < 1:
            raise ValueError(f"delta_chunk_bytes must be >= 1, "
                             f"got {delta_chunk_bytes}")
        self.engine = make_cr_engine(engine, self.config)
        self.async_save = async_save
        self.keep = keep
        self.verify_crc = verify_crc
        self.delta = delta
        self.delta_chunk_bytes = delta_chunk_bytes
        self.device_fingerprint = device_fingerprint
        # test hook: how long an unreferenced store file is spared by the
        # refcount GC (a publish may not have landed its manifest yet)
        self.delta_gc_grace_s = delta_mod.GC_GRACE_S
        self.last_gc_stats: delta_mod.StoreGCStats | None = None
        self.quantize_prefixes = tuple(quantize_prefixes)
        self.quantize_min_bytes = quantize_min_bytes
        self.streaming = streaming
        self.eager_snapshot = eager_snapshot
        self._flush_thread: threading.Thread | None = None
        self._flush_error: BaseException | None = None
        self._snapshot_staged: threading.Event | None = None
        self.last_save_metrics: SaveMetrics | None = None
        self.last_restore_metrics: RestoreMetrics | None = None
        # Optional tiered.RestorePrefetcher: when set, restore of a step not
        # committed here is staged from the remote tier extent-by-extent.
        self.prefetcher = None
        # Optional multiwriter.CommitCoordinator: when set, _commit runs the
        # two-phase rank-0 protocol (per-rank manifests, merge, one rename)
        # instead of publishing per manager (DESIGN.md §11).
        self.coordinator = None
        # Optional allgather shim: (value, rank, num_ranks) -> list[int],
        # overriding the jax multihost exchange for in-process writer ranks.
        self.allgather = None
        self._gc_tmp()

    # ---------------------------------------------------------------- steps
    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and Manifest.exists(os.path.join(self.directory, name)):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _gc_tmp(self) -> None:
        """Reap stale ``.tmp-*`` dirs — but never a live in-flight save's.

        Two guards close the startup races: (1) a displaced previous version
        (``.tmp-old-*``, see ``_publish``) whose final step dir never landed
        is RECOVERED, not deleted — a crash inside the publish window cannot
        lose the prior checkpoint; (2) a tmp dir owned by a live process
        (ownership pidfile; young-dir age as fallback) is another manager's
        or rank's save mid-flush and is left alone."""
        for name in os.listdir(self.directory):
            if ".tmp-" not in name:
                continue
            full = os.path.join(self.directory, name)
            m = _ASIDE_RE.match(name)
            if m:
                final = os.path.join(self.directory, m.group(1))
                if Manifest.exists(full) and not os.path.exists(final):
                    try:
                        # rollback of an already-durable displaced aside;
                        # recovery is idempotent — a crash here just re-runs
                        # this scan on the next startup
                        # crlint: allow(CRL002): idempotent startup rollback
                        faults.replace(full, final)  # publish crashed: roll back
                        continue
                    except (faults.InjectedCrash, faults.InjectedIOError):
                        raise   # never absorb injected faults (PR-6 class)
                    except OSError:
                        # a LIVE publisher landed the new version between our
                        # exists() check and the rename; if final is still
                        # missing, keep the aside for the next startup
                        if not os.path.exists(final):
                            continue
            elif tmp_in_flight(full):
                continue
            faults.rmtree(full, ignore_errors=True)

    def _make_tmp(self, step: int) -> str:
        """Create (or join, under a coordinator) the step's staging dir."""
        if self.coordinator is not None:
            return self.coordinator.tmp_dir(self.directory, step)
        tmp = os.path.join(
            self.directory,
            f"{step_dir_name(step)}.tmp-{uuid.uuid4().hex[:8]}")
        os.makedirs(tmp, exist_ok=True)
        write_owner(tmp)
        return tmp

    def _gc_old(self) -> None:
        """Retention GC: drop steps beyond ``keep`` (None = retain all),
        then reap chunkstore files no kept step references (refcount-aware,
        DESIGN.md §12 — runs whenever a store exists, so a non-delta manager
        sharing the directory still converges it).

        The store pass walks every pack and re-parses every kept manifest,
        so it only runs when it can have new work: a step was dropped just
        now, or this manager's first pass (converging orphans a crashed
        publish left behind) — not on every commit of a ``keep=None`` run.
        """
        dropped = 0
        if self.keep is not None:
            for s in self.all_steps()[:-self.keep]:
                faults.rmtree(os.path.join(self.directory, step_dir_name(s)),
                              ignore_errors=True)
                dropped += 1
        if (dropped or self.last_gc_stats is None) and (
                self.delta or os.path.isdir(
                    os.path.join(self.directory, delta_mod.CHUNKSTORE_DIR))):
            self.last_gc_stats = delta_mod.gc_store(
                self.directory, grace_s=self.delta_gc_grace_s)

    # ----------------------------------------------------------------- save
    def save(self, step: int, state, *, rank: int | None = None,
             num_ranks: int | None = None) -> SaveMetrics:
        """Checkpoint ``state``.

        Streaming (default): D2H snapshot, quant-packing, CRC and storage
        writes overlap per extent; async mode returns after submission.
        Legacy (``streaming=False``): full host copy first, flush after."""
        self.wait()  # at most one checkpoint in flight
        t_start = trace.clock()
        rank = jax.process_index() if rank is None else rank
        num_ranks = jax.process_count() if num_ranks is None else num_ranks
        if self.streaming:
            mode = "pipelined" if self.async_save else "blocking"
            if self.delta:
                mode = f"delta-{mode}"
        else:
            mode = "legacy-async" if self.async_save else "legacy"
        metrics = SaveMetrics(step=step, mode=mode)

        # Stage 1: tensor extraction + lean-object serialization.
        t0 = trace.clock()
        tensors, lean_tree = extract_tensors(state)
        lean_blob = serialize_lean(lean_tree)
        t1 = trace.clock()
        metrics.extract_seconds = t1 - t0
        trace.complete("extract", t0, t1, attrs={"step": step})

        if self.streaming:
            self._save_streaming(step, tensors, lean_blob, rank, num_ranks,
                                 metrics, t_start)
        else:
            self._save_legacy(step, tensors, lean_blob, rank, num_ranks,
                              metrics, t_start)
        self.last_save_metrics = metrics
        return metrics

    def _save_streaming(self, step, tensors, lean_blob, rank, num_ranks,
                        metrics, t_start) -> None:
        """Pipelined save: declare sizes, then snapshot→stage→flush overlap.

        Blocking portion = spec building + prefix-sum + (for async) eager
        copies of in-place-mutable sources; every byte of D2H and packing
        runs on the pipeline worker, interleaved with the engine's writes.
        """
        puts, quantized_keys = build_save_puts(
            tensors, lean_blob,
            quantize_prefixes=self.quantize_prefixes,
            quantize_min_bytes=self.quantize_min_bytes,
            copy_mutable=self.async_save,
            copy_all=self.async_save and self.eager_snapshot)
        metrics.total_bytes = sum(p.spec.nbytes for p in puts)

        # Cross-rank prefix sum for the single-file layout (paper §3.6) —
        # spec sizes are exact (packed sizes are deterministic), so the
        # exchange happens before any payload is materialized. Delta saves
        # only know their dirty set after the worker-side hash pass, so the
        # exchange moves into the worker (every rank reaches it from its own
        # save thread, DESIGN.md §12).
        rank_totals = None
        if not self.delta:
            rank_totals = self._single_file_totals(puts, rank, num_ranks)

        tmp = self._make_tmp(step)
        pipeline = SnapshotPipeline(self.engine)

        staged = threading.Event()

        def run():
            try:
                with trace.span("save", nbytes=metrics.total_bytes,
                                attrs={"step": step, "mode": metrics.mode}):
                    self._run_streaming_flush(step, puts, rank, num_ranks,
                                              rank_totals, metrics, t_start,
                                              quantized_keys, tmp, pipeline,
                                              staged)
            finally:
                staged.set()   # never leave wait_snapshotted() hanging

        if self.async_save:
            metrics.blocking_seconds = trace.clock() - t_start
            self._flush_error = None
            self._snapshot_staged = staged
            th = threading.Thread(target=self._guard(run), daemon=True,
                                  name=f"ckpt-pipeline-{step}")
            self._flush_thread = th
            th.start()
        else:
            run()
            metrics.blocking_seconds = metrics.end_to_end_seconds

    def _run_streaming_flush(self, step, puts, rank, num_ranks, rank_totals,
                             metrics, t_start, quantized_keys, tmp, pipeline,
                             staged) -> None:
        run_puts, plan = puts, None
        totals = rank_totals
        if self.delta:
            # fingerprint + diff on the worker: zero blocking cost
            plan = delta_mod.plan_delta(
                puts, self._load_delta_index(),
                chunk_bytes=self.delta_chunk_bytes,
                checksum=self.config.checksum,
                device_fingerprint=self.device_fingerprint)
            metrics.fingerprint_seconds = plan.fingerprint_seconds
            metrics.diff_seconds = plan.diff_seconds
            metrics.d2h_bytes = plan.d2h_bytes
            metrics.chunks_total = plan.chunks_total
            metrics.chunks_dirty = plan.chunks_dirty
            run_puts = plan.puts
            totals = self._single_file_totals(run_puts, rank, num_ranks)
        t1 = trace.clock()
        manifest = pipeline.run(tmp, run_puts, step=step, rank=rank,
                                num_ranks=num_ranks, rank_totals=totals,
                                on_staged=staged.set)
        metrics.flush_seconds = trace.clock() - t1
        st = self.engine.last_save_stats
        metrics.d2h_seconds = st.copy_seconds + st.alloc_seconds
        if plan is not None:
            manifest = delta_mod.apply_plan(manifest, plan)
            metrics.written_bytes = plan.written_bytes
        else:
            metrics.written_bytes = metrics.total_bytes
        self._commit(manifest, tmp, step, quantized_keys, metrics,
                     t_start, rank=rank)

    def _save_legacy(self, step, tensors, lean_blob, rank, num_ranks,
                     metrics, t_start) -> None:
        """Monolithic save: full host copy (and quant-packing) inline on the
        blocking path, then a one-shot engine flush (async: on a thread).
        Kept for A/B benchmarking against the pipelined path."""
        # Stage 2: device→host. Shards owned by this process; DP replicas
        # deduplicated by replica_id == 0.
        t0 = trace.clock()
        items: list[SaveItem] = []
        quantized_keys: list[str] = []
        for key, t in tensors.items():
            quant = (any(key.startswith(p) for p in self.quantize_prefixes)
                     and tensor_nbytes(t) >= self.quantize_min_bytes
                     and np.dtype(t.dtype).kind == "f")
            if quant:
                quantized_keys.append(key)
            for n, (data, index) in enumerate(self._host_shards(t)):
                if quant:
                    from . import quant_codec
                    payload = np.frombuffer(quant_codec.pack(data), np.uint8)
                else:
                    if self.async_save:
                        data = np.array(data, copy=True)  # stable snapshot
                    payload = as_bytes_view(data)
                items.append(SaveItem(f"{key}#{n}", payload,
                                      str(data.dtype), tuple(t.shape), index,
                                      record_key=key))
        items.append(SaveItem(LEAN_KEY, lean_blob, is_blob=True))
        metrics.d2h_seconds = trace.clock() - t0
        metrics.total_bytes = sum(it.nbytes for it in items)
        metrics.written_bytes = metrics.total_bytes

        # Cross-rank prefix sum for the single-file layout (paper §3.6).
        rank_totals = None
        if Strategy.parse(self.config.strategy) is Strategy.SINGLE_FILE:
            local_total = rank_padded_total(
                [ObjectSpec(i.key, i.nbytes) for i in items], self.config.align)
            rank_totals = self._allgather_totals(local_total, rank, num_ranks)

        tmp = self._make_tmp(step)

        def flush():
            with trace.span("save", nbytes=metrics.total_bytes,
                            attrs={"step": step, "mode": metrics.mode}):
                t1 = trace.clock()
                with trace.span("flush", tier="level0",
                                nbytes=metrics.total_bytes):
                    manifest = self.engine.save(tmp, items, step=step,
                                                rank=rank,
                                                num_ranks=num_ranks,
                                                rank_totals=rank_totals)
                metrics.flush_seconds = trace.clock() - t1
                self._commit(manifest, tmp, step, quantized_keys, metrics,
                             t_start, rank=rank)

        if self.async_save:
            metrics.blocking_seconds = trace.clock() - t_start
            self._flush_error = None
            th = threading.Thread(target=self._guard(flush), daemon=True,
                                  name=f"ckpt-flush-{step}")
            self._flush_thread = th
            th.start()
        else:
            flush()
            metrics.blocking_seconds = metrics.end_to_end_seconds

    def _commit(self, manifest, tmp, step, quantized_keys, metrics,
                t_start, rank: int = 0) -> None:
        """Manifest write + atomic publish + GC (paper §2 stage 4).

        Under a multi-writer ``coordinator`` this becomes phase 1 + the
        rank-0 phase 2 of the two-phase commit (DESIGN.md §11); the step dir
        is renamed exactly once, by rank 0."""
        t2 = trace.clock()
        with trace.span("commit", tier="level0", attrs={"step": step}):
            manifest.extra["save_metrics"] = {
                "total_bytes": metrics.total_bytes,
                "written_bytes": metrics.written_bytes,
                "flush_seconds": metrics.flush_seconds,
            }
            if quantized_keys:
                manifest.extra["quantized"] = quantized_keys
            if self.coordinator is not None:
                self.coordinator.commit(self, manifest, tmp, step, rank)
            else:
                saved = False
                if self.delta:
                    # relocate fresh chunk/blob files into the shared store
                    # and rewrite the manifest's references BEFORE it is
                    # written — a published manifest never points into a
                    # GC-able step dir
                    saved = delta_mod.publish_packs(manifest, tmp,
                                                    self.directory,
                                                    step_dir_name(step))
                if not saved:
                    manifest.save(tmp)
                self._publish(tmp, step)
                self._gc_old()
        metrics.commit_seconds = trace.clock() - t2
        metrics.end_to_end_seconds = trace.clock() - t_start

    def _publish(self, tmp: str, step: int) -> None:
        """Atomically swap ``tmp`` in as the step dir (``replace_dir``;
        ``_gc_tmp`` rolls a displaced-but-never-replaced version back, so a
        crash anywhere in the sequence leaves a restorable checkpoint)."""
        try:
            os.remove(os.path.join(tmp, OWNER_NAME))
        except OSError:
            pass
        replace_dir(tmp, os.path.join(self.directory, step_dir_name(step)))

    def _guard(self, fn):
        def wrapped():
            try:
                fn()
            except BaseException as e:  # surfaced on next wait()/save()
                self._flush_error = e
        return wrapped

    def wait_snapshotted(self) -> None:
        """Block until the in-flight async save holds a stable snapshot —
        every source byte staged into pooled buffers (or copied). Callers
        that mutate IN PLACE or DONATE the arrays they saved must call this
        before doing so; the flush keeps draining in the background.
        (JAX rebinding needs no barrier: old arrays stay alive and
        immutable while the pipeline references them.)"""
        ev = self._snapshot_staged
        if ev is not None:
            ev.wait()

    def wait(self) -> None:
        """Block until any in-flight async flush committed."""
        th = self._flush_thread
        if th is not None:
            th.join()
            self._flush_thread = None
        self._snapshot_staged = None
        if self._flush_error is not None:
            err, self._flush_error = self._flush_error, None
            raise RuntimeError("async checkpoint flush failed") from err

    # -------------------------------------------------------------- restore
    def restore(self, state_template=None, *, step: int | None = None,
                shardings=None, window_fn=None):
        """Restore a checkpoint.

        ``state_template``: a pytree of like-shaped arrays (or
        ShapeDtypeStructs) whose shardings define the target placement. When
        None, tensors come back as host numpy arrays in the saved tree
        structure (using the lean object).

        ``window_fn(record) -> [(window, placement_or_None), ...]`` overrides
        the per-tensor wanted windows (the multi-writer elastic restore
        materializes one row-partition window per reader rank this way).

        When ``step`` is None, a step whose manifest is truncated/corrupt
        (``ManifestError``) is skipped and the next-older step restored; an
        explicitly requested step propagates the error.
        """
        if step is not None:
            return self._restore_step(step, state_template, shardings,
                                      window_fn)
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        last_err: ManifestError | None = None
        for s in reversed(steps):
            try:
                return self._restore_step(s, state_template, shardings,
                                          window_fn)
            except ManifestError as e:
                last_err = e   # corrupt manifest: fall back to older step
        raise last_err

    def _restore_step(self, step: int, state_template, shardings, window_fn):
        t_start = trace.clock()
        ckpt = os.path.join(self.directory, step_dir_name(step))
        prefetch = None
        if self.prefetcher is not None and not Manifest.exists(ckpt):
            # level-1 → level-0 prefetch: stage manifest + lean extents now,
            # tensor extents once the read plan is known (DESIGN.md §8.3)
            staged = self.prefetcher.begin(step, self.directory)
            if staged is not None:
                ckpt, prefetch = staged, self.prefetcher
        try:
            return self._restore_from(ckpt, step, state_template, shardings,
                                      prefetch, t_start, window_fn)
        except BaseException:
            if prefetch is not None:
                prefetch.discard(ckpt)
            raise

    def _restore_from(self, ckpt: str, step: int, state_template, shardings,
                      prefetch, t_start: float, window_fn=None):
        with trace.span("restore", attrs={"step": step}):
            return self._restore_from_traced(ckpt, step, state_template,
                                             shardings, prefetch, t_start,
                                             window_fn)

    def _restore_from_traced(self, ckpt, step, state_template, shardings,
                             prefetch, t_start, window_fn=None):
        manifest = Manifest.load(ckpt)
        faults.check_quarantined(ckpt, manifest)
        metrics = RestoreMetrics(
            step=step, mode="streaming" if self.streaming else "monolithic")

        # lean object first (its stubs define the saved tree)
        lean_rec = manifest.blobs[LEAN_KEY]
        lean_raw = self.engine.read(
            ckpt, [ReadReq(LEAN_KEY, lean_rec.path, lean_rec.offset,
                           lean_rec.nbytes)])[LEAN_KEY]
        self._check_crc(lean_rec.crc32, lean_raw, LEAN_KEY,
                        lean_rec.path, lean_rec.offset)
        lean_tree = deserialize_lean(lean_raw.tobytes())

        # decide the wanted windows per tensor
        wanted: dict[str, list[tuple]] = {}   # key -> [(window, device|None)]
        template_by_key: dict[str, object] = {}
        if state_template is not None:
            template_by_key = _template_tensors(state_template)
        for stub in iter_stubs(lean_tree):
            rec = manifest.tensors[stub.key]
            if window_fn is not None:
                shard_list = window_fn(rec)
            else:
                tmpl = template_by_key.get(stub.key)
                shard_list = self._target_windows(rec, tmpl, shardings)
            wanted[stub.key] = shard_list

        qset = set(manifest.extra.get("quantized", ()))
        if self.streaming:
            out_tensors = self._restore_streaming(
                ckpt, manifest, lean_tree, wanted, qset, prefetch, metrics)
        else:
            out_tensors = self._restore_monolithic(
                ckpt, manifest, lean_tree, wanted, qset, prefetch, metrics)

        metrics.total_bytes = sum(
            s.nbytes for r in manifest.tensors.values() for s in r.shards)
        if prefetch is not None:
            # full-coverage prefetch commits the step at this tier; a
            # partial (resharded) one stays staged and is discarded
            prefetch.finish(ckpt, os.path.join(self.directory,
                                               step_dir_name(step)))
        metrics.end_to_end_seconds = trace.clock() - t_start
        self.last_restore_metrics = metrics
        state = reinsert_tensors(lean_tree, out_tensors)
        return state

    def _restore_streaming(self, ckpt, manifest, lean_tree, wanted, qset,
                           prefetch, metrics) -> dict[str, object]:
        """Pipelined restore (DESIGN.md §10): extents stream per tensor
        through dequant → window assembly → device placement while later
        tensors' reads are in flight; CRCs verify inside the stream."""
        tasks = []
        crcs: dict[str, int] | None = None
        for stub in iter_stubs(lean_tree):
            rec = _deduped(manifest.tensors[stub.key])
            tasks.append(RestoreTask(stub.key, rec, wanted[stub.key],
                                     quantized=stub.key in qset))
        if self.verify_crc:
            # chunked shards (delta, §12) verify per chunk in-stream, plus a
            # whole-payload CRC under the entry's synthetic key (checked by
            # the pipeline after reassembly)
            crcs = {}
            for t in tasks:
                for sh in t.record.shards:
                    refs = (sh.chunks or ()) if delta_mod.is_chunked(sh) \
                        else (sh,)
                    for r in refs:
                        if r.crc32 is not None:
                            crcs[f"{t.key}@{r.path}@{r.offset}"] = r.crc32
                    if delta_mod.is_chunked(sh) and sh.crc32 is not None:
                        crcs[f"{t.key}@{sh.path}@{sh.offset}"] = sh.crc32
        on_reqs = None
        if prefetch is not None:   # pull exactly the planned extents
            def on_reqs(reqs):
                t0 = trace.clock()
                prefetch.fetch_extents(ckpt, reqs)
                metrics.prefetch_seconds = trace.clock() - t0
        return RestorePipeline(self.engine).run(
            ckpt, tasks, crcs=crcs, place=self._place, on_reqs=on_reqs,
            metrics=metrics)

    def _place(self, task: RestoreTask, windows: dict) -> object:
        """Final leaf from assembled windows (the pipeline's H2D stage)."""
        if task.windows and task.windows[0][1] is None:
            return windows[tuple(task.windows[0][0])]
        sharding = task.windows[0][1][0]
        arrays = [jax.device_put(windows[tuple(w)], dev)
                  for w, (_shd, dev) in task.windows]
        return jax.make_array_from_single_device_arrays(
            tuple(task.record.global_shape), sharding, arrays)

    def _restore_monolithic(self, ckpt, manifest, lean_tree, wanted, qset,
                            prefetch, metrics) -> dict[str, object]:
        """Legacy restore: every extent materialized in host memory (peak =
        full checkpoint), then verify → assemble → H2D serially. Kept as
        ``streaming=False`` for A/B benchmarking."""
        t0 = trace.clock()
        extent_reqs: dict[tuple[str, str, int], ReadReq] = {}
        chunked: dict[tuple[str, str, int], object] = {}  # delta entries
        for key, windows in wanted.items():
            rec = _deduped(manifest.tensors[key])
            for window, _dev in windows:
                for piece in plan_window(rec, window):
                    sh = piece.shard
                    if delta_mod.is_chunked(sh):
                        # chunk-reference shard (§12): read the real chunk
                        # extents; the payload is reassembled below under
                        # the entry's synthetic (path, offset) identity
                        chunked.setdefault((key, sh.path, sh.offset), sh)
                        for r in sh.chunks or ():
                            extent_reqs.setdefault(
                                (key, r.path, r.offset),
                                ReadReq(f"{key}@{r.path}@{r.offset}", r.path,
                                        r.offset, r.nbytes, obj=key))
                        continue
                    extent_reqs.setdefault(
                        (key, sh.path, sh.offset),
                        ReadReq(f"{key}@{sh.path}@{sh.offset}", sh.path,
                                sh.offset, sh.nbytes, obj=key))
        if prefetch is not None:   # pull exactly the planned extents
            tp = trace.clock()
            prefetch.fetch_extents(ckpt, list(extent_reqs.values()))
            metrics.prefetch_seconds = trace.clock() - tp
            t0 = trace.clock()
        raw = self.engine.read(ckpt, list(extent_reqs.values()))
        metrics.read_seconds = trace.clock() - t0
        metrics.read_stall_seconds = metrics.read_seconds
        metrics.peak_staged_bytes = sum(
            req.nbytes for req in extent_reqs.values())
        extent_bytes = {eo: raw[req.key] for eo, req in extent_reqs.items()}
        for (key, spath, soff), sh in chunked.items():
            extent_bytes[(key, spath, soff)] = delta_mod.reassemble_payload(
                sh,
                lambda r, k=key: extent_bytes[(k, r.path, r.offset)],
                lambda r, b, k=key: self._check_crc(r.crc32, b, k, r.path,
                                                    r.offset))
        if self.verify_crc:
            self._verify_extents(manifest, extent_bytes)

        # assemble + device placement
        t0 = trace.clock()
        out_tensors: dict[str, object] = {}
        for stub in iter_stubs(lean_tree):
            rec = _deduped(manifest.tensors[stub.key])
            out_tensors[stub.key] = self._materialize(
                rec, wanted[stub.key], extent_bytes, metrics,
                quantized=stub.key in qset)
        metrics.assemble_seconds = (trace.clock() - t0
                                    - metrics.h2d_seconds
                                    - metrics.decode_seconds)
        return out_tensors

    # ------------------------------------------------------------- internals
    @staticmethod
    def _host_shards(t):
        """Yield (host_array, global_index) for shards this process owns —
        the eager (legacy-path) view over pipeline.iter_host_shards, so the
        shard-ownership rule lives in exactly one place."""
        for arr, idx in iter_host_shards(t):
            yield to_numpy_view(arr), idx

    def _single_file_totals(self, puts, rank: int,
                            num_ranks: int) -> list[int] | None:
        """SINGLE_FILE prefix-sum exchange over the declared put sizes
        (paper §3.6); None for the other layouts."""
        if Strategy.parse(self.config.strategy) is not Strategy.SINGLE_FILE:
            return None
        local_total = rank_padded_total(
            [ObjectSpec(p.spec.key, p.spec.nbytes) for p in puts],
            self.config.align)
        return self._allgather_totals(local_total, rank, num_ranks)

    def _load_delta_index(self) -> "delta_mod.DeltaIndex":
        """Chunk index of the newest committed step (empty when there is
        none, its manifest is unreadable, or it predates delta — every
        chunk then hashes dirty, i.e. the save degrades to a full write).
        Reloaded per save rather than cached: under the multi-writer
        coordinator the authoritative chunkstore paths only exist in the
        merged manifest rank 0 published."""
        step = self.latest_step()
        if step is None:
            return delta_mod.DeltaIndex()
        try:
            m = Manifest.load(os.path.join(self.directory,
                                           step_dir_name(step)))
        except ManifestError:
            return delta_mod.DeltaIndex()
        return delta_mod.DeltaIndex.from_manifest(m)

    def _allgather_totals(self, local_total: int, rank: int,
                          num_ranks: int) -> list[int]:
        """Cross-rank padded-total exchange for SINGLE_FILE (paper §3.6).

        ``self.allgather`` (an in-process shim under the multi-writer
        harness) overrides the jax multihost path."""
        if self.allgather is not None:
            return [int(x) for x in self.allgather(local_total, rank,
                                                   num_ranks)]
        if num_ranks == 1:
            return [local_total]
        from jax.experimental import multihost_utils
        gathered = multihost_utils.process_allgather(
            np.asarray([local_total], dtype=np.int64))
        return [int(x) for x in np.asarray(gathered).reshape(-1)]

    def _target_windows(self, rec, tmpl, shardings):
        """(window, sharding_or_None) pairs this process must materialize."""
        sharding = None
        if shardings is not None and rec.key in shardings:
            sharding = shardings[rec.key]
        elif tmpl is not None:
            sharding = getattr(tmpl, "sharding", None)
        if sharding is None:
            return [(tuple((0, s) for s in rec.global_shape), None)]
        # one window per addressable device
        windows = []
        idx_map = sharding.addressable_devices_indices_map(tuple(rec.global_shape))
        for dev, idx in idx_map.items():
            windows.append((normalize_index(idx, rec.global_shape),
                            (sharding, dev)))
        return windows

    def _materialize(self, rec, windows, extent_bytes, metrics,
                     quantized: bool = False):
        if quantized:
            from . import quant_codec
            dt = parse_dtype(rec.dtype)
            cache: dict = {}

            def lookup(sh):
                k = (rec.key, sh.path, sh.offset)
                if k not in cache:
                    td = trace.clock()
                    cache[k] = quant_codec.unpack(extent_bytes[k], dt)
                    metrics.decode_seconds += trace.clock() - td
                return cache[k]
        else:
            lookup = lambda sh: extent_bytes[(rec.key, sh.path, sh.offset)]
        if windows and windows[0][1] is None:
            return assemble(rec, windows[0][0], lookup)
        # build one array per device, then a global jax.Array
        sharding = windows[0][1][0]
        per_device = {}
        arrays = []
        t0 = trace.clock()
        for window, (shd, dev) in windows:
            wkey = tuple(window)
            if wkey not in per_device:
                per_device[wkey] = assemble(rec, window, lookup)
            arrays.append(jax.device_put(per_device[wkey], dev))
        global_shape = tuple(rec.global_shape)
        out = jax.make_array_from_single_device_arrays(
            global_shape, sharding, arrays)
        metrics.h2d_seconds += trace.clock() - t0
        return out

    def _check_crc(self, expect, raw, key, path: str = "",
                   offset: int = 0) -> None:
        if self.verify_crc and expect is not None:
            got = crc32_of(raw)
            if got != expect:
                raise ChecksumError(key, path, offset, expect, got)

    def _verify_extents(self, manifest, extent_bytes) -> None:
        by_extent = {}
        for rec in manifest.tensors.values():
            for sh in rec.shards:
                by_extent[(rec.key, sh.path, sh.offset)] = (sh.crc32, rec.key)
        for eo, raw in extent_bytes.items():
            expect, key = by_extent.get(eo, (None, None))
            self._check_crc(expect, raw, key, eo[1], eo[2])

    def close(self) -> None:
        self.wait()
        if self.prefetcher is not None:
            self.prefetcher.close()
        self.engine.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def _deduped(rec):
    import copy
    out = copy.copy(rec)
    out.shards = dedupe_shards(rec)
    return out


def _template_tensors(state_template) -> dict[str, object]:
    """key -> template leaf (anything with .shape/.dtype, incl. SDS)."""
    from .serialization import path_str
    flat, _ = jax.tree_util.tree_flatten_with_path(state_template)
    out = {}
    for path, leaf in flat:
        if (isinstance(leaf, jax.Array)
                and jax.dtypes.issubdtype(leaf.dtype, jax.dtypes.prng_key)):
            out[path_str(path)] = jax.random.key_data(leaf)
        elif hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            out[path_str(path)] = leaf
    return out
