"""Raw io_uring binding via ctypes — the paper's liburing, without the C shim.

Implements the io_uring syscall ABI directly (x86_64 syscall numbers 425/426/427),
mmap'd submission/completion rings, 64-byte SQEs, registered buffers and files.
This is the kernel-accelerated I/O backend the paper characterizes; see DESIGN.md §2.

Only the opcodes the checkpoint/restore path needs are exposed:
READ / WRITE / READ_FIXED / WRITE_FIXED / FSYNC / NOP.
"""

from __future__ import annotations

import ctypes
import ctypes.util
import errno
import mmap
import os
import struct
from dataclasses import dataclass

# ---------------------------------------------------------------------------
# Syscall numbers (x86_64)
# ---------------------------------------------------------------------------
SYS_io_uring_setup = 425
SYS_io_uring_enter = 426
SYS_io_uring_register = 427

# mmap offsets for the three ring regions
IORING_OFF_SQ_RING = 0
IORING_OFF_CQ_RING = 0x8000000
IORING_OFF_SQES = 0x10000000

# io_uring_enter flags
IORING_ENTER_GETEVENTS = 1 << 0
IORING_ENTER_SQ_WAKEUP = 1 << 1

# setup flags
IORING_SETUP_IOPOLL = 1 << 0
IORING_SETUP_SQPOLL = 1 << 1
IORING_SETUP_CQSIZE = 1 << 3

# features
IORING_FEAT_SINGLE_MMAP = 1 << 0
IORING_FEAT_NODROP = 1 << 1

# sq ring flags (read from kernel)
IORING_SQ_NEED_WAKEUP = 1 << 0

# register opcodes
IORING_REGISTER_BUFFERS = 0
IORING_UNREGISTER_BUFFERS = 1
IORING_REGISTER_FILES = 2
IORING_UNREGISTER_FILES = 3

# sqe opcodes (subset)
IORING_OP_NOP = 0
IORING_OP_READV = 1
IORING_OP_WRITEV = 2
IORING_OP_FSYNC = 3
IORING_OP_READ_FIXED = 4
IORING_OP_WRITE_FIXED = 5
IORING_OP_READ = 22
IORING_OP_WRITE = 23

IORING_FSYNC_DATASYNC = 1 << 0

SQE_SIZE = 64
CQE_SIZE = 16

_libc = ctypes.CDLL(None, use_errno=True)
_libc.syscall.restype = ctypes.c_long


class _SqringOffsets(ctypes.Structure):
    _fields_ = [
        ("head", ctypes.c_uint32),
        ("tail", ctypes.c_uint32),
        ("ring_mask", ctypes.c_uint32),
        ("ring_entries", ctypes.c_uint32),
        ("flags", ctypes.c_uint32),
        ("dropped", ctypes.c_uint32),
        ("array", ctypes.c_uint32),
        ("resv1", ctypes.c_uint32),
        ("user_addr", ctypes.c_uint64),
    ]


class _CqringOffsets(ctypes.Structure):
    _fields_ = [
        ("head", ctypes.c_uint32),
        ("tail", ctypes.c_uint32),
        ("ring_mask", ctypes.c_uint32),
        ("ring_entries", ctypes.c_uint32),
        ("overflow", ctypes.c_uint32),
        ("cqes", ctypes.c_uint32),
        ("flags", ctypes.c_uint32),
        ("resv1", ctypes.c_uint32),
        ("user_addr", ctypes.c_uint64),
    ]


class IoUringParams(ctypes.Structure):
    _fields_ = [
        ("sq_entries", ctypes.c_uint32),
        ("cq_entries", ctypes.c_uint32),
        ("flags", ctypes.c_uint32),
        ("sq_thread_cpu", ctypes.c_uint32),
        ("sq_thread_idle", ctypes.c_uint32),
        ("features", ctypes.c_uint32),
        ("wq_fd", ctypes.c_uint32),
        ("resv", ctypes.c_uint32 * 3),
        ("sq_off", _SqringOffsets),
        ("cq_off", _CqringOffsets),
    ]


class _Iovec(ctypes.Structure):
    _fields_ = [("iov_base", ctypes.c_void_p), ("iov_len", ctypes.c_size_t)]


@dataclass(frozen=True)
class Cqe:
    """One completion-queue entry."""

    user_data: int
    res: int  # >=0: bytes transferred; <0: -errno
    flags: int


class UringError(OSError):
    pass


def _check(ret: int, what: str) -> int:
    if ret < 0:
        err = ctypes.get_errno()
        raise UringError(err, f"{what}: {os.strerror(err)}")
    return ret


def probe_io_uring() -> bool:
    """True if the kernel/container permits io_uring."""
    params = IoUringParams()
    fd = _libc.syscall(SYS_io_uring_setup, 4, ctypes.byref(params))
    if fd < 0:
        return False
    os.close(fd)
    return True


class IoUring:
    """A single io_uring instance: submission + completion rings.

    Not thread-safe by itself; the engine layer serializes submissions and may
    reap completions from a dedicated thread (reaping and submitting touch
    disjoint ring words, and the GIL orders the python-side bookkeeping).
    """

    def __init__(self, entries: int = 256, sqpoll: bool = False,
                 sqpoll_idle_ms: int = 2000):
        if entries & (entries - 1):
            raise ValueError("entries must be a power of two")
        params = IoUringParams()
        if sqpoll:
            params.flags |= IORING_SETUP_SQPOLL
            params.sq_thread_idle = sqpoll_idle_ms
        fd = _libc.syscall(SYS_io_uring_setup, entries, ctypes.byref(params))
        if fd < 0 and sqpoll:
            # SQPOLL may need privileges; retry without.
            params = IoUringParams()
            fd = _libc.syscall(SYS_io_uring_setup, entries, ctypes.byref(params))
            sqpoll = False
        _check(fd, "io_uring_setup")
        self.fd = fd
        self.params = params
        self.sqpoll = sqpoll
        self.sq_entries = params.sq_entries
        self.cq_entries = params.cq_entries

        sq_sz = params.sq_off.array + params.sq_entries * 4
        cq_sz = params.cq_off.cqes + params.cq_entries * CQE_SIZE
        single = bool(params.features & IORING_FEAT_SINGLE_MMAP)
        if single:
            sz = max(sq_sz, cq_sz)
            self._sq_mm = mmap.mmap(fd, sz, flags=mmap.MAP_SHARED | getattr(mmap, "MAP_POPULATE", 0),
                                    prot=mmap.PROT_READ | mmap.PROT_WRITE,
                                    offset=IORING_OFF_SQ_RING)
            self._cq_mm = self._sq_mm
        else:
            self._sq_mm = mmap.mmap(fd, sq_sz, flags=mmap.MAP_SHARED,
                                    prot=mmap.PROT_READ | mmap.PROT_WRITE,
                                    offset=IORING_OFF_SQ_RING)
            self._cq_mm = mmap.mmap(fd, cq_sz, flags=mmap.MAP_SHARED,
                                    prot=mmap.PROT_READ | mmap.PROT_WRITE,
                                    offset=IORING_OFF_CQ_RING)
        self._sqe_mm = mmap.mmap(fd, params.sq_entries * SQE_SIZE,
                                 flags=mmap.MAP_SHARED,
                                 prot=mmap.PROT_READ | mmap.PROT_WRITE,
                                 offset=IORING_OFF_SQES)

        so, co = params.sq_off, params.cq_off
        self._sq_head_off = so.head
        self._sq_tail_off = so.tail
        self._sq_mask = self._u32(self._sq_mm, so.ring_mask)
        self._sq_flags_off = so.flags
        self._sq_dropped_off = so.dropped
        self._sq_array_off = so.array
        self._cq_head_off = co.head
        self._cq_tail_off = co.tail
        self._cq_mask = self._u32(self._cq_mm, co.ring_mask)
        self._cqes_off = co.cqes
        self._to_submit = 0  # sqes written but not yet passed to enter()
        self._inflight = 0
        self._registered_bufs: list | None = None

        # Pre-fill the SQ index array once: we always use slot i -> sqe i.
        for i in range(self.sq_entries):
            self._put_u32(self._sq_mm, self._sq_array_off + 4 * i, i)

    # -- ring word accessors ------------------------------------------------
    @staticmethod
    def _u32(mm, off) -> int:
        return struct.unpack_from("<I", mm, off)[0]

    @staticmethod
    def _put_u32(mm, off, val) -> None:
        struct.pack_into("<I", mm, off, val & 0xFFFFFFFF)

    # -- capacity -----------------------------------------------------------
    def sq_space(self) -> int:
        head = self._u32(self._sq_mm, self._sq_head_off)
        tail = self._u32(self._sq_mm, self._sq_tail_off)
        return self.sq_entries - (tail - head) % (1 << 32)

    @property
    def inflight(self) -> int:
        return self._inflight

    # -- registration -------------------------------------------------------
    def register_buffers(self, buffers) -> None:
        """Register fixed buffers; each must expose .address and .nbytes."""
        n = len(buffers)
        iovs = (_Iovec * n)()
        for i, b in enumerate(buffers):
            iovs[i].iov_base = b.address
            iovs[i].iov_len = b.nbytes
        ret = _libc.syscall(SYS_io_uring_register, self.fd,
                            IORING_REGISTER_BUFFERS, ctypes.byref(iovs), n)
        _check(ret, "io_uring_register(BUFFERS)")
        self._registered_bufs = list(buffers)

    def unregister_buffers(self) -> None:
        ret = _libc.syscall(SYS_io_uring_register, self.fd,
                            IORING_UNREGISTER_BUFFERS, None, 0)
        _check(ret, "io_uring_register(UNREGISTER_BUFFERS)")
        self._registered_bufs = None

    # -- sqe preparation ----------------------------------------------------
    # struct io_uring_sqe (64B):
    #  u8 opcode; u8 flags; u16 ioprio; s32 fd; u64 off; u64 addr; u32 len;
    #  u32 rw_flags; u64 user_data; u16 buf_index; u16 personality;
    #  s32 splice_fd_in; u64 addr3; u64 pad
    _SQE_FMT = "<BBHiQQIIQHHiQQ"
    assert struct.calcsize(_SQE_FMT) == SQE_SIZE

    def _prep(self, opcode: int, fd: int, off: int, addr: int, length: int,
              user_data: int, rw_flags: int = 0, buf_index: int = 0) -> None:
        if self.sq_space() <= 0:
            raise UringError(errno.EBUSY, "submission queue full")
        tail = self._u32(self._sq_mm, self._sq_tail_off)
        idx = tail & self._sq_mask
        struct.pack_into(self._SQE_FMT, self._sqe_mm, idx * SQE_SIZE,
                         opcode, 0, 0, fd, off, addr, length,
                         rw_flags, user_data, buf_index, 0, 0, 0, 0)
        # publish: the array is pre-filled identity, just bump the tail
        self._put_u32(self._sq_mm, self._sq_tail_off, tail + 1)
        self._to_submit += 1

    def prep_write(self, fd: int, addr: int, nbytes: int, offset: int,
                   user_data: int) -> None:
        self._prep(IORING_OP_WRITE, fd, offset, addr, nbytes, user_data)

    def prep_read(self, fd: int, addr: int, nbytes: int, offset: int,
                  user_data: int) -> None:
        self._prep(IORING_OP_READ, fd, offset, addr, nbytes, user_data)

    def prep_write_fixed(self, fd: int, addr: int, nbytes: int, offset: int,
                         user_data: int, buf_index: int) -> None:
        self._prep(IORING_OP_WRITE_FIXED, fd, offset, addr, nbytes, user_data,
                   buf_index=buf_index)

    def prep_read_fixed(self, fd: int, addr: int, nbytes: int, offset: int,
                        user_data: int, buf_index: int) -> None:
        self._prep(IORING_OP_READ_FIXED, fd, offset, addr, nbytes, user_data,
                   buf_index=buf_index)

    def prep_fsync(self, fd: int, user_data: int, datasync: bool = True) -> None:
        self._prep(IORING_OP_FSYNC, fd, 0, 0, 0, user_data,
                   rw_flags=IORING_FSYNC_DATASYNC if datasync else 0)

    def prep_nop(self, user_data: int) -> None:
        self._prep(IORING_OP_NOP, 0, 0, 0, 0, user_data)

    # -- submit / complete ---------------------------------------------------
    def submit(self, wait_for: int = 0) -> int:
        """Pass pending sqes to the kernel; optionally wait for completions."""
        to_submit = self._to_submit
        flags = 0
        if wait_for:
            flags |= IORING_ENTER_GETEVENTS
        if self.sqpoll:
            sqflags = self._u32(self._sq_mm, self._sq_flags_off)
            if sqflags & IORING_SQ_NEED_WAKEUP:
                flags |= IORING_ENTER_SQ_WAKEUP
            elif not wait_for:
                # SQPOLL thread picks the sqes up without a syscall.
                self._inflight += to_submit
                self._to_submit = 0
                return to_submit
        ret = _libc.syscall(SYS_io_uring_enter, self.fd, to_submit,
                            wait_for, flags, None, 0)
        while ret < 0 and ctypes.get_errno() in (errno.EINTR, errno.EAGAIN):
            ret = _libc.syscall(SYS_io_uring_enter, self.fd, to_submit,
                                wait_for, flags, None, 0)
        _check(ret, "io_uring_enter")
        self._inflight += ret
        self._to_submit -= ret
        return ret

    def peek_cqes(self, max_n: int | None = None) -> list[Cqe]:
        """Drain available completions without blocking."""
        out: list[Cqe] = []
        head = self._u32(self._cq_mm, self._cq_head_off)
        tail = self._u32(self._cq_mm, self._cq_tail_off)
        while head != tail and (max_n is None or len(out) < max_n):
            idx = head & self._cq_mask
            user_data, res, flags = struct.unpack_from(
                "<QiI", self._cq_mm, self._cqes_off + idx * CQE_SIZE)
            out.append(Cqe(user_data, res, flags))
            head += 1
        self._put_u32(self._cq_mm, self._cq_head_off, head)
        self._inflight -= len(out)
        return out

    def wait_cqes(self, n: int = 1) -> list[Cqe]:
        """Block until at least n completions are available, drain all."""
        got = self.peek_cqes()
        while len(got) < n:
            need = n - len(got)
            ret = _libc.syscall(SYS_io_uring_enter, self.fd, 0, need,
                                IORING_ENTER_GETEVENTS, None, 0)
            if ret < 0 and ctypes.get_errno() not in (errno.EINTR, errno.EAGAIN):
                _check(ret, "io_uring_enter(GETEVENTS)")
            got.extend(self.peek_cqes())
        return got

    def close(self) -> None:
        if getattr(self, "fd", -1) >= 0:
            try:
                if self._registered_bufs is not None:
                    self.unregister_buffers()
            except OSError:
                pass
            self._sqe_mm.close()
            if self._cq_mm is not self._sq_mm:
                self._cq_mm.close()
            self._sq_mm.close()
            os.close(self.fd)
            self.fd = -1

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
