"""Aggregation strategies — the paper's §3.2.1 axis.

Maps a rank's set of checkpoint objects (tensor shards + lean blobs) onto file
extents under one of three layouts:

- ``FILE_PER_TENSOR``  — one file per object. The uncoalesced baseline used by
  DeepSpeed/TorchSnapshot; maximizes metadata load.
- ``FILE_PER_PROCESS`` — one file per rank, objects at sequential aligned
  offsets. Moderate aggregation.
- ``SINGLE_FILE``      — every rank writes disjoint extents of ONE shared file.
  Rank r's base offset is an exclusive prefix-sum of the padded per-rank totals
  (the serialized offset computation the paper describes in §3.6).

All offsets/extents are aligned to ``align`` (page size) so the same plan works
under O_DIRECT. The planner is pure (no I/O) — engines execute plans.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field

from .buffers import PAGE


class Strategy(enum.Enum):
    FILE_PER_TENSOR = "file_per_tensor"
    FILE_PER_PROCESS = "file_per_process"
    SINGLE_FILE = "single_file"

    @classmethod
    def parse(cls, s: "Strategy | str") -> "Strategy":
        return s if isinstance(s, Strategy) else cls(s)


@dataclass(frozen=True)
class ObjectSpec:
    """One savable byte object (a tensor shard or a serialized blob)."""
    key: str
    nbytes: int


@dataclass(frozen=True)
class Extent:
    """Placement of one object inside a checkpoint directory."""
    key: str
    path: str      # relative file path within the checkpoint dir
    offset: int    # aligned byte offset within the file
    nbytes: int    # logical (unpadded) size


@dataclass
class WritePlan:
    strategy: Strategy
    rank: int
    extents: list[Extent] = field(default_factory=list)
    file_sizes: dict[str, int] = field(default_factory=dict)  # path -> aligned bytes
    align: int = PAGE

    @property
    def total_logical_bytes(self) -> int:
        return sum(e.nbytes for e in self.extents)

    @property
    def total_padded_bytes(self) -> int:
        return sum(self.file_sizes.values())

    @property
    def num_files(self) -> int:
        return len(self.file_sizes)

    def by_file(self) -> dict[str, list[Extent]]:
        out: dict[str, list[Extent]] = {}
        for e in self.extents:
            out.setdefault(e.path, []).append(e)
        for lst in out.values():
            lst.sort(key=lambda e: e.offset)
        return out


def _align_up(n: int, a: int) -> int:
    return (n + a - 1) // a * a


def rank_padded_total(objects: list[ObjectSpec], align: int = PAGE) -> int:
    """Padded bytes rank needs in an aggregated layout (for the prefix sum)."""
    return sum(_align_up(o.nbytes, align) for o in objects)


def partition_spans(n: int, parts: int) -> list[tuple[int, int]]:
    """Split ``[0, n)`` into ``parts`` contiguous near-even spans.

    The row-partition used by the multi-writer harness to assign each
    writer (and each elastic-restore reader) its window of a global
    tensor's leading dim. The first ``n % parts`` spans get the extra row,
    so any two rank counts produce overlapping-but-coverable windows —
    exactly what ``plan_window`` reshards across."""
    if parts < 1:
        raise ValueError("parts must be >= 1")
    base, rem = divmod(n, parts)
    out, start = [], 0
    for p in range(parts):
        size = base + (1 if p < rem else 0)
        out.append((start, start + size))
        start += size
    return out


def single_file_base_offsets(rank_totals: list[int], align: int = PAGE) -> list[int]:
    """Exclusive prefix-sum of per-rank padded totals (paper §3.6).

    On a real multi-host deployment this scan is the serialized cross-rank
    dependency the paper measures; repro.core.checkpoint runs it through a
    process-group allgather, and the multi-process benchmark through a shared
    memory header.
    """
    offs, acc = [], 0
    for t in rank_totals:
        offs.append(acc)
        acc += _align_up(t, align)
    return offs


def plan_layout(objects: list[ObjectSpec], strategy: Strategy | str, rank: int = 0,
                rank_totals: list[int] | None = None, align: int = PAGE,
                data_subdir: str = "data") -> WritePlan:
    """Produce the write plan for this rank's objects under a strategy.

    ``rank_totals`` (padded totals for all ranks) is required for SINGLE_FILE;
    it is the result of the cross-rank prefix-sum exchange.
    """
    strategy = Strategy.parse(strategy)
    plan = WritePlan(strategy=strategy, rank=rank, align=align)

    if strategy is Strategy.FILE_PER_TENSOR:
        for o in objects:
            path = f"{data_subdir}/rank{rank:05d}/{_sanitize(o.key)}.bin"
            plan.extents.append(Extent(o.key, path, 0, o.nbytes))
            plan.file_sizes[path] = _align_up(o.nbytes, align)
        return plan

    if strategy is Strategy.FILE_PER_PROCESS:
        path = f"{data_subdir}/shard{rank:05d}.bin"
        off = 0
        for o in objects:
            plan.extents.append(Extent(o.key, path, off, o.nbytes))
            off += _align_up(o.nbytes, align)
        plan.file_sizes[path] = off
        return plan

    # SINGLE_FILE
    if rank_totals is None:
        raise ValueError("SINGLE_FILE needs rank_totals for the offset prefix-sum")
    bases = single_file_base_offsets(rank_totals, align)
    if rank >= len(bases):
        raise ValueError(f"rank {rank} outside rank_totals of {len(bases)}")
    path = f"{data_subdir}/checkpoint.bin"
    off = bases[rank]
    for o in objects:
        plan.extents.append(Extent(o.key, path, off, o.nbytes))
        off += _align_up(o.nbytes, align)
    total = bases[-1] + _align_up(rank_totals[-1], align)
    plan.file_sizes[path] = total
    return plan


def coalesce(extents: list[Extent], threshold: int, align: int = PAGE
             ) -> list[list[Extent]]:
    """Group file-adjacent extents into batches of ≥ threshold bytes.

    This is the request-level coalescing the paper recommends: extents in a
    group are contiguous in the file (modulo alignment padding) and can be
    staged into one buffer and issued as ONE write. Extents larger than the
    threshold form their own group (written zero-copy from their source).
    """
    groups: list[list[Extent]] = []
    cur: list[Extent] = []
    cur_bytes = 0
    prev_end = None
    for e in sorted(extents, key=lambda e: (e.path, e.offset)):
        padded = _align_up(e.nbytes, align)
        contiguous = (prev_end is not None and cur
                      and e.path == cur[-1].path and e.offset == prev_end)
        if cur and (not contiguous or cur_bytes + padded > threshold):
            groups.append(cur)
            cur, cur_bytes = [], 0
        cur.append(e)
        cur_bytes += padded
        prev_end = e.offset + padded
        if cur_bytes >= threshold:
            groups.append(cur)
            cur, cur_bytes, prev_end = [], 0, None
    if cur:
        groups.append(cur)
    return groups


def chunk_extents(path: str, nbytes: int, chunk_bytes: int,
                  align: int = PAGE, start: int = 0) -> list[Extent]:
    """Split one file interval ``[start, start + nbytes)`` into transfer
    extents of at most ``chunk_bytes``.

    This is the planning half of a tier-to-tier copy (DESIGN.md §8): large
    files become pipelined, individually-hedgeable extents at aligned
    boundaries; the final extent carries any unaligned tail. Keys are
    ``<path>@<offset>`` so extents are addressable in transfer stats."""
    if chunk_bytes <= 0:
        raise ValueError("chunk_bytes must be positive")
    chunk = _align_up(chunk_bytes, align)
    out: list[Extent] = []
    off = start
    end = start + nbytes
    while off < end:
        n = min(chunk, end - off)
        out.append(Extent(f"{path}@{off}", path, off, n))
        off += n
    return out


def _sanitize(key: str) -> str:
    return "".join(c if c.isalnum() or c in "._-" else "_" for c in key)[:180]
