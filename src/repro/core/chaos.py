"""Deterministic seeded chaos campaign over the checkpoint stack (§13).

Drives randomized save / restore / flush / GC schedules across the
delta × multiwriter × multilevel composition matrix with faults injected
through ``core.faults`` (syscall-level crashes, torn/short writes,
ENOSPC/EIO) and filesystem-level corruptors (bit-flips, truncation,
zeroing), then checks the two design invariants after every fault:

  I1  a committed step always restores bit-exactly (storage is never left
      corrupt by a crash — a step either restores byte-identical to what
      was saved, or is not committed),
  I2  a crash never loses the previously committed step (the newest
      pre-fault committed step is still present and restorable from a
      fresh manager, exactly as a restarted trainer would find it).

Post-commit corruption trials check the complementary pair: restore must
never *silently* return wrong bytes (typed ``ChecksumError`` /
``ManifestError`` / ``QuarantinedChunkError``, or clean fallback to an
older step), and ``scrub_store`` must detect every injected corruption —
repairing from level 1 when a mirror exists, quarantining otherwise.

Every trial derives its RNG from ``(seed, trial-index, cell)``, so a
campaign failure is reproducible from the seed line it prints:

    PYTHONPATH=src python -m repro.core.faults --campaign \
        --seed <S> --only-trial <I> --cells <CELL> -v

Multiwriter / threadpool trials interleave threads, which can move WHERE
in the syscall stream a fault lands between runs — the invariants must
hold at every site, so any landing is a valid trial; the schedule itself
(states, steps, fault specs) is fully seed-determined.
"""

from __future__ import annotations

import argparse
import errno as _errno
import os
import random
import shutil
import tempfile
from collections import Counter
from dataclasses import dataclass, field

import numpy as np

from . import delta as delta_mod
from . import faults, trace
from .checkpoint import CheckpointManager
from .engines import ChecksumError, EngineConfig
from .manifest import MANIFEST_NAME, ManifestError
from .multilevel import MultiLevelCheckpointer
from .multiwriter import MultiWriterAborted, MultiWriterCheckpointer

CELLS = ("solo", "delta", "ml", "ml-delta", "mw", "mw-delta",
         "delta-gather", "remote", "remote-delta")
_CHUNK = 2048         # delta chunk grid for campaign states (small & fast)


class InvariantViolation(AssertionError):
    """A chaos trial observed a broken design invariant."""


@dataclass
class CampaignStats:
    seed: int = 0
    trials: int = 0
    faults: int = 0                      # faults actually fired/injected
    no_fire: int = 0                     # trials whose fault never triggered
    by_kind: Counter = field(default_factory=Counter)
    by_cell: Counter = field(default_factory=Counter)
    elapsed: float = 0.0

    def summary(self) -> str:
        kinds = ", ".join(f"{k}={n}" for k, n in sorted(self.by_kind.items()))
        cells = ", ".join(f"{c}={n}" for c, n in sorted(self.by_cell.items()))
        return (f"campaign seed={self.seed}: {self.trials} trials, "
                f"{self.faults} faults fired ({self.no_fire} no-fire) in "
                f"{self.elapsed:.1f}s\n  kinds: {kinds}\n  cells: {cells}")


# --------------------------------------------------------------- state helpers
def _make_state(rng: random.Random) -> dict:
    r = np.random.default_rng(rng.randrange(2 ** 32))
    return {
        "w": r.standard_normal((512, 4)).astype(np.float32),     # 8 KiB
        "b": r.standard_normal(256),                             # 2 KiB f64
        "emb": r.integers(0, 256, 6144).astype(np.uint8),        # 6 KiB
        "step_count": int(rng.randrange(10 ** 6)),
    }


def _mutate(state: dict, rng: random.Random) -> dict:
    """Sparsely mutated copy: realistic delta dirtiness (some chunks clean)."""
    r = np.random.default_rng(rng.randrange(2 ** 32))
    out = {}
    for k, v in state.items():
        if not isinstance(v, np.ndarray):
            out[k] = v + 1
            continue
        a = v.copy()
        if rng.random() < 0.75:          # leave ~25% of tensors untouched
            flat = a.reshape(-1)
            span = max(1, flat.shape[0] // 8)
            at = rng.randrange(max(flat.shape[0] - span, 1))
            if a.dtype == np.uint8:
                flat[at:at + span] = r.integers(0, 256, span, dtype=np.int64)
            else:
                flat[at:at + span] = r.standard_normal(span)
        out[k] = a
    return out


def _fp(state) -> dict:
    """Bit-exact fingerprint of a (restored) state tree."""
    out = {}
    for k, v in state.items():
        a = np.asarray(v)
        out[k] = (str(a.dtype), tuple(a.shape), a.tobytes())
    return out


def _injected(err: BaseException) -> bool:
    """True when an exception chain bottoms out in an injected fault."""
    seen = set()
    e: BaseException | None = err
    while e is not None and id(e) not in seen:
        seen.add(id(e))
        if isinstance(e, (faults.InjectedCrash, faults.InjectedIOError,
                          MultiWriterAborted)):
            return True
        e = e.__cause__ or e.__context__
    return False


# ------------------------------------------------------------------- trial ctx
@dataclass
class _Trial:
    cell: str
    rng: random.Random
    root: str                  # local checkpoint directory
    remote: str | None         # level-1 directory (ml cells)
    committed: dict = field(default_factory=dict)   # step -> fingerprint
    # a faulted re-save of step S may legally land either version
    acceptable: dict = field(default_factory=dict)  # step -> [fp, ...]
    fault_desc: str = ""

    def die(self, msg: str):
        raise InvariantViolation(
            f"[{self.cell}] {self.fault_desc}: {msg} (dir kept at "
            f"{self.root})")

    def ok_fps(self, step: int) -> list:
        fps = list(self.acceptable.get(step, ()))
        if step in self.committed:
            fps.append(self.committed[step])
        return fps


def _engine_config(rng: random.Random) -> EngineConfig:
    return EngineConfig(
        backend="posix" if rng.random() < 0.8 else "threadpool",
        strategy=rng.choice(["single_file", "file_per_tensor"]),
        direct=False)


def _mgr_kw(t: _Trial) -> dict:
    kw = dict(engine="aggregated", config=_engine_config(t.rng), keep=2,
              verify_crc=True)
    if "delta" in t.cell:
        kw.update(delta=True, delta_chunk_bytes=_CHUNK)
    return kw


def _fresh_verifier(t: _Trial) -> CheckpointManager:
    """A restarted trainer's view: new manager, runs ``_gc_tmp`` recovery."""
    return CheckpointManager(
        t.root, engine="aggregated",
        config=EngineConfig(backend="posix", direct=False), keep=None)


def _check_restores(t: _Trial, mgr: CheckpointManager, step: int,
                    expect_fps: list) -> None:
    try:
        got = _fp(mgr.restore(step=step))
    except Exception as e:
        t.die(f"restore of committed step {step} failed: {e!r}")
    if got not in expect_fps:
        t.die(f"restore of committed step {step} is not bit-exact")


def _verify_recovery(t: _Trial, pending_step: int | None,
                     pending_fp) -> None:
    """Crash aftermath: fresh manager, I1 + I2, then GC + scrub + re-check."""
    faults.simulate_owner_death(t.root)
    if t.remote is not None:
        faults.simulate_owner_death(t.remote)
    v = _fresh_verifier(t)
    steps = v.all_steps()
    if t.committed:
        last = max(t.committed)
        if last not in steps:
            t.die(f"previously committed step {last} lost (found {steps})")
    if pending_step is not None and pending_step in steps \
            and pending_step not in t.committed:
        # the faulted save actually committed: it must restore bit-exactly
        t.committed[pending_step] = pending_fp
    for s in steps:
        fps = t.ok_fps(s)
        if fps:
            _check_restores(t, v, s, fps)
    # a crash must leave the store GC-convergent and corruption-free
    delta_mod.gc_store(t.root, grace_s=0.0)
    rep = faults.scrub_store(t.root)
    if not rep.clean:
        t.die(f"crash left corrupt store data: {rep.summary()}")
    if t.committed:
        last = max(s for s in steps if s in t.committed) \
            if any(s in t.committed for s in steps) else None
        if last is not None:
            _check_restores(t, v, last, t.ok_fps(last))
    v.close()


# ------------------------------------------------------------- fault schedules
def _pick_fault(rng: random.Random, for_restore: bool = False) -> faults.Fault:
    if for_restore:
        kind = rng.choice(["eio-read", "crash-read", "short-read"])
        if kind == "eio-read":
            return faults.Fault(faults.OP_READ, at=rng.randint(1, 3),
                                action=faults.A_ERRNO, err=_errno.EIO)
        if kind == "crash-read":
            return faults.Fault(faults.OP_READ, at=rng.randint(1, 3),
                                action=faults.A_CRASH)
        return faults.Fault(faults.OP_READ, at=rng.randint(1, 3),
                            action=faults.A_SHORT,
                            frac=rng.choice([0.25, 0.5, 0.75]))
    kind = rng.choice(["crash-write", "crash-fsync", "crash-rename",
                       "crash-fallocate", "torn-write", "short-write",
                       "enospc-write", "eio-write", "eio-rename",
                       "enospc-fallocate"])
    at = rng.randint(1, 4)
    if kind == "crash-write":
        return faults.Fault(faults.OP_WRITE, at=at)
    if kind == "crash-fsync":
        return faults.Fault(faults.OP_FSYNC, at=rng.randint(1, 3))
    if kind == "crash-rename":
        return faults.Fault(faults.OP_RENAME, at=rng.randint(1, 2))
    if kind == "crash-fallocate":
        return faults.Fault(faults.OP_FALLOCATE, at=1)
    if kind == "torn-write":
        return faults.Fault(faults.OP_WRITE, at=at, action=faults.A_TORN,
                            frac=rng.choice([0.1, 0.5, 0.9]))
    if kind == "short-write":
        return faults.Fault(faults.OP_WRITE, at=at, action=faults.A_SHORT,
                            frac=rng.choice([0.25, 0.5, 0.75]))
    if kind == "enospc-write":
        return faults.Fault(faults.OP_WRITE, at=at, action=faults.A_ERRNO,
                            err=_errno.ENOSPC)
    if kind == "eio-write":
        return faults.Fault(faults.OP_WRITE, at=at, action=faults.A_ERRNO,
                            err=_errno.EIO)
    if kind == "eio-rename":
        return faults.Fault(faults.OP_RENAME, at=rng.randint(1, 2),
                            action=faults.A_ERRNO, err=_errno.EIO)
    return faults.Fault(faults.OP_FALLOCATE, at=1, action=faults.A_ERRNO,
                        err=_errno.ENOSPC)


def _pick_gather_fault(rng: random.Random) -> faults.Fault:
    """Fault in the dirty-chunk gather window between the fingerprint diff
    and put submission (delta §14): a crash or I/O error mid-gather must
    abort the stream so no manifest ever references never-copied chunks."""
    at = rng.randint(1, 2)
    if rng.random() < 0.5:
        return faults.Fault(faults.OP_GATHER, at=at)
    return faults.Fault(faults.OP_GATHER, at=at,
                        action=faults.A_ERRNO, err=_errno.EIO)


def _fault_kind(f: faults.Fault) -> str:
    return f"{f.action}-{f.op}"


# ------------------------------------------------------------------ trial body
def run_trial(cell: str, rng: random.Random, base_dir: str,
              stats: CampaignStats) -> None:
    """One seeded trial: committed saves, one fault, invariant checks.
    Raises InvariantViolation (keeping the trial dir) on any breakage."""
    root = tempfile.mkdtemp(prefix=f"chaos-{cell}-", dir=base_dir)
    remote = None
    if cell.startswith("ml") or cell.startswith("remote"):
        remote = tempfile.mkdtemp(prefix=f"chaos-{cell}-l1-", dir=base_dir)
    t = _Trial(cell, rng, root, remote)
    # fresh per-trial ring: a violation dumps exactly this trial's spans,
    # fault injections included, next to the kept dir
    owned_tracer = not trace.is_enabled()
    if owned_tracer:
        trace.enable()
    try:
        try:
            if cell.startswith("mw"):
                _trial_multiwriter(t, stats)
            elif cell.startswith("remote"):
                _trial_remote(t, stats)
            else:
                _trial_single(t, stats)
        except InvariantViolation:
            raise                  # keep the dir for forensics
        except Exception as e:
            t.die(f"unexpected trial error: {e!r}")
    except InvariantViolation:
        trace.export_perfetto(os.path.join(root, "trace.json"))
        raise
    finally:
        if owned_tracer:
            trace.disable()
    shutil.rmtree(root, ignore_errors=True)
    if remote is not None:
        shutil.rmtree(remote, ignore_errors=True)


def _record(t: _Trial, stats: CampaignStats, plan: faults.FaultPlan) -> bool:
    fired = bool(plan.fired)
    stats.faults += len(plan.fired)
    if not fired:
        stats.no_fire += 1
    for d in plan.fired:
        stats.by_kind[d.split("#")[0]] += 1
    return fired


def _trial_single(t: _Trial, stats: CampaignStats) -> None:
    rng = t.rng
    ml = t.cell.startswith("ml")
    kw = _mgr_kw(t)
    if ml:
        mgr = MultiLevelCheckpointer(t.root, t.remote, flush_workers=2, **kw)
        base = mgr.local
    else:
        mgr = CheckpointManager(t.root, async_save=rng.random() < 0.3, **kw)
        base = mgr
    base.delta_gc_grace_s = 0.0

    state = _make_state(rng)
    if t.cell == "delta-gather":
        # hold one tensor on device: exercises the on-device fingerprint +
        # D2H dirty-span gather path instead of free host views
        import jax.numpy as jnp
        state["w"] = jnp.asarray(state["w"])
    step = rng.randint(1, 5)
    for _ in range(rng.randint(1, 2)):
        mgr.save(step, state)
        mgr.wait()
        t.committed[step] = _fp(state)
        state = _mutate(state, rng)
        step += rng.randint(1, 3)

    if t.cell == "delta-gather":
        scenario = rng.choice(["save", "save", "resave"])
    else:
        scenario = rng.choice(["save", "save", "save", "resave", "restore",
                               "corrupt", "corrupt"]
                              + (["flush"] if ml else []))
    if scenario == "resave":
        step = max(t.committed)        # overwrite: the displaced-aside window
    pending_fp = _fp(state)

    if scenario == "corrupt":
        mgr.close()
        _trial_corruption(t, stats)
        return

    fault = (_pick_gather_fault(rng) if t.cell == "delta-gather"
             else _pick_fault(rng, for_restore=(scenario == "restore")))
    t.fault_desc = fault.describe()
    plan = faults.FaultPlan([fault])
    err: BaseException | None = None
    try:
        with faults.inject(plan):
            if scenario == "restore":
                got = _fp(mgr.restore(step=max(t.committed)))
                if got != t.committed[max(t.committed)]:
                    t.die("restore under fault returned wrong bytes "
                          "instead of failing")
            elif scenario == "flush":
                # fault lands in the level-1 flush of a NEW step: level 0
                # commits first, so the local step must survive the fault
                mgr.save(step, state)
                mgr.wait()
            else:
                mgr.save(step, state)
                mgr.wait()
    except Exception as e:
        err = e
    fired = _record(t, stats, plan)
    if err is not None and not _injected(err):
        t.die(f"fault surfaced as unexpected error: {err!r}")
    if err is not None and not fired:
        t.die(f"error raised but no fault fired: {err!r}")

    if scenario == "restore":
        # the manager must be fully usable after a failed restore: no leaked
        # budget/buffers, and both a retry restore and the next save work
        if base.engine.pool.outstanding_bytes:
            t.die(f"read-stream abort leaked "
                  f"{base.engine.pool.outstanding_bytes} pooled bytes")
        _check_restores(t, base, max(t.committed),
                        t.ok_fps(max(t.committed)))
        mgr.save(step, state)
        mgr.wait()
        t.committed[step] = pending_fp
        _check_restores(t, base, step, [pending_fp])
        mgr.close()
        return

    if scenario == "resave":
        t.acceptable.setdefault(step, []).append(pending_fp)
    if err is None:
        # fault did not break the op (no-fire, short write, or post-commit
        # crash point): the step is committed and must restore bit-exactly
        t.committed[step] = pending_fp
        t.acceptable.pop(step, None)
    if scenario == "flush" and err is not None:
        # the fault may have hit the level-0 save rather than the flush
        # (both run inside the armed window); only when the step committed
        # locally must a flush retry converge and publish it at level 1
        if step in base.all_steps():
            t.committed[step] = pending_fp
            mgr.flush_to_remote(step)
            if not os.path.exists(os.path.join(
                    t.remote, f"step_{step:08d}", MANIFEST_NAME)):
                t.die("flush retry did not publish the step at level 1")
    crashed = err is not None and any(
        isinstance(e, faults.InjectedCrash) for e in _chain(err))
    if err is not None and not crashed:
        # errno faults are survivable failures: the SAME manager must accept
        # the next save (no wedged budget/engine state)
        state2 = _mutate(state, rng)
        step2 = step + 1
        mgr.save(step2, state2)
        mgr.wait()
        t.committed[step2] = _fp(state2)
    try:
        mgr.close()
    except Exception:
        pass               # a crashed manager may not close cleanly
    _verify_recovery(t, step if err is not None else None, pending_fp)


def _chain(err: BaseException):
    seen = set()
    e: BaseException | None = err
    while e is not None and id(e) not in seen:
        seen.add(id(e))
        yield e
        e = e.__cause__ or e.__context__


def _trial_corruption(t: _Trial, stats: CampaignStats) -> None:
    """Post-commit damage: detection (scrub / typed errors), never silence."""
    rng = t.rng
    last = max(t.committed)
    step_dir = os.path.join(t.root, f"step_{last:08d}")
    choices = ["manifest-zero", "manifest-trunc", "manifest-flip"]
    if "delta" in t.cell:
        choices += ["chunk-flip", "chunk-flip"]
    else:
        choices += ["data-flip"]
    mode = rng.choice(choices)
    t.fault_desc = f"corrupt:{mode}"
    stats.faults += 1
    stats.by_kind[f"corrupt:{mode.split('-')[1]}"] += 1

    if mode == "chunk-flip":
        hit = faults.corrupt_store_chunk(t.root, rng)
        if hit is None:
            stats.faults -= 1
            stats.no_fire += 1
            return
        rel, _off = hit
        rep = faults.scrub_store(
            t.root, remote_root=t.remote if t.remote else None)
        if rel not in rep.corrupt:
            t.die(f"scrub missed injected corruption in {rel}")
        v = _fresh_verifier(t)
        if t.remote is not None:
            if rel not in rep.repaired:
                t.die(f"scrub did not repair {rel} from level 1")
            for s in v.all_steps():
                if s in t.committed:
                    _check_restores(t, v, s, t.ok_fps(s))
        else:
            if rel not in rep.quarantined:
                t.die(f"scrub did not quarantine {rel}")
            try:
                got = _fp(v.restore())
                if got not in [t.committed[s] for s in t.committed]:
                    t.die("restore silently returned wrong bytes after "
                          "quarantine")
            except faults.QuarantinedChunkError:
                pass       # typed failure naming the chunk: acceptable
            except ManifestError:
                pass       # every kept step depended on the chunk
        v.close()
        return

    if mode == "data-flip":
        # flip one byte inside a referenced data extent of the latest step
        try:
            from .manifest import Manifest
            m = Manifest.load(step_dir)
        except ManifestError:
            return
        exts = [sh for rec in m.tensors.values() for sh in rec.shards
                if getattr(sh, "kind", "extent") == "extent"
                and not sh.path.startswith(delta_mod.STORE_PREFIX)]
        if not exts:
            stats.faults -= 1
            stats.no_fire += 1
            return
        sh = exts[rng.randrange(len(exts))]
        faults.flip_byte(os.path.join(step_dir, sh.path),
                         sh.offset + rng.randrange(max(sh.nbytes, 1)))
        v = _fresh_verifier(t)
        try:
            got = _fp(v.restore(step=last))
            if got == t.committed[last]:
                t.die("bit-flip in a referenced extent went undetected "
                      "(restore returned the pre-flip bytes?)")
            t.die("restore silently returned corrupt bytes (no CRC error)")
        except (ChecksumError, ManifestError):
            pass           # typed detection: the invariant
        v.close()
        return

    # manifest damage on the latest step
    mpath = os.path.join(step_dir, MANIFEST_NAME)
    if mode == "manifest-zero":
        faults.zero_file(mpath)
    elif mode == "manifest-trunc":
        faults.truncate_file(mpath, rng.randrange(
            max(os.path.getsize(mpath) // 2, 1)))
    else:
        faults.flip_byte(mpath, rng.randrange(os.path.getsize(mpath)))
    v = _fresh_verifier(t)
    try:
        v.restore(step=last)
        if mode != "manifest-flip":
            t.die("restore of a zeroed/truncated manifest succeeded")
        # a single bit-flip inside a JSON string can remain parseable; the
        # restore then either succeeds bit-exactly or fails typed below
    except ManifestError:
        pass               # typed: the regression contract (satellite 1)
    except ChecksumError:
        pass               # flipped a crc/offset field: caught downstream
    older = [s for s in t.committed if s != last]
    if older and mode in ("manifest-zero", "manifest-trunc"):
        # latest-step fallback: restore() must skip the corrupt manifest
        got = _fp(v.restore())
        if got != t.committed[max(older)]:
            t.die("latest-step fallback did not restore the previous step "
                  "bit-exactly")
    v.close()


def _trial_multiwriter(t: _Trial, stats: CampaignStats) -> None:
    rng = t.rng
    kw = _mgr_kw(t)
    kw["config"] = EngineConfig(
        backend="posix" if rng.random() < 0.8 else "threadpool",
        strategy="single_file", direct=False)
    w = MultiWriterCheckpointer(t.root, 2, **kw)
    for m in w.managers:
        m.delta_gc_grace_s = 0.0

    state = _make_state(rng)
    step = rng.randint(1, 5)
    for _ in range(rng.randint(1, 2)):
        w.save(step, state)
        t.committed[step] = _fp(state)
        state = _mutate(state, rng)
        step += rng.randint(1, 3)

    resave = rng.random() < 0.25
    if resave:
        step = max(t.committed)
    pending_fp = _fp(state)
    fault = _pick_fault(rng)
    t.fault_desc = fault.describe()
    plan = faults.FaultPlan([fault])
    err: BaseException | None = None
    try:
        with faults.inject(plan):
            w.save(step, state)
    except Exception as e:
        err = e
    fired = _record(t, stats, plan)
    if err is not None and not _injected(err):
        t.die(f"fault surfaced as unexpected error: {err!r}")
    if err is not None and not fired:
        t.die(f"error raised but no fault fired: {err!r}")
    if resave:
        t.acceptable.setdefault(step, []).append(pending_fp)
    if err is None:
        t.committed[step] = pending_fp
        t.acceptable.pop(step, None)
    else:
        # a failed group save must leave the group usable: the next save
        # (same writer set, fresh step) commits and restores
        step2 = max(max(t.committed), step) + 1
        state2 = _mutate(state, rng)
        w.save(step2, state2)
        t.committed[step2] = _fp(state2)
        got = _fp(w.restore(step=step2))
        if got != t.committed[step2]:
            t.die("post-fault group save did not restore bit-exactly")
    try:
        w.close()
    except Exception:
        pass
    _verify_recovery(t, step if err is not None else None, pending_fp)


def _pick_remote_fault(rng: random.Random, *, upload: bool) -> faults.Fault:
    """Object-tier faults (§15): on uploads a crash/errno/torn PUT must
    never publish the step (manifest-last), a stalled PUT just slows it;
    on ranged reads stalls must be masked by hedging, short ranges by the
    remainder re-request, and crash/errno must surface typed."""
    if upload:
        kind = rng.choice(["crash", "crash", "errno", "torn", "stall"])
        at = rng.randint(1, 3)
        if kind == "crash":
            return faults.Fault(faults.OP_RPUT, at=at)
        if kind == "errno":
            return faults.Fault(faults.OP_RPUT, at=at,
                                action=faults.A_ERRNO, err=_errno.EIO)
        if kind == "torn":
            return faults.Fault(faults.OP_RPUT, at=at, action=faults.A_TORN,
                                frac=rng.choice([0.1, 0.5, 0.9]))
        return faults.Fault(faults.OP_RPUT, at=at, action=faults.A_STALL,
                            delay_s=0.05)
    kind = rng.choice(["stall", "stall", "short", "short", "errno", "crash"])
    at = rng.randint(1, 4)
    if kind == "stall":
        return faults.Fault(faults.OP_RGET, at=at, action=faults.A_STALL,
                            delay_s=0.15)
    if kind == "short":
        return faults.Fault(faults.OP_RGET, at=at, action=faults.A_SHORT,
                            frac=rng.choice([0.25, 0.5, 0.75]))
    if kind == "errno":
        return faults.Fault(faults.OP_RGET, at=at,
                            action=faults.A_ERRNO, err=_errno.EIO)
    return faults.Fault(faults.OP_RGET, at=at)


def _remote_verifier(t: _Trial, store, cfg, mode: str):
    """A fresh trainer on a NEW machine: empty local dir, so every restore
    must come over the remote tier (stream or promote)."""
    from .remote import RemoteCheckpointer
    vdir = tempfile.mkdtemp(prefix="chaos-rverify-", dir=t.remote)
    return RemoteCheckpointer(
        vdir, store, remote=cfg, upload_async=False, restore_mode=mode,
        engine="aggregated",
        config=EngineConfig(backend="posix", direct=False),
        keep=None, verify_crc=True)


def _verify_remote(t: _Trial, store, cfg, mode: str) -> None:
    """I1 at level 2: every step whose remote manifest object exists
    restores bit-exactly on a fresh machine."""
    v = _remote_verifier(t, store, cfg, mode)
    for s in v.tier.committed_steps():
        if s in t.committed:
            try:
                got = _fp(v.restore(step=s))
            except Exception as e:
                t.die(f"remote restore of published step {s} failed: {e!r}")
            if got not in t.ok_fps(s):
                t.die(f"remote restore of published step {s} is not "
                      f"bit-exact")
    v.close()


def _trial_remote(t: _Trial, stats: CampaignStats) -> None:
    """Level-2 object-tier trials: faulted uploads (crash mid-upload must
    leave the step unpublished and a retry must converge via dedup),
    faulted ranged restores (stall/short masked, crash/errno typed and
    retryable), and remote object corruption (typed detection)."""
    from .remote import RemoteCheckpointer, RemoteConfig, SimObjectStore
    rng = t.rng
    cfg = RemoteConfig(range_bytes=4096, window=4, hedge_after_s=0.02,
                       min_bw_bytes_s=1e12, retry_backoff_s=0.001,
                       put_workers=rng.choice([1, 4]))
    store = SimObjectStore(os.path.join(t.remote, "bucket"))
    mode = rng.choice(["stream", "stream", "promote"])
    mgr = RemoteCheckpointer(t.root, store, remote=cfg, upload_async=False,
                             restore_mode=mode, **_mgr_kw(t))
    mgr.local.delta_gc_grace_s = 0.0

    state = _make_state(rng)
    step = rng.randint(1, 5)
    for _ in range(rng.randint(1, 2)):
        mgr.save(step, state)
        t.committed[step] = _fp(state)
        state = _mutate(state, rng)
        step += rng.randint(1, 3)

    scenario = rng.choice(["upload", "upload", "restore", "restore",
                           "restore", "corrupt"])

    if scenario == "corrupt":
        mgr.close()
        _trial_remote_corruption(t, stats, store, cfg, mode)
        return

    if scenario == "upload":
        fault = _pick_remote_fault(rng, upload=True)
        t.fault_desc = fault.describe()
        plan = faults.FaultPlan([fault])
        pending_fp = _fp(state)
        err: BaseException | None = None
        try:
            with faults.inject(plan):
                mgr.save(step, state)
        except Exception as e:
            err = e
        fired = _record(t, stats, plan)
        if err is not None and not _injected(err):
            t.die(f"fault surfaced as unexpected error: {err!r}")
        if err is not None and not fired:
            t.die(f"error raised but no fault fired: {err!r}")
        published = set(mgr.tier.committed_steps())
        if err is not None:
            # manifest-last: a failed upload must never have published the
            # step (no remote manifest may reference un-uploaded objects)
            if step in published:
                t.die("crashed upload published the step's manifest")
            # the step DID commit locally; a plain upload retry must
            # converge, deduping whatever the failed attempt shipped
            mgr.tier.upload_step(t.root, step)
            if step not in mgr.tier.committed_steps():
                t.die("upload retry after fault did not publish the step")
        t.committed[step] = pending_fp
        mgr.close()
        _verify_remote(t, store, cfg, mode)
        return

    # restore scenario: fault the ranged reads of a fresh-machine restore
    mgr.close()
    fault = _pick_remote_fault(rng, upload=False)
    t.fault_desc = fault.describe()
    plan = faults.FaultPlan([fault])
    last = max(t.committed)
    v = _remote_verifier(t, store, cfg, mode)
    err = None
    try:
        with faults.inject(plan):
            got = _fp(v.restore(step=last))
            if got != t.committed[last]:
                t.die("remote restore under fault returned wrong bytes "
                      "instead of failing")
    except Exception as e:
        err = e
    fired = _record(t, stats, plan)
    if err is not None and not _injected(err):
        t.die(f"fault surfaced as unexpected error: {err!r}")
    if err is not None and not fired:
        t.die(f"error raised but no fault fired: {err!r}")
    if fired and err is not None \
            and fault.action in (faults.A_STALL, faults.A_SHORT):
        # stalls are masked by hedged re-issue, short ranges by the
        # remainder re-request: neither may surface as a failure
        t.die(f"masked fault surfaced as error: {err!r}")
    # failed or not, a retry on the same verifier must restore bit-exactly
    try:
        got = _fp(v.restore(step=last))
    except Exception as e:
        t.die(f"retry restore after remote fault failed: {e!r}")
    if got != t.committed[last]:
        t.die("retry restore after remote fault is not bit-exact")
    v.close()


def _trial_remote_corruption(t: _Trial, stats: CampaignStats, store, cfg,
                             mode: str) -> None:
    """Damage a published remote object in place: restore on a fresh
    machine must fail typed (ManifestError / ChecksumError / RemoteError),
    never silently return wrong bytes; undamaged steps stay restorable."""
    from .remote import RemoteError, join_key
    rng = t.rng
    last = max(t.committed)
    step_key = f"step_{last:08d}"
    mkey = join_key(step_key, MANIFEST_NAME)
    choices = ["manifest-trunc", "manifest-zero"]
    if "delta" not in t.cell:
        choices.append("data-flip")
    kind = rng.choice(choices)
    t.fault_desc = f"corrupt:remote-{kind}"
    stats.faults += 1
    stats.by_kind[f"corrupt:remote-{kind.split('-')[0]}"] += 1

    if kind == "data-flip":
        # flip one byte inside a REFERENCED extent (a flip in alignment
        # padding would legitimately restore bit-exactly)
        from .manifest import Manifest
        m = Manifest.loads(store.get(mkey))
        exts = [sh for rec in m.tensors.values() for sh in rec.shards
                if getattr(sh, "kind", "extent") == "extent"
                and not sh.path.startswith(delta_mod.STORE_PREFIX)]
        if not exts:
            stats.faults -= 1
            stats.no_fire += 1
            return
        sh = exts[rng.randrange(len(exts))]
        path = store.backing_path(join_key(step_key, sh.path))
        faults.flip_byte(path, sh.offset + rng.randrange(max(sh.nbytes, 1)))
    elif kind == "manifest-zero":
        faults.zero_file(store.backing_path(mkey))
    else:
        path = store.backing_path(mkey)
        faults.truncate_file(path, rng.randrange(
            max(os.path.getsize(path) // 2, 1)))

    v = _remote_verifier(t, store, cfg, mode)
    try:
        got = _fp(v.restore(step=last))
        if got == t.committed[last]:
            t.die("remote corruption went undetected (restore returned "
                  "the pre-damage bytes?)")
        t.die("restore silently returned corrupt remote bytes")
    except (ManifestError, ChecksumError, RemoteError):
        pass               # typed detection: the invariant
    # other published steps are untouched and must still restore
    for s in v.tier.committed_steps():
        if s != last and s in t.committed:
            try:
                got = _fp(v.restore(step=s))
            except Exception as e:
                t.die(f"undamaged remote step {s} failed to restore: {e!r}")
            if got not in t.ok_fps(s):
                t.die(f"undamaged remote step {s} is not bit-exact")
    v.close()


# -------------------------------------------------------------------- campaign
def run_campaign(seed: int = 0, *, min_faults: int = 200,
                 max_trials: int | None = None,
                 cells: tuple = CELLS, base_dir: str | None = None,
                 only_trial: int | None = None,
                 verbose: bool = False) -> CampaignStats:
    """Run seeded trials round-robin over ``cells`` until ``min_faults``
    faults have fired (or ``max_trials`` trials ran). Deterministic per
    (seed, trial index, cell). Raises ``InvariantViolation`` with a
    reproduction line on the first broken invariant."""
    stats = CampaignStats(seed=seed)
    t0 = trace.clock()
    owned_base = None
    if base_dir is None:
        owned_base = tempfile.mkdtemp(prefix=f"chaos-campaign-{seed}-")
        base_dir = owned_base
    else:
        os.makedirs(base_dir, exist_ok=True)
    cap = max_trials if max_trials is not None else max(min_faults * 4, 64)
    failed = False
    try:
        i = -1
        while stats.faults < min_faults and stats.trials < cap:
            i += 1
            if only_trial is not None and i != only_trial:
                continue
            cell = cells[i % len(cells)]
            rng = random.Random(f"{seed}:{i}:{cell}")
            stats.trials += 1
            stats.by_cell[cell] += 1
            if verbose:
                print(f"  trial {i} [{cell}] ...", flush=True)
            try:
                run_trial(cell, rng, base_dir, stats)
            except InvariantViolation as e:
                failed = True
                raise InvariantViolation(
                    f"{e}\nreproduce: PYTHONPATH=src python -m "
                    f"repro.core.faults --campaign --seed {seed} "
                    f"--only-trial {i} --cells {cell} -v") from e
            if only_trial is not None:
                break
    finally:
        stats.elapsed = trace.clock() - t0
        if owned_base is not None and not failed:
            shutil.rmtree(owned_base, ignore_errors=True)
    return stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.faults",
        description="chaos campaign over the checkpoint stack (DESIGN.md §13)")
    ap.add_argument("--campaign", action="store_true",
                    help="run the seeded campaign (the only mode)")
    ap.add_argument("--seed", default="0",
                    help="campaign seed (int, or 'random')")
    ap.add_argument("--min-faults", type=int, default=200,
                    help="keep running trials until this many faults fired")
    ap.add_argument("--max-trials", type=int, default=None)
    ap.add_argument("--only-trial", type=int, default=None,
                    help="re-run exactly one trial index (reproduction)")
    ap.add_argument("--cells", default=",".join(CELLS),
                    help=f"comma-separated subset of {CELLS}")
    ap.add_argument("--base-dir", default=None)
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)
    if not args.campaign:
        ap.error("nothing to do: pass --campaign")
    seed = (int.from_bytes(os.urandom(4), "little")
            if args.seed == "random" else int(args.seed))
    cells = tuple(c.strip() for c in args.cells.split(",") if c.strip())
    for c in cells:
        if c not in CELLS:
            ap.error(f"unknown cell {c!r} (choose from {CELLS})")
    try:
        stats = run_campaign(
            seed, min_faults=args.min_faults, max_trials=args.max_trials,
            cells=cells, base_dir=args.base_dir,
            only_trial=args.only_trial, verbose=args.verbose)
    except InvariantViolation as e:
        print(f"INVARIANT VIOLATION\n{e}")
        return 1
    print(stats.summary())
    return 0
