"""Multi-level checkpointing: local-first capture, async PFS flush, hedged
straggler mitigation.

Traditional HPC C/R frameworks (VELOC/FTI/SCR, paper §2) pre-coalesce to local
storage before flushing to the PFS. We adopt the same split for the LLM case:

  level 0 — node-local directory (fast, survives process crash, not node loss)
  level 1 — shared/parallel FS directory (slow, survives node loss)

``save`` returns as soon as level 0 committed; the level-1 flush runs in the
background. The flush executes through the tiered transfer engine
(DESIGN.md §8): extents stream through an io_engine backend (uring when the
kernel has it), and slow extents (stragglers — e.g. a contended OST) are
*hedged*: after a deadline a duplicate transfer is issued and the first to
finish wins — bounding the tail without failing the flush. Passing a
``copy_fn`` selects the legacy whole-file path with whole-file hedging.

Restore prefers level 0; a step only at level 1 is restored through
``RestorePrefetcher``, which pulls the planned extents into level 0 ahead of
tensor materialization and commits the step locally when fully covered.

``delta=True`` flushes only the store chunks a step actually references
(never re-flushing residents); the fp128 digest kind (DESIGN.md §14)
rides inside the manifest's chunk entries, so level-1 mirrors verify and
repair with the same digest the scrubber uses locally.
"""

from __future__ import annotations

import glob
import os
import shutil
import threading
import time
from concurrent.futures import ThreadPoolExecutor, wait, FIRST_COMPLETED
from dataclasses import dataclass, field

from . import delta as delta_mod
from . import faults, trace
from .checkpoint import CheckpointManager, replace_dir, step_dir_name
from .manifest import Manifest, ManifestError
from .tiered import RestorePrefetcher, TieredTransferEngine


@dataclass
class FlushStats:
    files: int = 0
    bytes: int = 0
    seconds: float = 0.0
    hedged: int = 0          # duplicate transfers issued
    hedge_wins: int = 0      # duplicates that beat the original
    extents: int = 0         # extent-granular segments (tiered path)
    chunks_flushed: int = 0  # delta store files copied to level 1 (§12)
    chunks_skipped: int = 0  # delta store files already resident at level 1
    backend: str = ""        # io_engine backend the flush executed on
    read_gbps: float = 0.0   # source tier (level 0) bandwidth
    write_gbps: float = 0.0  # destination tier (level 1) bandwidth
    per_tier: dict = field(default_factory=dict)  # EngineStats per tier

    @property
    def gbps(self) -> float:
        return self.bytes / self.seconds / 1e9 if self.seconds else 0.0


def _default_copy(src: str, dst: str) -> None:
    tmp = dst + ".tmp"
    with open(src, "rb") as fi, open(tmp, "wb") as fo:
        shutil.copyfileobj(fi, fo, length=8 << 20)
        fo.flush()
        faults.fsync(fo.fileno())
    faults.replace(tmp, dst)


class MultiLevelCheckpointer:
    """CheckpointManager wrapper adding a second (remote) persistence level."""

    def __init__(self, local_dir: str, remote_dir: str, *,
                 engine: str = "aggregated", config=None,
                 hedge_after_s: float = 5.0, min_bw_bytes_s: float = 50e6,
                 flush_workers: int = 4, copy_fn=None,
                 transfer_backend: str = "auto", direct: bool = False,
                 chunk_bytes: int = 4 << 20, transfer=None,
                 stage_inflight_bytes: int | None = None, **mgr_kw):
        """``copy_fn=None`` (default) flushes through the tiered transfer
        engine; a callable selects the legacy per-file copy path with
        whole-file hedging. ``transfer`` injects a preconfigured
        TieredTransferEngine (tests, shared pools).
        ``stage_inflight_bytes`` caps the flush's staged bytes in flight —
        the same backpressure primitive the in-training SnapshotPipeline
        uses, so both capture and tier flush stage through one bounded
        pooled-buffer flow."""
        self.local = CheckpointManager(local_dir, engine=engine,
                                       config=config, **mgr_kw)
        self.remote_dir = os.path.abspath(remote_dir)
        os.makedirs(self.remote_dir, exist_ok=True)
        self.hedge_after_s = hedge_after_s
        self.min_bw_bytes_s = min_bw_bytes_s
        self.copy_fn = copy_fn
        self.transfer = transfer or TieredTransferEngine(
            transfer_backend, chunk_bytes=chunk_bytes, direct=direct,
            queue_depth=flush_workers * 4, hedge_after_s=hedge_after_s,
            min_bw_bytes_s=min_bw_bytes_s,
            inflight_bytes=stage_inflight_bytes)
        # restore-side: steps only at level 1 are prefetched extent-wise
        self.local.prefetcher = RestorePrefetcher(self.remote_dir,
                                                  self.transfer)
        self._pool = ThreadPoolExecutor(max_workers=flush_workers,
                                        thread_name_prefix="flush")
        self._flush_thread: threading.Thread | None = None
        self._flush_error: BaseException | None = None
        self.last_flush_stats = FlushStats()

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, **kw):
        self.wait()
        metrics = self.local.save(step, state, **kw)   # level 0
        self.local.wait()
        th = threading.Thread(target=self._flush_guarded, args=(step,),
                              daemon=True, name=f"l1-flush-{step}")
        self._flush_thread = th
        th.start()
        return metrics

    def _flush_guarded(self, step: int) -> None:
        try:
            self.flush_to_remote(step)
        except BaseException as e:
            self._flush_error = e

    def flush_to_remote(self, step: int) -> FlushStats:
        """Copy a committed local step dir to the remote level, hedged."""
        with trace.span("flush.level1", tier="level1",
                        attrs={"step": step}):
            return self._flush_to_remote_traced(step)

    def _flush_to_remote_traced(self, step: int) -> FlushStats:
        stats = FlushStats()
        t0 = trace.clock()
        src_dir = os.path.join(self.local.directory, step_dir_name(step))
        dst_tmp = os.path.join(self.remote_dir,
                               f"{step_dir_name(step)}.tmp-flush")
        dst_fin = os.path.join(self.remote_dir, step_dir_name(step))
        faults.rmtree(dst_tmp, ignore_errors=True)

        files = []
        for root, _dirs, names in os.walk(src_dir):
            for n in names:
                full = os.path.join(root, n)
                rel = os.path.relpath(full, src_dir)
                files.append((full, rel, os.path.getsize(full)))
        # manifest last: its presence defines validity at level 1 too
        files.sort(key=lambda f: (f[1] == "manifest.json", f[1]))

        # delta composition (§12): chunkstore files the step references must
        # be resident at level 1 BEFORE the step publishes there — but a
        # chunk already flushed by an earlier step is never moved again
        # (that is most of the point of delta: clean bytes cross no tier).
        # Copies land under unique .tmp names and are renamed in, so a
        # crashed flush can never leave a full-sized-but-partial chunk file
        # that a later flush would wrongly skip.
        store_pairs: list[tuple[str, str, str]] = []   # (src, tmp, final)
        store_rels = self._store_files(src_dir)
        for rel in store_rels:
            local = os.path.join(self.local.directory,
                                 delta_mod.CHUNKSTORE_DIR, rel)
            remote = os.path.join(self.remote_dir,
                                  delta_mod.CHUNKSTORE_DIR, rel)
            if (os.path.exists(remote)
                    and os.path.getsize(remote) == os.path.getsize(local)):
                stats.chunks_skipped += 1
                continue
            # reap tmp copies a crashed earlier flush stranded (no manager
            # ever GCs the remote tier); age-guarded so a concurrent
            # flusher's live tmp is left alone
            for stale in glob.glob(f"{remote}.tmp-flush-*"):
                try:
                    # crlint: allow(CRL006): mtime age check is wall-clock
                    if time.time() - os.path.getmtime(stale) > 300.0:
                        os.remove(stale)
                except OSError:
                    pass
            store_pairs.append(
                (local, f"{remote}.tmp-flush-{os.getpid()}", remote))
            stats.chunks_flushed += 1

        if self.copy_fn is not None:
            # legacy path: one copy_fn call per file, whole-file hedging
            for src, tmp, _fin in store_pairs:
                os.makedirs(os.path.dirname(tmp), exist_ok=True)
                size = os.path.getsize(src)
                self._copy_hedged(src, tmp, size, stats)
                stats.files += 1
                stats.bytes += size
            for src, rel, size in files:
                dst = os.path.join(dst_tmp, rel)
                os.makedirs(os.path.dirname(dst), exist_ok=True)
                self._copy_hedged(src, dst, size, stats)
                stats.files += 1
                stats.bytes += size
        else:
            # tiered path: extent streams through an io_engine backend
            pairs = [(src, tmp) for src, tmp, _fin in store_pairs]
            pairs += [(src, os.path.join(dst_tmp, rel))
                      for src, rel, _size in files]
            ts = self.transfer.transfer(pairs)
            stats.files = ts.files
            stats.bytes = ts.bytes
            stats.extents = ts.extents
            stats.hedged = ts.hedged
            stats.hedge_wins = ts.hedge_wins
            stats.backend = ts.backend
            stats.per_tier = ts.per_tier()
        for _src, tmp, fin in store_pairs:
            # crlint: allow(CRL002): pack bytes were fsync'd by the transfer
            # engine (or _copy_hedged) before the rename; dir sync is below
            faults.replace(tmp, fin)
        # chunk renames must be dir-durable BEFORE the step publishes at
        # level 1: a step whose manifest is visible but whose chunk entries
        # evaporated in a crash would restore torn (gap found by CRL002)
        for d in sorted({os.path.dirname(fin) for _s, _t, fin in store_pairs}):
            dfd = os.open(d, os.O_RDONLY)
            try:
                faults.fsync(dfd)
            finally:
                os.close(dfd)
        # the shared displaced-aside publish: a re-flush of an existing
        # remote step never leaves a window where the previous copy is gone
        # before the new one landed
        replace_dir(dst_tmp, dst_fin)
        stats.seconds = trace.clock() - t0
        if stats.seconds:
            stats.read_gbps = (stats.per_tier.get("source", {})
                               .get("bytes_read", 0) / stats.seconds / 1e9)
            stats.write_gbps = (stats.per_tier.get("destination", {})
                                .get("bytes_written", 0) / stats.seconds / 1e9)
        self.last_flush_stats = stats
        return stats

    @staticmethod
    def _store_files(src_dir: str) -> list[str]:
        """Store-relative chunkstore files the committed step references."""
        try:
            manifest = Manifest.load(src_dir)
        except ManifestError:
            return []
        return sorted(set(delta_mod.manifest_store_paths(manifest)))

    def _copy_hedged(self, src: str, dst: str, size: int,
                     stats: FlushStats) -> None:
        deadline = max(self.hedge_after_s, size / self.min_bw_bytes_s)
        attempts = {self._pool.submit(self.copy_fn, src, dst): "primary"}
        hedged = False
        while True:
            done, pending = wait(list(attempts), timeout=deadline,
                                 return_when=FIRST_COMPLETED)
            if done:
                winner = next(iter(done))
                err = winner.exception()
                if err is None:
                    if attempts[winner] == "hedge":
                        stats.hedge_wins += 1
                        faults.replace(dst + ".hedge", dst)
                    return
                del attempts[winner]
                if not attempts:  # all attempts failed
                    raise err
            elif not hedged:
                hedged = True
                stats.hedged += 1
                attempts[self._pool.submit(self.copy_fn, src,
                                           dst + ".hedge")] = "hedge"
                # a winning hedge is moved into place
                deadline = None
            if hedged and os.path.exists(dst + ".hedge"):
                faults.replace(dst + ".hedge", dst)
                return

    # --------------------------------------------------------------- restore
    def restore(self, state_template=None, *, step: int | None = None, **kw):
        """Prefer level 0; fall back to level 1 (node-loss recovery)."""
        self.wait()
        local_steps = self.local.all_steps()
        if step is None:
            remote_steps = self._remote_steps()
            all_steps = sorted(set(local_steps) | set(remote_steps))
            if not all_steps:
                raise FileNotFoundError("no checkpoints at any level")
            step = all_steps[-1]
        if step in local_steps:
            return self.local.restore(state_template, step=step, **kw)
        # level-1 only: the local manager's RestorePrefetcher stages the
        # manifest, then pulls exactly the planned extents ahead of tensor
        # materialization; full coverage commits the step at level 0
        src = os.path.join(self.remote_dir, step_dir_name(step))
        if not Manifest.exists(src):
            raise FileNotFoundError(f"step {step} not committed at level 1")
        return self.local.restore(state_template, step=step, **kw)

    def _remote_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.remote_dir):
            if name.startswith("step_") and ".tmp" not in name and \
                    Manifest.exists(os.path.join(self.remote_dir, name)):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    @property
    def last_restore_metrics(self):
        """Restore attribution of the local manager (restores always run
        there — level-1-only steps are prefetched into it first)."""
        return self.local.last_restore_metrics

    def wait_snapshotted(self) -> None:
        """Barrier on the local manager's staged snapshot (see
        CheckpointManager.wait_snapshotted); the level-1 flush keeps going."""
        self.local.wait_snapshotted()

    def wait(self) -> None:
        th = self._flush_thread
        if th is not None:
            th.join()
            self._flush_thread = None
        if self._flush_error is not None:
            err, self._flush_error = self._flush_error, None
            raise RuntimeError("level-1 flush failed") from err

    def close(self) -> None:
        self.wait()
        self._pool.shutdown(wait=True)
        self.transfer.close()
        self.local.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
