"""Content-addressed delta checkpointing (DESIGN.md §12).

Every save in this repo used to persist the full byte image of every tensor
at every step. When optimizer slots, frozen layers, embeddings, or quantized
weights change sparsely between steps, most of those bytes are identical to
the previous step — the paper's *volume* axis multiplied by training length
for no information gain. This module decouples *what state is* from *which
bytes must move* (ByteCheckpoint's decomposition, DataStates-LLM's
composable state providers):

  chunking        every tensor shard's snapshot payload is split into fixed,
                  alignment-friendly extents and hashed (blake2b-128) on the
                  pipeline worker — never on the training loop's blocking
                  path,
  dirty detection the hashes are diffed against the previous step's chunk
                  index (recovered from the prior manifest's chunk entries);
                  only dirty chunks are declared and submitted through the
                  existing streaming save path (``CREngine.begin_save/put``),
                  so they ride the same coalescing/backpressure machinery as
                  a full save,
  chunk store     at publish, the step's freshly written data files are
                  renamed into ``<root>/chunkstore/packs/<step>-<uuid>/`` and
                  the manifest's chunk references rewritten to
                  ``../chunkstore/...`` paths — resolvable from ANY step
                  directory by the unchanged engine path join. Clean chunks
                  are recorded as references into packs written by earlier
                  steps,
  retention GC    ``CheckpointManager._gc_old`` becomes refcount-aware: a
                  store file is deleted only when no kept step (and no live
                  in-flight save's staged manifest) references it. Refcounts
                  are recomputed from manifests on every pass — no mutable
                  counter files to corrupt, so the GC is crash-safe and
                  self-healing; packs younger than a grace period are never
                  reaped (they may belong to a publish in flight).

Restore resolves chunk references back through the streaming read path
(``begin_restore/get``) with per-chunk CRCs verified in-stream, reassembles
each shard payload in order, and verifies the whole-payload CRC —
bit-exactly equal to a full-save restore.
"""

from __future__ import annotations

import hashlib
import os
import posixpath
import threading
import time
import uuid
import zlib
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field, replace

import numpy as np

from . import faults, trace
from .engines import SaveSpec
from .engines.base import as_u8
from .manifest import (CHUNK_KIND, DIGEST_BLAKE2B, DIGEST_FP128, ChunkRef,
                       Manifest, ManifestError, MANIFEST_NAME, ShardEntry,
                       _RANK_MANIFEST_RE)
from .pipeline import PendingPut

CHUNKSTORE_DIR = "chunkstore"
PACK_SUBDIR = "packs"
# how store-resident refs appear relative to a step directory: one level up,
# into the checkpoint root's store (engines join paths against the step dir)
STORE_PREFIX = "../" + CHUNKSTORE_DIR + "/"
DEFAULT_CHUNK_BYTES = 256 << 10
# store files younger than this are never reaped: they may belong to a
# publish (or a cross-tier fetch) that has not landed its manifest yet
GC_GRACE_S = 300.0

# host-fallback fingerprint thread pool (DESIGN.md §14): per-put digest
# jobs fan out across a few threads — the numpy uint32 matmul releases the
# GIL, so multi-core hosts overlap the per-tensor passes instead of
# serializing them on the one pipeline worker
FP_POOL_WORKERS = min(4, os.cpu_count() or 1)
_fp_pool: ThreadPoolExecutor | None = None
_fp_pool_lock = threading.Lock()


def _host_fp_pool() -> ThreadPoolExecutor:
    global _fp_pool
    if _fp_pool is None:
        with _fp_pool_lock:
            if _fp_pool is None:
                _fp_pool = ThreadPoolExecutor(
                    max_workers=FP_POOL_WORKERS,
                    thread_name_prefix="fp128-host")
    return _fp_pool


def chunk_hash(mv) -> str:
    """Content address of one chunk: blake2b-128 hex digest."""
    return hashlib.blake2b(mv, digest_size=16).hexdigest()


def chunk_spans(nbytes: int, chunk_bytes: int):
    """Fixed chunk grid over a payload: (pos, n) pairs, last one ragged."""
    pos = 0
    while pos < nbytes:
        n = min(chunk_bytes, nbytes - pos)
        yield pos, n
        pos += n


def is_chunked(sh: ShardEntry) -> bool:
    return getattr(sh, "kind", None) == CHUNK_KIND


def reassemble_payload(sh: ShardEntry, fetch, check_chunk=None) -> np.ndarray:
    """Concatenate a chunk-reference shard's chunks back into its payload.

    ``fetch(ref)`` returns each chunk's uint8 bytes in declaration order;
    ``check_chunk(ref, bytes)`` optionally verifies each as it lands. Both
    restore paths (streaming pipeline and monolithic) reassemble through
    this one implementation so they cannot drift apart on chunk ordering
    (test_delta_monolithic_restore_parity guards the equivalence)."""
    payload = np.empty(sh.nbytes, np.uint8)
    pos = 0
    for r in sh.chunks or ():
        b = fetch(r)
        if check_chunk is not None:
            check_chunk(r, b)
        payload[pos:pos + r.nbytes] = b
        pos += r.nbytes
    if pos != sh.nbytes:
        # a parseable manifest whose chunk list lost a trailing ref must
        # fail loudly, not hand back uninitialized tail bytes (the
        # whole-payload CRC would also catch this, but only when CRCs are on)
        raise ManifestError(
            f"chunk refs cover {pos} of {sh.nbytes} payload bytes "
            f"({sh.path!r})")
    return payload


def store_rel(path: str) -> str:
    """Normalize a step-relative store ref to a store-relative path."""
    return posixpath.normpath(path[len(STORE_PREFIX):])


class DeltaIndex:
    """Chunk index of the previous step, recovered from its manifest.

    Keyed by (record_key, shard index window, payload nbytes): a shard whose
    tensor, window, or size changed gets no match and is fully dirty —
    which also makes resharding, chunk-size changes, and delta-over-non-delta
    transitions trivially correct (everything rewrites once). Each entry
    carries its manifest's digest kind; ``lookup`` only matches when the
    caller diffs with the same kind, so a blake2b-keyed index under an
    fp128 planner (or vice versa) degrades to a full write — content
    addresses of different digest functions must never compare equal.
    Only references already resident in the chunkstore are indexed; a fresh
    save must never point at bytes inside a GC-able step directory.
    """

    def __init__(self):
        # key -> (digest kind, chunk refs)
        self._by_shard: dict[tuple, tuple[str, tuple[ChunkRef, ...]]] = {}

    @staticmethod
    def from_manifest(manifest: Manifest | None) -> "DeltaIndex":
        idx = DeltaIndex()
        if manifest is None:
            return idx
        for rec in manifest.tensors.values():
            for sh in rec.shards:
                if not is_chunked(sh) or sh.chunks is None:
                    continue
                if not all(r.path.startswith(STORE_PREFIX)
                           for r in sh.chunks):
                    continue
                idx._by_shard.setdefault(
                    (rec.key, tuple(sh.index), sh.nbytes),
                    (sh.digest_kind, sh.chunks))
        return idx

    def lookup(self, record_key: str, index, nbytes: int, *,
               digest: str = DIGEST_BLAKE2B
               ) -> tuple[ChunkRef, ...] | None:
        e = self._by_shard.get((record_key, tuple(index or ()), nbytes))
        if e is None or e[0] != digest:
            return None
        return e[1]

    def __len__(self) -> int:
        return len(self._by_shard)


@dataclass
class _ShardChunks:
    """One original tensor-shard put, decomposed into chunk references.

    ``refs`` holds, per chunk in payload order, either a ``ChunkRef`` (clean
    — points into the store) or a ``(put_key, hash)`` pair (dirty — resolved
    against the stream manifest after the flush lands)."""
    spec: SaveSpec
    refs: list
    payload_crc: int | None


@dataclass
class DeltaPlan:
    """Output of the fingerprint/diff pass: what to write, how to describe
    it, and where the planning time went (SaveMetrics feeds off the phase
    timers and the D2H ledger)."""
    puts: list[PendingPut] = field(default_factory=list)
    shards: list[_ShardChunks] = field(default_factory=list)
    total_bytes: int = 0       # logical tensor + blob bytes of the state
    dirty_bytes: int = 0       # chunk bytes actually submitted
    blob_bytes: int = 0        # lean-object bytes (always written)
    chunks_total: int = 0
    chunks_dirty: int = 0
    digest_kind: str = DIGEST_BLAKE2B
    fingerprint_seconds: float = 0.0   # phase A: digest every chunk
    diff_seconds: float = 0.0          # phase B: diff + build refs
    d2h_bytes: int = 0         # device bytes that (will) cross to the host

    @property
    def written_bytes(self) -> int:
        return self.dirty_bytes + self.blob_bytes


def quant_write_spans(packed_nbytes: int, chunk_bytes: int,
                      header_bytes: int):
    """Write spans for a quant-packed payload under fp128 digests.

    The fp128 digest domain is ``packed[header_bytes:]`` (the q rows + f32
    scales stream) on the plain ``chunk_spans`` grid: the 20-byte header is
    a pure function of the element count, which is already part of the
    delta index key, so fingerprinting it would only re-dirty chunk 0 of
    every save. The WRITE spans merge the header into the first chunk so
    the refs still concatenate back to the exact packed payload:
    span_0 = packed[0 : header+c], span_j = packed[header + j*c :][:n].
    """
    first = True
    for pos, n in chunk_spans(packed_nbytes - header_bytes, chunk_bytes):
        if first:
            yield 0, n + header_bytes
            first = False
        else:
            yield pos + header_bytes, n


@dataclass
class _FpJob:
    """Phase-A fingerprint result for one tensor put (fp128 planner)."""
    kind: str                     # "host" | "device" | "qhost" | "qdevice"
    spans: list                   # write spans [(pos, n)] in payload order
    digests: np.ndarray | None = None   # (n_chunks, 4) uint32
    future: object = None               # pending host digest job
    payload: np.ndarray | None = None   # host payload (host / qhost)
    flat: object = None                 # device 1-D array (device)
    header: bytes = b""                 # packed header (qdevice)
    qflat: object = None                # device int8 q stream (qdevice)
    scales: object = None               # device f32 scales (qdevice)


def _gather_host(ck: str, chunk: np.ndarray) -> np.ndarray:
    faults.gather(ck)
    return chunk


def _gather_device(ck: str, flat, pos: int, n: int, isz: int) -> np.ndarray:
    """D2H-copy one dirty span of a device array (the only payload bytes
    of a clean-mostly tensor that ever cross the link)."""
    faults.gather(ck)
    with trace.span("gather", tier="device", nbytes=n, attrs={"key": ck}):
        sl = flat[pos // isz:(pos + n) // isz]
        return np.asarray(sl).view(np.uint8)


def _gather_quant_device(ck: str, job: _FpJob, pos: int, n: int
                         ) -> np.ndarray:
    """Assemble one dirty span of a quant-packed payload from its device
    pieces (header is host bytes; q / scales slices are gathered D2H).
    All q/s boundaries here are 4-aligned: chunk boundaries are multiples
    of ``chunk_bytes`` (itself a multiple of 4) in the qs-stream, and the
    q-region size is rows*GROUP_COLS."""
    faults.gather(ck)
    out = np.empty(n, np.uint8)
    hb = len(job.header)
    filled = 0
    if pos < hb:                                  # chunk 0 carries the header
        k = min(hb - pos, n)
        out[:k] = np.frombuffer(job.header, np.uint8)[pos:pos + k]
        filled = k
    a = pos + filled - hb                         # qs-stream byte range
    b = pos + n - hb
    qb = int(job.qflat.shape[0])
    if a < qb and b > a:
        k = min(qb, b) - a
        out[filled:filled + k] = np.asarray(job.qflat[a:a + k]) \
            .view(np.uint8)
        filled += k
        a += k
    if b > qb:
        out[filled:] = np.asarray(
            job.scales[(a - qb) // 4:(b - qb) // 4]).view(np.uint8)
    return out


def _device_digestable(src, chunk_bytes: int) -> bool:
    """Can this put's bytes be fingerprinted where they live?

    Needs a jax.Array whose element size divides the lane width (1/2/4 —
    f64 state falls back to the host pass) and a lane-aligned chunk grid so
    per-chunk digest domains tile the global lane stream."""
    import jax
    if not isinstance(src, jax.Array):
        return False
    dt = np.dtype(src.dtype)
    return (chunk_bytes % 4 == 0 and dt.itemsize in (1, 2, 4)
            and dt.kind not in "bO")


def plan_delta(puts: list[PendingPut], index: DeltaIndex, *,
               chunk_bytes: int = DEFAULT_CHUNK_BYTES,
               checksum: bool = True,
               device_fingerprint: bool = False) -> DeltaPlan:
    """Fingerprint, diff, and re-declare every put as its dirty chunks.

    Runs on the pipeline worker (async saves pay zero blocking time for the
    digest pass). Blob puts (the lean object) pass through unchanged; tensor
    puts are replaced by one put per DIRTY chunk — a clean chunk becomes a
    reference to the previous step's store extent.

    ``device_fingerprint=False`` is the PR-5 path: resolve every payload to
    host bytes and blake2b-hash each chunk — every byte crosses the link
    just to be diffed. ``device_fingerprint=True`` computes fp128 digests
    where the bytes live (Pallas kernel on TPU, one jitted uint32 matmul
    otherwise, the vectorized numpy fallback for host arrays, all
    bit-identical — kernels/fingerprint.py) and D2H-copies only the chunks
    the diff proves dirty, so clean bytes never cross PCIe; quantized puts
    run the fused quantize+fingerprint pass and gather dirty spans of the
    packed stream. The two paths key the delta index with their own digest
    kind, so flipping the flag (or restoring onto an old blake2b index)
    degrades to one full write — never a wrong delta.

    Memory: dirty-chunk puts hold VIEWS of resolved host payloads (free for
    host arrays — they alias the caller's state) or deferred D2H gathers
    for device arrays, which the pipeline worker materializes one chunk at
    a time in staging order.
    """
    if device_fingerprint:
        return _plan_delta_fp128(puts, index, chunk_bytes=chunk_bytes)
    plan = DeltaPlan()
    t0 = trace.clock()
    for p in puts:
        if p.spec.is_blob:
            plan.puts.append(p)
            plan.blob_bytes += p.spec.nbytes
            plan.total_bytes += p.spec.nbytes
            continue
        payload = np.frombuffer(as_u8(p.resolve()), np.uint8)
        if payload.nbytes != p.spec.nbytes:
            raise ValueError(
                f"declared {p.spec.nbytes} bytes for {p.spec.key!r}, "
                f"resolved {payload.nbytes}")
        plan.total_bytes += payload.nbytes
        rkey = p.spec.record_key or p.spec.key
        prior = index.lookup(rkey, p.spec.index, p.spec.nbytes,
                             digest=DIGEST_BLAKE2B)
        crc = 0 if checksum else None
        refs: list = []
        for j, (pos, n) in enumerate(chunk_spans(p.spec.nbytes, chunk_bytes)):
            chunk = payload[pos:pos + n]
            h = chunk_hash(chunk)
            if checksum:
                crc = zlib.crc32(chunk, crc) & 0xFFFFFFFF
            plan.chunks_total += 1
            pr = prior[j] if prior is not None and j < len(prior) else None
            if pr is not None and pr.hash == h and pr.nbytes == n:
                refs.append(pr)                       # clean: reference
                continue
            ck = f"{p.spec.key}.c{j:05d}"
            plan.puts.append(PendingPut(
                SaveSpec(ck, n, "uint8", (n,), ((0, n),), record_key=ck),
                (lambda c=chunk, k=ck: _gather_host(k, c))))
            refs.append((ck, h))                      # dirty: write
            plan.chunks_dirty += 1
            plan.dirty_bytes += n
        plan.shards.append(_ShardChunks(p.spec, refs, crc))
    plan.fingerprint_seconds = trace.clock() - t0
    trace.complete("fingerprint", t0, nbytes=plan.total_bytes,
                   attrs={"chunks": plan.chunks_total})
    return plan


def _plan_delta_fp128(puts: list[PendingPut], index: DeltaIndex, *,
                      chunk_bytes: int) -> DeltaPlan:
    """The device-fingerprint planner (DESIGN.md §14).

    Phase A fingerprints every put where its bytes live — device digests
    via kernels.fingerprint (16 B/chunk crossing D2H), host fallbacks
    fanned across the fp128 thread pool. Phase B diffs the digest tables
    against the previous index and declares one put per dirty chunk whose
    resolve D2H-gathers exactly that span.

    fp128 shard entries carry NO whole-payload CRC: per-chunk CRCs (fresh
    from the write stream for dirty chunks, inherited with the store ref
    for clean ones) already cover every payload byte, and the whole-payload
    pass would re-read on the host the very bytes this path exists to keep
    off it.
    """
    from ..kernels import fingerprint as fpk
    from . import quant_codec
    plan = DeltaPlan(digest_kind=DIGEST_FP128)
    hb = quant_codec.HEADER.size
    t0 = trace.clock()
    jobs: list[_FpJob | None] = []
    pool = _host_fp_pool()
    for p in puts:
        if p.spec.is_blob:
            jobs.append(None)
            continue
        if p.quant and _device_digestable(p.source, chunk_bytes) \
                and np.dtype(p.source.dtype).kind == "f":
            import jax.numpy as jnp
            src = p.source
            n_elems = int(np.prod(src.shape, dtype=np.int64))
            rows = quant_codec.packed_rows(n_elems)
            flat = jnp.ravel(src).astype(jnp.float32)
            padded = jnp.pad(
                flat, (0, rows * quant_codec.GROUP_COLS - n_elems)) \
                .reshape(rows, quant_codec.GROUP_COLS)
            q, s, dig = fpk.quant_fingerprint(padded, chunk_bytes)
            header = quant_codec.HEADER.pack(
                quant_codec.MAGIC, n_elems * 4, rows, quant_codec.GROUP_COLS)
            assert hb + rows * quant_codec.GROUP_COLS + rows * 4 \
                == p.spec.nbytes
            jobs.append(_FpJob(
                "qdevice", list(quant_write_spans(p.spec.nbytes, chunk_bytes,
                                                  hb)),
                digests=dig, header=header, qflat=q.reshape(-1), scales=s))
            plan.d2h_bytes += dig.nbytes
        elif p.quant:
            payload = np.frombuffer(as_u8(p.resolve()), np.uint8)
            _check_resolved(p, payload)
            jobs.append(_FpJob(
                "qhost", list(quant_write_spans(p.spec.nbytes, chunk_bytes,
                                                hb)),
                future=pool.submit(fpk.fingerprint_chunks_host,
                                   payload[hb:], chunk_bytes),
                payload=payload))
        elif _device_digestable(p.source, chunk_bytes) and p.spec.nbytes:
            flat = p.source.reshape(-1)
            dig = fpk.fingerprint_digests(flat, chunk_bytes)
            jobs.append(_FpJob(
                "device", list(chunk_spans(p.spec.nbytes, chunk_bytes)),
                digests=dig, flat=flat))
            plan.d2h_bytes += dig.nbytes
        else:
            payload = np.frombuffer(as_u8(p.resolve()), np.uint8)
            _check_resolved(p, payload)
            jobs.append(_FpJob(
                "host", list(chunk_spans(p.spec.nbytes, chunk_bytes)),
                future=pool.submit(fpk.fingerprint_chunks_host,
                                   payload, chunk_bytes),
                payload=payload))
    for job in jobs:
        if job is not None and job.future is not None:
            job.digests = job.future.result()
            job.future = None
    plan.fingerprint_seconds = trace.clock() - t0
    trace.complete("fingerprint", t0, tier="device",
                   attrs={"puts": len(puts)})

    t1 = trace.clock()
    for p, job in zip(puts, jobs):
        if job is None:                               # blob passthrough
            plan.puts.append(p)
            plan.blob_bytes += p.spec.nbytes
            plan.total_bytes += p.spec.nbytes
            continue
        plan.total_bytes += p.spec.nbytes
        rkey = p.spec.record_key or p.spec.key
        prior = index.lookup(rkey, p.spec.index, p.spec.nbytes,
                             digest=DIGEST_FP128)
        hexes = fpk.digests_hex(job.digests)
        assert len(hexes) == len(job.spans), (p.spec.key, len(hexes),
                                              len(job.spans))
        isz = (np.dtype(p.source.dtype).itemsize
               if job.kind == "device" else 1)
        refs: list = []
        for j, (pos, n) in enumerate(job.spans):
            h = hexes[j]
            plan.chunks_total += 1
            pr = prior[j] if prior is not None and j < len(prior) else None
            if pr is not None and pr.hash == h and pr.nbytes == n:
                refs.append(pr)                       # clean: reference
                continue
            ck = f"{p.spec.key}.c{j:05d}"
            if job.kind == "device":
                resolve = (lambda k=ck, f=job.flat, o=pos, m=n, z=isz:
                           _gather_device(k, f, o, m, z))
                plan.d2h_bytes += n
            elif job.kind == "qdevice":
                resolve = (lambda k=ck, jb=job, o=pos, m=n:
                           _gather_quant_device(k, jb, o, m))
                plan.d2h_bytes += n
            else:
                chunk = job.payload[pos:pos + n]
                resolve = lambda k=ck, c=chunk: _gather_host(k, c)
            plan.puts.append(PendingPut(
                SaveSpec(ck, n, "uint8", (n,), ((0, n),), record_key=ck),
                resolve))
            refs.append((ck, h))                      # dirty: write
            plan.chunks_dirty += 1
            plan.dirty_bytes += n
        plan.shards.append(_ShardChunks(p.spec, refs, None))
    plan.diff_seconds = trace.clock() - t1
    trace.complete("diff", t1, nbytes=plan.dirty_bytes,
                   attrs={"dirty": plan.chunks_dirty,
                          "total": plan.chunks_total})
    return plan


def _check_resolved(p: PendingPut, payload: np.ndarray) -> None:
    if payload.nbytes != p.spec.nbytes:
        raise ValueError(
            f"declared {p.spec.nbytes} bytes for {p.spec.key!r}, "
            f"resolved {payload.nbytes}")


def apply_plan(stream_manifest: Manifest, plan: DeltaPlan) -> Manifest:
    """Fold the flushed stream manifest back into chunked shard entries.

    The stream manifest maps each dirty-chunk put to its file extent; the
    returned manifest replaces those per-chunk records with one
    ``kind="chunks"`` entry per original tensor shard, mixing fresh extents
    (still step-dir-relative — relocated by ``publish_packs``) with the
    plan's clean store references. Blobs and extra metadata ride through.
    """
    out = Manifest(stream_manifest.step, stream_manifest.num_ranks,
                   stream_manifest.strategy)
    out.blobs = stream_manifest.blobs
    out.extra = stream_manifest.extra
    for sc in plan.shards:
        spec = sc.spec
        chunks: list[ChunkRef] = []
        for r in sc.refs:
            if isinstance(r, ChunkRef):
                chunks.append(r)
                continue
            ck, h = r
            ext = stream_manifest.tensors[ck].shards[0]
            chunks.append(ChunkRef(h, ext.path, ext.offset, ext.nbytes,
                                   ext.crc32))
        index = spec.index
        if index is None:
            index = tuple((0, s) for s in (spec.global_shape or ()))
        gshape = (spec.global_shape if spec.global_shape is not None
                  else (spec.nbytes,))
        out.add_shard(
            spec.record_key or spec.key, spec.dtype or "uint8", gshape,
            ShardEntry(tuple(index), f"<chunks:{uuid.uuid4().hex[:12]}>", 0,
                       spec.nbytes, sc.payload_crc, CHUNK_KIND,
                       tuple(chunks),
                       digest=(plan.digest_kind
                               if plan.digest_kind != DIGEST_BLAKE2B
                               else None)))
    return out


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        faults.fsync(fd)
    finally:
        os.close(fd)


def publish_packs(manifest: Manifest, tmp: str, root: str, tag: str) -> bool:
    """Relocate the step's freshly written data files into the chunkstore.
    Returns True when the rewritten manifest was already written into
    ``tmp`` (callers must not redundantly re-serialize it).

    Every file referenced by a step-dir-relative path (fresh dirty chunks
    AND the lean blob — under single-file layouts they share one file) is
    renamed from ``tmp`` into ``<root>/chunkstore/packs/<tag>-<uuid>/`` and
    the manifest rewritten to ``../chunkstore/...`` references, so the bytes
    survive the step directory's eventual ``rmtree``.

    Ordering closes the GC race: the REWRITTEN manifest is written into the
    (pidfile-owned, GC-pinning) staging dir BEFORE any file is renamed into
    the store, so the moment a pack file becomes visible there, a live
    manifest referencing it already exists — a concurrent refcount GC
    (which snapshots its candidate list before computing refs) can never
    see it as an orphan. A crash mid-sequence leaves either a doomed tmp
    dir (reaped by ``_gc_tmp``) or unreferenced store files (reaped after
    the grace period) — never a committed manifest pointing at missing
    bytes, because the commit rename happens strictly after the moves.
    """
    fresh: set[str] = set()
    for rec in manifest.tensors.values():
        for sh in rec.shards:
            if is_chunked(sh) and sh.chunks:
                fresh.update(r.path for r in sh.chunks
                             if not r.path.startswith(STORE_PREFIX))
            elif not is_chunked(sh) and not sh.path.startswith(STORE_PREFIX):
                fresh.add(sh.path)
    fresh.update(b.path for b in manifest.blobs.values()
                 if not b.path.startswith(STORE_PREFIX))
    fresh = {p for p in fresh if os.path.exists(os.path.join(tmp, p))}
    if not fresh:
        return False
    pack = f"{tag}-{uuid.uuid4().hex[:8]}"
    pack_dir = os.path.join(root, CHUNKSTORE_DIR, PACK_SUBDIR, pack)
    moved = {rel: posixpath.join(STORE_PREFIX.rstrip("/"), PACK_SUBDIR,
                                 pack, rel)
             for rel in sorted(fresh)}
    # 1. rewrite references (ShardEntry/ChunkRef are frozen: rebuild)
    for rec in manifest.tensors.values():
        new_shards = []
        for sh in rec.shards:
            if is_chunked(sh) and sh.chunks:
                refs = tuple(
                    replace(r, path=moved[r.path]) if r.path in moved else r
                    for r in sh.chunks)
                sh = replace(sh, chunks=refs)
            elif sh.path in moved:
                sh = replace(sh, path=moved[sh.path])
            new_shards.append(sh)
        rec.shards = new_shards
    for key, b in list(manifest.blobs.items()):
        if b.path in moved:
            manifest.blobs[key] = replace(b, path=moved[b.path])
    # 2. land the rewritten manifest in the pinning tmp dir FIRST: the refs
    # exist on disk before any file they name becomes reapable
    manifest.save(tmp)
    # 3. now move the payload files into the store. A concurrent gc_store
    # prunes EMPTY pack dirs (os.rmdir), so the freshly made dir can vanish
    # between makedirs and replace — retry until the rename lands; once the
    # first file is in, the dir is non-empty and unprunable, so this
    # converges (the retry bound only guards against programming errors).
    dirs_to_sync = set()
    for rel in sorted(fresh):
        src = os.path.join(tmp, rel)
        dst = os.path.join(pack_dir, rel)
        for _ in range(100):
            try:
                os.makedirs(os.path.dirname(dst), exist_ok=True)
                faults.replace(src, dst)
                break
            except FileNotFoundError:
                if not os.path.exists(src):
                    raise
        else:
            raise OSError(f"pack dir kept vanishing under {dst!r}")
        dirs_to_sync.add(os.path.dirname(dst))
    for d in sorted(dirs_to_sync, reverse=True):
        _fsync_dir(d)
    _fsync_dir(os.path.join(root, CHUNKSTORE_DIR, PACK_SUBDIR))
    # drop now-empty data dirs so the published step holds only metadata
    for rel in sorted(fresh, reverse=True):
        d = os.path.dirname(os.path.join(tmp, rel))
        while len(d) > len(tmp):
            try:
                os.rmdir(d)
            except OSError:
                break
            d = os.path.dirname(d)
    return True


# ------------------------------------------------------------ retention GC
@dataclass
class StoreGCStats:
    scanned: int = 0
    kept: int = 0
    deleted: int = 0
    bytes_freed: int = 0
    refcounts: dict = field(default_factory=dict)  # store-rel path -> refs


def manifest_store_paths(m: Manifest):
    """Store-relative paths this manifest references."""
    for rec in m.tensors.values():
        for sh in rec.shards:
            if is_chunked(sh) and sh.chunks:
                for r in sh.chunks:
                    if r.path.startswith(STORE_PREFIX):
                        yield store_rel(r.path)
            elif sh.path.startswith(STORE_PREFIX):
                yield store_rel(sh.path)
    for b in m.blobs.values():
        if b.path.startswith(STORE_PREFIX):
            yield store_rel(b.path)


def _scan_store_refs(root: str) -> tuple[dict[str, int], bool]:
    """One refcount pass; also reports whether a listed manifest vanished
    mid-scan (a concurrent publish renaming ``tmp`` → step dir between our
    ``listdir`` and the read — the refs exist but under a name this pass
    never visited, so the caller must rescan)."""
    from .checkpoint import _STEP_RE, tmp_in_flight  # runtime: avoid cycle
    counts: dict[str, int] = {}
    vanished = False
    try:
        names = os.listdir(root)
    except OSError:
        return counts, False
    for name in names:
        full = os.path.join(root, name)
        if not os.path.isdir(full):
            continue
        mpaths = []
        if _STEP_RE.match(name):
            mpaths = [os.path.join(full, MANIFEST_NAME)]
        elif ".tmp-" in name and tmp_in_flight(full):
            try:
                inner = os.listdir(full)
            except OSError:
                vanished = True
                continue
            mpaths = [os.path.join(full, n) for n in inner
                      if n == MANIFEST_NAME or _RANK_MANIFEST_RE.match(n)]
        for mp in mpaths:
            try:
                m = Manifest._read(mp)
            except ManifestError:
                if not os.path.exists(mp):
                    vanished = True   # dir renamed away under us
                continue   # truly corrupt/foreign manifest pins nothing
            for rel in manifest_store_paths(m):
                counts[rel] = counts.get(rel, 0) + 1
    return counts, vanished


def referenced_store_paths(root: str) -> dict[str, int]:
    """Refcount every store file referenced by manifests under ``root``.

    Committed step dirs count via their ``manifest.json``; ``.tmp-*`` dirs
    belonging to a LIVE save (ownership pidfile / young-dir age — the same
    machinery that protects in-flight saves from ``_gc_tmp``) pin whatever
    their staged ``manifest.json`` / ``MANIFEST.rank-*`` files reference, so
    a concurrent manager's GC cannot reap chunks a peer's in-flight save
    has already committed to referencing. Rescans when a publish renames a
    manifest out from under the pass; raises ``InterruptedError`` if it
    never stabilizes (callers skip deletions and converge next pass).
    """
    for _ in range(5):
        counts, vanished = _scan_store_refs(root)
        if not vanished:
            return counts
    raise InterruptedError(
        "store refcount scan kept racing concurrent publishes")


def gc_store(root: str, *, grace_s: float = GC_GRACE_S) -> StoreGCStats:
    """Reap store files unreferenced by any kept step (refcounted GC).

    Crash-safe by construction: refcounts are recomputed from the manifests
    actually on disk, so an interrupted GC (or publish) converges on the
    next pass; files younger than ``grace_s`` are spared because their
    referencing manifest may not have landed yet. The candidate file list
    is snapshotted BEFORE refs are computed — a pack that appears mid-pass
    is not a candidate, and ``publish_packs`` writes its referencing
    manifest before moving any file, so the two passes can interleave
    freely without reaping a just-published chunk.
    """
    stats = StoreGCStats()
    store = os.path.join(root, CHUNKSTORE_DIR)
    if not os.path.isdir(store):
        return stats
    candidates: list[str] = []
    dirs: list[str] = []
    for dirpath, _dirnames, filenames in os.walk(store, topdown=False):
        candidates += [os.path.join(dirpath, fn) for fn in filenames]
        if dirpath != store:
            dirs.append(dirpath)
    try:
        stats.refcounts = referenced_store_paths(root)
    except InterruptedError:
        # publishes kept racing the ref scan: skip deletions this pass (the
        # next GC converges) rather than risk reaping a live chunk
        stats.scanned = stats.kept = len(candidates)
        return stats
    # crlint: allow(CRL006): GC grace compares against file mtimes
    now = time.time()
    for fp in candidates:
        rel = posixpath.normpath(os.path.relpath(fp, store))
        stats.scanned += 1
        if stats.refcounts.get(rel):
            stats.kept += 1
            continue
        try:
            st = os.stat(fp)
        except OSError:
            continue   # vanished concurrently
        if now - st.st_mtime < grace_s:
            stats.kept += 1
            continue
        try:
            os.remove(fp)
        except OSError:
            continue
        stats.deleted += 1
        stats.bytes_freed += st.st_size
    for d in dirs:
        try:
            os.rmdir(d)   # prune empty pack dirs
        except OSError:
            pass
    return stats
