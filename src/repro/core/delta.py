"""Content-addressed delta checkpointing (DESIGN.md §12).

Every save in this repo used to persist the full byte image of every tensor
at every step. When optimizer slots, frozen layers, embeddings, or quantized
weights change sparsely between steps, most of those bytes are identical to
the previous step — the paper's *volume* axis multiplied by training length
for no information gain. This module decouples *what state is* from *which
bytes must move* (ByteCheckpoint's decomposition, DataStates-LLM's
composable state providers):

  chunking        every tensor shard's snapshot payload is split into fixed,
                  alignment-friendly extents and hashed (blake2b-128) on the
                  pipeline worker — never on the training loop's blocking
                  path,
  dirty detection the hashes are diffed against the previous step's chunk
                  index (recovered from the prior manifest's chunk entries);
                  only dirty chunks are declared and submitted through the
                  existing streaming save path (``CREngine.begin_save/put``),
                  so they ride the same coalescing/backpressure machinery as
                  a full save,
  chunk store     at publish, the step's freshly written data files are
                  renamed into ``<root>/chunkstore/packs/<step>-<uuid>/`` and
                  the manifest's chunk references rewritten to
                  ``../chunkstore/...`` paths — resolvable from ANY step
                  directory by the unchanged engine path join. Clean chunks
                  are recorded as references into packs written by earlier
                  steps,
  retention GC    ``CheckpointManager._gc_old`` becomes refcount-aware: a
                  store file is deleted only when no kept step (and no live
                  in-flight save's staged manifest) references it. Refcounts
                  are recomputed from manifests on every pass — no mutable
                  counter files to corrupt, so the GC is crash-safe and
                  self-healing; packs younger than a grace period are never
                  reaped (they may belong to a publish in flight).

Restore resolves chunk references back through the streaming read path
(``begin_restore/get``) with per-chunk CRCs verified in-stream, reassembles
each shard payload in order, and verifies the whole-payload CRC —
bit-exactly equal to a full-save restore.
"""

from __future__ import annotations

import hashlib
import os
import posixpath
import time
import uuid
import zlib
from dataclasses import dataclass, field, replace

import numpy as np

from . import faults
from .engines import SaveSpec
from .engines.base import as_u8
from .manifest import (CHUNK_KIND, ChunkRef, Manifest, ManifestError,
                       MANIFEST_NAME, ShardEntry, _RANK_MANIFEST_RE)
from .pipeline import PendingPut

CHUNKSTORE_DIR = "chunkstore"
PACK_SUBDIR = "packs"
# how store-resident refs appear relative to a step directory: one level up,
# into the checkpoint root's store (engines join paths against the step dir)
STORE_PREFIX = "../" + CHUNKSTORE_DIR + "/"
DEFAULT_CHUNK_BYTES = 256 << 10
# store files younger than this are never reaped: they may belong to a
# publish (or a cross-tier fetch) that has not landed its manifest yet
GC_GRACE_S = 300.0


def chunk_hash(mv) -> str:
    """Content address of one chunk: blake2b-128 hex digest."""
    return hashlib.blake2b(mv, digest_size=16).hexdigest()


def chunk_spans(nbytes: int, chunk_bytes: int):
    """Fixed chunk grid over a payload: (pos, n) pairs, last one ragged."""
    pos = 0
    while pos < nbytes:
        n = min(chunk_bytes, nbytes - pos)
        yield pos, n
        pos += n


def is_chunked(sh: ShardEntry) -> bool:
    return getattr(sh, "kind", None) == CHUNK_KIND


def reassemble_payload(sh: ShardEntry, fetch, check_chunk=None) -> np.ndarray:
    """Concatenate a chunk-reference shard's chunks back into its payload.

    ``fetch(ref)`` returns each chunk's uint8 bytes in declaration order;
    ``check_chunk(ref, bytes)`` optionally verifies each as it lands. Both
    restore paths (streaming pipeline and monolithic) reassemble through
    this one implementation so they cannot drift apart on chunk ordering
    (test_delta_monolithic_restore_parity guards the equivalence)."""
    payload = np.empty(sh.nbytes, np.uint8)
    pos = 0
    for r in sh.chunks or ():
        b = fetch(r)
        if check_chunk is not None:
            check_chunk(r, b)
        payload[pos:pos + r.nbytes] = b
        pos += r.nbytes
    if pos != sh.nbytes:
        # a parseable manifest whose chunk list lost a trailing ref must
        # fail loudly, not hand back uninitialized tail bytes (the
        # whole-payload CRC would also catch this, but only when CRCs are on)
        raise ManifestError(
            f"chunk refs cover {pos} of {sh.nbytes} payload bytes "
            f"({sh.path!r})")
    return payload


def store_rel(path: str) -> str:
    """Normalize a step-relative store ref to a store-relative path."""
    return posixpath.normpath(path[len(STORE_PREFIX):])


class DeltaIndex:
    """Chunk index of the previous step, recovered from its manifest.

    Keyed by (record_key, shard index window, payload nbytes): a shard whose
    tensor, window, or size changed gets no match and is fully dirty —
    which also makes resharding, chunk-size changes, and delta-over-non-delta
    transitions trivially correct (everything rewrites once).
    Only references already resident in the chunkstore are indexed; a fresh
    save must never point at bytes inside a GC-able step directory.
    """

    def __init__(self):
        self._by_shard: dict[tuple, tuple[ChunkRef, ...]] = {}

    @staticmethod
    def from_manifest(manifest: Manifest | None) -> "DeltaIndex":
        idx = DeltaIndex()
        if manifest is None:
            return idx
        for rec in manifest.tensors.values():
            for sh in rec.shards:
                if not is_chunked(sh) or sh.chunks is None:
                    continue
                if not all(r.path.startswith(STORE_PREFIX)
                           for r in sh.chunks):
                    continue
                idx._by_shard.setdefault(
                    (rec.key, tuple(sh.index), sh.nbytes), sh.chunks)
        return idx

    def lookup(self, record_key: str, index, nbytes: int
               ) -> tuple[ChunkRef, ...] | None:
        return self._by_shard.get((record_key, tuple(index or ()), nbytes))

    def __len__(self) -> int:
        return len(self._by_shard)


@dataclass
class _ShardChunks:
    """One original tensor-shard put, decomposed into chunk references.

    ``refs`` holds, per chunk in payload order, either a ``ChunkRef`` (clean
    — points into the store) or a ``(put_key, hash)`` pair (dirty — resolved
    against the stream manifest after the flush lands)."""
    spec: SaveSpec
    refs: list
    payload_crc: int | None


@dataclass
class DeltaPlan:
    """Output of the hash/diff pass: what to write, and how to describe it."""
    puts: list[PendingPut] = field(default_factory=list)
    shards: list[_ShardChunks] = field(default_factory=list)
    total_bytes: int = 0       # logical tensor + blob bytes of the state
    dirty_bytes: int = 0       # chunk bytes actually submitted
    blob_bytes: int = 0        # lean-object bytes (always written)
    chunks_total: int = 0
    chunks_dirty: int = 0

    @property
    def written_bytes(self) -> int:
        return self.dirty_bytes + self.blob_bytes


def plan_delta(puts: list[PendingPut], index: DeltaIndex, *,
               chunk_bytes: int = DEFAULT_CHUNK_BYTES,
               checksum: bool = True) -> DeltaPlan:
    """Resolve, chunk, hash, and diff every declared put.

    Runs on the pipeline worker (async saves pay zero blocking time for the
    hash pass). Blob puts (the lean object) pass through unchanged; tensor
    puts are replaced by one put per DIRTY chunk — a clean chunk becomes a
    reference to the previous step's store extent. Chunk hashing touches
    every payload byte, which is exactly the D2H snapshot the full save
    would have done anyway; what it buys is not writing the clean ones.

    Memory: dirty-chunk puts hold VIEWS of the resolved payload, so host
    residency during the flush is the payloads of tensors with >= 1 dirty
    chunk (clean-only tensors are dropped as the loop advances). For host
    arrays those views are free (they alias the caller's state); only
    device-array D2H copies and quant-packed buffers are real allocations
    — copying dirty chunks instead would shrink the sparse case but add a
    full extra copy at high dirty fractions, so views win on balance.
    """
    plan = DeltaPlan()
    for p in puts:
        if p.spec.is_blob:
            plan.puts.append(p)
            plan.blob_bytes += p.spec.nbytes
            plan.total_bytes += p.spec.nbytes
            continue
        payload = np.frombuffer(as_u8(p.resolve()), np.uint8)
        if payload.nbytes != p.spec.nbytes:
            raise ValueError(
                f"declared {p.spec.nbytes} bytes for {p.spec.key!r}, "
                f"resolved {payload.nbytes}")
        plan.total_bytes += payload.nbytes
        rkey = p.spec.record_key or p.spec.key
        prior = index.lookup(rkey, p.spec.index, p.spec.nbytes)
        crc = 0 if checksum else None
        refs: list = []
        for j, (pos, n) in enumerate(chunk_spans(p.spec.nbytes, chunk_bytes)):
            chunk = payload[pos:pos + n]
            h = chunk_hash(chunk)
            if checksum:
                crc = zlib.crc32(chunk, crc) & 0xFFFFFFFF
            plan.chunks_total += 1
            pr = prior[j] if prior is not None and j < len(prior) else None
            if pr is not None and pr.hash == h and pr.nbytes == n:
                refs.append(pr)                       # clean: reference
                continue
            ck = f"{p.spec.key}.c{j:05d}"
            plan.puts.append(PendingPut(
                SaveSpec(ck, n, "uint8", (n,), ((0, n),), record_key=ck),
                (lambda c=chunk: c)))
            refs.append((ck, h))                      # dirty: write
            plan.chunks_dirty += 1
            plan.dirty_bytes += n
        plan.shards.append(_ShardChunks(p.spec, refs, crc))
    return plan


def apply_plan(stream_manifest: Manifest, plan: DeltaPlan) -> Manifest:
    """Fold the flushed stream manifest back into chunked shard entries.

    The stream manifest maps each dirty-chunk put to its file extent; the
    returned manifest replaces those per-chunk records with one
    ``kind="chunks"`` entry per original tensor shard, mixing fresh extents
    (still step-dir-relative — relocated by ``publish_packs``) with the
    plan's clean store references. Blobs and extra metadata ride through.
    """
    out = Manifest(stream_manifest.step, stream_manifest.num_ranks,
                   stream_manifest.strategy)
    out.blobs = stream_manifest.blobs
    out.extra = stream_manifest.extra
    for sc in plan.shards:
        spec = sc.spec
        chunks: list[ChunkRef] = []
        for r in sc.refs:
            if isinstance(r, ChunkRef):
                chunks.append(r)
                continue
            ck, h = r
            ext = stream_manifest.tensors[ck].shards[0]
            chunks.append(ChunkRef(h, ext.path, ext.offset, ext.nbytes,
                                   ext.crc32))
        index = spec.index
        if index is None:
            index = tuple((0, s) for s in (spec.global_shape or ()))
        gshape = (spec.global_shape if spec.global_shape is not None
                  else (spec.nbytes,))
        out.add_shard(
            spec.record_key or spec.key, spec.dtype or "uint8", gshape,
            ShardEntry(tuple(index), f"<chunks:{uuid.uuid4().hex[:12]}>", 0,
                       spec.nbytes, sc.payload_crc, CHUNK_KIND,
                       tuple(chunks)))
    return out


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        faults.fsync(fd)
    finally:
        os.close(fd)


def publish_packs(manifest: Manifest, tmp: str, root: str, tag: str) -> bool:
    """Relocate the step's freshly written data files into the chunkstore.
    Returns True when the rewritten manifest was already written into
    ``tmp`` (callers must not redundantly re-serialize it).

    Every file referenced by a step-dir-relative path (fresh dirty chunks
    AND the lean blob — under single-file layouts they share one file) is
    renamed from ``tmp`` into ``<root>/chunkstore/packs/<tag>-<uuid>/`` and
    the manifest rewritten to ``../chunkstore/...`` references, so the bytes
    survive the step directory's eventual ``rmtree``.

    Ordering closes the GC race: the REWRITTEN manifest is written into the
    (pidfile-owned, GC-pinning) staging dir BEFORE any file is renamed into
    the store, so the moment a pack file becomes visible there, a live
    manifest referencing it already exists — a concurrent refcount GC
    (which snapshots its candidate list before computing refs) can never
    see it as an orphan. A crash mid-sequence leaves either a doomed tmp
    dir (reaped by ``_gc_tmp``) or unreferenced store files (reaped after
    the grace period) — never a committed manifest pointing at missing
    bytes, because the commit rename happens strictly after the moves.
    """
    fresh: set[str] = set()
    for rec in manifest.tensors.values():
        for sh in rec.shards:
            if is_chunked(sh) and sh.chunks:
                fresh.update(r.path for r in sh.chunks
                             if not r.path.startswith(STORE_PREFIX))
            elif not is_chunked(sh) and not sh.path.startswith(STORE_PREFIX):
                fresh.add(sh.path)
    fresh.update(b.path for b in manifest.blobs.values()
                 if not b.path.startswith(STORE_PREFIX))
    fresh = {p for p in fresh if os.path.exists(os.path.join(tmp, p))}
    if not fresh:
        return False
    pack = f"{tag}-{uuid.uuid4().hex[:8]}"
    pack_dir = os.path.join(root, CHUNKSTORE_DIR, PACK_SUBDIR, pack)
    moved = {rel: posixpath.join(STORE_PREFIX.rstrip("/"), PACK_SUBDIR,
                                 pack, rel)
             for rel in sorted(fresh)}
    # 1. rewrite references (ShardEntry/ChunkRef are frozen: rebuild)
    for rec in manifest.tensors.values():
        new_shards = []
        for sh in rec.shards:
            if is_chunked(sh) and sh.chunks:
                refs = tuple(
                    replace(r, path=moved[r.path]) if r.path in moved else r
                    for r in sh.chunks)
                sh = replace(sh, chunks=refs)
            elif sh.path in moved:
                sh = replace(sh, path=moved[sh.path])
            new_shards.append(sh)
        rec.shards = new_shards
    for key, b in list(manifest.blobs.items()):
        if b.path in moved:
            manifest.blobs[key] = replace(b, path=moved[b.path])
    # 2. land the rewritten manifest in the pinning tmp dir FIRST: the refs
    # exist on disk before any file they name becomes reapable
    manifest.save(tmp)
    # 3. now move the payload files into the store. A concurrent gc_store
    # prunes EMPTY pack dirs (os.rmdir), so the freshly made dir can vanish
    # between makedirs and replace — retry until the rename lands; once the
    # first file is in, the dir is non-empty and unprunable, so this
    # converges (the retry bound only guards against programming errors).
    dirs_to_sync = set()
    for rel in sorted(fresh):
        src = os.path.join(tmp, rel)
        dst = os.path.join(pack_dir, rel)
        for _ in range(100):
            try:
                os.makedirs(os.path.dirname(dst), exist_ok=True)
                faults.replace(src, dst)
                break
            except FileNotFoundError:
                if not os.path.exists(src):
                    raise
        else:
            raise OSError(f"pack dir kept vanishing under {dst!r}")
        dirs_to_sync.add(os.path.dirname(dst))
    for d in sorted(dirs_to_sync, reverse=True):
        _fsync_dir(d)
    _fsync_dir(os.path.join(root, CHUNKSTORE_DIR, PACK_SUBDIR))
    # drop now-empty data dirs so the published step holds only metadata
    for rel in sorted(fresh, reverse=True):
        d = os.path.dirname(os.path.join(tmp, rel))
        while len(d) > len(tmp):
            try:
                os.rmdir(d)
            except OSError:
                break
            d = os.path.dirname(d)
    return True


# ------------------------------------------------------------ retention GC
@dataclass
class StoreGCStats:
    scanned: int = 0
    kept: int = 0
    deleted: int = 0
    bytes_freed: int = 0
    refcounts: dict = field(default_factory=dict)  # store-rel path -> refs


def manifest_store_paths(m: Manifest):
    """Store-relative paths this manifest references."""
    for rec in m.tensors.values():
        for sh in rec.shards:
            if is_chunked(sh) and sh.chunks:
                for r in sh.chunks:
                    if r.path.startswith(STORE_PREFIX):
                        yield store_rel(r.path)
            elif sh.path.startswith(STORE_PREFIX):
                yield store_rel(sh.path)
    for b in m.blobs.values():
        if b.path.startswith(STORE_PREFIX):
            yield store_rel(b.path)


def _scan_store_refs(root: str) -> tuple[dict[str, int], bool]:
    """One refcount pass; also reports whether a listed manifest vanished
    mid-scan (a concurrent publish renaming ``tmp`` → step dir between our
    ``listdir`` and the read — the refs exist but under a name this pass
    never visited, so the caller must rescan)."""
    from .checkpoint import _STEP_RE, tmp_in_flight  # runtime: avoid cycle
    counts: dict[str, int] = {}
    vanished = False
    try:
        names = os.listdir(root)
    except OSError:
        return counts, False
    for name in names:
        full = os.path.join(root, name)
        if not os.path.isdir(full):
            continue
        mpaths = []
        if _STEP_RE.match(name):
            mpaths = [os.path.join(full, MANIFEST_NAME)]
        elif ".tmp-" in name and tmp_in_flight(full):
            try:
                inner = os.listdir(full)
            except OSError:
                vanished = True
                continue
            mpaths = [os.path.join(full, n) for n in inner
                      if n == MANIFEST_NAME or _RANK_MANIFEST_RE.match(n)]
        for mp in mpaths:
            try:
                m = Manifest._read(mp)
            except ManifestError:
                if not os.path.exists(mp):
                    vanished = True   # dir renamed away under us
                continue   # truly corrupt/foreign manifest pins nothing
            for rel in manifest_store_paths(m):
                counts[rel] = counts.get(rel, 0) + 1
    return counts, vanished


def referenced_store_paths(root: str) -> dict[str, int]:
    """Refcount every store file referenced by manifests under ``root``.

    Committed step dirs count via their ``manifest.json``; ``.tmp-*`` dirs
    belonging to a LIVE save (ownership pidfile / young-dir age — the same
    machinery that protects in-flight saves from ``_gc_tmp``) pin whatever
    their staged ``manifest.json`` / ``MANIFEST.rank-*`` files reference, so
    a concurrent manager's GC cannot reap chunks a peer's in-flight save
    has already committed to referencing. Rescans when a publish renames a
    manifest out from under the pass; raises ``InterruptedError`` if it
    never stabilizes (callers skip deletions and converge next pass).
    """
    for _ in range(5):
        counts, vanished = _scan_store_refs(root)
        if not vanished:
            return counts
    raise InterruptedError(
        "store refcount scan kept racing concurrent publishes")


def gc_store(root: str, *, grace_s: float = GC_GRACE_S) -> StoreGCStats:
    """Reap store files unreferenced by any kept step (refcounted GC).

    Crash-safe by construction: refcounts are recomputed from the manifests
    actually on disk, so an interrupted GC (or publish) converges on the
    next pass; files younger than ``grace_s`` are spared because their
    referencing manifest may not have landed yet. The candidate file list
    is snapshotted BEFORE refs are computed — a pack that appears mid-pass
    is not a candidate, and ``publish_packs`` writes its referencing
    manifest before moving any file, so the two passes can interleave
    freely without reaping a just-published chunk.
    """
    stats = StoreGCStats()
    store = os.path.join(root, CHUNKSTORE_DIR)
    if not os.path.isdir(store):
        return stats
    candidates: list[str] = []
    dirs: list[str] = []
    for dirpath, _dirnames, filenames in os.walk(store, topdown=False):
        candidates += [os.path.join(dirpath, fn) for fn in filenames]
        if dirpath != store:
            dirs.append(dirpath)
    try:
        stats.refcounts = referenced_store_paths(root)
    except InterruptedError:
        # publishes kept racing the ref scan: skip deletions this pass (the
        # next GC converges) rather than risk reaping a live chunk
        stats.scanned = stats.kept = len(candidates)
        return stats
    now = time.time()
    for fp in candidates:
        rel = posixpath.normpath(os.path.relpath(fp, store))
        stats.scanned += 1
        if stats.refcounts.get(rel):
            stats.kept += 1
            continue
        try:
            st = os.stat(fp)
        except OSError:
            continue   # vanished concurrently
        if now - st.st_mtime < grace_s:
            stats.kept += 1
            continue
        try:
            os.remove(fp)
        except OSError:
            continue
        stats.deleted += 1
        stats.bytes_freed += st.st_size
    for d in dirs:
        try:
            os.rmdir(d)   # prune empty pack dirs
        except OSError:
            pass
    return stats
