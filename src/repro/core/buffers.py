"""Aligned, reusable host buffer pool.

The paper's Fig 13–14 finding: dynamic per-read allocation dominates restore time;
preallocated, reusable, page-aligned buffers nearly double restore throughput.
This pool is that fix. Buffers are mmap-backed (page-aligned by construction,
satisfying O_DIRECT alignment) and size-classed in powers of two so a buffer
released by one tensor is reusable by the next.
"""

from __future__ import annotations

import ctypes
import mmap
import threading

from . import trace
from dataclasses import dataclass, field

PAGE = mmap.PAGESIZE  # typically 4096; also the O_DIRECT alignment quantum


def align_up(n: int, quantum: int = PAGE) -> int:
    return (n + quantum - 1) // quantum * quantum


def aligned_span(offset: int, nbytes: int, quantum: int = PAGE) -> tuple[int, int]:
    """Expand a logical byte range to alignment boundaries.

    Returns ``(start, span)`` with ``start % quantum == 0`` and
    ``span % quantum == 0`` covering ``[offset, offset + nbytes)`` — the shape
    an O_DIRECT read/write of that range must take (tiered prefetch pulls
    manifest extents as aligned spans; see DESIGN.md §8)."""
    start = offset - offset % quantum
    return start, align_up(offset + nbytes - start, quantum)


class AlignedBuffer:
    """A page-aligned host buffer backed by anonymous mmap."""

    __slots__ = ("mm", "nbytes", "address", "pool", "size_class", "_mv")

    def __init__(self, nbytes: int, pool: "BufferPool | None" = None,
                 size_class: int | None = None):
        nbytes = align_up(max(nbytes, PAGE))
        self.mm = mmap.mmap(-1, nbytes)
        self.nbytes = nbytes
        self.address = ctypes.addressof(ctypes.c_char.from_buffer(self.mm))
        self.pool = pool
        self.size_class = size_class if size_class is not None else nbytes
        self._mv = memoryview(self.mm)

    def view(self, offset: int = 0, nbytes: int | None = None) -> memoryview:
        end = self.nbytes if nbytes is None else offset + nbytes
        return self._mv[offset:end]

    def write_bytes(self, data, offset: int = 0) -> int:
        n = len(data)
        self._mv[offset:offset + n] = data
        return n

    def release(self) -> None:
        if self.pool is not None:
            self.pool.put(self)

    def destroy(self) -> None:
        pool, self.pool = self.pool, None
        if pool is not None:
            # destroyed without passing through put() (e.g. a janitor reaping
            # a straggling transfer): settle the outstanding-byte books so
            # acquire() budgets don't leak
            pool._forget(self)
        try:
            self._mv.release()
            self.mm.close()
        except (BufferError, ValueError):
            # Outstanding exported views (e.g. np.frombuffer slices) keep the
            # mapping alive; the munmap happens when they are GC'd. The
            # allocation-cost accounting (what the disabled-pool mode models)
            # already happened at get().
            pass

    def __len__(self) -> int:
        return self.nbytes


@dataclass
class PoolStats:
    allocations: int = 0      # fresh mmap allocations
    reuses: int = 0           # satisfied from the free list
    released: int = 0
    bytes_allocated: int = 0
    high_water_bytes: int = 0
    peak_outstanding_bytes: int = 0   # max bytes handed out and unreleased
    by_class: dict = field(default_factory=dict)

    @property
    def reuse_rate(self) -> float:
        total = self.allocations + self.reuses
        return self.reuses / total if total else 0.0


class StageBudget:
    """In-flight staged-byte accounting for streaming transfer loops.

    The snapshot pipeline (engines.aggregated save stream), the restore
    pipeline (its read stream), and the tiered transfer engine all stage data
    through pooled buffers; this is the shared backpressure primitive that
    caps how many staged bytes may be in flight at once. ``limit=None`` disables the cap. Not thread-safe by design — each
    user drives its own single-threaded submit/reap loop and consults the
    budget only from that loop (cross-thread blocking waits go through
    ``BufferPool.acquire`` instead).
    """

    __slots__ = ("limit", "in_flight", "peak")

    def __init__(self, limit: int | None):
        self.limit = limit
        self.in_flight = 0
        self.peak = 0

    def admits(self, nbytes: int) -> bool:
        """True if staging ``nbytes`` more fits the budget. Always grants
        when nothing is in flight so one oversized request can't deadlock."""
        return (self.limit is None or self.in_flight == 0
                or self.in_flight + nbytes <= self.limit)

    def add(self, nbytes: int) -> None:
        self.in_flight += nbytes
        self.peak = max(self.peak, self.in_flight)

    def sub(self, nbytes: int) -> None:
        self.in_flight -= nbytes

    def settle(self) -> None:
        """Zero the in-flight books (abort paths: every staged buffer was
        force-released, so the next loop on this budget must start clean)."""
        self.in_flight = 0


class BufferPool:
    """Size-classed (power-of-two ≥ 1 page) pool of AlignedBuffers.

    ``get`` either reuses a free buffer of the right class or allocates fresh.
    ``disabled=True`` models DataStates-LLM's dynamic-allocation behaviour for
    the bench_restore_alloc experiment: every get() is a fresh mmap and
    released buffers are destroyed.
    """

    def __init__(self, disabled: bool = False, max_cached_bytes: int | None = None,
                 max_outstanding_bytes: int | None = None):
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        # crlint: guarded-by(_lock, _cond)
        self._free: dict[int, list[AlignedBuffer]] = {}
        self.disabled = disabled
        self.max_cached_bytes = max_cached_bytes
        self.max_outstanding_bytes = max_outstanding_bytes  # acquire() budget
        # crlint: guarded-by(_lock, _cond)
        self._cached_bytes = 0
        # crlint: guarded-by(_lock, _cond)
        self._outstanding = 0     # bytes handed out and not yet released
        self.stats = PoolStats()

    @staticmethod
    def size_class(nbytes: int) -> int:
        nbytes = max(nbytes, PAGE)
        return 1 << (nbytes - 1).bit_length()

    @property
    def outstanding_bytes(self) -> int:
        # crlint: allow(CRL003): deliberately racy stats read — a single
        # int load for dashboards; callers never branch durability on it
        return self._outstanding

    def get(self, nbytes: int) -> AlignedBuffer:
        with self._lock:
            return self._get_locked(self.size_class(nbytes))

    def acquire(self, nbytes: int, budget: int | None = None,
                timeout: float | None = None) -> AlignedBuffer:
        """Blocking bounded ``get``: waits until granting ``nbytes`` keeps the
        pool's outstanding (handed-out, unreleased) bytes within ``budget``
        (default: ``max_outstanding_bytes``). A request is always granted when
        nothing is outstanding, so one oversized buffer can't deadlock.
        Raises TimeoutError after ``timeout`` seconds."""
        cls = self.size_class(nbytes)
        limit = self.max_outstanding_bytes if budget is None else budget
        deadline = None if timeout is None else trace.clock() + timeout
        with self._cond:
            while (limit is not None and self._outstanding
                   and self._outstanding + cls > limit):
                remaining = None if deadline is None \
                    else deadline - trace.clock()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"buffer budget exhausted: {self._outstanding} B "
                        f"outstanding, want {cls} B under a {limit} B budget")
                self._cond.wait(0.5 if remaining is None else min(remaining, 0.5))
            return self._get_locked(cls)

    def _get_locked(self, cls: int) -> AlignedBuffer:  # crlint: holds(_lock)
        buf = None
        if not self.disabled:
            lst = self._free.get(cls)
            if lst:
                buf = lst.pop()
                self._cached_bytes -= buf.nbytes
                self.stats.reuses += 1
        if buf is None:
            buf = AlignedBuffer(cls, pool=self, size_class=cls)
            self.stats.allocations += 1
            self.stats.bytes_allocated += buf.nbytes
            self.stats.by_class[cls] = self.stats.by_class.get(cls, 0) + 1
            self.stats.high_water_bytes = max(
                self.stats.high_water_bytes, self.stats.bytes_allocated)
        self._outstanding += buf.nbytes
        self.stats.peak_outstanding_bytes = max(
            self.stats.peak_outstanding_bytes, self._outstanding)
        return buf

    def put(self, buf: AlignedBuffer) -> None:
        with self._cond:
            self.stats.released += 1
            self._outstanding -= buf.nbytes
            self._cond.notify_all()
            if self.disabled or (
                    self.max_cached_bytes is not None
                    and self._cached_bytes + buf.nbytes > self.max_cached_bytes):
                self.stats.bytes_allocated -= buf.nbytes
                buf.pool = None   # books settled here; destroy must not _forget
                buf.destroy()
                return
            self._free.setdefault(buf.size_class, []).append(buf)
            self._cached_bytes += buf.nbytes

    def _forget(self, buf: AlignedBuffer) -> None:
        """A handed-out buffer was destroyed without release(): drop it from
        the outstanding and allocation books (called from destroy())."""
        with self._cond:
            self._outstanding -= buf.nbytes
            self.stats.bytes_allocated -= buf.nbytes
            self._cond.notify_all()

    def preallocate(self, sizes) -> None:
        """Warm the pool (the paper's 'preallocated buffers' mode)."""
        bufs = [self.get(s) for s in sizes]
        for b in bufs:
            b.release()

    def free_buffers(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._free.values())

    def drain(self) -> None:
        with self._lock:
            for lst in self._free.values():
                for b in lst:
                    self.stats.bytes_allocated -= b.nbytes
                    b.pool = None   # free-list buffers aren't outstanding
                    b.destroy()
            self._free.clear()
            self._cached_bytes = 0
