"""Checkpoint manifest: the metadata header mapping tensors to file extents.

Paper §2 stage (4): "Metadata headers map tensors to offsets in files for
reconstruction during the restore." Ours additionally records the *global*
shape and per-shard index windows so restore can reshard elastically (restore
onto a different mesh than the one that saved — DESIGN.md §2 extension 4).

The manifest is a single JSON document per checkpoint version, written last and
fsync'd, then the version directory is atomically committed via rename. A
checkpoint without a committed manifest is invalid by definition (crash
consistency).
"""

from __future__ import annotations

import json
import os
import re
import zlib
from dataclasses import dataclass, field, asdict

MANIFEST_NAME = "manifest.json"
# Ceiling this reader accepts / writes. Version 3 added the chunk-reference
# shard entry kind (content-addressed delta checkpoints, DESIGN.md §12);
# version 4 adds per-shard chunk digest *kinds* (on-device fp128
# fingerprints, DESIGN.md §14). The written version floats with content —
# a manifest without chunk entries stays at BASE_FORMAT_VERSION, blake2b
# chunk manifests at CHUNK_FORMAT_VERSION — so older readers keep loading
# everything they can actually interpret and refuse (loudly) what they
# can't: a v3 reader must never scrub/diff fp128 refs as if they were
# blake2b content addresses.
FORMAT_VERSION = 4
CHUNK_FORMAT_VERSION = 3
BASE_FORMAT_VERSION = 2

# shard entry kinds: "extent" = bytes at (path, offset); "chunks" = the
# payload is the concatenation of content-addressed ChunkRefs into the
# chunkstore. An unknown kind raises typed ManifestError (old readers must
# not misread a chunk entry as a raw extent).
EXTENT_KIND = "extent"
CHUNK_KIND = "chunks"
_SHARD_KINDS = (EXTENT_KIND, CHUNK_KIND)

# chunk digest kinds: which function produced ``ChunkRef.hash``. Content
# addresses of different kinds never compare equal — the delta planner
# treats a kind mismatch exactly like a chunk-grid change (full write).
DIGEST_BLAKE2B = "blake2b128"   # host blake2b-128 (PR 5, implicit default)
DIGEST_FP128 = "fp128"          # on-device multilinear digest (DESIGN.md §14)

_RANK_MANIFEST_RE = re.compile(r"^MANIFEST\.rank-(\d+)$")


def rank_manifest_name(rank: int) -> str:
    """Per-rank manifest file in a (tmp) step dir — phase 1 of the
    multi-writer commit (DESIGN.md §11). ``manifest.json`` remains the one
    and only name that makes a checkpoint valid."""
    return f"MANIFEST.rank-{rank}"


class ManifestError(ValueError):
    """Manifest missing, truncated, corrupt, or semantically invalid."""


class ManifestMergeError(ManifestError):
    """Per-rank manifests disagree (step / strategy / tensor shape)."""


@dataclass(frozen=True)
class ChunkRef:
    """One content-addressed chunk of a shard's payload bytes.

    ``hash`` is the blake2b-128 hex digest of the chunk bytes (the content
    address); ``path`` is step-dir-relative like every other manifest path —
    chunks resident in the store use ``../chunkstore/packs/...`` so the same
    engine path-join resolves them from any step directory.
    """
    hash: str
    path: str
    offset: int
    nbytes: int
    crc32: int | None = None

    def to_json(self):
        return {"hash": self.hash, "path": self.path, "offset": self.offset,
                "nbytes": self.nbytes, "crc32": self.crc32}

    @staticmethod
    def from_json(d) -> "ChunkRef":
        return ChunkRef(d["hash"], d["path"], d["offset"], d["nbytes"],
                        d.get("crc32"))


@dataclass(frozen=True)
class ShardEntry:
    """One saved shard of one global tensor.

    ``kind == EXTENT_KIND``: the payload is the bytes at (path, offset).
    ``kind == CHUNK_KIND``: the payload is the in-order concatenation of
    ``chunks`` (content-addressed delta entries, DESIGN.md §12); ``path`` is
    then a synthetic unique identifier (never opened), ``offset`` is 0, and
    ``crc32`` — when present — covers the whole reassembled payload (fp128
    shards omit it: per-chunk CRCs already cover every byte, and skipping
    the extra host pass is half the point of device fingerprints).
    ``digest`` names the digest kind of the ``ChunkRef.hash`` values
    (``None`` means DIGEST_BLAKE2B, the pre-v4 implicit default).
    """
    index: tuple[tuple[int, int], ...]  # (start, stop) per dim, global coords
    path: str                           # file path relative to ckpt dir
    offset: int                         # byte offset in file
    nbytes: int                         # logical bytes
    crc32: int | None = None
    kind: str = EXTENT_KIND
    chunks: tuple[ChunkRef, ...] | None = None
    digest: str | None = None

    @property
    def digest_kind(self) -> str:
        return self.digest or DIGEST_BLAKE2B

    def to_json(self):
        d = {"index": [list(p) for p in self.index], "path": self.path,
             "offset": self.offset, "nbytes": self.nbytes, "crc32": self.crc32}
        if self.kind != EXTENT_KIND:
            d["kind"] = self.kind
            d["chunks"] = [c.to_json() for c in (self.chunks or ())]
            if self.digest is not None and self.digest != DIGEST_BLAKE2B:
                d["digest"] = self.digest
        return d

    @staticmethod
    def from_json(d) -> "ShardEntry":
        kind = d.get("kind", EXTENT_KIND)
        if kind not in _SHARD_KINDS:
            raise ManifestError(
                f"unknown shard entry kind {kind!r} (this reader understands "
                f"{_SHARD_KINDS}); refusing to misread the entry")
        chunks = None
        if kind == CHUNK_KIND:
            chunks = tuple(ChunkRef.from_json(c) for c in d.get("chunks", ()))
        return ShardEntry(tuple(tuple(p) for p in d["index"]), d["path"],
                          d["offset"], d["nbytes"], d.get("crc32"),
                          kind, chunks, d.get("digest"))


@dataclass
class TensorRecord:
    key: str
    dtype: str           # numpy dtype string, e.g. 'bfloat16', 'float32'
    global_shape: tuple[int, ...]
    shards: list[ShardEntry] = field(default_factory=list)

    def to_json(self):
        return {"key": self.key, "dtype": self.dtype,
                "global_shape": list(self.global_shape),
                "shards": [s.to_json() for s in self.shards]}

    @staticmethod
    def from_json(d) -> "TensorRecord":
        return TensorRecord(d["key"], d["dtype"], tuple(d["global_shape"]),
                            [ShardEntry.from_json(s) for s in d["shards"]])


@dataclass
class BlobRecord:
    """A serialized non-tensor byte object (e.g. the 'lean' pytree)."""
    key: str
    path: str
    offset: int
    nbytes: int
    crc32: int | None = None

    def to_json(self):
        return asdict(self)

    @staticmethod
    def from_json(d) -> "BlobRecord":
        return BlobRecord(d["key"], d["path"], d["offset"], d["nbytes"],
                          d.get("crc32"))


@dataclass
class Manifest:
    step: int
    num_ranks: int
    strategy: str
    format_version: int = BASE_FORMAT_VERSION
    tensors: dict[str, TensorRecord] = field(default_factory=dict)
    blobs: dict[str, BlobRecord] = field(default_factory=dict)
    extra: dict = field(default_factory=dict)  # engine config, mesh, timings

    # ---- construction helpers -------------------------------------------
    def add_shard(self, key: str, dtype: str, global_shape: tuple[int, ...],
                  entry: ShardEntry) -> None:
        rec = self.tensors.get(key)
        if rec is None:
            rec = self.tensors[key] = TensorRecord(key, dtype, tuple(global_shape))
        else:
            if rec.dtype != dtype or rec.global_shape != tuple(global_shape):
                raise ValueError(f"inconsistent tensor record for {key}")
        rec.shards.append(entry)

    def merge(self, other: "Manifest", *, rank: int | None = None) -> None:
        """Merge a per-rank manifest into this (global) one — rank-0 commit.

        Raises ``ManifestMergeError`` when the two manifests describe
        different checkpoints (step, strategy) or disagree on a tensor's
        dtype/global_shape. Idempotent: re-merging a rank already merged
        (``rank`` arg, or the manifest's recorded ``extra["rank"]``) is a
        no-op, and an exact-duplicate ``ShardEntry`` is skipped — a retried
        commit cannot accumulate duplicates that corrupt restore windows.
        Blobs keep the first writer's copy (every rank's lean object is
        equivalent)."""
        if other.step != self.step:
            raise ManifestMergeError(
                f"cannot merge manifests of different steps: "
                f"{self.step} vs {other.step}")
        if other.strategy != self.strategy:
            raise ManifestMergeError(
                f"cannot merge manifests of different strategies: "
                f"{self.strategy!r} vs {other.strategy!r}")
        if rank is None:
            rank = other.extra.get("rank")
        merged = self.extra.setdefault("merged_ranks", [])
        own = self.extra.get("rank")
        if own is not None and own not in merged:
            merged.append(own)
        if rank is not None and rank in merged:
            return
        # validate EVERYTHING before mutating anything: a mid-merge raise
        # must not leave this manifest half-merged yet marked as merged
        for key, rec in other.tensors.items():
            mine = self.tensors.get(key)
            if mine is not None and (
                    mine.dtype != rec.dtype
                    or tuple(mine.global_shape) != tuple(rec.global_shape)):
                raise ManifestMergeError(
                    f"tensor {key!r} disagrees across ranks: "
                    f"{mine.dtype}{tuple(mine.global_shape)} vs "
                    f"{rec.dtype}{tuple(rec.global_shape)}")
        for key, rec in other.tensors.items():
            mine = self.tensors.get(key)
            for s in rec.shards:
                if mine is not None and s in mine.shards:
                    continue   # already merged (re-merge / retry)
                self.add_shard(key, rec.dtype, rec.global_shape, s)
                mine = self.tensors[key]
        for k, b in other.blobs.items():
            self.blobs.setdefault(k, b)
        if rank is not None:
            merged.append(rank)
        q = set(self.extra.get("quantized", ())) \
            | set(other.extra.get("quantized", ()))
        if q:
            self.extra["quantized"] = sorted(q)

    @property
    def total_bytes(self) -> int:
        return (sum(s.nbytes for r in self.tensors.values() for s in r.shards)
                + sum(b.nbytes for b in self.blobs.values()))

    # ---- (de)serialization ------------------------------------------------
    def to_json(self) -> dict:
        # version floats with content: chunk-reference entries need the v3
        # reader, non-blake2b digest kinds the v4 reader; everything else
        # stays loadable by pre-delta readers
        fv = self.format_version
        shards = [sh for rec in self.tensors.values() for sh in rec.shards]
        if any(sh.kind != EXTENT_KIND for sh in shards):
            fv = max(fv, CHUNK_FORMAT_VERSION)
        if any(sh.kind == CHUNK_KIND and sh.digest_kind != DIGEST_BLAKE2B
               for sh in shards):
            fv = max(fv, FORMAT_VERSION)
        return {"format_version": fv, "step": self.step,
                "num_ranks": self.num_ranks, "strategy": self.strategy,
                "tensors": {k: v.to_json() for k, v in self.tensors.items()},
                "blobs": {k: v.to_json() for k, v in self.blobs.items()},
                "extra": self.extra}

    def dumps(self) -> bytes:
        return json.dumps(self.to_json(), separators=(",", ":")).encode()

    @staticmethod
    def loads(data: bytes) -> "Manifest":
        """Parse manifest bytes; any structural defect (truncated JSON,
        missing fields, malformed records) raises ``ManifestError`` so
        callers can fall back to an older checkpoint instead of dying on a
        raw ``JSONDecodeError``/``KeyError``."""
        try:
            d = json.loads(data)
            if d["format_version"] > FORMAT_VERSION:
                raise ManifestError(
                    f"manifest from the future: {d['format_version']}")
            m = Manifest(d["step"], d["num_ranks"], d["strategy"],
                         d["format_version"])
            m.tensors = {k: TensorRecord.from_json(v)
                         for k, v in d["tensors"].items()}
            m.blobs = {k: BlobRecord.from_json(v)
                       for k, v in d["blobs"].items()}
            m.extra = d.get("extra", {})
            return m
        except ManifestError:
            raise
        except (ValueError, KeyError, TypeError, AttributeError) as e:
            raise ManifestError(f"corrupt manifest: {e}") from e

    def _write(self, path: str) -> None:
        from . import faults   # runtime: faults imports ManifestError above
        payload = self.dumps()
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            faults.file_write(f, payload)
            f.flush()
            faults.fsync(f.fileno())
        faults.replace(tmp, path)

    def save(self, ckpt_dir: str) -> None:
        self._write(os.path.join(ckpt_dir, MANIFEST_NAME))

    def save_rank(self, ckpt_dir: str, rank: int) -> None:
        """Write this rank's manifest as ``MANIFEST.rank-{r}`` (fsync'd,
        atomically renamed). Does NOT make the checkpoint valid — only the
        merged ``manifest.json`` does."""
        self._write(os.path.join(ckpt_dir, rank_manifest_name(rank)))

    @staticmethod
    def _read(path: str) -> "Manifest":
        try:
            with open(path, "rb") as f:
                data = f.read()
        except OSError as e:
            raise ManifestError(f"unreadable manifest {path}: {e}") from e
        return Manifest.loads(data)

    @staticmethod
    def load(ckpt_dir: str) -> "Manifest":
        return Manifest._read(os.path.join(ckpt_dir, MANIFEST_NAME))

    @staticmethod
    def load_rank(ckpt_dir: str, rank: int) -> "Manifest":
        return Manifest._read(
            os.path.join(ckpt_dir, rank_manifest_name(rank)))

    @staticmethod
    def rank_manifests(ckpt_dir: str) -> list[int]:
        """Ranks that completed phase 1 (their ``MANIFEST.rank-{r}`` is on
        disk) in a step dir."""
        out = []
        for name in os.listdir(ckpt_dir):
            m = _RANK_MANIFEST_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    @staticmethod
    def exists(ckpt_dir: str) -> bool:
        return os.path.exists(os.path.join(ckpt_dir, MANIFEST_NAME))


def crc32_of(mv) -> int:
    return zlib.crc32(mv) & 0xFFFFFFFF
