"""Checkpoint manifest: the metadata header mapping tensors to file extents.

Paper §2 stage (4): "Metadata headers map tensors to offsets in files for
reconstruction during the restore." Ours additionally records the *global*
shape and per-shard index windows so restore can reshard elastically (restore
onto a different mesh than the one that saved — DESIGN.md §2 extension 4).

The manifest is a single JSON document per checkpoint version, written last and
fsync'd, then the version directory is atomically committed via rename. A
checkpoint without a committed manifest is invalid by definition (crash
consistency).
"""

from __future__ import annotations

import json
import os
import zlib
from dataclasses import dataclass, field, asdict

MANIFEST_NAME = "manifest.json"
FORMAT_VERSION = 2


@dataclass(frozen=True)
class ShardEntry:
    """One saved shard of one global tensor."""
    index: tuple[tuple[int, int], ...]  # (start, stop) per dim, global coords
    path: str                           # file path relative to ckpt dir
    offset: int                         # byte offset in file
    nbytes: int                         # logical bytes
    crc32: int | None = None

    def to_json(self):
        return {"index": [list(p) for p in self.index], "path": self.path,
                "offset": self.offset, "nbytes": self.nbytes, "crc32": self.crc32}

    @staticmethod
    def from_json(d) -> "ShardEntry":
        return ShardEntry(tuple(tuple(p) for p in d["index"]), d["path"],
                          d["offset"], d["nbytes"], d.get("crc32"))


@dataclass
class TensorRecord:
    key: str
    dtype: str           # numpy dtype string, e.g. 'bfloat16', 'float32'
    global_shape: tuple[int, ...]
    shards: list[ShardEntry] = field(default_factory=list)

    def to_json(self):
        return {"key": self.key, "dtype": self.dtype,
                "global_shape": list(self.global_shape),
                "shards": [s.to_json() for s in self.shards]}

    @staticmethod
    def from_json(d) -> "TensorRecord":
        return TensorRecord(d["key"], d["dtype"], tuple(d["global_shape"]),
                            [ShardEntry.from_json(s) for s in d["shards"]])


@dataclass
class BlobRecord:
    """A serialized non-tensor byte object (e.g. the 'lean' pytree)."""
    key: str
    path: str
    offset: int
    nbytes: int
    crc32: int | None = None

    def to_json(self):
        return asdict(self)

    @staticmethod
    def from_json(d) -> "BlobRecord":
        return BlobRecord(d["key"], d["path"], d["offset"], d["nbytes"],
                          d.get("crc32"))


@dataclass
class Manifest:
    step: int
    num_ranks: int
    strategy: str
    format_version: int = FORMAT_VERSION
    tensors: dict[str, TensorRecord] = field(default_factory=dict)
    blobs: dict[str, BlobRecord] = field(default_factory=dict)
    extra: dict = field(default_factory=dict)  # engine config, mesh, timings

    # ---- construction helpers -------------------------------------------
    def add_shard(self, key: str, dtype: str, global_shape: tuple[int, ...],
                  entry: ShardEntry) -> None:
        rec = self.tensors.get(key)
        if rec is None:
            rec = self.tensors[key] = TensorRecord(key, dtype, tuple(global_shape))
        else:
            if rec.dtype != dtype or rec.global_shape != tuple(global_shape):
                raise ValueError(f"inconsistent tensor record for {key}")
        rec.shards.append(entry)

    def merge(self, other: "Manifest") -> None:
        """Merge per-rank manifests into the global one (rank-0 commit)."""
        for key, rec in other.tensors.items():
            for s in rec.shards:
                self.add_shard(key, rec.dtype, rec.global_shape, s)
        self.blobs.update(other.blobs)

    @property
    def total_bytes(self) -> int:
        return (sum(s.nbytes for r in self.tensors.values() for s in r.shards)
                + sum(b.nbytes for b in self.blobs.values()))

    # ---- (de)serialization ------------------------------------------------
    def to_json(self) -> dict:
        return {"format_version": self.format_version, "step": self.step,
                "num_ranks": self.num_ranks, "strategy": self.strategy,
                "tensors": {k: v.to_json() for k, v in self.tensors.items()},
                "blobs": {k: v.to_json() for k, v in self.blobs.items()},
                "extra": self.extra}

    def dumps(self) -> bytes:
        return json.dumps(self.to_json(), separators=(",", ":")).encode()

    @staticmethod
    def loads(data: bytes) -> "Manifest":
        d = json.loads(data)
        if d["format_version"] > FORMAT_VERSION:
            raise ValueError(f"manifest from the future: {d['format_version']}")
        m = Manifest(d["step"], d["num_ranks"], d["strategy"],
                     d["format_version"])
        m.tensors = {k: TensorRecord.from_json(v) for k, v in d["tensors"].items()}
        m.blobs = {k: BlobRecord.from_json(v) for k, v in d["blobs"].items()}
        m.extra = d.get("extra", {})
        return m

    def save(self, ckpt_dir: str) -> None:
        payload = self.dumps()
        tmp = os.path.join(ckpt_dir, MANIFEST_NAME + ".tmp")
        with open(tmp, "wb") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(ckpt_dir, MANIFEST_NAME))

    @staticmethod
    def load(ckpt_dir: str) -> "Manifest":
        with open(os.path.join(ckpt_dir, MANIFEST_NAME), "rb") as f:
            return Manifest.loads(f.read())

    @staticmethod
    def exists(ckpt_dir: str) -> bool:
        return os.path.exists(os.path.join(ckpt_dir, MANIFEST_NAME))


def crc32_of(mv) -> int:
    return zlib.crc32(mv) & 0xFFFFFFFF
