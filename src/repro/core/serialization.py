"""Tensor extraction + "lean object" serialization (paper §2, stage 1).

A checkpointable state is an arbitrary pytree. Tensors (jax.Array / numpy) are
pre-serialized contiguous byte streams and bypass pickling entirely; everything
else — step counters, python scalars, strings, dataloader state — is the "lean
checkpoint object", pickled as one small blob.
"""

from __future__ import annotations

import pickle
from dataclasses import dataclass

import jax
import numpy as np

LEAN_KEY = "__lean__"


@dataclass(frozen=True)
class TensorStub:
    """Placeholder left in the lean object where a tensor was extracted."""
    key: str
    shape: tuple[int, ...]
    dtype: str
    is_prng_key: bool = False
    prng_impl: str | None = None


@dataclass(frozen=True)
class LocalShard:
    """A rank-local window of a global tensor (multi-writer leaf).

    Looks like a tensor whose ``.shape`` is the GLOBAL shape while holding
    only this rank's ``data`` covering ``index`` (global (start, stop) per
    dim). The save path records the window in the manifest exactly as it
    does for an addressable shard of a sharded ``jax.Array`` — this is how
    an in-process writer rank declares ownership without a multi-host mesh.
    """
    data: np.ndarray
    index: tuple[tuple[int, int], ...]
    global_shape: tuple[int, ...]

    @property
    def shape(self) -> tuple[int, ...]:
        return self.global_shape

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def ndim(self) -> int:
        return len(self.global_shape)

    @property
    def nbytes(self) -> int:
        return self.data.nbytes


def path_str(path) -> str:
    """Stable string form of a jax key path."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts) if parts else "<root>"


def _is_tensor(x) -> bool:
    return isinstance(x, (jax.Array, np.ndarray, LocalShard))


def _is_typed_prng(x) -> bool:
    return isinstance(x, jax.Array) and jax.dtypes.issubdtype(x.dtype, jax.dtypes.prng_key)


def extract_tensors(state):
    """Split a pytree into ({key: tensor}, lean_tree_with_stubs).

    Typed PRNG key arrays are stored as their uint32 key_data with the impl
    recorded on the stub so restore can re-wrap them.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    tensors: dict[str, jax.Array | np.ndarray] = {}
    lean_leaves = []
    for path, leaf in flat:
        if _is_tensor(leaf) and leaf.ndim == 0 and isinstance(leaf, np.ndarray):
            # 0-d numpy scalars ride in the lean object (cheaper than an extent)
            lean_leaves.append(leaf)
            continue
        if _is_typed_prng(leaf):
            key = path_str(path)
            impl = str(jax.random.key_impl(leaf))
            data = jax.random.key_data(leaf)
            tensors[key] = data
            lean_leaves.append(TensorStub(key, tuple(data.shape),
                                          str(data.dtype), True, impl))
        elif _is_tensor(leaf):
            key = path_str(path)
            if key in tensors:
                raise ValueError(f"duplicate tensor key {key}")
            tensors[key] = leaf
            lean_leaves.append(TensorStub(key, tuple(leaf.shape),
                                          str(leaf.dtype)))
        else:
            lean_leaves.append(leaf)
    lean_tree = jax.tree_util.tree_unflatten(treedef, lean_leaves)
    return tensors, lean_tree


def serialize_lean(lean_tree) -> bytes:
    return pickle.dumps(lean_tree, protocol=pickle.HIGHEST_PROTOCOL)


def deserialize_lean(data: bytes):
    return pickle.loads(data)


def reinsert_tensors(lean_tree, tensors: dict):
    """Inverse of extract_tensors: replace stubs with loaded tensors."""
    def sub(leaf):
        if isinstance(leaf, TensorStub):
            t = tensors[leaf.key]
            if leaf.is_prng_key:
                t = jax.random.wrap_key_data(t, impl=leaf.prng_impl)
            return t
        return leaf
    return jax.tree_util.tree_map(
        sub, lean_tree, is_leaf=lambda x: isinstance(x, TensorStub))


def iter_stubs(lean_tree):
    for leaf in jax.tree_util.tree_leaves(
            lean_tree, is_leaf=lambda x: isinstance(x, TensorStub)):
        if isinstance(leaf, TensorStub):
            yield leaf


def tensor_nbytes(t) -> int:
    return int(np.dtype(t.dtype).itemsize) * int(np.prod(t.shape, dtype=np.int64))


def to_numpy_view(t) -> np.ndarray:
    """Zero-copy (when possible) contiguous numpy view of a host tensor."""
    if isinstance(t, np.ndarray):
        return np.ascontiguousarray(t)
    return np.asarray(t)  # CPU jax.Array: usually zero-copy


def as_bytes_view(arr: np.ndarray) -> np.ndarray:
    """Flat uint8 reinterpretation (buffer-protocol safe for ml_dtypes)."""
    arr = np.ascontiguousarray(arr)
    if arr.ndim == 0:
        arr = arr.reshape(1)
    return arr.view(np.uint8).reshape(-1)
