"""Fault injection at the engine/OS boundary (DESIGN.md §13).

PRs 4–5 closed publish/GC/torn-manifest crash windows that were found by
hand-auditing the commit protocol. This module systematizes that auditing
into a permanent, deterministic fault-injection layer:

  FaultPlan       a one-shot schedule of faults, armed via ``inject(plan)``.
                  Every instrumented syscall site in the checkpoint stack
                  (``io_engine`` pwrite/preadv/fdatasync, ``engines/base``
                  fallocate, ``manifest`` write/fsync/replace,
                  ``checkpoint.replace_dir``, ``delta.publish_packs``,
                  ``multilevel`` flush renames) consults the active plan and
                  can crash (``InjectedCrash``), raise an errno
                  (ENOSPC/EIO), tear a write (persist a prefix, then crash),
                  or short-write (persist a prefix and return — exercising
                  the engines' retry loops).
  corruptors      filesystem-level post-commit damage: bit-flips, truncation,
                  zeroing — aimed at chunkstore files and manifests.
  scrub_store     CRC walk of the refcounted chunkstore driven by the kept
                  steps' manifests: corrupt files are repaired from a level-1
                  mirror when one is given, quarantined otherwise; a restore
                  that would touch a quarantined chunk fails with the typed
                  ``QuarantinedChunkError`` (a ``ManifestError``, so the
                  latest-step fallback can still try an older step).

The shims are pass-throughs (one ``is None`` check) when no plan is active;
production code pays nothing for the instrumentation. The module must stay
import-light — ``io_engine`` imports it — so anything touching the
checkpoint/delta layers is imported at call time.

Campaign entry point: ``python -m repro.core.faults --campaign`` (the
deterministic seeded campaign lives in ``core/chaos.py``; the pytest driver
in ``tests/chaos/`` runs the same engine).
"""

from __future__ import annotations

import errno as _errno
import os
import shutil
import threading
import time
import zlib
from contextlib import contextmanager
from dataclasses import dataclass, field

from . import trace

from .manifest import ManifestError

# syscall kinds an instrumented site reports
OP_WRITE = "write"
OP_READ = "read"
OP_FSYNC = "fsync"
OP_RENAME = "rename"
OP_FALLOCATE = "fallocate"
# not a syscall: the D2H gather of one dirty chunk between the
# fingerprint-diff and its put submission (DESIGN.md §14) — the window in
# which a crash must not commit a manifest referencing never-copied chunks
OP_GATHER = "gather"
# object-store requests (core/remote.py): ranged GET / PUT against the
# level-2 tier — not syscalls, but the same one-shot schedule drives them
OP_RGET = "rget"
OP_RPUT = "rput"
# recursive delete of a staging/aside/retired checkpoint dir — one consult
# per tree, carrying the root path; a torn rmtree leaves a half-deleted tree
OP_RMTREE = "rmtree"
OP_KINDS = (OP_WRITE, OP_READ, OP_FSYNC, OP_RENAME, OP_FALLOCATE, OP_GATHER,
            OP_RGET, OP_RPUT, OP_RMTREE)

# fault actions
A_CRASH = "crash"    # simulate process death at the syscall
A_ERRNO = "errno"    # raise OSError(err) from the syscall
A_TORN = "torn"      # persist a prefix of the write, then crash
A_SHORT = "short"    # persist a prefix and return its length (no crash)
A_CALL = "call"      # run a callback at the syscall, then perform it
A_STALL = "stall"    # delay the op by ``delay_s``, then perform it
ACTIONS = (A_CRASH, A_ERRNO, A_TORN, A_SHORT, A_CALL, A_STALL)

QUARANTINE_SUBDIR = "quarantine"


class InjectedCrash(RuntimeError):
    """Simulated process death at an instrumented syscall.

    In-process crash simulation: the exception unwinds the save/restore
    (running ``finally`` cleanup a real SIGKILL would skip — which releases
    buffers but does not change what already reached the filesystem), and
    the campaign then abandons the manager, marks its staging-dir owner
    dead (``simulate_owner_death``), and verifies recovery from a fresh
    manager, exactly as a restarted trainer would."""


class InjectedIOError(OSError):
    """Injected errno fault — distinguishable from a real I/O error."""


class QuarantinedChunkError(ManifestError):
    """A restore touched a chunk the scrubber quarantined as corrupt.

    Subclasses ``ManifestError`` so a latest-step restore falls back to an
    older step (which may succeed if it does not share the chunk); an
    explicitly requested step propagates the error, naming the chunk."""

    def __init__(self, store_path: str, key: str, chunk_hash: str | None):
        self.store_path = store_path
        self.key = key
        self.chunk_hash = chunk_hash
        h = f" hash={chunk_hash}" if chunk_hash else ""
        super().__init__(
            f"chunk {store_path!r} (ref by {key!r}{h}) is quarantined as "
            f"corrupt; restore cannot proceed from this step")


@dataclass
class Fault:
    """Fire ``action`` at the ``at``-th eligible syscall of kind ``op``.

    Eligibility: the op kind matches AND, when ``path_contains`` is set,
    the syscall carries a path containing it (fd-only ops never match a
    path-filtered fault). Each fault keeps its own counter and fires once.
    """
    op: str
    at: int = 1
    action: str = A_CRASH
    err: int = _errno.EIO
    frac: float = 0.5               # fraction of bytes persisted (torn/short)
    delay_s: float = 0.25           # stall duration (action="stall")
    path_contains: str | None = None
    callback: object = None         # for action="call"
    seen: int = 0                   # eligible syscalls observed so far
    done: bool = False

    def __post_init__(self):
        if self.op not in OP_KINDS:
            raise ValueError(f"unknown op kind {self.op!r}")
        if self.action not in ACTIONS:
            raise ValueError(f"unknown action {self.action!r}")
        if self.at < 1:
            raise ValueError("at is 1-based")

    def describe(self) -> str:
        where = f"@{self.path_contains}" if self.path_contains else ""
        return f"{self.action}:{self.op}#{self.at}{where}"


class FaultPlan:
    """A schedule of one-shot faults plus counters, armed via ``inject``.

    Thread-safe: engine worker threads, pipeline workers, and flush threads
    all consult the same plan. Counters are deterministic whenever the
    instrumented code path is (single-writer posix-backend schedules are;
    multiwriter rank threads interleave, which only moves WHERE a fault
    lands — the invariants must hold at every site, so any interleaving is
    a valid trial)."""

    def __init__(self, faults=()):
        self._lock = threading.Lock()
        self.faults: list[Fault] = list(faults)
        self.counts: dict[str, int] = {k: 0 for k in OP_KINDS}
        self.fired: list[str] = []    # Fault.describe() of each fired fault

    def add(self, fault: Fault) -> "FaultPlan":
        with self._lock:
            self.faults.append(fault)
        return self

    def _consult(self, op: str, path: str | None = None) -> Fault | None:
        """Count one syscall; return the fault to apply, if one fires."""
        with self._lock:
            self.counts[op] += 1
            for f in self.faults:
                if f.done or f.op != op:
                    continue
                if f.path_contains is not None and (
                        path is None or f.path_contains not in path):
                    continue
                f.seen += 1
                if f.seen >= f.at:
                    f.done = True
                    self.fired.append(f.describe())
                    trace.event("fault.injected", tier="faults",
                                attrs={"op": op, "action": f.action,
                                       "path": path or "",
                                       "fault": f.describe()})
                    return f
            return None

    @property
    def fired_count(self) -> int:
        return len(self.fired)


_ACTIVE: FaultPlan | None = None
_ARM_LOCK = threading.Lock()


@contextmanager
def inject(plan: FaultPlan):
    """Arm ``plan`` for the duration of the block (one plan at a time)."""
    global _ACTIVE
    with _ARM_LOCK:
        if _ACTIVE is not None:
            raise RuntimeError("a FaultPlan is already active")
        _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = None


def _raise_for(f: Fault, op: str):
    if f.action == A_ERRNO:
        raise InjectedIOError(f.err, os.strerror(f.err),
                              f"<injected:{op}>")
    raise InjectedCrash(f"injected crash at {f.describe()}")


def _soft(f: Fault) -> bool:
    """call/stall are soft actions: run the side effect here, then the shim
    performs the real op. Returns True when the fault was consumed."""
    if f.action == A_CALL:
        f.callback()
        return True
    if f.action == A_STALL:
        time.sleep(f.delay_s)
        return True
    return False


# --------------------------------------------------------------- syscall shims
def pwrite(fd: int, buf, offset: int) -> int:
    f = _ACTIVE._consult(OP_WRITE) if _ACTIVE is not None else None
    if f is None:
        return os.pwrite(fd, buf, offset)
    if f.action in (A_TORN, A_SHORT):
        mv = memoryview(buf)
        keep = min(max(int(len(mv) * f.frac), 0), max(len(mv) - 1, 0))
        n = os.pwrite(fd, mv[:keep], offset) if keep else 0
        if f.action == A_TORN:
            raise InjectedCrash(
                f"torn write: {n} of {len(mv)} bytes persisted")
        return n
    if _soft(f):
        return os.pwrite(fd, buf, offset)
    _raise_for(f, OP_WRITE)


def preadv(fd: int, buffers, offset: int) -> int:
    f = _ACTIVE._consult(OP_READ) if _ACTIVE is not None else None
    if f is None:
        return os.preadv(fd, buffers, offset)
    if f.action == A_SHORT:
        mv = memoryview(buffers[0])
        keep = min(max(int(len(mv) * f.frac), 1), len(mv))
        return os.preadv(fd, [mv[:keep]], offset)
    if _soft(f):
        return os.preadv(fd, buffers, offset)
    _raise_for(f, OP_READ)   # crash / errno / torn all abort the read


def _fsync_fault(fd: int) -> Fault | None:
    f = _ACTIVE._consult(OP_FSYNC) if _ACTIVE is not None else None
    if f is None:
        return None
    if _soft(f):
        return None
    _raise_for(f, OP_FSYNC)


def fsync(fd: int) -> None:
    if _fsync_fault(fd) is None:
        os.fsync(fd)


def fdatasync(fd: int) -> None:
    if _fsync_fault(fd) is None:
        os.fdatasync(fd)


def replace(src: str, dst: str) -> None:
    f = (_ACTIVE._consult(OP_RENAME, path=f"{src}\x00{dst}")
         if _ACTIVE is not None else None)
    if f is None:
        return os.replace(src, dst)
    if _soft(f):
        return os.replace(src, dst)
    _raise_for(f, OP_RENAME)


def rmtree(path: str, *, ignore_errors: bool = False) -> None:
    """Recursive-delete shim (staging/aside/retired checkpoint trees).

    Consulted once per tree with the root path. A_TORN deletes a prefix of
    the tree's files bottom-up and then crashes, modelling death mid-GC:
    recovery must tolerate (and re-reap) half-deleted staging dirs.
    ``ignore_errors`` applies to the real deletion only — injected faults
    always surface, since swallowing them is exactly the bug class the
    chaos campaign exists to catch."""
    f = (_ACTIVE._consult(OP_RMTREE, path=path)
         if _ACTIVE is not None else None)
    if f is None:
        return shutil.rmtree(path, ignore_errors=ignore_errors)
    if f.action in (A_TORN, A_SHORT):
        victims = []
        for dirpath, _dirnames, filenames in os.walk(path):
            victims.extend(os.path.join(dirpath, n) for n in filenames)
        keep = min(max(int(len(victims) * f.frac), 0),
                   max(len(victims) - 1, 0))
        for p in victims[:keep]:
            try:
                os.remove(p)
            except OSError:
                pass
        if f.action == A_TORN:
            raise InjectedCrash(
                f"torn rmtree: {keep} of {len(victims)} files removed "
                f"under {path}")
        return   # short: partial delete, no crash — tree left half-reaped
    if _soft(f):
        return shutil.rmtree(path, ignore_errors=ignore_errors)
    _raise_for(f, OP_RMTREE)


def posix_fallocate(fd: int, offset: int, length: int) -> None:
    f = _ACTIVE._consult(OP_FALLOCATE) if _ACTIVE is not None else None
    if f is None:
        return os.posix_fallocate(fd, offset, length)
    if _soft(f):
        return os.posix_fallocate(fd, offset, length)
    _raise_for(f, OP_FALLOCATE)
    # note: an A_ERRNO here is swallowed by _open_files' best-effort
    # fallocate (by design — filesystems without fallocate); A_CRASH is a
    # RuntimeError and propagates


def gather(key: str) -> None:
    """Dirty-chunk D2H gather shim (delta fp128 path, DESIGN.md §14).

    Consulted once per dirty-chunk resolve, carrying the chunk's put key as
    the path so schedules can target specific chunks. Runs on the pipeline
    worker between the fingerprint diff and the chunk's ``stream.put`` —
    a crash here unwinds through the stream abort, so the step commits
    nothing (the manifest that would have referenced the never-copied
    chunk is never written)."""
    f = _ACTIVE._consult(OP_GATHER, path=key) if _ACTIVE is not None else None
    if f is None:
        return
    if _soft(f):
        return
    _raise_for(f, OP_GATHER)   # crash / errno / torn / short all abort


def file_write(f, data: bytes) -> None:
    """Buffered-file write shim (the manifest tmp-file path)."""
    flt = _ACTIVE._consult(OP_WRITE) if _ACTIVE is not None else None
    if flt is None:
        f.write(data)
        return
    if flt.action == A_SHORT:
        # libc's buffered write loops internally: a regular-file write
        # cannot land short without an error, so the fault is a full write
        f.write(data)
        return
    if flt.action == A_TORN:
        keep = min(max(int(len(data) * flt.frac), 0), max(len(data) - 1, 0))
        f.write(data[:keep])
        f.flush()
        raise InjectedCrash(
            f"torn write: {keep} of {len(data)} bytes persisted")
    if _soft(flt):
        f.write(data)
        return
    _raise_for(flt, OP_WRITE)


def remote_op(op: str, key: str) -> Fault | None:
    """Object-store request shim (core/remote.py ranged GET / PUT).

    Unlike the syscall shims this cannot perform the op itself — the store
    does the "I/O". crash/errno raise here (before any bytes move); soft
    and data-shaping actions (stall, short, torn) are returned for the
    store to apply at the protocol-appropriate point: a stalled request
    sleeps before first byte, a short GET returns a prefix of the range,
    a torn PUT persists a prefix of the staged object and crashes without
    ever making it visible (PUT visibility is atomic)."""
    f = _ACTIVE._consult(op, path=key) if _ACTIVE is not None else None
    if f is None:
        return None
    if f.action == A_CALL:
        f.callback()
        return None
    if f.action in (A_STALL, A_SHORT, A_TORN):
        return f
    _raise_for(f, op)


# ------------------------------------------------------- post-commit corruptors
def flip_byte(path: str, offset: int) -> None:
    """Invert one byte in place (the classic silent media corruption)."""
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        if not b:
            raise ValueError(f"offset {offset} beyond EOF of {path!r}")
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))


def truncate_file(path: str, keep_bytes: int) -> None:
    with open(path, "r+b") as f:
        f.truncate(keep_bytes)


def zero_file(path: str) -> None:
    """Model ext4-style crash journal replay: rename survived, data did not."""
    with open(path, "wb"):
        pass


def simulate_owner_death(root: str, *, backdate_s: float = 3600.0) -> int:
    """Make every ``.tmp-*`` staging dir under ``root`` look like its writer
    process died ``backdate_s`` ago: rewrite ownership pidfiles to a dead
    pid and backdate dir mtimes past the young-dir grace, so a fresh
    manager's ``_gc_tmp`` treats them exactly like a crashed trainer's.
    Returns the number of dirs marked."""
    import socket
    dead_pid = 2 ** 30 + 7    # beyond pid_max everywhere we run
    # crlint: allow(CRL006): backdating an mtime needs the wall clock
    then = time.time() - backdate_s
    marked = 0
    try:
        names = os.listdir(root)
    except OSError:
        return 0
    for name in names:
        full = os.path.join(root, name)
        if ".tmp-" not in name or not os.path.isdir(full):
            continue
        from .checkpoint import OWNER_NAME  # runtime: avoid cycle
        pidfile = os.path.join(full, OWNER_NAME)
        if os.path.exists(pidfile):
            with open(pidfile, "w") as f:
                f.write(f"{dead_pid} {then:.3f} {socket.gethostname()}")
        os.utime(full, (then, then))
        marked += 1
    return marked


def referenced_chunks(root: str) -> dict[str, list]:
    """Map store-relative path ->
    [(offset, nbytes, crc32, hash, digest_kind, key), ...] for every
    store-resident reference in committed step manifests.

    ``digest_kind`` names the hash's digest function (manifest constants;
    None for extent/blob refs that carry no content address) so the
    scrubber verifies each span with the function that produced it. The
    FIRST chunk of a quantized fp128 shard gets hash=None: its write span
    includes the 20-byte packed header, which the fp128 digest domain
    excludes, so its content cannot be checked against the digest directly
    (CRC, when recorded, still covers it)."""
    from .checkpoint import _STEP_RE          # runtime: avoid cycle
    from .delta import STORE_PREFIX, is_chunked, store_rel
    from .manifest import DIGEST_FP128, Manifest
    refs: dict[str, list] = {}
    try:
        names = sorted(os.listdir(root))
    except OSError:
        return refs
    for name in names:
        if not _STEP_RE.match(name):
            continue
        try:
            m = Manifest.load(os.path.join(root, name))
        except ManifestError:
            continue
        quantized = set(m.extra.get("quantized", ()))
        for rec in m.tensors.values():
            for sh in rec.shards:
                if is_chunked(sh) and sh.chunks:
                    kind = sh.digest_kind
                    headered = (kind == DIGEST_FP128
                                and rec.key in quantized)
                    for j, r in enumerate(sh.chunks):
                        if r.path.startswith(STORE_PREFIX):
                            h = None if (headered and j == 0) else r.hash
                            refs.setdefault(store_rel(r.path), []).append(
                                (r.offset, r.nbytes, r.crc32, h, kind,
                                 rec.key))
                elif sh.path.startswith(STORE_PREFIX):
                    refs.setdefault(store_rel(sh.path), []).append(
                        (sh.offset, sh.nbytes, sh.crc32, None, None,
                         rec.key))
        for key, b in m.blobs.items():
            if b.path.startswith(STORE_PREFIX):
                refs.setdefault(store_rel(b.path), []).append(
                    (b.offset, b.nbytes, getattr(b, "crc32", None), None,
                     None, key))
    return refs


def corrupt_store_chunk(root: str, rng) -> tuple[str, int] | None:
    """Flip one byte inside a randomly chosen referenced chunk span.
    Returns (store-relative path, absolute flip offset) or None when the
    directory holds no store-resident references."""
    from .delta import CHUNKSTORE_DIR
    refs = referenced_chunks(root)
    candidates = [(rel, spans) for rel, spans in sorted(refs.items())
                  if os.path.exists(os.path.join(root, CHUNKSTORE_DIR, rel))]
    if not candidates:
        return None
    rel, spans = candidates[rng.randrange(len(candidates))]
    off, nbytes, _crc, _h, _kind, _key = spans[rng.randrange(len(spans))]
    flip_at = off + rng.randrange(max(nbytes, 1))
    flip_byte(os.path.join(root, CHUNKSTORE_DIR, rel), flip_at)
    return rel, flip_at


# ------------------------------------------------------------------ scrubber
@dataclass
class ScrubReport:
    files_scanned: int = 0
    chunks_checked: int = 0
    corrupt: list = field(default_factory=list)      # store-rel paths found bad
    repaired: list = field(default_factory=list)     # refetched from level 1
    quarantined: list = field(default_factory=list)  # moved aside

    @property
    def clean(self) -> bool:
        return not self.corrupt

    def summary(self) -> str:
        return (f"scrub: {self.files_scanned} files / "
                f"{self.chunks_checked} chunks checked, "
                f"{len(self.corrupt)} corrupt "
                f"({len(self.repaired)} repaired, "
                f"{len(self.quarantined)} quarantined)")


def _verify_spans(path: str, spans) -> tuple[int, bool]:
    """(spans checked, all good). A span verifies by CRC when recorded,
    else by recomputing its content hash with the digest kind that
    produced it (blake2b or fp128 — fp128 chunk digests cover exactly the
    written span, quantized first-chunks excepted, see
    ``referenced_chunks``), else by being readable at its extent."""
    import hashlib
    from .manifest import DIGEST_FP128
    checked = 0
    try:
        with open(path, "rb") as f:
            for off, nbytes, crc, h, kind, _key in spans:
                f.seek(off)
                data = f.read(nbytes)
                checked += 1
                if len(data) != nbytes:
                    return checked, False
                if crc is not None:
                    if zlib.crc32(data) & 0xFFFFFFFF != crc:
                        return checked, False
                elif h is not None:
                    if kind == DIGEST_FP128:
                        from ..kernels.fingerprint import digest_bytes
                        if digest_bytes(data) != h:
                            return checked, False
                    elif hashlib.blake2b(
                            data, digest_size=16).hexdigest() != h:
                        return checked, False
    except OSError:
        return checked, False
    return checked, True


def scrub_store(root: str, *, remote_root: str | None = None) -> ScrubReport:
    """Verify every store file the kept steps reference, span by span.

    A file failing verification is repaired from ``remote_root``'s mirror
    of the store (level 1) when that copy verifies, else moved to
    ``<root>/chunkstore/quarantine/<rel>`` — out of the restore path, but
    kept for forensics. Quarantined chunks make dependent restores fail
    with ``QuarantinedChunkError`` instead of a CRC mismatch deep in the
    read stream (see ``check_quarantined``)."""
    from .delta import CHUNKSTORE_DIR
    store = os.path.join(root, CHUNKSTORE_DIR)
    report = ScrubReport()
    refs = referenced_chunks(root)
    for rel in sorted(refs):
        spans = refs[rel]
        fp = os.path.join(store, rel)
        report.files_scanned += 1
        checked, good = _verify_spans(fp, spans)
        report.chunks_checked += checked
        if good:
            continue
        report.corrupt.append(rel)
        if remote_root is not None and _repair_from(
                remote_root, store, rel, spans):
            report.repaired.append(rel)
            continue
        _quarantine(store, rel)
        report.quarantined.append(rel)
    return report


def _repair_from(remote_root: str, store: str, rel: str, spans) -> bool:
    """Refetch one store file from the level-1 mirror, verify, land it
    atomically. Returns False when no (good) mirror copy exists."""
    import shutil
    from .delta import CHUNKSTORE_DIR
    src = os.path.join(remote_root, CHUNKSTORE_DIR, rel)
    if not os.path.exists(src):
        return False
    _checked, good = _verify_spans(src, spans)
    if not good:
        return False     # mirror is corrupt too: quarantine instead
    dst = os.path.join(store, rel)
    os.makedirs(os.path.dirname(dst), exist_ok=True)
    tmp = dst + ".repair"
    shutil.copyfile(src, tmp)
    with open(tmp, "rb") as f:
        os.fsync(f.fileno())
    os.replace(tmp, dst)
    return True


def _quarantine(store: str, rel: str) -> None:
    src = os.path.join(store, rel)
    if not os.path.exists(src):
        return           # already missing — nothing to move aside
    dst = os.path.join(store, QUARANTINE_SUBDIR, rel)
    os.makedirs(os.path.dirname(dst), exist_ok=True)
    os.replace(src, dst)


def check_quarantined(ckpt_dir: str, manifest) -> None:
    """Raise ``QuarantinedChunkError`` if the manifest references a store
    file that is missing from the store but present under quarantine.
    Called at the top of every restore: a typed, named failure beats a
    FileNotFoundError from deep inside the read pipeline."""
    from .delta import CHUNKSTORE_DIR, STORE_PREFIX, is_chunked, store_rel
    root = os.path.dirname(os.path.abspath(ckpt_dir))
    store = os.path.join(root, CHUNKSTORE_DIR)
    qdir = os.path.join(store, QUARANTINE_SUBDIR)
    if not os.path.isdir(qdir):
        return
    seen: set[str] = set()

    def _check(path: str, key: str, chunk_hash: str | None):
        rel = store_rel(path)
        if rel in seen:
            return
        seen.add(rel)
        if not os.path.exists(os.path.join(store, rel)) and os.path.exists(
                os.path.join(qdir, rel)):
            raise QuarantinedChunkError(rel, key, chunk_hash)

    for rec in manifest.tensors.values():
        for sh in rec.shards:
            if is_chunked(sh) and sh.chunks:
                for r in sh.chunks:
                    if r.path.startswith(STORE_PREFIX):
                        _check(r.path, rec.key, r.hash)
            elif sh.path.startswith(STORE_PREFIX):
                _check(sh.path, rec.key, None)
    for key, b in manifest.blobs.items():
        if b.path.startswith(STORE_PREFIX):
            _check(b.path, key, None)


def main(argv=None) -> int:
    from .chaos import main as chaos_main   # runtime: chaos imports the stack
    return chaos_main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
