"""Tiered transfer engine — tier-to-tier checkpoint movement through the
io_engine stack (DESIGN.md §8).

Checkpoint bytes traverse storage tiers whose bandwidths differ by orders of
magnitude (HBM → host DRAM → node-local NVMe → PFS). The initial capture is
only half the story: the level-0 → level-1 flush and the level-1 → level-0
restore prefetch move the same bytes again, and a buffered ``shutil`` loop on
that path throws away everything the paper's measurements argue for (batched
kernel-accelerated submission, request coalescing, aligned buffers).

``TieredTransferEngine`` executes those transfers as ``IORequest`` streams:

  · files are split into pipelined extents (``aggregation.chunk_extents``);
    requested ranges are expanded to alignment boundaries and
    interval-merged so every submission is one large aligned I/O,
  · data is staged through pooled ``AlignedBuffer``s, O_DIRECT-capable on
    both sides of the transfer,
  · reads (source tier) and writes (destination tier) run on separate
    ``io_engine`` backends whose ``EngineStats`` attribute bandwidth per tier,
  · stragglers are hedged at *extent* granularity: a late extent gets a
    duplicate request and the first completion wins, so one contended OST
    stalls megabytes, not a whole file. Losing attempts that outlive the
    transfer are handed to a background janitor with their engines, fds,
    and buffers — the caller's latency is bounded by the hedge, not by a
    hung syscall. The janitor drains the stragglers and parks the engine
    pair in a bounded pool for the next transfer, so repeated hedged
    transfers reuse engines instead of growing thread count monotonically.

``RestorePrefetcher`` is the restore-side consumer: it stages a remote
checkpoint's manifest and lean object into a level-0 staging directory, then
pulls exactly the extents the restore plan will read (elastic resharding
reads a subset) ahead of tensor materialization. When the fetched extents
cover the full checkpoint, the staging directory is promoted to a committed
level-0 step so the next restore is local.
"""

from __future__ import annotations

import os
import threading
from collections import deque
from dataclasses import dataclass, field

from . import faults, trace
from .aggregation import Extent, chunk_extents
from .buffers import (AlignedBuffer, BufferPool, PAGE, StageBudget, align_up,
                      aligned_span)
from .io_engine import (EngineStats, IOEngine, IORequest, OP_READ, OP_WRITE,
                        make_engine, open_for, resolve_backend)
from .manifest import CHUNK_KIND, MANIFEST_NAME, Manifest


@dataclass
class TransferStats:
    files: int = 0
    bytes: int = 0            # logical bytes moved (once, hedges excluded)
    extents: int = 0          # extent-granular segments issued
    seconds: float = 0.0
    hedged: int = 0           # duplicate extent requests issued
    hedge_wins: int = 0       # duplicates that beat the original
    peak_staged_bytes: int = 0  # max staged bytes in flight (backpressure)
    backend: str = ""
    read_stats: EngineStats = field(default_factory=EngineStats)   # source tier
    write_stats: EngineStats = field(default_factory=EngineStats)  # dest tier

    @property
    def gbps(self) -> float:
        return self.bytes / self.seconds / 1e9 if self.seconds else 0.0

    def per_tier(self) -> dict:
        """Per-tier attribution for benchmark reports."""
        return {"source": self.read_stats.as_dict(),
                "destination": self.write_stats.as_dict()}


class _Segment:
    """One contiguous file region in flight: src fd → staged buffer → dst fd."""

    __slots__ = ("path", "offset", "nbytes", "src_fd", "dst_fd", "state",
                 "buf", "deadline", "primary_read", "primary_write",
                 "writes_out", "hedged_read", "hedged_write", "buf_forgiven")

    def __init__(self, path: str, offset: int, nbytes: int,
                 src_fd: int, dst_fd: int):
        self.path, self.offset, self.nbytes = path, offset, nbytes
        self.src_fd, self.dst_fd = src_fd, dst_fd
        self.state = "queued"          # queued → reading → writing → done
        self.buf: AlignedBuffer | None = None
        self.deadline = 0.0
        self.primary_read = self.primary_write = -1
        self.writes_out = 0
        self.hedged_read = self.hedged_write = False
        self.buf_forgiven = False      # buf bytes already dropped from budget


class TieredTransferEngine:
    """Moves checkpoint bytes between tiers as hedged IORequest streams."""

    def __init__(self, backend: str = "auto", *,
                 chunk_bytes: int = 4 << 20,
                 queue_depth: int = 16,
                 direct: bool = False,
                 hedge_after_s: float = 5.0,
                 min_bw_bytes_s: float = 50e6,
                 fsync: bool = True,
                 align: int = PAGE,
                 pool: BufferPool | None = None,
                 inflight_bytes: int | None = None,
                 engine_factory=None):
        """``inflight_bytes`` caps staged bytes in flight (StageBudget — the
        same backpressure primitive as the streaming save pipeline); None
        leaves staging bounded only by ``queue_depth`` segments."""
        self.backend = resolve_backend(backend)
        self.chunk_bytes = chunk_bytes
        self.queue_depth = queue_depth
        self.inflight_bytes = inflight_bytes
        self.direct = direct
        self.hedge_after_s = hedge_after_s
        self.min_bw_bytes_s = min_bw_bytes_s
        self.fsync = fsync
        self.align = align
        self.pool = pool or BufferPool()
        self._engine_factory = engine_factory   # (role) -> IOEngine, tests
        self._read_io: IOEngine | None = None   # reused across transfers
        self._write_io: IOEngine | None = None
        # drained engine pairs parked by the janitor for reuse: repeated
        # hedged transfers must not grow thread/engine count monotonically
        # crlint: guarded-by(_pool_lock)
        self._engine_pool: list[tuple[IOEngine, IOEngine]] = []
        self._pool_lock = threading.Lock()
        self.engine_pool_limit = 2
        self.engines_built = 0                  # test observability
        # serializes transfers on the shared engine pair (a background
        # flush and a restore prefetch may arrive from different threads)
        self._xfer_lock = threading.Lock()
        self.last_stats = TransferStats()

    # ------------------------------------------------------------------- API
    def transfer(self, pairs: list[tuple[str, str]]) -> TransferStats:
        """Copy whole files ``[(src_abs, dst_abs), ...]`` tier to tier."""
        ranges = []
        for src, dst in pairs:
            size = os.path.getsize(src)
            ranges.append((src, dst, size, [(0, size)]))
        return self._execute(ranges, files=len(pairs))

    def fetch_ranges(self, src_dir: str, dst_dir: str,
                     extents: list[Extent]) -> TransferStats:
        """Pull byte ranges of files under ``src_dir`` into same-named files
        under ``dst_dir`` (sized like the source, sparse elsewhere)."""
        by_path: dict[str, list[tuple[int, int]]] = {}
        for e in extents:
            by_path.setdefault(e.path, []).append((e.offset, e.nbytes))
        ranges = []
        for path, spans in by_path.items():
            src = os.path.join(src_dir, path)
            size = os.path.getsize(src)
            aligned = []
            for off, n in spans:
                start, span = aligned_span(off, n, self.align)
                aligned.append((start, min(start + span, size)))
            ranges.append((src, os.path.join(dst_dir, path), size,
                           _merge_intervals(aligned)))
        return self._execute(ranges, files=len(ranges))

    def close(self) -> None:
        self._discard_engines()
        with self._pool_lock:
            pairs, self._engine_pool = self._engine_pool[:], []
        for r, w in pairs:
            r.close()
            w.close()
        self.pool.drain()

    # ------------------------------------------------------------- execution
    def _make_engine(self, role: str) -> IOEngine:
        if self._engine_factory is not None:
            return self._engine_factory(role)
        kw = {}
        if self.backend == "threadpool":
            kw = {"workers": min(self.queue_depth, 16)}
        return make_engine(self.backend, **kw)

    def _engines(self) -> tuple[IOEngine, IOEngine]:
        """Lazily build the read/write pair once; transfers are serialized
        (flush waits on flush, restore on flush), so reuse is safe. A pair
        the janitor drained after a hedged transfer is reused before a new
        one is built."""
        if self._read_io is None:
            with self._pool_lock:
                pair = (self._engine_pool.pop() if self._engine_pool
                        else None)
            if pair is not None:
                self._read_io, self._write_io = pair
            else:
                self._read_io = self._make_engine("read")
                self._write_io = self._make_engine("write")
                self._write_io.tier = "level1"   # spans land on the L1 track
                self.engines_built += 2
                # hedged attempts must tolerate one attempt failing while
                # its sibling succeeds — errors arrive as Completion.error
                self._read_io.capture_errors = True
                self._write_io.capture_errors = True
        return self._read_io, self._write_io

    def _discard_engines(self) -> None:
        for e in (self._read_io, self._write_io):
            if e is not None:
                e.close()
        self._read_io = self._write_io = None

    def _park_engines(self, read_io: IOEngine, write_io: IOEngine) -> None:
        """Return a drained pair to the bounded pool (close when full)."""
        with self._pool_lock:
            if len(self._engine_pool) < self.engine_pool_limit:
                self._engine_pool.append((read_io, write_io))
                return
        read_io.close()
        write_io.close()

    def _execute(self, ranges, files: int) -> TransferStats:
        """ranges: [(src_abs, dst_abs, file_size, [(start, end), ...])]"""
        with self._xfer_lock:
            return self._execute_locked(ranges, files)

    def _execute_locked(self, ranges, files: int) -> TransferStats:
        total = sum(end - start for _s, _d, _sz, iv in ranges
                    for start, end in iv)
        with trace.span("tier.transfer", tier="level1", nbytes=total,
                        attrs={"files": files}):
            return self._execute_traced(ranges, files)

    def _execute_traced(self, ranges, files: int) -> TransferStats:
        stats = TransferStats(backend=self.backend, files=files)
        t0 = trace.clock()
        segments: list[_Segment] = []
        src_fds: list[int] = []
        dst_fds: list[int] = []
        read_io, write_io = self._engines()
        read_io.stats = EngineStats()    # per-call tier attribution
        write_io.stats = EngineStats()
        ok = False
        orphans = None
        try:
            for src, dst, size, intervals in ranges:
                # O_DIRECT only for alignment-sized files (data files are
                # fallocated to aligned sizes; manifest.json is not)
                direct = self.direct and size % self.align == 0
                sfd = open_for(src, "r", direct=direct)
                dfd = open_for(dst, "rw", direct=direct)
                src_fds.append(sfd)
                dst_fds.append(dfd)
                try:
                    faults.posix_fallocate(dfd, 0, size)
                # modeled fallback for filesystems without fallocate — an
                # injected ENOSPC here degrades to ftruncate by design
                # crlint: allow(CRL005): fallocate fallback is the contract
                except OSError:
                    os.ftruncate(dfd, size)
                for start, end in intervals:
                    for seg in self._plan_segments(src, start, end, sfd, dfd):
                        segments.append(seg)
            orphans = self._run(segments, read_io, write_io, stats)
            if self.fsync:
                for fd in dst_fds:
                    write_io.fsync(fd)
            ok = True
        finally:
            keep = orphans[1] if (ok and orphans) else ()
            if not ok:   # inflight state unknown after an error: rebuild
                self._discard_engines()   # waits out any hung attempt
            for fd in src_fds + dst_fds:
                if fd not in keep:
                    os.close(fd)
        if orphans:
            # losing hedge attempts outlive this call: hand their engines,
            # buffers, and fds to a janitor so the caller isn't tail-bound
            # by a hung syscall (the hedge already won)
            self._spawn_janitor(read_io, write_io, *orphans)
        stats.read_stats = read_io.stats
        stats.write_stats = write_io.stats
        stats.seconds = trace.clock() - t0
        self.last_stats = stats
        return stats

    def _spawn_janitor(self, read_io: IOEngine, write_io: IOEngine,
                       bufs, fds) -> None:
        # detach the pair so the next transfer starts immediately; the
        # janitor drains the stragglers and parks the pair for reuse
        self._read_io = self._write_io = None

        def drain(io: IOEngine, deadline: float) -> bool:
            while io.inflight and trace.clock() < deadline:
                try:
                    io.poll(min_n=1, timeout_s=0.1)
                # crlint: allow(CRL005): draining losing hedge attempts —
                # the winner already committed; a loser's error is expected
                except BaseException:
                    pass           # loser failed after its hedge won
            return not io.inflight

        def janitor():
            deadline = trace.clock() + 60.0
            ok = drain(read_io, deadline) and drain(write_io, deadline)
            if ok:
                # no attempt references the buffers or fds anymore: release
                # buffers back to the shared pool and park the engine pair
                for fd in fds:
                    os.close(fd)
                for b in bufs:
                    b.release()
                self._park_engines(read_io, write_io)
                return
            # a syscall is still hung past the deadline: fall back to the
            # discard path — reusing its buffer or engine would hand a live
            # kernel write target to the next transfer
            try:
                read_io.close()
                write_io.close()
            # crlint: allow(CRL005): closing a wedged engine past the drain
            # deadline — nothing observes the janitor thread's errors
            except BaseException:
                pass
            for b in bufs:
                b.destroy()
            for fd in fds:
                os.close(fd)

        threading.Thread(target=janitor, daemon=True,
                         name="tiered-janitor").start()

    def _plan_segments(self, path: str, start: int, end: int,
                       src_fd: int, dst_fd: int):
        """One pipelined, individually-hedgeable segment per aligned chunk
        of the interval (small ranges were already interval-merged)."""
        for e in chunk_extents(path, end - start, self.chunk_bytes,
                               self.align, start=start):
            yield _Segment(path, e.offset, e.nbytes, src_fd, dst_fd)

    def _stage_deadline(self, nbytes: int) -> float:
        return trace.clock() + max(self.hedge_after_s,
                                         nbytes / self.min_bw_bytes_s)

    def _run(self, segments: list[_Segment], read_io: IOEngine,
             write_io: IOEngine, stats: TransferStats
             ) -> tuple[list, set] | None:
        """Drive all segments to done; returns straggling losing attempts'
        (buffers, fds) when a hedge won but its original is still in
        flight, else None."""
        pending = deque(segments)
        active: set[_Segment] = set()
        reads: dict[int, tuple[_Segment, AlignedBuffer]] = {}
        writes: dict[int, _Segment] = {}
        token = 0
        budget = StageBudget(self.inflight_bytes)
        forgiven_reads: set[int] = set()

        def release(buf: AlignedBuffer):
            budget.sub(buf.nbytes)
            buf.release()

        def release_read(tok: int, buf: AlignedBuffer):
            if tok in forgiven_reads:   # bytes already dropped at hedge win
                forgiven_reads.discard(tok)
                buf.release()
            else:
                release(buf)

        def forgive_stragglers(seg: _Segment, winner_tok: int):
            """A hedge attempt won this segment's read: the losing attempt's
            buffer is a straggler — drop its bytes from the budget NOW so
            backpressure never re-serializes issuance behind the very
            straggler the hedge just masked."""
            for tok, (s, b) in reads.items():
                if s is seg and tok != winner_tok and tok not in forgiven_reads:
                    budget.sub(b.nbytes)
                    forgiven_reads.add(tok)

        def release_seg_buf(seg: _Segment):
            if seg.buf_forgiven:
                seg.buf.release()
            else:
                release(seg.buf)

        def issue_read(seg: _Segment, hedge: bool = False):
            nonlocal token
            token += 1
            # staged buffers are deliberately NOT pool-released on error — a
            # hung async attempt may still target them; _execute_locked
            # discards the engines (waiting out inflight attempts) and the
            # buffers die with GC via AlignedBuffer.destroy
            # crlint: allow(CRL004): buffers intentionally die with engines
            buf = self.pool.get(align_up(seg.nbytes, self.align))
            budget.add(buf.nbytes)
            reads[token] = (seg, buf)
            if not hedge:
                seg.primary_read = token
                seg.state = "reading"
                seg.deadline = self._stage_deadline(seg.nbytes)
            read_io.submit([IORequest(OP_READ, seg.src_fd, seg.offset, buf,
                                      0, seg.nbytes, user_data=token)])

        def issue_write(seg: _Segment, hedge: bool = False):
            nonlocal token
            token += 1
            writes[token] = seg
            seg.writes_out += 1
            if not hedge:
                seg.primary_write = token
                seg.state = "writing"
                seg.deadline = self._stage_deadline(seg.nbytes)
            write_io.submit([IORequest(OP_WRITE, seg.dst_fd, seg.offset,
                                       seg.buf, 0, seg.nbytes,
                                       user_data=token)])

        def on_read(c):
            seg, buf = reads.pop(c.user_data)
            if c.error is not None:
                release_read(c.user_data, buf)
                if seg.state != "reading":
                    return                 # loser failed after the win
                if any(s is seg for s, _b in reads.values()):
                    return                 # sibling attempt still racing
                raise c.error              # ALL read attempts failed
            if seg.state != "reading":     # losing hedge attempt: discard
                release_read(c.user_data, buf)
                return
            if c.user_data != seg.primary_read:
                stats.hedge_wins += 1
                trace.event("hedge.win", tier="level1", nbytes=seg.nbytes,
                            attrs={"op": "read"})
            elif seg.hedged_read:
                trace.event("hedge.lose", tier="level1",
                            attrs={"op": "read"})
            forgive_stragglers(seg, c.user_data)
            seg.buf = buf
            issue_write(seg)

        def on_write(c):
            seg = writes.pop(c.user_data)
            seg.writes_out -= 1
            if c.error is not None:
                if seg.state != "writing":
                    if seg.state == "done" and seg.writes_out == 0:
                        release_seg_buf(seg)
                    return                 # loser failed after the win
                if any(s is seg for s in writes.values()):
                    return                 # sibling attempt still racing
                raise c.error              # ALL write attempts failed
            if seg.state == "writing":     # first completion wins
                if c.user_data != seg.primary_write:
                    stats.hedge_wins += 1
                    trace.event("hedge.win", tier="level1",
                                nbytes=seg.nbytes, attrs={"op": "write"})
                elif seg.hedged_write:
                    trace.event("hedge.lose", tier="level1",
                                attrs={"op": "write"})
                seg.state = "done"
                stats.bytes += seg.nbytes
                active.discard(seg)
                if seg.writes_out > 0 and not seg.buf_forgiven:
                    # a losing write still references buf: straggler — stop
                    # counting it against issuance (mirrors forgive_stragglers)
                    budget.sub(seg.buf.nbytes)
                    seg.buf_forgiven = True
            if seg.state == "done" and seg.writes_out == 0:
                release_seg_buf(seg)       # safe: no attempt references it

        def maybe_hedge():
            now = trace.clock()
            for seg in active:
                if now < seg.deadline:
                    continue
                if seg.state == "reading" and not seg.hedged_read:
                    seg.hedged_read = True
                    stats.hedged += 1
                    trace.event("hedge.issue", tier="level1",
                                nbytes=seg.nbytes, attrs={"op": "read"})
                    issue_read(seg, hedge=True)
                elif seg.state == "writing" and not seg.hedged_write:
                    seg.hedged_write = True
                    stats.hedged += 1
                    trace.event("hedge.issue", tier="level1",
                                nbytes=seg.nbytes, attrs={"op": "write"})
                    issue_write(seg, hedge=True)

        def next_deadline() -> float:
            now = trace.clock()
            cands = [seg.deadline - now for seg in active
                     if not (seg.hedged_read if seg.state == "reading"
                             else seg.hedged_write)]
            return max(0.001, min(cands)) if cands else 0.05

        # Exit when every segment is done — NOT when every attempt has
        # completed: leftover attempts are losing hedges whose segments
        # already committed, and waiting on them would re-introduce the
        # exact tail the hedge was issued against.
        while pending or active:
            while pending and len(active) < self.queue_depth:
                # staged-byte backpressure: defer issuance (never block the
                # completion loop) until writes land and release buffers
                need = BufferPool.size_class(
                    align_up(max(pending[0].nbytes, 1), self.align))
                if not budget.admits(need):
                    break
                seg = pending.popleft()
                active.add(seg)
                stats.extents += 1
                issue_read(seg)
            rcs = read_io.poll() if reads else []
            wcs = write_io.poll() if writes else []
            if not rcs and not wcs and (reads or writes):
                timeout = min(next_deadline(), 0.05)
                if read_io.inflight:
                    rcs = read_io.poll(min_n=1, timeout_s=timeout)
                elif write_io.inflight:
                    wcs = write_io.poll(min_n=1, timeout_s=timeout)
            for c in rcs:
                on_read(c)
            for c in wcs:
                on_write(c)
            maybe_hedge()

        stats.peak_staged_bytes = budget.peak
        if not reads and not writes:
            return None
        # straggling losers: their buffers (private read buffers + the
        # shared seg.buf a losing write still reads from) and fds must
        # outlive this call; the janitor reaps them
        bufs = [buf for _seg, buf in reads.values()]
        bufs += list({id(s.buf): s.buf for s in writes.values()}.values())
        fds = ({s.src_fd for s, _b in reads.values()}
               | {s.dst_fd for s in writes.values()})
        return bufs, fds


class _IntervalSet:
    """Merged logical byte intervals, for prefetch coverage accounting."""

    def __init__(self):
        self._ivs: list[tuple[int, int]] = []

    def add(self, start: int, end: int) -> None:
        if end > start:
            self._ivs = _merge_intervals(self._ivs + [(start, end)])

    def covers(self, start: int, end: int) -> bool:
        if end <= start:
            return True
        for lo, hi in self._ivs:
            if lo <= start and end <= hi:
                return True
        return False


def _merge_intervals(ivs: list[tuple[int, int]]) -> list[tuple[int, int]]:
    out: list[tuple[int, int]] = []
    for lo, hi in sorted(ivs):
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


class RestorePrefetcher:
    """Stages a level-1 checkpoint's hot extents at level 0 ahead of restore.

    Wired into ``CheckpointManager.restore``: ``begin`` stages the manifest
    and lean-object extents (enough to plan the read set), ``fetch_extents``
    pulls the planned tensor extents, ``finish`` promotes the staging
    directory to a committed level-0 step when the fetched extents cover the
    whole checkpoint (a resharded restore that reads a subset stays staged
    and is garbage-collected instead).

    The staged level-0 copy feeds the same streaming ReadStream as a local
    restore (the RestorePipeline's ``on_reqs`` hook fires ``fetch_extents``
    with exactly the planned reads before the stream opens), so a level-1
    resume gets the identical overlap of decode/assembly/H2D against the
    local reads; ``last_fetch_stats`` attributes the tier-1 pull separately
    (``RestoreMetrics.prefetch_seconds`` records its wall time).
    """

    STAGING_SUFFIX = ".tmp-prefetch"

    def __init__(self, remote_dir: str,
                 transfer: TieredTransferEngine | None = None):
        self.remote_dir = os.path.abspath(remote_dir)
        self._owns_transfer = transfer is None
        self.transfer = transfer or TieredTransferEngine()
        self._active: dict[str, dict] = {}   # staged dir -> state
        self.last_fetch_stats: TransferStats | None = None

    def begin(self, step: int, local_dir: str) -> str | None:
        """Stage manifest + blob extents for ``step``; returns the staging
        dir, or None when the step is not committed at the remote tier."""
        from .checkpoint import step_dir_name
        src = os.path.join(self.remote_dir, step_dir_name(step))
        if not Manifest.exists(src):
            return None
        manifest = Manifest.load(src)
        staged = os.path.join(local_dir,
                              step_dir_name(step) + self.STAGING_SUFFIX)
        faults.rmtree(staged, ignore_errors=True)
        os.makedirs(staged)
        try:
            self.transfer.transfer([(os.path.join(src, MANIFEST_NAME),
                                     os.path.join(staged, MANIFEST_NAME))])
            fetched: dict[str, _IntervalSet] = {}
            blob_extents = [Extent(k, b.path, b.offset, b.nbytes)
                            for k, b in manifest.blobs.items()]
            if blob_extents:
                self.transfer.fetch_ranges(src, staged, blob_extents)
                for e in blob_extents:
                    fetched.setdefault(e.path, _IntervalSet()).add(
                        e.offset, e.offset + e.nbytes)
        except BaseException:   # failed mid-stage: don't leak the dir
            faults.rmtree(staged, ignore_errors=True)
            raise
        self._active[staged] = {"src": src, "manifest": manifest,
                                "fetched": fetched}
        return staged

    def fetch_extents(self, staged: str, reqs) -> TransferStats | None:
        """Pull planned read extents (objects with .path/.offset/.nbytes)
        not already staged."""
        state = self._active.get(staged)
        if state is None:
            return None
        todo = []
        for r in reqs:
            ivs = state["fetched"].setdefault(r.path, _IntervalSet())
            if not ivs.covers(r.offset, r.offset + r.nbytes):
                todo.append(Extent(getattr(r, "key", r.path), r.path,
                                   r.offset, r.nbytes))
        if not todo:
            return None
        stats = self.transfer.fetch_ranges(state["src"], staged, todo)
        for e in todo:
            state["fetched"][e.path].add(e.offset, e.offset + e.nbytes)
        self.last_fetch_stats = stats
        return stats

    def finish(self, staged: str, final: str) -> bool:
        """Promote the staging dir to a committed level-0 step iff the
        fetched extents cover every extent in the manifest."""
        state = self._active.pop(staged, None)
        if state is None:
            return False
        manifest: Manifest = state["manifest"]
        fetched = state["fetched"]

        def covered(path, off, n):
            ivs = fetched.get(path)
            return ivs is not None and ivs.covers(off, off + n)

        def extents(rec):
            """Real on-disk extents of a record: chunk-reference shards
            (delta, §12) resolve to their chunk extents — the synthetic
            entry path names nothing fetchable."""
            for sh in rec.shards:
                if sh.kind == CHUNK_KIND:
                    yield from (sh.chunks or ())
                else:
                    yield sh

        complete = all(
            covered(x.path, x.offset, x.nbytes)
            for rec in manifest.tensors.values() for x in extents(rec)
        ) and all(covered(b.path, b.offset, b.nbytes)
                  for b in manifest.blobs.values())
        if not complete:
            faults.rmtree(staged, ignore_errors=True)
            return False
        # displaced-aside publish (checkpoint.replace_dir): promoting over an
        # existing local step must never open a window where a crash leaves
        # NEITHER the old nor the new copy — the naive rmtree-then-rename
        # promote did exactly that
        from .checkpoint import replace_dir
        replace_dir(staged, final)
        return True

    def discard(self, staged: str) -> None:
        """Abandon an in-flight prefetch (restore failed mid-way)."""
        self._active.pop(staged, None)
        faults.rmtree(staged, ignore_errors=True)

    def close(self) -> None:
        for staged in list(self._active):
            self.discard(staged)
        if self._owns_transfer:   # injected engines belong to their owner
            self.transfer.close()
