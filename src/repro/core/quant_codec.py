"""int8 checkpoint codec — beyond-paper flush-volume optimization.

The paper's roofline is storage bandwidth; quantizing optimizer moments
(fp32 → int8 + per-512-group scales) cuts their flush bytes ~3.9× and
end-to-end checkpoint volume ~2.3× (moments are 8 of every 10 state bytes
under AdamW with bf16 params). Uses the Pallas kernel on TPU and its jitted
jnp oracle on CPU (interpret-mode Pallas would be Python-slow at GB scale).

Wire format per packed shard (little-endian):
    magic  u32 = 0x51384B50  ("PQ8P")
    orig_nbytes u64, rows u32, cols u32
    q payload  int8[rows*cols]
    scales     f32[rows]
"""

from __future__ import annotations

import struct

import jax
import jax.numpy as jnp
import numpy as np

MAGIC = 0x51384B50
HEADER = struct.Struct("<IQII")
GROUP_COLS = 512   # must match kernels.quantize.LANE_COLS


@jax.jit
def _quant_ref(x):
    from repro.kernels.ref import quantize_blocks_ref
    return quantize_blocks_ref(x)


@jax.jit
def _dequant_ref(q, s):
    from repro.kernels.ref import dequantize_blocks_ref
    return dequantize_blocks_ref(q, s, out_dtype=jnp.float32)


def _quantize(padded: np.ndarray):
    if jax.default_backend() == "tpu":
        from repro.kernels.quantize import quantize_blocks
        return quantize_blocks(jnp.asarray(padded))
    return _quant_ref(jnp.asarray(padded))


def packed_rows(n_elems: int) -> int:
    rows = -(-n_elems // GROUP_COLS)
    return -(-rows // 8) * 8   # ROW_BLK alignment


def packed_nbytes(n_elems: int) -> int:
    """Exact ``pack`` output size for an ``n_elems``-element input.

    The packed size depends only on the element count, so the streaming save
    pipeline can plan file offsets (and the cross-rank prefix sum) before any
    packing runs — quantization stays off the blocking path."""
    rows = packed_rows(n_elems)
    return HEADER.size + rows * GROUP_COLS + rows * 4


def pack(arr: np.ndarray) -> bytes:
    """arr: any-shape fp array -> packed int8 bytes."""
    flat = np.ascontiguousarray(arr).reshape(-1).astype(np.float32)
    n = flat.nbytes
    rows = packed_rows(flat.size)
    padded = np.zeros((rows, GROUP_COLS), np.float32)
    padded.reshape(-1)[:flat.size] = flat
    q, s = _quantize(padded)
    return (HEADER.pack(MAGIC, n, rows, GROUP_COLS)
            + np.asarray(q).tobytes() + np.asarray(s).tobytes())


def unpack(raw: np.ndarray | bytes, orig_dtype: np.dtype) -> np.ndarray:
    """Inverse of pack: returns flat uint8 view of the original bytes."""
    buf = raw.tobytes() if isinstance(raw, np.ndarray) else bytes(raw)
    magic, orig_nbytes, rows, cols = HEADER.unpack_from(buf, 0)
    if magic != MAGIC:
        raise ValueError("not a PQ8P quantized payload")
    off = HEADER.size
    q = np.frombuffer(buf, np.int8, rows * cols, off).reshape(rows, cols)
    s = np.frombuffer(buf, np.float32, rows, off + rows * cols)
    x = np.asarray(_dequant_ref(jnp.asarray(q), jnp.asarray(s)))
    n_elem = orig_nbytes // np.dtype(orig_dtype).itemsize
    return x.reshape(-1)[:n_elem].astype(orig_dtype).view(np.uint8)


def is_packed(raw) -> bool:
    try:
        b = raw[:4].tobytes() if hasattr(raw, "tobytes") else bytes(raw[:4])
        return struct.unpack("<I", b)[0] == MAGIC
    except Exception:
        return False
