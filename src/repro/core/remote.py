"""Object-store level-2 tier — parallel hedged range I/O over ranged
GET/PUT (DESIGN.md §15).

The tier stack so far stops at local disk (level 1); production checkpoints
live in object stores behind high-latency ranged HTTP, where the paper's
tiering argument bites hardest: first-byte latency is milliseconds, not
microseconds, and per-request throughput is far below what the store serves
in aggregate. The remedies are the same ones the aggregation study
motivated locally, shifted up a level:

  · objects are read as *aligned ranges* sized like transfer extents
    (``RemoteConfig.range_bytes``), with a configurable window of ranges in
    flight under the shared ``StageBudget`` backpressure primitive,
  · a late range is *hedged*: past ``max(hedge_after_s, nbytes/min_bw)``
    a duplicate request is issued and the first completion wins —
    ``tiered.py``'s extent hedging generalized to per-request hedges, which
    is how serving stacks mask object-store stall tails (gcsfuse's
    read-stall-retry),
  · partial-range responses re-request the remainder; transient 5xx
    responses retry with backoff,
  · uploads are chunkstore-aware: the level-1→2 flush consults the delta
    manifest and HEADs each content-addressed chunk object, shipping only
    chunks the store does not already hold — a 1%-dirty step moves ~1% of
    the bytes over the wire,
  · the manifest object is PUT **last**; its existence is the remote commit
    point, so a crashed upload never publishes a step that references
    un-uploaded chunks (the same manifest-last protocol as levels 0/1).

``SimObjectStore`` is an in-process simulator (configurable latency /
bandwidth / stall / error / partial-response distributions plus the
``faults`` remote shims) so benchmarks and the chaos campaign run
hermetically; a real HTTP/S3 client only needs the four-method
``ObjectStore`` surface.

Restore has two shapes: ``RemotePrefetcher`` stages ranges at level 0 and
promotes on full coverage (inheriting ``RestorePrefetcher``'s coverage
accounting and promotion protocol), while ``engines.remote.RemoteReadEngine``
streams remote ranges straight into the ``RestorePipeline`` — read →
dequantize → assemble → H2D with no local copy of the checkpoint at all.
"""

from __future__ import annotations

import os
import posixpath
import random
import re
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from queue import Empty, SimpleQueue

from . import delta as delta_mod
from . import faults, trace
from .aggregation import Extent
from .buffers import PAGE, StageBudget, aligned_span
from .manifest import MANIFEST_NAME, Manifest
from .tiered import RestorePrefetcher, _IntervalSet, _merge_intervals


class RemoteError(OSError):
    """Object-store request failed (HTTP-style status carried along)."""

    def __init__(self, status: int, key: str, what: str):
        super().__init__(f"remote {what} ({key!r}): HTTP {status}")
        self.status = status
        self.key = key


class RemoteTransientError(RemoteError):
    """Retryable failure (5xx / connection reset): retried with backoff."""


def join_key(*parts: str) -> str:
    """Join object-key components and collapse ``..`` segments — manifests
    reference the shared chunkstore as ``../chunkstore/<pack>`` relative to
    the step dir, which under a step key normalizes to the tier-wide
    ``<prefix>/chunkstore/<pack>`` object."""
    key = posixpath.normpath(posixpath.join(*[p for p in parts if p]))
    return "" if key == "." else key


@dataclass
class ObjectMeta:
    key: str
    size: int


class ObjectStore:
    """Minimal ranged-GET/PUT object-store surface (S3/GCS-shaped).

    ``put`` is atomic: the object is either fully visible at its final key
    or absent — there is no partially-visible PUT (multipart uploads only
    publish on complete). Everything above relies on that for the
    manifest-last commit protocol.
    """

    def put(self, key: str, data) -> ObjectMeta:
        raise NotImplementedError

    def get_range(self, key: str, offset: int, nbytes: int) -> bytes:
        """May return fewer bytes than asked (a partial-range response);
        callers re-request the remainder."""
        raise NotImplementedError

    def head(self, key: str) -> ObjectMeta | None:
        raise NotImplementedError

    def list_prefix(self, prefix: str) -> list[str]:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def get(self, key: str, *, max_retries: int = 3) -> bytes:
        """Whole-object GET: loops partial responses, retries transient
        errors (small objects only — manifests; data goes through the
        range scheduler)."""
        meta = self.head(key)
        if meta is None:
            raise RemoteError(404, key, "GET")
        out = bytearray(meta.size)
        got = 0
        errors = 0
        while got < meta.size:
            try:
                data = self.get_range(key, got, meta.size - got)
            except RemoteTransientError:
                errors += 1
                if errors > max_retries:
                    raise
                time.sleep(0.005 * errors)
                continue
            if not data:
                raise RemoteError(416, key, f"empty range at +{got}")
            out[got:got + len(data)] = data
            got += len(data)
        return bytes(out)


@dataclass
class SimProfile:
    """Pathology knobs for the in-process store (all off by default).

    ``stall_prob``/``stall_s`` model the object-store tail the hedging is
    aimed at: a stalled request sleeps ``stall_s`` before serving — a
    hedged duplicate re-rolls the dice and typically wins."""
    latency_s: float = 0.0            # per-request first-byte latency
    jitter_s: float = 0.0             # uniform extra latency
    bandwidth_bytes_s: float = 0.0    # per-request streaming cap (0 = off)
    stall_prob: float = 0.0
    stall_s: float = 0.5
    error_prob: float = 0.0           # transient 5xx
    partial_prob: float = 0.0         # ranged GET returns a prefix
    seed: int = 0


class SimObjectStore(ObjectStore):
    """Local filesystem-backed object store with simulated remoteness.

    Objects are files under ``root``; PUT stages to a tmp file and renames,
    so visibility is atomic like a real store. The ``faults`` remote shims
    (``rget``/``rput``) are consulted on every request, which is how the
    chaos campaign injects crashes, errnos, stalls, and short ranges
    deterministically on top of the probabilistic profile."""

    def __init__(self, root: str, profile: SimProfile | None = None):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self.profile = profile or SimProfile()
        self._lock = threading.Lock()
        # crlint: guarded-by(_lock)
        self._rng = random.Random(self.profile.seed)
        # crlint: guarded-by(_lock)
        self.gets = 0
        # crlint: guarded-by(_lock)
        self.puts = 0
        # crlint: guarded-by(_lock)
        self.heads = 0
        # crlint: guarded-by(_lock)
        self.bytes_in = 0     # over-the-wire upload payload
        # crlint: guarded-by(_lock)
        self.bytes_out = 0    # over-the-wire download payload

    def backing_path(self, key: str) -> str:
        """Filesystem path of an object — exposed so chaos corruptors can
        damage remote objects in place."""
        norm = posixpath.normpath(key)
        if posixpath.isabs(norm) or norm.startswith(".."):
            raise ValueError(f"key escapes store root: {key!r}")
        return os.path.join(self.root, *norm.split("/"))

    def _weather(self, key: str, what: str, nbytes: int) -> bool:
        """Apply the profile to one request; returns the partial flag."""
        p = self.profile
        with self._lock:
            stall = self._rng.random() < p.stall_prob
            err = self._rng.random() < p.error_prob
            partial = self._rng.random() < p.partial_prob
            jitter = self._rng.uniform(0.0, p.jitter_s) if p.jitter_s else 0.0
        delay = p.latency_s + jitter + (p.stall_s if stall else 0.0)
        if p.bandwidth_bytes_s:
            delay += nbytes / p.bandwidth_bytes_s
        if delay > 0.0:
            time.sleep(delay)
        if err:
            raise RemoteTransientError(503, key, what)
        return partial

    def put(self, key: str, data) -> ObjectMeta:
        mv = memoryview(data).cast("B")
        f = faults.remote_op(faults.OP_RPUT, key)   # crash/errno raise here
        self._weather(key, "PUT", mv.nbytes)
        path = self.backing_path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp-put-{os.getpid()}-{threading.get_ident()}"
        if f is not None and f.action == faults.A_STALL:
            time.sleep(f.delay_s)
        if f is not None and f.action == faults.A_TORN:
            # aborted multipart upload: a prefix reached the store's staging
            # area but the object is never published at its key
            keep = min(max(int(mv.nbytes * f.frac), 0), max(mv.nbytes - 1, 0))
            with open(tmp, "wb") as fh:
                fh.write(mv[:keep])
            raise faults.InjectedCrash(
                f"torn PUT: {keep} of {mv.nbytes} bytes staged, "
                f"object {key!r} never published")
        with open(tmp, "wb") as fh:
            fh.write(mv)
            fh.flush()
            # simulated store INTERNALS — the store plays the remote side of
            # the wire, so faults inject at the protocol boundary (OP_RPUT
            # above), not at its backing files
            # crlint: allow(CRL001): simulated remote internals
            os.fsync(fh.fileno())
        # crlint: allow(CRL001): see fsync above — same simulated-internals
        os.replace(tmp, path)
        with self._lock:
            self.puts += 1
            self.bytes_in += mv.nbytes
        return ObjectMeta(key, mv.nbytes)

    def get_range(self, key: str, offset: int, nbytes: int) -> bytes:
        f = faults.remote_op(faults.OP_RGET, key)   # crash/errno raise here
        self._weather(key, "GET", nbytes)
        if f is not None and f.action == faults.A_STALL:
            time.sleep(f.delay_s)
        try:
            with open(self.backing_path(key), "rb") as fh:
                fh.seek(offset)
                data = fh.read(nbytes)
        except FileNotFoundError:
            raise RemoteError(404, key, "GET") from None
        if f is not None and f.action in (faults.A_SHORT, faults.A_TORN):
            data = data[:min(max(int(len(data) * f.frac), 1), len(data))]
        elif len(data) > 1:
            with self._lock:
                partial = self._rng.random() < self.profile.partial_prob
                keep = (self._rng.randrange(1, len(data))
                        if partial else len(data))
            data = data[:keep]
        with self._lock:
            self.gets += 1
            self.bytes_out += len(data)
        return data

    def head(self, key: str) -> ObjectMeta | None:
        with self._lock:
            self.heads += 1
        if self.profile.latency_s:
            time.sleep(self.profile.latency_s)
        try:
            return ObjectMeta(key, os.path.getsize(self.backing_path(key)))
        except OSError:
            return None

    def list_prefix(self, prefix: str) -> list[str]:
        out = []
        for dirpath, _dirs, files in os.walk(self.root):
            for name in files:
                if ".tmp-put-" in name:
                    continue
                rel = os.path.relpath(os.path.join(dirpath, name), self.root)
                key = rel.replace(os.sep, "/")
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)

    def delete(self, key: str) -> None:
        try:
            os.unlink(self.backing_path(key))
        except FileNotFoundError:
            pass


# --------------------------------------------------------- range scheduling
@dataclass
class RemoteConfig:
    """Remote-tier tuning (DESIGN.md §15 for how each knob was sized)."""
    range_bytes: int = 4 << 20       # aligned range size (aggregation sweet spot)
    window: int = 8                  # ranges in flight per transfer
    hedge_after_s: float = 5.0       # stall detector floor
    min_bw_bytes_s: float = 50e6     # deadline slope: nbytes / min_bw
    max_hedges: int = 2              # duplicate attempts per range: bounds the
                                     # tail at ~(1+max_hedges) * hedge_after_s
                                     # even when a hedge itself stalls
    max_retries: int = 3             # transient 5xx retries per attempt
    retry_backoff_s: float = 0.01
    inflight_bytes: int | None = 256 << 20   # StageBudget cap on staged bytes
    align: int = PAGE
    put_workers: int = 4             # parallel uploads per step


@dataclass
class RangeStats:
    objects: int = 0
    ranges: int = 0            # range requests planned (hedges excluded)
    bytes: int = 0             # logical bytes delivered (once)
    seconds: float = 0.0
    hedged: int = 0            # duplicate range requests issued
    hedge_wins: int = 0        # duplicates that beat the original
    retries: int = 0           # partial-range re-requests + 5xx retries
    peak_staged_bytes: int = 0
    # time-to-first-completion per range (issue -> winning attempt): the
    # distribution the hedging policy is judged on — its tail must be
    # bounded by the hedge threshold, not by the store's stalls
    range_seconds: list = field(default_factory=list)

    @property
    def gbps(self) -> float:
        return self.bytes / self.seconds / 1e9 if self.seconds else 0.0

    def range_percentile(self, p: float) -> float:
        if not self.range_seconds:
            return 0.0
        s = sorted(self.range_seconds)
        return s[min(len(s) - 1, int(round(p * (len(s) - 1))))]


class _Range:
    """One ranged GET in flight (possibly hedged)."""

    __slots__ = ("rid", "key", "offset", "nbytes", "obj", "deadline",
                 "attempts", "outstanding", "demanded", "done",
                 "issued_at")

    def __init__(self, rid: int, key: str, offset: int, nbytes: int,
                 obj=None):
        self.rid, self.key, self.offset, self.nbytes = rid, key, offset, nbytes
        self.obj = obj                 # consumer tag (req key / dst fd)
        self.deadline = 0.0
        self.attempts = 0
        self.outstanding = 0
        self.demanded = False
        self.done = False
        self.issued_at = 0.0


def _split(start: int, end: int, range_bytes: int):
    """Split [start, end) on absolute range_bytes boundaries, so hedged
    re-issues and cache keys line up across callers reading overlapping
    spans of the same object."""
    off = start
    while off < end:
        nxt = min(((off // range_bytes) + 1) * range_bytes, end)
        yield off, nxt - off
        off = nxt


def _req_ranges(reqs, prefix: str, range_bytes: int) -> list[_Range]:
    """Plan ranges for engine ReadReqs: obj = (req key, offset within req)."""
    tasks = []
    for rq in reqs:
        key = join_key(prefix, rq.path)
        for off, n in _split(rq.offset, rq.offset + rq.nbytes, range_bytes):
            tasks.append(_Range(len(tasks), key, off, n,
                                obj=(rq.key, off - rq.offset)))
    return tasks


class RangeScheduler:
    """Windowed parallel ranged reads with stall-detection + hedged re-issue.

    The driving loop mirrors ``TieredTransferEngine._run`` one tier up:
    issue up to ``window`` ranges under the staged-byte budget, wait for
    completions, and past a per-range deadline issue a duplicate request
    (re-hedged after a fresh grace period if it stalls too, up to
    ``max_hedges``) — first completion wins, losers' results are discarded
    when they land (never waited on). Attempt workers run on a bounded
    executor; a hung request occupies a worker slot, not the caller's
    latency.

    ``run`` is the only entry point and is single-threaded per call (the
    budget is consulted only from the loop); concurrent ``run`` calls on
    one scheduler serialize on an internal lock.
    """

    def __init__(self, store: ObjectStore, cfg: RemoteConfig | None = None):
        self.store = store
        self.cfg = cfg or RemoteConfig()
        self._pool = ThreadPoolExecutor(
            max_workers=min(2 * self.cfg.window + 2, 64),
            thread_name_prefix="rget")
        self._run_lock = threading.Lock()

    def close(self) -> None:
        self._pool.shutdown(wait=False)

    # ------------------------------------------------------------- attempts
    def _fetch(self, r: _Range) -> tuple[bytes, int]:
        """One full-range attempt: loops partial-range responses (each one
        makes progress, so this terminates), retries transient errors."""
        out = bytearray(r.nbytes)
        got = 0
        retries = 0
        errors = 0
        while got < r.nbytes:
            try:
                data = self.store.get_range(r.key, r.offset + got,
                                            r.nbytes - got)
            except RemoteTransientError:
                errors += 1
                retries += 1
                trace.event("range.retry", tier="remote",
                            attrs={"key": r.key, "errors": errors})
                if errors > self.cfg.max_retries:
                    raise
                time.sleep(self.cfg.retry_backoff_s * errors)
                continue
            if not data:
                raise RemoteError(416, r.key, f"empty range at +{got}")
            out[got:got + len(data)] = data
            got += len(data)
            if got < r.nbytes:
                retries += 1      # partial response: re-request the rest
        return bytes(out), retries

    def _worker(self, r: _Range, idx: int, q: SimpleQueue) -> None:
        try:
            data, retries = self._fetch(r)
            q.put((r.rid, idx, data, None, retries))
        except BaseException as e:
            q.put((r.rid, idx, None, e, 0))

    def _issue(self, r: _Range, q: SimpleQueue, hedge: bool) -> None:
        if not hedge:
            r.issued_at = trace.clock()
            r.deadline = r.issued_at + max(
                self.cfg.hedge_after_s, r.nbytes / self.cfg.min_bw_bytes_s)
            trace.event("range.issue", tier="remote", nbytes=r.nbytes,
                        attrs={"key": r.key})
        else:
            trace.event("range.hedge", tier="remote", nbytes=r.nbytes,
                        attrs={"key": r.key, "attempt": r.attempts})
        idx = r.attempts
        r.attempts += 1
        r.outstanding += 1
        self._pool.submit(self._worker, r, idx, q)

    # ----------------------------------------------------------------- loop
    def run(self, tasks: list[_Range], deliver, *,
            budget: StageBudget | None = None, demand=None, reclaim=None,
            cancel: threading.Event | None = None) -> RangeStats:
        """Drive every range to completion; ``deliver(range, data)`` runs in
        this loop as winners land and returns True to keep the bytes on the
        staged-byte books (the consumer credits them back via ``reclaim``)
        or False to release them immediately. ``demand()`` names range ids
        a blocked consumer needs now: they jump the issue queue and may
        exceed the budget by one range so an out-of-order ``get`` always
        makes progress (the ReadStream contract)."""
        with self._run_lock:
            return self._run(tasks, deliver, budget, demand, reclaim, cancel)

    def _run(self, tasks, deliver, budget, demand, reclaim, cancel):
        stats = RangeStats()
        if budget is None:
            budget = StageBudget(self.cfg.inflight_bytes)
        by_id = {r.rid: r for r in tasks}
        pending = deque(tasks)
        active: dict[int, _Range] = {}
        q: SimpleQueue = SimpleQueue()
        t0 = trace.clock()
        try:
            while pending or active:
                if cancel is not None and cancel.is_set():
                    budget.settle()
                    break
                if reclaim is not None:
                    got = reclaim()
                    if got:
                        budget.sub(got)
                want = demand() if demand is not None else None
                if want:
                    for r in pending:
                        if r.rid in want and not r.demanded:
                            r.demanded = True
                            pending.remove(r)
                            pending.appendleft(r)
                            break
                while pending and len(active) < self.cfg.window:
                    r = pending[0]
                    # demanded ranges escape the budget by one range —
                    # blocking them behind staged-but-unconsumed bytes
                    # would deadlock the consumer that needs them
                    if not (r.demanded or budget.admits(r.nbytes)):
                        break
                    pending.popleft()
                    active[r.rid] = r
                    budget.add(r.nbytes)
                    stats.ranges += 1
                    self._issue(r, q, hedge=False)
                try:
                    rid, idx, data, err, retries = q.get(
                        timeout=self._next_deadline(active))
                except Empty:
                    pass
                else:
                    stats.retries += retries
                    r = by_id[rid]
                    r.outstanding -= 1
                    if err is not None:
                        if not r.done and r.outstanding == 0:
                            raise err      # every attempt failed
                        # else: loser failed after the win, or a sibling
                        # attempt is still racing — tolerate
                    elif not r.done:       # first completion wins
                        r.done = True
                        del active[rid]
                        stats.bytes += r.nbytes
                        t_done = trace.clock()
                        stats.range_seconds.append(t_done - r.issued_at)
                        trace.complete("remote.get", r.issued_at, t_done,
                                       tier="remote", nbytes=r.nbytes,
                                       attrs={"key": r.key,
                                              "attempts": r.attempts})
                        if idx > 0:
                            stats.hedge_wins += 1
                            trace.event("hedge.win", tier="remote",
                                        nbytes=r.nbytes,
                                        attrs={"key": r.key})
                        if not deliver(r, data):
                            budget.sub(r.nbytes)
                    # else: losing hedge attempt landed late — discard
                now = trace.clock()
                for r in active.values():
                    if now >= r.deadline \
                            and r.attempts <= self.cfg.max_hedges:
                        # a hedge that itself stalls gets re-hedged after a
                        # fresh grace period, up to max_hedges duplicates —
                        # the completion tail is bounded by the hedge
                        # threshold, not by the store's stall time
                        stats.hedged += 1
                        self._issue(r, q, hedge=True)
                        r.deadline = now + max(
                            self.cfg.hedge_after_s,
                            r.nbytes / self.cfg.min_bw_bytes_s)
        except BaseException:
            budget.settle()
            raise
        finally:
            stats.seconds = trace.clock() - t0
            stats.peak_staged_bytes = budget.peak
        return stats

    def _next_deadline(self, active) -> float:
        now = trace.clock()
        cands = [r.deadline - now for r in active.values()
                 if r.attempts <= self.cfg.max_hedges]
        # cap the wait so reclaim/demand/cancel are re-polled promptly even
        # when no completion is due
        return min(max(0.001, min(cands)) if cands else 0.02, 0.02)


# -------------------------------------------------------- tier-2 transfers
class RemoteTransferEngine:
    """``TieredTransferEngine``-shaped reader over an object store.

    ``transfer`` pulls whole objects into local files; ``fetch_ranges``
    pulls byte ranges of objects under a key prefix into same-named local
    files (sized like the object, sparse elsewhere) — the exact surface
    ``RestorePrefetcher`` drives, so ``RemotePrefetcher`` below reuses its
    staging/coverage/promotion machinery unchanged. Chunk refs
    (``../chunkstore/<pack>``) normalize to tier-wide chunk objects on the
    key side and land in the local shared chunkstore on the file side.
    """

    def __init__(self, store: ObjectStore, cfg: RemoteConfig | None = None):
        self.store = store
        self.cfg = cfg or RemoteConfig()
        self.sched = RangeScheduler(store, self.cfg)
        self._lock = threading.Lock()
        # crlint: guarded-by(_lock)
        self.last_stats = RangeStats()

    def transfer(self, pairs: list[tuple[str, str]]) -> RangeStats:
        """Pull whole objects ``[(key, local_dst_abs), ...]``."""
        items = []
        for key, dst in pairs:
            meta = self.store.head(key)
            if meta is None:
                raise RemoteError(404, key, "HEAD")
            items.append((key, dst, meta.size, [(0, meta.size)]))
        return self._pull(items)

    def fetch_ranges(self, src_prefix: str, dst_dir: str,
                     extents: list[Extent]) -> RangeStats:
        by_path: dict[str, list[tuple[int, int]]] = {}
        for e in extents:
            by_path.setdefault(e.path, []).append((e.offset, e.nbytes))
        items = []
        for path, spans in sorted(by_path.items()):
            key = join_key(src_prefix, path)
            meta = self.store.head(key)
            if meta is None:
                raise RemoteError(404, key, "HEAD")
            aligned = []
            for off, n in spans:
                start, span = aligned_span(off, n, self.cfg.align)
                aligned.append((start, min(start + span, meta.size)))
            items.append((key, os.path.join(dst_dir, path), meta.size,
                          _merge_intervals(aligned)))
        return self._pull(items)

    def _pull(self, items) -> RangeStats:
        """items: [(key, dst_abs, object_size, [(start, end), ...])]"""
        with self._lock:
            fds = []
            try:
                tasks = []
                for key, dst, size, intervals in items:
                    d = os.path.dirname(dst)
                    if d:
                        os.makedirs(d, exist_ok=True)
                    fd = os.open(dst, os.O_RDWR | os.O_CREAT, 0o644)
                    fds.append(fd)
                    os.ftruncate(fd, size)
                    for start, end in intervals:
                        for off, n in _split(start, end,
                                             self.cfg.range_bytes):
                            tasks.append(_Range(len(tasks), key, off, n,
                                                obj=fd))
                def deliver(r, data):
                    faults.pwrite(r.obj, data, r.offset)
                    return False
                stats = self.sched.run(tasks, deliver)
                for fd in fds:
                    faults.fsync(fd)
            finally:
                for fd in fds:
                    os.close(fd)
            stats.objects = len(items)
            self.last_stats = stats
            return stats

    def close(self) -> None:
        self.sched.close()


class RemotePrefetcher(RestorePrefetcher):
    """``RestorePrefetcher`` whose remote tier is an object store.

    Only ``begin`` differs from the level-1 prefetcher: the manifest is a
    whole-object GET (it is small and unplannable until read) and blob
    extents ride the range scheduler. Coverage accounting, planned-extent
    fetches, and the promote-on-full-coverage commit are inherited — a
    fully-pulled level-2 step becomes a committed level-0 step bit-exactly,
    a partial pull stays staged and is garbage-collected.
    """

    def __init__(self, store: ObjectStore, prefix: str = "",
                 cfg: RemoteConfig | None = None,
                 transfer: RemoteTransferEngine | None = None):
        self.store = store
        self.prefix = prefix
        self._owns_transfer = transfer is None
        self.transfer = transfer or RemoteTransferEngine(store, cfg)
        self._active: dict[str, dict] = {}
        self.last_fetch_stats: RangeStats | None = None

    def begin(self, step: int, local_dir: str) -> str | None:
        from .checkpoint import step_dir_name
        src = join_key(self.prefix, step_dir_name(step))
        mkey = join_key(src, MANIFEST_NAME)
        if self.store.head(mkey) is None:
            return None
        raw = self.store.get(mkey)
        manifest = Manifest.loads(raw)
        staged = os.path.join(local_dir,
                              step_dir_name(step) + self.STAGING_SUFFIX)
        faults.rmtree(staged, ignore_errors=True)
        os.makedirs(staged)
        try:
            with open(os.path.join(staged, MANIFEST_NAME), "wb") as f:
                f.write(raw)
                f.flush()
                faults.fsync(f.fileno())
            fetched: dict[str, _IntervalSet] = {}
            blob_extents = [Extent(k, b.path, b.offset, b.nbytes)
                            for k, b in manifest.blobs.items()]
            if blob_extents:
                self.transfer.fetch_ranges(src, staged, blob_extents)
                for e in blob_extents:
                    fetched.setdefault(e.path, _IntervalSet()).add(
                        e.offset, e.offset + e.nbytes)
        except BaseException:   # failed mid-stage: don't leak the dir
            faults.rmtree(staged, ignore_errors=True)
            raise
        self._active[staged] = {"src": src, "manifest": manifest,
                                "fetched": fetched}
        return staged


# ----------------------------------------------------------- upload / tier
@dataclass
class UploadStats:
    objects: int = 0           # objects PUT (incl. the manifest)
    bytes: int = 0             # payload bytes shipped over the wire
    chunks_shipped: int = 0
    chunks_skipped: int = 0    # content-addressed dedup: already remote
    bytes_skipped: int = 0     # bytes the dedup kept off the wire
    seconds: float = 0.0


class RemoteTier:
    """Level-2 step publisher: chunk-dedup upload + committed-step listing.

    Key layout mirrors the local multilevel layout —
    ``<prefix>/step_XXXXXXXX/<file>`` and ``<prefix>/chunkstore/<pack>`` —
    so manifests' store-relative chunk refs resolve identically on both
    sides. Chunkstore packs are content-addressed and immutable (uuid
    names, never rewritten), so a HEAD returning the local pack's size
    proves the remote copy is identical and the pack is skipped.
    """

    def __init__(self, store: ObjectStore, *, prefix: str = "",
                 cfg: RemoteConfig | None = None):
        self.store = store
        self.prefix = prefix
        self.cfg = cfg or RemoteConfig()

    def step_key(self, step: int) -> str:
        from .checkpoint import step_dir_name
        return join_key(self.prefix, step_dir_name(step))

    def committed_steps(self) -> list[int]:
        """Steps whose manifest object exists — the remote commit point."""
        pat = re.compile(r"step_(\d{8})/" + re.escape(MANIFEST_NAME) + "$")
        steps = []
        for key in self.store.list_prefix(join_key(self.prefix, "step_")
                                          if self.prefix else "step_"):
            m = pat.search(key)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def upload_step(self, local_root: str, step: int) -> UploadStats:
        """Publish a committed local step: referenced chunkstore packs
        first (deduped via HEAD), then step data files, then the manifest
        object LAST — a crash anywhere before that final PUT leaves the
        step unpublished and every already-shipped object unreferenced
        (and reusable by the next attempt)."""
        from .checkpoint import step_dir_name
        t0 = trace.clock()
        with trace.span("upload", tier="remote", attrs={"step": step}):
            return self._upload_step_traced(local_root, step, t0)

    def _upload_step_traced(self, local_root: str, step: int,
                            t0: float) -> UploadStats:
        from .checkpoint import step_dir_name
        src_dir = os.path.join(local_root, step_dir_name(step))
        manifest = Manifest.load(src_dir)
        step_key = self.step_key(step)
        stats = UploadStats()
        puts: list[tuple[str, str]] = []
        for rel in sorted(set(delta_mod.manifest_store_paths(manifest))):
            local = os.path.join(local_root, delta_mod.CHUNKSTORE_DIR, rel)
            key = join_key(self.prefix, delta_mod.CHUNKSTORE_DIR, rel)
            size = os.path.getsize(local)
            meta = self.store.head(key)
            if meta is not None and meta.size == size:
                stats.chunks_skipped += 1
                stats.bytes_skipped += size
                continue
            stats.chunks_shipped += 1
            puts.append((key, local))
        manifest_file = None
        for dirpath, _dirs, files in os.walk(src_dir):
            for name in sorted(files):
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, src_dir).replace(os.sep, "/")
                if rel == MANIFEST_NAME:
                    manifest_file = path
                    continue
                puts.append((join_key(step_key, rel), path))
        if manifest_file is None:
            raise FileNotFoundError(f"{src_dir} has no {MANIFEST_NAME}")

        def ship(item: tuple[str, str]) -> int:
            key, path = item
            with open(path, "rb") as f:
                data = f.read()
            with trace.span("remote.put", tier="remote", nbytes=len(data),
                            attrs={"key": key}):
                self.store.put(key, data)
            return len(data)

        if self.cfg.put_workers > 1 and len(puts) > 1:
            with ThreadPoolExecutor(
                    max_workers=self.cfg.put_workers,
                    thread_name_prefix="rput") as ex:
                for n in ex.map(ship, puts):
                    stats.bytes += n
        else:
            for item in puts:
                stats.bytes += ship(item)
        stats.bytes += ship((join_key(step_key, MANIFEST_NAME),
                             manifest_file))
        stats.objects = len(puts) + 1
        stats.seconds = trace.clock() - t0
        return stats


# ------------------------------------------------------------ checkpointer
class RemoteCheckpointer:
    """Level-0 ``CheckpointManager`` + level-2 object tier.

    ``save`` commits locally first, then publishes the step remotely
    (dedup upload, manifest last); ``restore`` prefers local steps and
    reaches the remote tier two ways:

      · ``restore_mode="stream"`` (default): the manifest is fetched into a
        private metadata dir and the restore runs on a
        ``RemoteReadEngine`` — every data/chunk extent streams from remote
        ranges straight into the RestorePipeline (read → dequantize →
        assemble → H2D), no local copy of the checkpoint is ever staged.
      · ``restore_mode="promote"``: a ``RemotePrefetcher`` on the local
        manager stages ranges at level 0 and promotes full pulls to a
        committed local step (the next restore of that step is local).

    Extra keyword arguments go to the local ``CheckpointManager`` (engine,
    delta, streaming, verify_crc, ...).
    """

    def __init__(self, local_dir: str, store: ObjectStore, *,
                 prefix: str = "", remote: RemoteConfig | None = None,
                 upload_async: bool = True, restore_mode: str = "stream",
                 **mgr_kw):
        from .checkpoint import CheckpointManager
        if restore_mode not in ("stream", "promote"):
            raise ValueError(f"unknown restore_mode {restore_mode!r}")
        self.store = store
        self.cfg = remote or RemoteConfig()
        self.tier = RemoteTier(store, prefix=prefix, cfg=self.cfg)
        self.local = CheckpointManager(local_dir, **mgr_kw)
        self.restore_mode = restore_mode
        if restore_mode == "promote":
            self.local.prefetcher = RemotePrefetcher(store, prefix, self.cfg)
        self.upload_async = upload_async
        self._upload_thread: threading.Thread | None = None
        self._upload_error: BaseException | None = None
        self._rmgr = None
        self.last_upload_stats = UploadStats()
        self.last_restore_metrics = None

    @property
    def directory(self) -> str:
        return self.local.directory

    def __enter__(self) -> "RemoteCheckpointer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, **kw):
        self.wait()
        out = self.local.save(step, state, **kw)
        self.local.wait()        # the upload reads the committed files
        if self.upload_async:
            t = threading.Thread(target=self._upload_bg, args=(step,),
                                 daemon=True, name="remote-upload")
            self._upload_thread = t
            t.start()
        else:
            self.last_upload_stats = self.tier.upload_step(
                self.local.directory, step)
        return out

    def _upload_bg(self, step: int) -> None:
        try:
            self.last_upload_stats = self.tier.upload_step(
                self.local.directory, step)
        except BaseException as e:
            self._upload_error = e

    def wait(self) -> None:
        """Block until the in-flight upload lands; re-raises its error."""
        t = self._upload_thread
        if t is not None:
            t.join()
            self._upload_thread = None
        err, self._upload_error = self._upload_error, None
        if err is not None:
            raise err

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        return sorted(set(self.local.all_steps())
                      | set(self.tier.committed_steps()))

    def restore(self, template=None, *, step: int | None = None, **kw):
        self.wait()
        local_steps = set(self.local.all_steps())
        if step is None:
            steps = self.all_steps()
            if not steps:
                raise FileNotFoundError(
                    f"no checkpoints under {self.local.directory} "
                    f"or the remote tier")
            step = steps[-1]
        if step in local_steps or self.restore_mode == "promote":
            out = self.local.restore(template, step=step, **kw)
            self.last_restore_metrics = self.local.last_restore_metrics
            return out
        return self._restore_stream(template, step, **kw)

    def _remote_mgr(self):
        """Lazy manager over a private metadata dir whose engine reads
        remote ranges; only manifests ever touch its directory."""
        if self._rmgr is None:
            from .checkpoint import CheckpointManager
            from .engines.remote import RemoteReadEngine
            mgr = CheckpointManager(
                os.path.join(self.local.directory, ".remote-meta"),
                engine="aggregated", streaming=True,
                verify_crc=self.local.verify_crc)
            mgr.engine.close()
            mgr.engine = RemoteReadEngine(self.store, self.cfg,
                                          config=mgr.config)
            self._rmgr = mgr
        return self._rmgr

    def _restore_stream(self, template, step: int, **kw):
        from .checkpoint import step_dir_name
        mgr = self._remote_mgr()
        step_key = self.tier.step_key(step)
        raw = self.store.get(join_key(step_key, MANIFEST_NAME))
        ckpt = os.path.join(mgr.directory, step_dir_name(step))
        os.makedirs(ckpt, exist_ok=True)
        with open(os.path.join(ckpt, MANIFEST_NAME), "wb") as f:
            f.write(raw)
        mgr.engine.step_prefix = step_key
        try:
            out = mgr.restore(template, step=step, **kw)
        finally:
            faults.rmtree(ckpt, ignore_errors=True)
        self.last_restore_metrics = mgr.last_restore_metrics
        return out

    def close(self) -> None:
        try:
            self.wait()
        # crlint: allow(CRL005): best-effort drain on close — the flush
        # error was already recorded/raised at wait()'s real call sites
        except BaseException:
            pass
        if self._rmgr is not None:
            self._rmgr.close()
            self._rmgr = None
        self.local.close()
