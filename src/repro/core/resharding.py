"""Elastic restore planning: map wanted shard windows onto saved extents.

Checkpoints record, per tensor, the *global* shape and each saved shard's
(start, stop) window in global coordinates. Restoring onto a different mesh
(different DP/TP degree, different pod count) means each new device wants a
window that may intersect several saved shards. This module plans the reads:

    wanted window ∩ saved shard  →  (read extent, src slice, dst slice)

The fast path (same-mesh restore) degenerates to exact matches and the whole
extent is read straight into the destination.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .manifest import ShardEntry, TensorRecord

Index = tuple[tuple[int, int], ...]  # (start, stop) per dim


def normalize_index(index, shape) -> Index:
    """Accept jax-style tuples of slices or (start, stop) pairs."""
    out = []
    for i, d in enumerate(shape):
        if index is None or i >= len(index):
            out.append((0, d))
            continue
        p = index[i]
        if isinstance(p, slice):
            start = 0 if p.start is None else int(p.start)
            stop = d if p.stop is None else int(p.stop)
            out.append((start, stop))
        else:
            out.append((int(p[0]), int(p[1])))
    return tuple(out)


def intersect(a: Index, b: Index) -> Index | None:
    out = []
    for (a0, a1), (b0, b1) in zip(a, b):
        lo, hi = max(a0, b0), min(a1, b1)
        if lo >= hi:
            return None
        out.append((lo, hi))
    return tuple(out)


def window_shape(w: Index) -> tuple[int, ...]:
    return tuple(hi - lo for lo, hi in w)


@dataclass(frozen=True)
class ReadPiece:
    """One saved shard contributing to one wanted window."""
    shard: ShardEntry
    src: tuple[slice, ...]   # slice within the saved shard array
    dst: tuple[slice, ...]   # slice within the wanted window array
    exact: bool              # shard == wanted window (whole-extent fast path)


def plan_window(record: TensorRecord, wanted: Index) -> list[ReadPiece]:
    """All pieces needed to fill ``wanted``; raises if coverage is incomplete."""
    pieces: list[ReadPiece] = []
    covered = 0
    for sh in record.shards:
        inter = intersect(tuple(sh.index), wanted)
        if inter is None:
            continue
        src = tuple(slice(lo - s0, hi - s0)
                    for (lo, hi), (s0, _) in zip(inter, sh.index))
        dst = tuple(slice(lo - w0, hi - w0)
                    for (lo, hi), (w0, _) in zip(inter, wanted))
        exact = tuple(sh.index) == wanted
        pieces.append(ReadPiece(sh, src, dst, exact))
        covered += int(np.prod(window_shape(inter), dtype=np.int64))
    want_n = int(np.prod(window_shape(wanted), dtype=np.int64))
    if covered < want_n:
        raise ValueError(
            f"checkpoint does not cover wanted window {wanted} of "
            f"{record.key}: {covered}/{want_n} elements found")
    return pieces


def dedupe_shards(record: TensorRecord) -> list[ShardEntry]:
    """Drop replicated saves of identical windows (DP replicas)."""
    seen: dict[Index, ShardEntry] = {}
    for sh in record.shards:
        seen.setdefault(tuple(sh.index), sh)
    return list(seen.values())


def record_dtype(record: TensorRecord) -> np.dtype:
    try:
        return np.dtype(record.dtype)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, record.dtype))


class WindowAssembler:
    """Incrementally fills one wanted window from per-extent arrivals.

    The batch path materialized every saved shard before assembly could
    start; the streaming restore pipeline instead ``feed``s each shard's raw
    bytes the moment its extent lands, so window assembly overlaps the reads
    still in flight. Coverage is validated up front by ``plan_window``;
    ``done`` flips once every contributing extent has been fed.
    """

    def __init__(self, record: TensorRecord, wanted: Index):
        self.record = record
        self.wanted = wanted
        self.dtype = record_dtype(record)
        self.out = np.empty(window_shape(wanted), dtype=self.dtype)
        self._by_extent: dict[tuple[str, int], list[ReadPiece]] = {}
        for piece in plan_window(record, wanted):
            self._by_extent.setdefault(
                (piece.shard.path, piece.shard.offset), []).append(piece)

    def pending_shards(self) -> list[ShardEntry]:
        """One ShardEntry per extent still needed (dedup: an extent feeding
        several pieces of this window is listed once)."""
        return [pieces[0].shard for pieces in self._by_extent.values()]

    def feed(self, shard: ShardEntry, raw) -> None:
        """``raw``: the shard's decoded bytes (uint8, ``shard.index`` worth of
        elements); fills every piece of this window the extent contributes."""
        pieces = self._by_extent.pop((shard.path, shard.offset), None)
        if pieces is None:
            return
        sh_shape = window_shape(tuple(shard.index))
        n = int(np.prod(sh_shape, dtype=np.int64))
        arr = np.asarray(raw).view(self.dtype)[:n].reshape(sh_shape)
        for piece in pieces:
            self.out[piece.dst] = arr[piece.src]

    @property
    def done(self) -> bool:
        return not self._by_extent

    def result(self) -> np.ndarray:
        if not self.done:
            missing = [f"{p}@{off}" for p, off in self._by_extent]
            raise RuntimeError(
                f"window {self.wanted} of {self.record.key} incomplete: "
                f"extents {missing[:3]} never arrived")
        return self.out


def assemble(record: TensorRecord, wanted: Index, lookup) -> np.ndarray:
    """Build the wanted window; ``lookup(shard) -> raw uint8 bytes``."""
    asm = WindowAssembler(record, wanted)
    for sh in asm.pending_shards():
        asm.feed(sh, lookup(sh))
    return asm.result()
