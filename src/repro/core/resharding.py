"""Elastic restore planning: map wanted shard windows onto saved extents.

Checkpoints record, per tensor, the *global* shape and each saved shard's
(start, stop) window in global coordinates. Restoring onto a different mesh
(different DP/TP degree, different pod count) means each new device wants a
window that may intersect several saved shards. This module plans the reads:

    wanted window ∩ saved shard  →  (read extent, src slice, dst slice)

The fast path (same-mesh restore) degenerates to exact matches and the whole
extent is read straight into the destination.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .manifest import ShardEntry, TensorRecord

Index = tuple[tuple[int, int], ...]  # (start, stop) per dim


def normalize_index(index, shape) -> Index:
    """Accept jax-style tuples of slices or (start, stop) pairs."""
    out = []
    for i, d in enumerate(shape):
        if index is None or i >= len(index):
            out.append((0, d))
            continue
        p = index[i]
        if isinstance(p, slice):
            start = 0 if p.start is None else int(p.start)
            stop = d if p.stop is None else int(p.stop)
            out.append((start, stop))
        else:
            out.append((int(p[0]), int(p[1])))
    return tuple(out)


def intersect(a: Index, b: Index) -> Index | None:
    out = []
    for (a0, a1), (b0, b1) in zip(a, b):
        lo, hi = max(a0, b0), min(a1, b1)
        if lo >= hi:
            return None
        out.append((lo, hi))
    return tuple(out)


def window_shape(w: Index) -> tuple[int, ...]:
    return tuple(hi - lo for lo, hi in w)


@dataclass(frozen=True)
class ReadPiece:
    """One saved shard contributing to one wanted window."""
    shard: ShardEntry
    src: tuple[slice, ...]   # slice within the saved shard array
    dst: tuple[slice, ...]   # slice within the wanted window array
    exact: bool              # shard == wanted window (whole-extent fast path)


def plan_window(record: TensorRecord, wanted: Index) -> list[ReadPiece]:
    """All pieces needed to fill ``wanted``; raises if coverage is incomplete."""
    pieces: list[ReadPiece] = []
    covered = 0
    for sh in record.shards:
        inter = intersect(tuple(sh.index), wanted)
        if inter is None:
            continue
        src = tuple(slice(lo - s0, hi - s0)
                    for (lo, hi), (s0, _) in zip(inter, sh.index))
        dst = tuple(slice(lo - w0, hi - w0)
                    for (lo, hi), (w0, _) in zip(inter, wanted))
        exact = tuple(sh.index) == wanted
        pieces.append(ReadPiece(sh, src, dst, exact))
        covered += int(np.prod(window_shape(inter), dtype=np.int64))
    want_n = int(np.prod(window_shape(wanted), dtype=np.int64))
    if covered < want_n:
        raise ValueError(
            f"checkpoint does not cover wanted window {wanted} of "
            f"{record.key}: {covered}/{want_n} elements found")
    return pieces


def dedupe_shards(record: TensorRecord) -> list[ShardEntry]:
    """Drop replicated saves of identical windows (DP replicas)."""
    seen: dict[Index, ShardEntry] = {}
    for sh in record.shards:
        seen.setdefault(tuple(sh.index), sh)
    return list(seen.values())


def assemble(record: TensorRecord, wanted: Index, lookup) -> np.ndarray:
    """Build the wanted window; ``lookup(shard) -> raw uint8 bytes``."""
    try:
        dtype = np.dtype(record.dtype)
    except TypeError:
        import ml_dtypes
        dtype = np.dtype(getattr(ml_dtypes, record.dtype))
    out = np.empty(window_shape(wanted), dtype=dtype)
    for piece in plan_window(record, wanted):
        sh = piece.shard
        raw = lookup(sh)
        n = int(np.prod(window_shape(tuple(sh.index)), dtype=np.int64))
        arr = raw.view(dtype)[:n].reshape(window_shape(tuple(sh.index)))
        out[piece.dst] = arr[piece.src]
    return out
