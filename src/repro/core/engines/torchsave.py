"""TorchSaveEngine — the ``torch.save`` baseline (paper §2, Fig 3).

"Synchronously and sequentially allocate host memory for all GPU resident data
structures, transfer them from GPU to the host memory, serialize the entire
logical object, and finally flush to disk."

Faithfully modeled: every tensor is *pickled* (full serialization cost, no
pre-serialized fast path), the pickle stream is written sequentially through
buffered POSIX I/O as one monolithic file per rank, then fsync'd. Restore
reads + unpickles the whole object even if one tensor is wanted; its
``begin_restore`` is the validating buffered fallback (DESIGN.md §10.3).
"""

from __future__ import annotations

import os
import pickle

import numpy as np

from .. import faults, trace
from ..manifest import Manifest, ShardEntry, BlobRecord
from .base import CREngine, EngineConfig, IOStats, ReadReq, SaveItem, item_mv


class TorchSaveEngine(CREngine):
    name = "torchsave"

    def __init__(self, config: EngineConfig | None = None, pool=None):
        from dataclasses import replace
        cfg = replace(config) if config is not None else EngineConfig()
        cfg.backend = "posix"
        cfg.direct = False            # torch.save is buffered
        cfg.pooled_buffers = False
        super().__init__(cfg, pool)
        self._cache: dict[str, dict[str, np.ndarray]] = {}

    def _path(self, rank: int) -> str:
        return f"data/mp_rank_{rank:05d}.pt"

    def save(self, ckpt_dir: str, items: list[SaveItem], *, step: int = 0,
             rank: int = 0, num_ranks: int = 1,
             rank_totals: list[int] | None = None) -> Manifest:
        t0 = trace.clock()
        stats = IOStats()
        # Full-object serialization: tensors are materialized & pickled.
        tc0 = trace.clock()
        obj = {it.key: (bytes(item_mv(it)), it.dtype, it.global_shape,
                        it.index, it.is_blob) for it in items}
        payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        stats.copy_seconds = trace.clock() - tc0

        rel = self._path(rank)
        full = os.path.join(ckpt_dir, rel)
        os.makedirs(os.path.dirname(full), exist_ok=True)
        ti0 = trace.clock()
        with open(full, "wb") as f:
            f.write(payload)
            f.flush()
            if self.config.fsync_on_save:
                faults.fsync(f.fileno())
        stats.io_seconds = trace.clock() - ti0
        stats.io_requests = 1
        stats.files = 1
        stats.logical_bytes = sum(it.nbytes for it in items)
        stats.seconds = trace.clock() - t0
        self.last_save_stats = stats

        m = Manifest(step=step, num_ranks=num_ranks, strategy="torchsave")
        for it in items:
            rkey = it.record_key or it.key
            # packed format: address shards as "<file>::<item key>"
            addr = f"{rel}::{it.key}"
            if it.is_blob:
                m.blobs[rkey] = BlobRecord(rkey, addr, 0, it.nbytes)
            else:
                index = it.index if it.index is not None else tuple(
                        (0, s) for s in (it.global_shape if it.global_shape is not None else ()))
                m.add_shard(rkey, it.dtype or "uint8",
                            it.global_shape if it.global_shape is not None else (it.nbytes,),
                            ShardEntry(index, addr, 0, it.nbytes))
        m.extra["engine"] = {"name": self.name, "packed": True}
        return m

    def read(self, ckpt_dir: str, reqs: list[ReadReq]) -> dict[str, np.ndarray]:
        t0 = trace.clock()
        stats = IOStats()
        out: dict[str, np.ndarray] = {}
        for path in {r.path.partition("::")[0] for r in reqs}:
            full = os.path.join(ckpt_dir, path)
            if full not in self._cache:
                ti0 = trace.clock()
                with open(full, "rb") as f:
                    payload = f.read()       # opaque: reads EVERYTHING
                stats.io_seconds += trace.clock() - ti0
                stats.io_requests += 1
                tc0 = trace.clock()
                obj = pickle.loads(payload)
                self._cache[full] = {
                    k: np.frombuffer(v[0], dtype=np.uint8).copy()
                    for k, v in obj.items()}
                stats.copy_seconds += trace.clock() - tc0
            stats.files += 1
        for r in reqs:
            file_rel, _, item_key = r.path.partition("::")
            arr = self._cache[os.path.join(ckpt_dir, file_rel)][
                item_key or r.obj or r.key]
            out[r.key] = arr[:r.nbytes] if r.nbytes < arr.nbytes else arr
        stats.logical_bytes = sum(r.nbytes for r in reqs)
        stats.seconds = trace.clock() - t0
        self.last_restore_stats = stats
        self._cache.clear()
        return out
