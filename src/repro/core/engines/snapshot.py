"""SnapshotEngine — TorchSnapshot-faithful baseline (paper §2).

"Large objects and model states are subdivided into fixed size amounts (512 MB
by default), and each fixed-size chunk is flushed to a separate file inside a
deeply nested subdirectory, stressing all levels of the PFS."

Modeled faithfully:
  · every object is split into ``chunk_bytes`` pieces, chunk-per-file under
    ``data/rank_<r>/<key>/<idx>.bin`` (deep nesting → metadata pressure),
  · buffered I/O (its libaio backend predates O_DIRECT-friendly batching),
  · writes are dispatched to a small thread pool as each chunk is produced —
    per-object granularity, no cross-object coalescing,
  · restore is SERIAL per logical object: all chunks of object k are read and
    assembled before object k+1 starts (paper: "all checkpoint engines restore
    the M logical objects serially"), with dynamic allocation per read. No
    native read stream: ``begin_restore`` is the validating buffered fallback
    (DESIGN.md §10.3).
"""

from __future__ import annotations

import os

import numpy as np

from .. import faults, trace
from ..io_engine import IORequest, OP_READ, OP_WRITE
from ..manifest import Manifest, ShardEntry, BlobRecord
from ..aggregation import _sanitize
from .base import CREngine, EngineConfig, IOStats, ReadReq, SaveItem, item_mv


class SnapshotEngine(CREngine):
    name = "snapshot"

    def __init__(self, config: EngineConfig | None = None, pool=None):
        from dataclasses import replace
        cfg = replace(config) if config is not None else EngineConfig()
        cfg.backend = "threadpool"     # libaio-era stand-in
        cfg.direct = False             # buffered
        cfg.pooled_buffers = False     # dynamic allocation
        super().__init__(cfg, pool)

    def _obj_dir(self, rank: int, key: str) -> str:
        return f"data/rank_{rank:05d}/{_sanitize(key)}"

    def save(self, ckpt_dir: str, items: list[SaveItem], *, step: int = 0,
             rank: int = 0, num_ranks: int = 1,
             rank_totals: list[int] | None = None) -> Manifest:
        cfg = self.config
        t0 = trace.clock()
        stats = IOStats()
        io = self._make_io()
        inflight: dict[int, tuple] = {}  # token -> (fd, buf)
        token = 0

        def reap(block_min: int):
            for c in io.poll(min_n=block_min):
                fd, buf = inflight.pop(c.user_data)
                if cfg.fsync_on_save:
                    faults.fsync(fd)
                os.close(fd)
                buf.release()

        m = Manifest(step=step, num_ranks=num_ranks, strategy="snapshot")
        try:
            for it in items:
                mv = item_mv(it)
                obj_dir = self._obj_dir(rank, it.key)
                os.makedirs(os.path.join(ckpt_dir, obj_dir), exist_ok=True)
                pos, idx = 0, 0
                while pos < it.nbytes or (it.nbytes == 0 and idx == 0):
                    n = min(cfg.chunk_bytes, it.nbytes - pos)
                    rel = f"{obj_dir}/{idx:06d}.bin"
                    # one file PER CHUNK — opened, written, fsync'd, closed
                    fd = os.open(os.path.join(ckpt_dir, rel),
                                 os.O_CREAT | os.O_WRONLY | os.O_TRUNC, 0o644)
                    ta = trace.clock()
                    buf = self.pool.get(max(n, 1))
                    tb = trace.clock()
                    buf.view(0, n)[:] = mv[pos:pos + n]
                    stats.alloc_seconds += tb - ta
                    stats.copy_seconds += trace.clock() - tb
                    token += 1
                    inflight[token] = (fd, buf)
                    io.submit([IORequest(OP_WRITE, fd, 0, buf, 0, n,
                                         user_data=token)])
                    stats.io_requests += 1
                    stats.files += 1
                    pos += n
                    idx += 1
                    while io.inflight >= cfg.queue_depth:
                        reap(1)
                rkey = it.record_key or it.key
                if it.is_blob:
                    m.blobs[rkey] = BlobRecord(rkey, obj_dir, 0, it.nbytes)
                else:
                    index = it.index if it.index is not None else tuple(
                        (0, s) for s in (it.global_shape if it.global_shape is not None else ()))
                    m.add_shard(rkey, it.dtype or "uint8",
                                it.global_shape if it.global_shape is not None else (it.nbytes,),
                                ShardEntry(index, obj_dir, 0, it.nbytes))
            while io.inflight:
                reap(1)
        finally:
            io.close()
        stats.logical_bytes = sum(it.nbytes for it in items)
        stats.seconds = trace.clock() - t0
        self.last_save_stats = stats
        m.extra["engine"] = {"name": self.name, "chunk_bytes": cfg.chunk_bytes,
                             "chunked_dirs": True}
        return m

    def read(self, ckpt_dir: str, reqs: list[ReadReq]) -> dict[str, np.ndarray]:
        """Serial, per-object, chunk-at-a-time restore with dynamic alloc."""
        cfg = self.config
        t0 = trace.clock()
        stats = IOStats()
        out: dict[str, np.ndarray] = {}
        for r in reqs:  # objects strictly one-after-another
            dest = np.empty(r.nbytes, dtype=np.uint8)
            pos = r.offset
            end = r.offset + r.nbytes
            while pos < end:
                idx = pos // cfg.chunk_bytes
                in_chunk = pos - idx * cfg.chunk_bytes
                n = min(end - pos, cfg.chunk_bytes - in_chunk)
                rel = f"{r.path}/{idx:06d}.bin"
                ta = trace.clock()
                buf = self.pool.get(n)          # fresh allocation per read
                try:
                    tb = trace.clock()
                    fd = os.open(os.path.join(ckpt_dir, rel), os.O_RDONLY)
                    total = 0
                    mv = buf.view(0, n)
                    try:
                        while total < n:
                            got = faults.preadv(fd, [mv[total:]],
                                                in_chunk + total)
                            if got == 0:
                                raise EOFError(rel)
                            total += got
                    finally:
                        os.close(fd)
                    tc = trace.clock()
                    dest[pos - r.offset:pos - r.offset + n] = np.frombuffer(mv, np.uint8)
                    stats.alloc_seconds += tb - ta
                    stats.io_seconds += tc - tb
                    stats.copy_seconds += trace.clock() - tc
                    stats.io_requests += 1
                    stats.files += 1
                finally:
                    buf.release()
                pos += n
            out[r.key] = dest
        stats.logical_bytes = sum(r.nbytes for r in reqs)
        stats.seconds = trace.clock() - t0
        self.last_restore_stats = stats
        return out
