"""Remote-read engine: restore streams object-store ranges straight into
the pipeline (DESIGN.md §15).

``RemoteReadEngine`` adapts the remote range scheduler to the ``CREngine``
read surface, so ``CheckpointManager``'s streaming restore runs unmodified
against a level-2 checkpoint: the RestorePipeline declares its planned
``ReadReq``s (chunk refs already expanded), the stream splits them into
aligned ranges, keeps a window in flight under the staged-byte budget with
hedged re-issue masking stalls, and ``get`` hands each request's bytes to
decode/assemble/H2D as they land — no local copy of the checkpoint is ever
staged. ``step_prefix`` names the remote step; manifest-relative request
paths (including ``../chunkstore/<pack>`` chunk refs) resolve against it.

Save-side methods are intentionally absent: uploads go through
``remote.RemoteTier`` (dedup + manifest-last commit), not a write engine.

Module note: ``..remote`` is imported lazily — this module is imported by
``engines/__init__`` while ``core.remote`` (via ``core.delta``) imports the
engines package, and the lazy import breaks that cycle.
"""

from __future__ import annotations

import threading
import zlib

import numpy as np

from .. import trace
from ..buffers import StageBudget
from .base import ChecksumError, CREngine, IOStats, ReadReq, ReadStream


class RemoteReadEngine(CREngine):
    """Read-only engine whose backing tier is an object store."""

    name = "remote"
    supports_streaming_read = True

    def __init__(self, store, remote=None, config=None, pool=None):
        from ..remote import RangeScheduler, RemoteConfig, RangeStats
        super().__init__(config, pool)
        self.store = store
        self.rcfg = remote or RemoteConfig()
        self.sched = RangeScheduler(store, self.rcfg)
        self.step_prefix = ""          # remote key prefix of the step
        self.last_range_stats = RangeStats()

    def close(self) -> None:
        self.sched.close()
        super().close()

    # -------------------------------------------------------------- batch
    def read(self, ckpt_dir: str,
             reqs: list[ReadReq]) -> dict[str, np.ndarray]:
        """Batch read (the lean-blob path): all ranges land before return."""
        from ..remote import _req_ranges
        t0 = trace.clock()
        bufs = {rq.key: bytearray(rq.nbytes) for rq in reqs}
        tasks = _req_ranges(reqs, self.step_prefix, self.rcfg.range_bytes)

        def deliver(r, data):
            rk, off = r.obj
            bufs[rk][off:off + len(data)] = data
            return False

        rstats = self.sched.run(tasks, deliver)
        self.last_range_stats = rstats
        self.last_restore_stats = IOStats(
            seconds=trace.clock() - t0,
            logical_bytes=rstats.bytes,
            io_requests=rstats.ranges,
            files=len({rq.path for rq in reqs}),
            io_seconds=rstats.seconds,
            peak_staged_bytes=rstats.peak_staged_bytes)
        return {k: np.frombuffer(bytes(v), dtype=np.uint8)
                for k, v in bufs.items()}

    # ---------------------------------------------------------- streaming
    def begin_restore(self, ckpt_dir: str, reqs: list[ReadReq], *,
                      crcs: dict[str, int] | None = None) -> ReadStream:
        return _RemoteReadStream(self, reqs, crcs)


class _RemoteReadStream(ReadStream):
    """Range scheduler on a background thread; ``get`` blocks per request.

    The scheduler owns the staged-byte budget single-threaded (the
    ``StageBudget`` contract): it adds bytes at issue, the consumer's
    ``get`` records consumed bytes under the stream lock, and the loop
    reclaims them between completions. A ``get`` for a request whose
    ranges have not been issued yet marks them demanded — they jump the
    issue queue and may exceed the budget by one range, so out-of-order
    consumption always makes progress."""

    def __init__(self, engine: RemoteReadEngine, reqs: list[ReadReq],
                 crcs: dict[str, int] | None):
        from ..remote import _req_ranges
        self.engine = engine
        self.reqs = {rq.key: rq for rq in reqs}
        self.crcs = dict(crcs) if (crcs and engine.config.checksum) else {}
        self.budget = StageBudget(engine.rcfg.inflight_bytes)
        self._cv = threading.Condition()
        self._bufs: dict[str, bytearray] = {}
        self._left: dict[str, int] = {}
        self._ready: dict[str, bytes] = {}
        self._rids: dict[str, list[int]] = {}
        self._demand: set[int] = set()
        self._consumed = 0
        self._err: BaseException | None = None
        self._rstats = None
        self._cancel = threading.Event()
        self._t0 = trace.clock()
        tasks = _req_ranges(reqs, engine.step_prefix,
                            engine.rcfg.range_bytes)
        for r in tasks:
            self._rids.setdefault(r.obj[0], []).append(r.rid)
        for rq in reqs:
            if rq.nbytes > 0:
                self._bufs[rq.key] = bytearray(rq.nbytes)
                self._left[rq.key] = rq.nbytes
            else:
                self._ready[rq.key] = b""
        self._thread = threading.Thread(target=self._run, args=(tasks,),
                                        daemon=True, name="remote-read")
        self._thread.start()

    def _run(self, tasks) -> None:
        def deliver(r, data):
            rk, off = r.obj
            with self._cv:
                buf = self._bufs.get(rk)
                if buf is None:
                    return False
                buf[off:off + len(data)] = data
                self._left[rk] -= len(data)
                if self._left[rk] == 0:
                    self._ready[rk] = bytes(self._bufs.pop(rk))
                    del self._left[rk]
                    self._cv.notify_all()
            return True       # staged until the consumer gets it

        def reclaim():
            with self._cv:
                n, self._consumed = self._consumed, 0
                return n

        def demand():
            with self._cv:
                return set(self._demand) if self._demand else None

        try:
            stats = self.engine.sched.run(
                tasks, deliver, budget=self.budget, demand=demand,
                reclaim=reclaim, cancel=self._cancel)
            with self._cv:
                self._rstats = stats
                self._cv.notify_all()
        except BaseException as e:
            with self._cv:
                self._err = e
                self._cv.notify_all()

    # ----------------------------------------------------------------- API
    def get(self, key: str) -> np.ndarray:
        rq = self.reqs[key]
        with self._cv:
            self._demand.update(self._rids.get(key, ()))
            while key not in self._ready and self._err is None:
                self._cv.wait(0.05)
            if key not in self._ready:
                raise self._err
            data = self._ready.pop(key)
            self._demand.difference_update(self._rids.get(key, ()))
            self._consumed += rq.nbytes
        if key in self.crcs:
            got = zlib.crc32(data)
            if got != self.crcs[key]:
                raise ChecksumError(key, rq.path, rq.offset,
                                    self.crcs[key], got)
        return np.frombuffer(data, dtype=np.uint8)

    def end_restore(self) -> IOStats:
        self._thread.join()
        if self._err is not None:
            raise self._err
        rstats = self._rstats
        stats = IOStats(
            seconds=trace.clock() - self._t0,
            logical_bytes=rstats.bytes,
            io_requests=rstats.ranges,
            files=len({rq.path for rq in self.reqs.values()}),
            io_seconds=rstats.seconds,
            peak_staged_bytes=rstats.peak_staged_bytes)
        self.engine.last_restore_stats = stats
        self.engine.last_range_stats = rstats
        return stats

    def abort(self) -> None:
        self._cancel.set()
        self._thread.join()
        with self._cv:
            self._ready.clear()
            self._bufs.clear()
            self._left.clear()
            self._demand.clear()
        self.budget.settle()
