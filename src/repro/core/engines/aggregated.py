"""AggregatedEngine — the paper's "ideal approach", productionized.

Write path (paper Observations 1, 2, 4):
  · layout per the configured aggregation strategy (default: single aggregated
    file with cross-rank prefix-sum offsets),
  · request-level coalescing: small objects are staged into pooled aligned
    buffers and flushed as FEW LARGE writes (one per ~coalesce_bytes group),
  · large objects are staged through a small ring of chunk buffers so the
    memcpy of chunk k+1 overlaps the write of chunk k (double buffering),
  · O_DIRECT by default (4.8× write uplift in the paper), deep submission
    queues, batched io_uring submission, optional registered buffers.

Restore path (paper Observation 3):
  · coalesced reads — one I/O per group region covering many small objects,
  · preallocated POOLED buffers (the fix for DataStates' dominant
    allocation cost), O_DIRECT reads for large transfers.
"""

from __future__ import annotations

import time

import numpy as np

from ..aggregation import Extent, coalesce
from ..buffers import align_up
from ..io_engine import IORequest, OP_READ, OP_WRITE
from ..manifest import Manifest, crc32_of
from .base import CREngine, IOStats, ReadReq, SaveItem, item_mv


class AggregatedEngine(CREngine):
    name = "aggregated"

    # ------------------------------------------------------------------ save
    def save(self, ckpt_dir: str, items: list[SaveItem], *, step: int = 0,
             rank: int = 0, num_ranks: int = 1,
             rank_totals: list[int] | None = None) -> Manifest:
        cfg = self.config
        t0 = time.perf_counter()
        stats = IOStats()
        plan = self._plan(items, rank, rank_totals)
        by_key = {it.key: it for it in items}
        groups = coalesce(plan.extents, cfg.coalesce_bytes, cfg.align)
        fds = self._open_files(ckpt_dir, plan, "w", preallocate=True)
        stats.files = len(fds)
        crcs: dict[str, int] = {}

        io = self._make_io()
        inflight_bufs: dict[int, object] = {}  # user_data -> buffer to release
        token = 0

        def reap(block_min: int):
            for c in io.poll(min_n=block_min):
                buf = inflight_bufs.pop(c.user_data, None)
                if buf is not None:
                    buf.release()

        def stage_and_write(fd: int, file_off: int, fill, span: int):
            """Acquire buffer, run fill(buf), submit one write of span bytes."""
            nonlocal token
            ta = time.perf_counter()
            buf = self.pool.get(span)
            tb = time.perf_counter()
            fill(buf)
            tc = time.perf_counter()
            stats.alloc_seconds += tb - ta
            stats.copy_seconds += tc - tb
            token += 1
            inflight_bufs[token] = buf
            io.submit([IORequest(OP_WRITE, fd, file_off, buf, 0, span,
                                 user_data=token)])
            stats.io_requests += 1
            while io.inflight >= cfg.queue_depth:
                reap(1)

        try:
            for group in groups:
                first, last = group[0], group[-1]
                if len(group) == 1 and first.nbytes > cfg.chunk_bytes:
                    # Large object: chunked staging, pipelined with writes.
                    mv = item_mv(by_key[first.key])
                    if cfg.checksum:
                        crcs[first.key] = crc32_of(mv)
                    pos = 0
                    while pos < first.nbytes:
                        n = min(cfg.chunk_bytes, first.nbytes - pos)
                        chunk = mv[pos:pos + n]
                        stage_and_write(
                            fds[first.path], first.offset + pos,
                            lambda b, c=chunk, n=n: b.view(0, n).__setitem__(
                                slice(None), c),
                            align_up(n, cfg.align))
                        pos += n
                else:
                    # Coalesced group: one staged buffer, ONE write.
                    span = (last.offset + align_up(last.nbytes, cfg.align)
                            - first.offset)

                    def fill(buf, group=group, first=first):
                        for e in group:
                            mv = item_mv(by_key[e.key])
                            buf.view(e.offset - first.offset, e.nbytes)[:] = mv
                            if cfg.checksum:
                                crcs[e.key] = crc32_of(mv)

                    stage_and_write(fds[first.path], first.offset, fill, span)
            while io.inflight:
                reap(1)
            reap(0)   # drain engines that complete inline (posix)
            t_io0 = time.perf_counter()
            self._fsync_all(io, fds)
            stats.io_seconds += time.perf_counter() - t_io0
        finally:
            io.close()
            self._close_files(fds)

        stats.logical_bytes = plan.total_logical_bytes
        stats.seconds = time.perf_counter() - t0
        self.last_save_stats = stats
        return self._manifest_from(items, plan, step=step,
                                   num_ranks=num_ranks, crcs=crcs or None)

    # ------------------------------------------------------------------ read
    def read(self, ckpt_dir: str, reqs: list[ReadReq]) -> dict[str, np.ndarray]:
        cfg = self.config
        t0 = time.perf_counter()
        stats = IOStats()
        out: dict[str, np.ndarray] = {}
        extents = [Extent(r.key, r.path, r.offset, r.nbytes) for r in reqs]
        groups = coalesce(extents, cfg.coalesce_bytes, cfg.align)
        fds = self._open_files(ckpt_dir, {r.path for r in reqs}, "r")
        stats.files = len(fds)
        io = self._make_io()
        handlers: dict[int, tuple] = {}  # token -> (buf, on_done)
        token = 0

        def reap(block_min: int):
            for c in io.poll(min_n=block_min):
                buf, on_done = handlers.pop(c.user_data)
                tb = time.perf_counter()
                on_done(buf)
                stats.copy_seconds += time.perf_counter() - tb
                buf.release()

        def submit_read(fd: int, file_off: int, span: int, on_done):
            nonlocal token
            ta = time.perf_counter()
            buf = self.pool.get(span)
            stats.alloc_seconds += time.perf_counter() - ta
            token += 1
            handlers[token] = (buf, on_done)
            io.submit([IORequest(OP_READ, fd, file_off, buf, 0, span,
                                 user_data=token)])
            stats.io_requests += 1
            while io.inflight >= cfg.queue_depth:
                reap(1)

        try:
            for group in groups:
                first, last = group[0], group[-1]
                if len(group) == 1 and first.nbytes > cfg.chunk_bytes:
                    # Large object: chunked pipelined reads into one dest array.
                    dest = np.empty(first.nbytes, dtype=np.uint8)
                    out[first.key] = dest
                    pos = 0
                    while pos < first.nbytes:
                        n = min(cfg.chunk_bytes, first.nbytes - pos)

                        def done(buf, dest=dest, pos=pos, n=n):
                            dest[pos:pos + n] = np.frombuffer(
                                buf.view(0, n), np.uint8)

                        submit_read(fds[first.path], first.offset + pos,
                                    align_up(n, cfg.align), done)
                        pos += n
                else:
                    span = (last.offset + align_up(last.nbytes, cfg.align)
                            - first.offset)

                    def done(buf, group=group, first=first):
                        for e in group:
                            arr = np.empty(e.nbytes, dtype=np.uint8)
                            arr[:] = np.frombuffer(
                                buf.view(e.offset - first.offset, e.nbytes),
                                np.uint8)
                            out[e.key] = arr

                    submit_read(fds[first.path], first.offset, span, done)
            while io.inflight:
                reap(1)
            reap(0)   # drain engines that complete inline (posix)
        finally:
            io.close()
            self._close_files(fds)
        stats.logical_bytes = sum(r.nbytes for r in reqs)
        stats.seconds = time.perf_counter() - t0
        self.last_restore_stats = stats
        return out
