"""AggregatedEngine — the paper's "ideal approach", productionized.

Write path (paper Observations 1, 2, 4), exposed as a STREAM
(``begin_save`` / ``put`` / ``end_save``; batch ``save`` is a degenerate
client that puts every item and drains):
  · layout per the configured aggregation strategy (default: single aggregated
    file with cross-rank prefix-sum offsets), planned from sizes alone before
    any payload exists,
  · request-level coalescing: small objects are staged into pooled aligned
    buffers and flushed as FEW LARGE writes (one per ~coalesce_bytes group),
  · large objects are staged through a small ring of chunk buffers so the
    memcpy of chunk k+1 overlaps the write of chunk k (double buffering),
  · staged bytes in flight are bounded by ``config.inflight_bytes`` —
    backpressure reaps completed writes before staging more,
  · O_DIRECT by default (4.8× write uplift in the paper), deep submission
    queues, batched io_uring submission, optional registered buffers.

Restore path (paper Observation 3), exposed as a STREAM
(``begin_restore`` / ``get`` / ``end_restore``; batch ``read`` is a
degenerate client that gets every request and drains):
  · coalesced reads — one I/O per group region covering many small objects,
  · preallocated POOLED buffers (the fix for DataStates' dominant
    allocation cost), O_DIRECT reads for large transfers,
  · per-request results surface the moment their extents land, so the
    consumer dequantizes/assembles/uploads tensor k while the reads for
    tensor k+1 are still in flight,
  · staged bytes in flight (read buffers + landed-but-unconsumed results)
    are bounded by ``config.inflight_bytes`` (StageBudget backpressure),
  · CRCs are verified incrementally against the manifest as extents land
    (``ChecksumError`` names the key and file offset).
"""

from __future__ import annotations

import zlib
from collections import deque

import numpy as np

from .. import trace
from ..aggregation import Extent, coalesce
from ..buffers import BufferPool, StageBudget, align_up
from ..io_engine import IORequest, OP_READ, OP_WRITE
from ..manifest import Manifest
from .base import (ChecksumError, CREngine, IOStats, ReadReq, ReadStream,
                   SaveItem, SaveSpec, SaveStream, as_u8, spec_of)


class _Group:
    """One coalesce group being filled across put() calls."""

    __slots__ = ("extents", "large", "buf", "filled", "seen", "submitted")

    def __init__(self, extents: list[Extent], large: bool):
        self.extents = extents
        self.large = large          # single object streamed in chunks
        self.buf = None             # staging buffer while filling
        self.filled = 0             # logical bytes staged so far
        self.seen = 0               # member objects fully put
        self.submitted = False


class _AggSaveStream(SaveStream):
    """Streaming writer against the io_engine request stream.

    Each put stages its bytes into pooled aligned buffers (coalescing small
    contiguous objects, chunking large ones) and submits the write
    immediately — storage I/O overlaps the caller's next snapshot/pack.
    """

    def __init__(self, eng: "AggregatedEngine", ckpt_dir: str,
                 specs: list[SaveSpec], step: int, rank: int, num_ranks: int,
                 rank_totals: list[int] | None):
        self.eng = eng
        self.cfg = cfg = eng.config
        self.step, self.num_ranks = step, num_ranks
        self.specs = list(specs)
        self.stats = IOStats()
        self.t0 = trace.clock()
        self.plan = eng._plan(self.specs, rank, rank_totals)
        self.extents = {e.key: e for e in self.plan.extents}
        regions = None
        if not cfg.truncate:
            # shared-file (multi-rank) mode: preallocate only this rank's
            # extent span, not the whole file once per rank
            regions = {}
            for path, exts in self.plan.by_file().items():
                start = exts[0].offset
                end = exts[-1].offset + align_up(exts[-1].nbytes, cfg.align)
                regions[path] = (start, end - start)
        self.fds = eng._open_files(ckpt_dir, self.plan, "w",
                                   preallocate=True, regions=regions)
        self.stats.files = len(self.fds)
        self.io = eng._make_io()
        self.budget = StageBudget(cfg.inflight_bytes)
        # clamp staging units to half the budget so the cap is HARD: every
        # buffer class then fits twice, and the admits() idle-override can
        # never be reached by an oversized single unit
        self._chunk = cfg.chunk_bytes
        thr = cfg.coalesce_bytes
        if cfg.inflight_bytes is not None:
            half = max(cfg.inflight_bytes // 2, 1)
            unit = max(cfg.align, 1 << (half.bit_length() - 1))  # floor pow2
            self._chunk = min(self._chunk, unit)
            thr = min(thr, unit)
        self.crcs: dict[str, int] = {}
        self._inflight: dict[int, object] = {}   # token -> buffer to release
        self._token = 0
        self._pos: dict[str, int] = {}           # chunked-put progress per key
        self._group_of: dict[str, _Group] = {}
        self._groups: list[_Group] = []
        for g in coalesce(self.plan.extents, thr, cfg.align):
            grp = _Group(g, len(g) == 1 and g[0].nbytes > self._chunk)
            self._groups.append(grp)
            for e in g:
                self._group_of[e.key] = grp
        self._state = "open"            # open → ended | aborted

    # ------------------------------------------------------------- plumbing
    def _reap(self, block_min: int) -> None:
        for c in self.io.poll(min_n=block_min):
            buf = self._inflight.pop(c.user_data, None)
            if buf is not None:
                self.budget.sub(buf.nbytes)
                buf.release()

    def _acquire(self, span: int):
        """Pooled staging buffer, bounded: reap completed writes until the
        staged bytes in flight admit one more buffer (backpressure).

        The bound is hard for clients that put objects in layout order
        (batch save and the snapshot pipeline): units are clamped to half
        the budget and every blocker is a reapable write. A client that
        interleaves puts across MANY coalesce groups can hold one open
        group buffer per interleaved group above the budget — open group
        buffers are only reclaimable by completing their groups."""
        need = BufferPool.size_class(max(span, 1))
        if not self.budget.admits(need) and self._inflight:
            with trace.span("budget.wait", nbytes=need):
                while not self.budget.admits(need) and self._inflight:
                    self._reap(1)
        buf = self.eng.pool.get(span)
        self.budget.add(buf.nbytes)
        return buf

    def _submit(self, fd: int, file_off: int, buf, span: int) -> None:
        self._token += 1
        self._inflight[self._token] = buf
        self.io.submit([IORequest(OP_WRITE, fd, file_off, buf, 0, span,
                                  user_data=self._token)])
        self.stats.io_requests += 1
        while self.io.inflight >= self.cfg.queue_depth:
            self._reap(1)

    # ------------------------------------------------------------------ API
    def put(self, key: str, data, pos: int = 0) -> None:
        if self._state != "open":
            raise RuntimeError(f"put() on a {self._state} save stream")
        cfg = self.cfg
        mv = as_u8(data)
        e = self.extents[key]
        g = self._group_of[key]
        if cfg.checksum:
            self.crcs[key] = zlib.crc32(mv, self.crcs.get(key, 0)) & 0xFFFFFFFF
        if g.large:
            expect = self._pos.get(key, 0)
            if pos != expect:
                raise ValueError(f"out-of-order put for {key!r}: "
                                 f"pos {pos} != expected {expect}")
            if pos % cfg.align:
                raise ValueError(f"partial put for {key!r} must start on a "
                                 f"{cfg.align}-byte boundary")
            if pos + mv.nbytes > e.nbytes:
                raise ValueError(f"put overruns {key!r}")
            p = 0
            while p < mv.nbytes:
                n = min(self._chunk, mv.nbytes - p)
                ta = trace.clock()
                buf = self._acquire(align_up(n, cfg.align))
                tb = trace.clock()
                buf.view(0, n)[:] = mv[p:p + n]
                tc = trace.clock()
                self.stats.alloc_seconds += tb - ta
                self.stats.copy_seconds += tc - tb
                self._submit(self.fds[e.path], e.offset + pos + p, buf,
                             align_up(n, cfg.align))
                p += n
            self._pos[key] = pos + mv.nbytes
            g.filled += mv.nbytes
            if self._pos[key] == e.nbytes:
                g.seen += 1
                g.submitted = True
            return
        # coalesced member: whole-object put staged into the group buffer
        if pos or mv.nbytes != e.nbytes:
            raise ValueError(f"coalesced object {key!r} needs one whole put")
        first, last = g.extents[0], g.extents[-1]
        span = last.offset + align_up(last.nbytes, cfg.align) - first.offset
        if g.buf is None:
            ta = trace.clock()
            g.buf = self._acquire(span)
            self.stats.alloc_seconds += trace.clock() - ta
        if mv.nbytes:
            tb = trace.clock()
            g.buf.view(e.offset - first.offset, e.nbytes)[:] = mv
            self.stats.copy_seconds += trace.clock() - tb
        g.filled += e.nbytes
        g.seen += 1
        if g.seen == len(g.extents) and not g.submitted:
            g.submitted = True
            buf, g.buf = g.buf, None
            self._submit(self.fds[first.path], first.offset, buf, span)

    def end_save(self) -> Manifest:
        if self._state != "open":
            raise RuntimeError("end_save() called twice" if
                               self._state == "ended" else
                               "end_save() after abort()")
        missing = [e.key for g in self._groups if not g.submitted
                   for e in g.extents]
        if missing:
            self.abort()
            raise RuntimeError(f"end_save with unfilled objects: {missing[:5]}")
        try:
            with trace.span("flush", tier="level0",
                            nbytes=self.plan.total_logical_bytes):
                while self.io.inflight:
                    self._reap(1)
                self._reap(0)   # drain engines that complete inline (posix)
                t_io0 = trace.clock()
                self.eng._fsync_all(self.io, self.fds)
                self.stats.io_seconds += trace.clock() - t_io0
        finally:
            self._state = "ended"
            self.io.close()
            self.eng._close_files(self.fds)
        self.stats.logical_bytes = self.plan.total_logical_bytes
        self.stats.peak_staged_bytes = self.budget.peak
        self.stats.seconds = trace.clock() - self.t0
        self.eng.last_save_stats = self.stats
        return self.eng._manifest_from(self.specs, self.plan, step=self.step,
                                       num_ranks=self.num_ranks,
                                       crcs=self.crcs or None)

    def abort(self) -> None:
        if self._state != "open":
            return
        self._state = "aborted"
        try:
            try:
                while self.io.inflight:
                    self._reap(1)
                self._reap(0)
            # crlint: allow(CRL005): abort() runs under an original error —
            # cleanup here must never mask it; buffers below still released
            except BaseException:
                pass   # inflight state unknown; buffers below still released
            self.io.close()
        finally:
            self.eng._close_files(self.fds)
            for buf in self._inflight.values():
                buf.release()
            self._inflight.clear()
            for g in self._groups:
                if g.buf is not None:
                    g.buf.release()
                    g.buf = None


class _ReadUnit:
    """One submission-granular read: a coalesced group region, or one chunk
    of an extent larger than the (budget-clamped) chunk size."""

    __slots__ = ("path", "file_off", "span", "group", "key", "pos", "n")

    def __init__(self, path: str, file_off: int, span: int, *,
                 group: list[Extent] | None = None, key: str | None = None,
                 pos: int = 0, n: int = 0):
        self.path, self.file_off, self.span = path, file_off, span
        self.group = group          # members of a coalesced group, else None
        self.key, self.pos, self.n = key, pos, n   # chunk of a large extent


class _AggReadStream(ReadStream):
    """Streaming reader against the io_engine request stream.

    All requests are planned (coalesced, chunked) up front and submitted in
    layout order as the staged-byte budget admits them; ``get`` surfaces each
    request's bytes the moment its extents have landed, so the consumer's
    decode/assemble/H2D overlaps the reads still in flight. The budget counts
    read buffers in flight AND landed-but-unconsumed coalesced-group results,
    so a slow consumer throttles submission instead of ballooning host
    memory. (A chunked large extent's destination array is consumer-owned
    output — the result the ``get`` will hand over — and is not charged, the
    same way the save stream never charges its caller's source arrays.)
    """

    def __init__(self, eng: "AggregatedEngine", ckpt_dir: str,
                 reqs: list[ReadReq], crcs: dict[str, int] | None):
        self.eng = eng
        self.cfg = cfg = eng.config
        self.stats = IOStats()
        self.t0 = trace.clock()
        self.extents: dict[str, Extent] = {}
        for r in reqs:
            if r.key in self.extents:
                raise ValueError(f"duplicate read request key {r.key!r}")
            self.extents[r.key] = Extent(r.key, r.path, r.offset, r.nbytes)
        self.crcs = dict(crcs or {}) if cfg.checksum else {}
        self.budget = StageBudget(cfg.inflight_bytes)
        # clamp staging units to half the budget (same rule as the save
        # stream) so an in-order consumer is never wedged by a single unit
        self._chunk = cfg.chunk_bytes
        thr = cfg.coalesce_bytes
        if cfg.inflight_bytes is not None:
            half = max(cfg.inflight_bytes // 2, 1)
            unit = max(cfg.align, 1 << (half.bit_length() - 1))  # floor pow2
            self._chunk = min(self._chunk, unit)
            thr = min(thr, unit)
        self._units: deque[_ReadUnit] = deque()
        self._unsubmitted: dict[str, int] = {}   # key -> units still queued
        self._dest: dict[str, np.ndarray] = {}   # chunked keys being filled
        self._left: dict[str, int] = {}          # chunked: bytes not landed
        self._crc_state: dict[str, list] = {}    # key -> [crc, pos, {pos: n}]
        self._done: dict[str, np.ndarray] = {}   # landed, awaiting get()
        self._staged_done: dict[str, int] = {}   # done bytes held in budget
        self._consumed: set[str] = set()
        self._handlers: dict[int, tuple] = {}    # token -> (buf, unit)
        self._token = 0
        for group in coalesce(list(self.extents.values()), thr, cfg.align):
            first, last = group[0], group[-1]
            if len(group) == 1 and first.nbytes > self._chunk:
                pos, n_units = 0, 0
                while pos < first.nbytes:
                    n = min(self._chunk, first.nbytes - pos)
                    self._units.append(_ReadUnit(
                        first.path, first.offset + pos,
                        align_up(n, cfg.align), key=first.key, pos=pos, n=n))
                    pos += n
                    n_units += 1
                self._unsubmitted[first.key] = n_units
                self._left[first.key] = first.nbytes
            else:
                span = (last.offset + align_up(last.nbytes, cfg.align)
                        - first.offset)
                self._units.append(
                    _ReadUnit(first.path, first.offset, span, group=group))
                for e in group:
                    self._unsubmitted[e.key] = 1
        self._state = "open"            # open → ended | aborted
        self.io = None
        self.fds = eng._open_files(
            ckpt_dir, {e.path for e in self.extents.values()}, "r")
        try:
            self.stats.files = len(self.fds)
            self.io = eng._make_io()
            self._submit_admitted(None)  # prime: reads overlap caller's work
        except BaseException:
            # begin_restore never returned, so no caller can abort(): free
            # everything here or the fds/backend/buffers leak for good
            self.abort()
            raise

    # ------------------------------------------------------------- plumbing
    def _submit_admitted(self, wait_for: str | None,
                         drain: bool = False) -> None:
        """Submit queued units while the queue depth and budget admit more.

        When the budget is held by landed-but-unconsumed results and no read
        is in flight, an out-of-order consumer (or the ``end_restore`` drain
        of a stream whose keys were never all consumed) would deadlock —
        exceed the budget one unit at a time until ``wait_for``'s units are
        submitted / the queue empties (the documented over-budget escape
        hatch)."""
        while self._units and self.io.inflight < self.cfg.queue_depth:
            unit = self._units[0]
            if not self.budget.admits(
                    BufferPool.size_class(max(unit.span, 1))):
                if self.io.inflight or not (
                        drain or (wait_for is not None
                                  and wait_for not in self._done
                                  and self._unsubmitted.get(wait_for))):
                    break
            self._units.popleft()
            self._submit(unit)

    def _submit(self, unit: _ReadUnit) -> None:
        ta = trace.clock()
        buf = self.eng.pool.get(unit.span)
        self.stats.alloc_seconds += trace.clock() - ta
        self.budget.add(buf.nbytes)
        self._token += 1
        self._handlers[self._token] = (buf, unit)
        self.io.submit([IORequest(OP_READ, self.fds[unit.path], unit.file_off,
                                  buf, 0, unit.span, user_data=self._token)])
        self.stats.io_requests += 1
        if unit.group is not None:
            for e in unit.group:
                self._unsubmitted[e.key] -= 1
        else:
            self._unsubmitted[unit.key] -= 1

    def _pump(self, wait_for: str | None = None, drain: bool = False) -> None:
        self._submit_admitted(wait_for, drain)
        if self.io.inflight:
            cs = self.io.poll(min_n=1)
        else:
            cs = self.io.poll()   # drain engines that complete inline (posix)
        for c in cs:
            self._complete(c)

    def _complete(self, c) -> None:
        buf, unit = self._handlers.pop(c.user_data)
        tb = trace.clock()
        if unit.group is not None:
            first = unit.group[0]
            landed = 0
            for e in unit.group:
                arr = np.empty(e.nbytes, dtype=np.uint8)
                arr[:] = np.frombuffer(
                    buf.view(e.offset - first.offset, e.nbytes), np.uint8)
                self._done[e.key] = arr
                self._staged_done[e.key] = e.nbytes
                landed += e.nbytes
            self.budget.sub(buf.nbytes)
            buf.release()
            self.budget.add(landed)
            self.stats.copy_seconds += trace.clock() - tb
            for e in unit.group:     # verify AFTER the books are settled
                self._verify_whole(e)
        else:
            e = self.extents[unit.key]
            dest = self._dest.get(unit.key)
            if dest is None:
                dest = self._dest[unit.key] = np.empty(e.nbytes, np.uint8)
            dest[unit.pos:unit.pos + unit.n] = np.frombuffer(
                buf.view(0, unit.n), np.uint8)
            self.budget.sub(buf.nbytes)
            buf.release()
            self._left[unit.key] -= unit.n
            if self._left[unit.key] == 0:
                self._done[unit.key] = self._dest.pop(unit.key)
            self.stats.copy_seconds += trace.clock() - tb
            self._advance_crc(e, dest, unit.pos, unit.n)

    # ------------------------------------------------------ CRC verification
    def _verify_whole(self, e: Extent) -> None:
        expect = self.crcs.get(e.key)
        if expect is None:
            return
        got = zlib.crc32(self._done[e.key]) & 0xFFFFFFFF
        if got != expect:
            raise ChecksumError(e.key, e.path, e.offset, expect, got)

    def _advance_crc(self, e: Extent, dest: np.ndarray, pos: int,
                     n: int) -> None:
        """Chunks may land out of order; the CRC rolls forward over the
        contiguous prefix as arrivals extend it."""
        expect = self.crcs.get(e.key)
        if expect is None:
            return
        st = self._crc_state.setdefault(e.key, [0, 0, {}])
        st[2][pos] = n
        while st[1] in st[2]:
            m = st[2].pop(st[1])
            st[0] = zlib.crc32(dest[st[1]:st[1] + m], st[0]) & 0xFFFFFFFF
            st[1] += m
        if st[1] == e.nbytes and st[0] != expect:
            raise ChecksumError(e.key, e.path, e.offset, expect, st[0])

    # ------------------------------------------------------------------ API
    def get(self, key: str) -> np.ndarray:
        if self._state != "open":
            raise RuntimeError(f"get() on a {self._state} read stream")
        if key in self._consumed:
            raise KeyError(f"read request {key!r} already consumed")
        if key not in self.extents:
            raise KeyError(key)
        t0 = trace.clock()
        while key not in self._done:
            self._pump(wait_for=key)
        self.stats.io_seconds += trace.clock() - t0  # blocked-on-read
        arr = self._done.pop(key)
        self._consumed.add(key)
        self.budget.sub(self._staged_done.pop(key, 0))
        return arr

    def end_restore(self) -> IOStats:
        if self._state != "open":
            raise RuntimeError("end_restore() called twice" if
                               self._state == "ended" else
                               "end_restore() after abort()")
        while self._units or self._handlers:
            self._pump(drain=True)
        self._state = "ended"
        self.io.close()
        self.eng._close_files(self.fds)
        self.stats.logical_bytes = sum(
            e.nbytes for e in self.extents.values())
        self.stats.peak_staged_bytes = self.budget.peak
        self.stats.seconds = trace.clock() - self.t0
        self.eng.last_restore_stats = self.stats
        return self.stats

    def abort(self) -> None:
        if self._state != "open":
            return
        self._state = "aborted"
        try:
            try:
                while self.io is not None and self.io.inflight:
                    for c in self.io.poll(min_n=1):
                        buf, _u = self._handlers.pop(c.user_data,
                                                     (None, None))
                        if buf is not None:
                            buf.release()
                if self.io is not None:
                    for c in self.io.poll():
                        buf, _u = self._handlers.pop(c.user_data,
                                                     (None, None))
                        if buf is not None:
                            buf.release()
            # crlint: allow(CRL005): abort() runs under an original error —
            # cleanup here must never mask it; handlers below still released
            except BaseException:
                pass   # inflight state unknown; handlers below still released
            if self.io is not None:
                self.io.close()
        finally:
            self.eng._close_files(self.fds)
            for buf, _u in self._handlers.values():
                buf.release()
            self._handlers.clear()
            self._done.clear()
            self._dest.clear()
            self.budget.settle()


class AggregatedEngine(CREngine):
    name = "aggregated"
    supports_streaming = True
    supports_streaming_read = True

    # ------------------------------------------------------------------ save
    def begin_save(self, ckpt_dir: str, specs: list[SaveSpec], *,
                   step: int = 0, rank: int = 0, num_ranks: int = 1,
                   rank_totals: list[int] | None = None) -> SaveStream:
        return _AggSaveStream(self, ckpt_dir, specs, step, rank, num_ranks,
                              rank_totals)

    def save(self, ckpt_dir: str, items: list[SaveItem], *, step: int = 0,
             rank: int = 0, num_ranks: int = 1,
             rank_totals: list[int] | None = None) -> Manifest:
        stream = self.begin_save(ckpt_dir, [spec_of(it) for it in items],
                                 step=step, rank=rank, num_ranks=num_ranks,
                                 rank_totals=rank_totals)
        try:
            for it in items:
                stream.put(it.key, it.data)
            return stream.end_save()
        except BaseException:
            stream.abort()
            raise

    # ------------------------------------------------------------------ read
    def begin_restore(self, ckpt_dir: str, reqs: list[ReadReq], *,
                      crcs: dict[str, int] | None = None) -> ReadStream:
        return _AggReadStream(self, ckpt_dir, reqs, crcs)

    def read(self, ckpt_dir: str, reqs: list[ReadReq]) -> dict[str, np.ndarray]:
        stream = self.begin_restore(ckpt_dir, reqs)
        try:
            out = {r.key: stream.get(r.key) for r in reqs}
            stream.end_restore()
            return out
        except BaseException:
            stream.abort()
            raise
