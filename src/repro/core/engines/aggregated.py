"""AggregatedEngine — the paper's "ideal approach", productionized.

Write path (paper Observations 1, 2, 4), exposed as a STREAM
(``begin_save`` / ``put`` / ``end_save``; batch ``save`` is a degenerate
client that puts every item and drains):
  · layout per the configured aggregation strategy (default: single aggregated
    file with cross-rank prefix-sum offsets), planned from sizes alone before
    any payload exists,
  · request-level coalescing: small objects are staged into pooled aligned
    buffers and flushed as FEW LARGE writes (one per ~coalesce_bytes group),
  · large objects are staged through a small ring of chunk buffers so the
    memcpy of chunk k+1 overlaps the write of chunk k (double buffering),
  · staged bytes in flight are bounded by ``config.inflight_bytes`` —
    backpressure reaps completed writes before staging more,
  · O_DIRECT by default (4.8× write uplift in the paper), deep submission
    queues, batched io_uring submission, optional registered buffers.

Restore path (paper Observation 3):
  · coalesced reads — one I/O per group region covering many small objects,
  · preallocated POOLED buffers (the fix for DataStates' dominant
    allocation cost), O_DIRECT reads for large transfers.
"""

from __future__ import annotations

import time
import zlib

import numpy as np

from ..aggregation import Extent, coalesce
from ..buffers import BufferPool, StageBudget, align_up
from ..io_engine import IORequest, OP_READ, OP_WRITE
from ..manifest import Manifest
from .base import (CREngine, IOStats, ReadReq, SaveItem, SaveSpec, SaveStream,
                   as_u8, spec_of)


class _Group:
    """One coalesce group being filled across put() calls."""

    __slots__ = ("extents", "large", "buf", "filled", "seen", "submitted")

    def __init__(self, extents: list[Extent], large: bool):
        self.extents = extents
        self.large = large          # single object streamed in chunks
        self.buf = None             # staging buffer while filling
        self.filled = 0             # logical bytes staged so far
        self.seen = 0               # member objects fully put
        self.submitted = False


class _AggSaveStream(SaveStream):
    """Streaming writer against the io_engine request stream.

    Each put stages its bytes into pooled aligned buffers (coalescing small
    contiguous objects, chunking large ones) and submits the write
    immediately — storage I/O overlaps the caller's next snapshot/pack.
    """

    def __init__(self, eng: "AggregatedEngine", ckpt_dir: str,
                 specs: list[SaveSpec], step: int, rank: int, num_ranks: int,
                 rank_totals: list[int] | None):
        self.eng = eng
        self.cfg = cfg = eng.config
        self.step, self.num_ranks = step, num_ranks
        self.specs = list(specs)
        self.stats = IOStats()
        self.t0 = time.perf_counter()
        self.plan = eng._plan(self.specs, rank, rank_totals)
        self.extents = {e.key: e for e in self.plan.extents}
        self.fds = eng._open_files(ckpt_dir, self.plan, "w", preallocate=True)
        self.stats.files = len(self.fds)
        self.io = eng._make_io()
        self.budget = StageBudget(cfg.inflight_bytes)
        # clamp staging units to half the budget so the cap is HARD: every
        # buffer class then fits twice, and the admits() idle-override can
        # never be reached by an oversized single unit
        self._chunk = cfg.chunk_bytes
        thr = cfg.coalesce_bytes
        if cfg.inflight_bytes is not None:
            half = max(cfg.inflight_bytes // 2, 1)
            unit = max(cfg.align, 1 << (half.bit_length() - 1))  # floor pow2
            self._chunk = min(self._chunk, unit)
            thr = min(thr, unit)
        self.crcs: dict[str, int] = {}
        self._inflight: dict[int, object] = {}   # token -> buffer to release
        self._token = 0
        self._pos: dict[str, int] = {}           # chunked-put progress per key
        self._group_of: dict[str, _Group] = {}
        self._groups: list[_Group] = []
        for g in coalesce(self.plan.extents, thr, cfg.align):
            grp = _Group(g, len(g) == 1 and g[0].nbytes > self._chunk)
            self._groups.append(grp)
            for e in g:
                self._group_of[e.key] = grp
        self._state = "open"            # open → ended | aborted

    # ------------------------------------------------------------- plumbing
    def _reap(self, block_min: int) -> None:
        for c in self.io.poll(min_n=block_min):
            buf = self._inflight.pop(c.user_data, None)
            if buf is not None:
                self.budget.sub(buf.nbytes)
                buf.release()

    def _acquire(self, span: int):
        """Pooled staging buffer, bounded: reap completed writes until the
        staged bytes in flight admit one more buffer (backpressure).

        The bound is hard for clients that put objects in layout order
        (batch save and the snapshot pipeline): units are clamped to half
        the budget and every blocker is a reapable write. A client that
        interleaves puts across MANY coalesce groups can hold one open
        group buffer per interleaved group above the budget — open group
        buffers are only reclaimable by completing their groups."""
        need = BufferPool.size_class(max(span, 1))
        while not self.budget.admits(need) and self._inflight:
            self._reap(1)
        buf = self.eng.pool.get(span)
        self.budget.add(buf.nbytes)
        return buf

    def _submit(self, fd: int, file_off: int, buf, span: int) -> None:
        self._token += 1
        self._inflight[self._token] = buf
        self.io.submit([IORequest(OP_WRITE, fd, file_off, buf, 0, span,
                                  user_data=self._token)])
        self.stats.io_requests += 1
        while self.io.inflight >= self.cfg.queue_depth:
            self._reap(1)

    # ------------------------------------------------------------------ API
    def put(self, key: str, data, pos: int = 0) -> None:
        if self._state != "open":
            raise RuntimeError(f"put() on a {self._state} save stream")
        cfg = self.cfg
        mv = as_u8(data)
        e = self.extents[key]
        g = self._group_of[key]
        if cfg.checksum:
            self.crcs[key] = zlib.crc32(mv, self.crcs.get(key, 0)) & 0xFFFFFFFF
        if g.large:
            expect = self._pos.get(key, 0)
            if pos != expect:
                raise ValueError(f"out-of-order put for {key!r}: "
                                 f"pos {pos} != expected {expect}")
            if pos % cfg.align:
                raise ValueError(f"partial put for {key!r} must start on a "
                                 f"{cfg.align}-byte boundary")
            if pos + mv.nbytes > e.nbytes:
                raise ValueError(f"put overruns {key!r}")
            p = 0
            while p < mv.nbytes:
                n = min(self._chunk, mv.nbytes - p)
                ta = time.perf_counter()
                buf = self._acquire(align_up(n, cfg.align))
                tb = time.perf_counter()
                buf.view(0, n)[:] = mv[p:p + n]
                tc = time.perf_counter()
                self.stats.alloc_seconds += tb - ta
                self.stats.copy_seconds += tc - tb
                self._submit(self.fds[e.path], e.offset + pos + p, buf,
                             align_up(n, cfg.align))
                p += n
            self._pos[key] = pos + mv.nbytes
            g.filled += mv.nbytes
            if self._pos[key] == e.nbytes:
                g.seen += 1
                g.submitted = True
            return
        # coalesced member: whole-object put staged into the group buffer
        if pos or mv.nbytes != e.nbytes:
            raise ValueError(f"coalesced object {key!r} needs one whole put")
        first, last = g.extents[0], g.extents[-1]
        span = last.offset + align_up(last.nbytes, cfg.align) - first.offset
        if g.buf is None:
            ta = time.perf_counter()
            g.buf = self._acquire(span)
            self.stats.alloc_seconds += time.perf_counter() - ta
        if mv.nbytes:
            tb = time.perf_counter()
            g.buf.view(e.offset - first.offset, e.nbytes)[:] = mv
            self.stats.copy_seconds += time.perf_counter() - tb
        g.filled += e.nbytes
        g.seen += 1
        if g.seen == len(g.extents) and not g.submitted:
            g.submitted = True
            buf, g.buf = g.buf, None
            self._submit(self.fds[first.path], first.offset, buf, span)

    def end_save(self) -> Manifest:
        if self._state != "open":
            raise RuntimeError("end_save() called twice" if
                               self._state == "ended" else
                               "end_save() after abort()")
        missing = [e.key for g in self._groups if not g.submitted
                   for e in g.extents]
        if missing:
            self.abort()
            raise RuntimeError(f"end_save with unfilled objects: {missing[:5]}")
        try:
            while self.io.inflight:
                self._reap(1)
            self._reap(0)   # drain engines that complete inline (posix)
            t_io0 = time.perf_counter()
            self.eng._fsync_all(self.io, self.fds)
            self.stats.io_seconds += time.perf_counter() - t_io0
        finally:
            self._state = "ended"
            self.io.close()
            self.eng._close_files(self.fds)
        self.stats.logical_bytes = self.plan.total_logical_bytes
        self.stats.peak_staged_bytes = self.budget.peak
        self.stats.seconds = time.perf_counter() - self.t0
        self.eng.last_save_stats = self.stats
        return self.eng._manifest_from(self.specs, self.plan, step=self.step,
                                       num_ranks=self.num_ranks,
                                       crcs=self.crcs or None)

    def abort(self) -> None:
        if self._state != "open":
            return
        self._state = "aborted"
        try:
            try:
                while self.io.inflight:
                    self._reap(1)
                self._reap(0)
            except BaseException:
                pass   # inflight state unknown; buffers below still released
            self.io.close()
        finally:
            self.eng._close_files(self.fds)
            for buf in self._inflight.values():
                buf.release()
            self._inflight.clear()
            for g in self._groups:
                if g.buf is not None:
                    g.buf.release()
                    g.buf = None


class AggregatedEngine(CREngine):
    name = "aggregated"
    supports_streaming = True

    # ------------------------------------------------------------------ save
    def begin_save(self, ckpt_dir: str, specs: list[SaveSpec], *,
                   step: int = 0, rank: int = 0, num_ranks: int = 1,
                   rank_totals: list[int] | None = None) -> SaveStream:
        return _AggSaveStream(self, ckpt_dir, specs, step, rank, num_ranks,
                              rank_totals)

    def save(self, ckpt_dir: str, items: list[SaveItem], *, step: int = 0,
             rank: int = 0, num_ranks: int = 1,
             rank_totals: list[int] | None = None) -> Manifest:
        stream = self.begin_save(ckpt_dir, [spec_of(it) for it in items],
                                 step=step, rank=rank, num_ranks=num_ranks,
                                 rank_totals=rank_totals)
        try:
            for it in items:
                stream.put(it.key, it.data)
            return stream.end_save()
        except BaseException:
            stream.abort()
            raise

    # ------------------------------------------------------------------ read
    def read(self, ckpt_dir: str, reqs: list[ReadReq]) -> dict[str, np.ndarray]:
        cfg = self.config
        t0 = time.perf_counter()
        stats = IOStats()
        out: dict[str, np.ndarray] = {}
        extents = [Extent(r.key, r.path, r.offset, r.nbytes) for r in reqs]
        groups = coalesce(extents, cfg.coalesce_bytes, cfg.align)
        fds = self._open_files(ckpt_dir, {r.path for r in reqs}, "r")
        stats.files = len(fds)
        io = self._make_io()
        handlers: dict[int, tuple] = {}  # token -> (buf, on_done)
        token = 0

        def reap(block_min: int):
            for c in io.poll(min_n=block_min):
                buf, on_done = handlers.pop(c.user_data)
                tb = time.perf_counter()
                on_done(buf)
                stats.copy_seconds += time.perf_counter() - tb
                buf.release()

        def submit_read(fd: int, file_off: int, span: int, on_done):
            nonlocal token
            ta = time.perf_counter()
            buf = self.pool.get(span)
            stats.alloc_seconds += time.perf_counter() - ta
            token += 1
            handlers[token] = (buf, on_done)
            io.submit([IORequest(OP_READ, fd, file_off, buf, 0, span,
                                 user_data=token)])
            stats.io_requests += 1
            while io.inflight >= cfg.queue_depth:
                reap(1)

        try:
            for group in groups:
                first, last = group[0], group[-1]
                if len(group) == 1 and first.nbytes > cfg.chunk_bytes:
                    # Large object: chunked pipelined reads into one dest array.
                    dest = np.empty(first.nbytes, dtype=np.uint8)
                    out[first.key] = dest
                    pos = 0
                    while pos < first.nbytes:
                        n = min(cfg.chunk_bytes, first.nbytes - pos)

                        def done(buf, dest=dest, pos=pos, n=n):
                            dest[pos:pos + n] = np.frombuffer(
                                buf.view(0, n), np.uint8)

                        submit_read(fds[first.path], first.offset + pos,
                                    align_up(n, cfg.align), done)
                        pos += n
                else:
                    span = (last.offset + align_up(last.nbytes, cfg.align)
                            - first.offset)

                    def done(buf, group=group, first=first):
                        for e in group:
                            arr = np.empty(e.nbytes, dtype=np.uint8)
                            arr[:] = np.frombuffer(
                                buf.view(e.offset - first.offset, e.nbytes),
                                np.uint8)
                            out[e.key] = arr

                    submit_read(fds[first.path], first.offset, span, done)
            while io.inflight:
                reap(1)
            reap(0)   # drain engines that complete inline (posix)
        finally:
            io.close()
            self._close_files(fds)
        stats.logical_bytes = sum(r.nbytes for r in reqs)
        stats.seconds = time.perf_counter() - t0
        self.last_restore_stats = stats
        return out
