"""Checkpoint/restore engines (paper §3.5): one interface, four designs.

- ``aggregated``  — the paper's "ideal approach", productionized (ours).
- ``datastates``  — DataStates-LLM-faithful: uring, per-object submission,
                    dynamic allocation, buffered.
- ``snapshot``    — TorchSnapshot-faithful: chunk-per-file nested dirs,
                    thread-pool buffered writes, serial restore.
- ``torchsave``   — torch.save-faithful: monolithic pickle, sequential write.
"""

from .base import (ChecksumError, CREngine, EngineConfig, IOStats, ReadReq,
                   ReadStream, SaveItem, SaveSpec, SaveStream, spec_of)
from .aggregated import AggregatedEngine
from .datastates import DataStatesEngine
from .remote import RemoteReadEngine
from .snapshot import SnapshotEngine
from .torchsave import TorchSaveEngine

ENGINES: dict[str, type[CREngine]] = {
    "aggregated": AggregatedEngine,
    "datastates": DataStatesEngine,
    "snapshot": SnapshotEngine,
    "torchsave": TorchSaveEngine,
}


def make_cr_engine(name: str, config: EngineConfig | None = None,
                   pool=None) -> CREngine:
    return ENGINES[name](config, pool)

__all__ = ["ChecksumError", "CREngine", "EngineConfig", "IOStats", "ReadReq",
           "ReadStream", "SaveItem", "SaveSpec", "SaveStream", "spec_of",
           "AggregatedEngine", "DataStatesEngine", "RemoteReadEngine",
           "SnapshotEngine", "TorchSaveEngine", "ENGINES", "make_cr_engine"]
