"""Common machinery for checkpoint/restore engines.

An engine turns a list of host-resident byte objects (``SaveItem``) into files
under a checkpoint directory and back. Engines differ along exactly the axes
the paper studies: layout (aggregation strategy), I/O backend (uring / threads
/ POSIX), caching mode (O_DIRECT or buffered), submission granularity
(batched-coalesced vs per-object), and buffer management (pooled vs dynamic).
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field, replace

import numpy as np

from .. import faults
from ..aggregation import Extent, ObjectSpec, Strategy, WritePlan, plan_layout, rank_padded_total
from ..buffers import AlignedBuffer, BufferPool, PAGE, align_up
from ..io_engine import (IOEngine, IORequest, OP_READ, OP_WRITE, make_engine,
                         open_for, resolve_backend)
from ..manifest import BlobRecord, Manifest, ShardEntry, crc32_of


@dataclass
class SaveItem:
    """One host-resident object to persist.

    ``key`` must be unique across the rank's items (it names the extent);
    ``record_key`` groups multiple shards of one global tensor in the manifest
    (defaults to ``key``).
    """
    key: str
    data: object                      # buffer-protocol object (np.ndarray, bytes, memoryview)
    dtype: str | None = None          # tensor metadata (None for blobs)
    global_shape: tuple[int, ...] | None = None
    index: tuple[tuple[int, int], ...] | None = None  # global (start, stop) per dim
    is_blob: bool = False
    record_key: str | None = None

    @property
    def nbytes(self) -> int:
        return memoryview(self.data).nbytes

    def mv(self) -> memoryview:
        return item_mv(self)


@dataclass
class SaveSpec:
    """Metadata-only declaration of one object a streaming save will ``put``.

    ``SaveItem`` minus the payload: the layout planner assigns file offsets
    from object sizes alone, so a save can be planned — and the cross-rank
    prefix sum exchanged — before a single byte is staged (quantized payload
    sizes are deterministic too, see ``quant_codec.packed_nbytes``)."""
    key: str
    nbytes: int
    dtype: str | None = None
    global_shape: tuple[int, ...] | None = None
    index: tuple[tuple[int, int], ...] | None = None
    is_blob: bool = False
    record_key: str | None = None


def spec_of(item: SaveItem) -> SaveSpec:
    return SaveSpec(item.key, item.nbytes, item.dtype, item.global_shape,
                    item.index, item.is_blob, item.record_key)


@dataclass
class ReadReq:
    """One byte-range to read back.

    ``key`` names the result in the returned dict (unique per request);
    ``obj`` is the logical object key in the manifest (used by engines whose
    formats are object-addressed rather than extent-addressed, e.g. torchsave).
    """
    key: str
    path: str
    offset: int
    nbytes: int
    obj: str | None = None


class ChecksumError(IOError):
    """Restored bytes did not match the CRC the manifest recorded at save."""

    def __init__(self, key: str, path: str, offset: int,
                 expect: int, got: int):
        super().__init__(
            f"CRC mismatch restoring {key!r} ({path} @ byte {offset}): "
            f"got {got:#010x}, manifest says {expect:#010x}")
        self.key = key
        self.path = path
        self.offset = offset
        self.expect = expect
        self.got = got


@dataclass
class IOStats:
    seconds: float = 0.0
    logical_bytes: int = 0
    io_requests: int = 0
    files: int = 0
    alloc_seconds: float = 0.0   # buffer acquisition time (paper Fig 13)
    copy_seconds: float = 0.0    # staging memcpy time
    io_seconds: float = 0.0      # submit+wait time
    peak_staged_bytes: int = 0   # max staged bytes in flight (backpressure)

    @property
    def gbps(self) -> float:
        return self.logical_bytes / self.seconds / 1e9 if self.seconds else 0.0


@dataclass
class EngineConfig:
    backend: str = "auto"             # auto | uring | threadpool | posix
    strategy: Strategy | str = Strategy.SINGLE_FILE
    direct: bool = True               # O_DIRECT
    queue_depth: int = 64
    ring_entries: int = 256
    chunk_bytes: int = 64 << 20       # submission chunk for large objects
    coalesce_bytes: int = 64 << 20    # staging-batch target (paper: ~2GB/rank saturates)
    checksum: bool = False
    pooled_buffers: bool = True       # False models DataStates' dynamic allocation
    register_buffers: bool = False    # io_uring fixed buffers
    sqpoll: bool = False
    fsync_on_save: bool = True
    truncate: bool = True             # False: multi-rank shared-file mode
    align: int = PAGE
    inflight_bytes: int = 256 << 20   # streaming-save staged-byte budget

    def normalized(self) -> "EngineConfig":
        """Resolved copy (strategy enum, concrete backend). Pure: the
        receiver is left untouched, so one config object can be shared by
        several engines/managers without them corrupting each other."""
        return replace(self, strategy=Strategy.parse(self.strategy),
                       backend=resolve_backend(self.backend))


class SaveStream:
    """One in-progress streaming save (returned by ``CREngine.begin_save``).

    Contract: every spec declared at ``begin_save`` must be fully ``put``
    before ``end_save``; all calls come from one thread at a time (the
    pipeline's worker), though that may differ from ``begin_save``'s caller.
    Partial puts (``pos > 0``, in order, align-granular) are only valid for
    objects that stand alone in the layout (larger than ``chunk_bytes``)."""

    def put(self, key: str, data, pos: int = 0) -> None:
        raise NotImplementedError

    def end_save(self) -> Manifest:
        raise NotImplementedError

    def abort(self) -> None:
        """Tear down after a failure; safe to call after end_save (no-op)."""


class _BufferedSaveStream(SaveStream):
    """Batch adapter: engines without a native streaming path accumulate the
    puts and run one batch ``save`` at ``end_save`` — same data path and
    manifests as before, no stage/flush overlap."""

    def __init__(self, engine: "CREngine", ckpt_dir: str,
                 specs: list[SaveSpec], step: int, rank: int, num_ranks: int,
                 rank_totals: list[int] | None):
        self.engine = engine
        self.ckpt_dir = ckpt_dir
        self.specs = list(specs)
        self.kw = dict(step=step, rank=rank, num_ranks=num_ranks,
                       rank_totals=rank_totals)
        self._parts: dict[str, list[tuple[int, object]]] = {}
        self._state = "open"            # open → ended | aborted

    def put(self, key: str, data, pos: int = 0) -> None:
        if self._state != "open":
            raise RuntimeError(f"put() on a {self._state} save stream")
        if not isinstance(data, bytes):
            # own the bytes: once a put returns, the save must never read
            # caller memory again (the pipeline's staged-snapshot contract)
            data = np.frombuffer(as_u8(data), np.uint8).copy()
        self._parts.setdefault(key, []).append((pos, data))

    def end_save(self) -> Manifest:
        if self._state != "open":
            raise RuntimeError("end_save() called twice" if
                               self._state == "ended" else
                               "end_save() after abort()")
        self._state = "ended"
        items: list[SaveItem] = []
        for spec in self.specs:
            parts = self._parts.get(spec.key)
            if parts is None:
                raise RuntimeError(f"missing put() for {spec.key!r}")
            # same completeness contract as the native stream: the layout
            # (and any cross-rank prefix sum) was planned from spec.nbytes,
            # so partial coverage must fail loudly, not commit garbage
            covered = 0
            for pos, chunk in sorted(parts, key=lambda p: p[0]):
                if pos != covered:
                    raise RuntimeError(
                        f"non-contiguous puts for {spec.key!r}: "
                        f"byte {covered} missing")
                covered += memoryview(chunk).nbytes
            if covered != spec.nbytes:
                raise RuntimeError(
                    f"end_save with unfilled object {spec.key!r}: "
                    f"{covered} of {spec.nbytes} bytes put")
            if len(parts) == 1:
                data = parts[0][1]
            else:  # chunked puts: assemble the object
                data = np.empty(spec.nbytes, np.uint8)
                for pos, chunk in parts:
                    mv = as_u8(chunk)
                    data[pos:pos + mv.nbytes] = np.frombuffer(mv, np.uint8)
            items.append(SaveItem(spec.key, data, spec.dtype,
                                  spec.global_shape, spec.index,
                                  spec.is_blob, spec.record_key))
        return self.engine.save(self.ckpt_dir, items, **self.kw)

    def abort(self) -> None:
        if self._state == "open":
            self._state = "aborted"
        self._parts.clear()


class ReadStream:
    """One in-progress streaming restore (returned by ``CREngine.begin_restore``).

    Contract: every ``ReadReq`` declared at ``begin_restore`` may be fetched
    exactly once via ``get``; all calls come from one thread (the restore
    pipeline's consumer loop). ``get`` blocks only until *that* request's
    bytes have landed — requests behind it stay in flight, so decode/assemble
    /H2D of tensor k overlaps the reads of tensor k+1. Keys should be
    consumed roughly in declaration (= layout) order: the stream's staged-byte
    budget admits new reads as earlier results are drained, and an
    out-of-order ``get`` may have to exceed the budget by one unit to
    guarantee progress."""

    def get(self, key: str) -> np.ndarray:
        raise NotImplementedError

    def end_restore(self) -> IOStats:
        """Drain remaining I/O, close resources, return the restore stats
        (also published as ``engine.last_restore_stats``)."""
        raise NotImplementedError

    def abort(self) -> None:
        """Tear down after a failure: release every pooled buffer and settle
        the staged-byte books so the engine is reusable. Safe to call after
        end_restore (no-op)."""


class _BufferedReadStream(ReadStream):
    """Batch adapter: engines without a native streaming read run one batch
    ``read`` up front — same data path and stats as before, no overlap —
    then serve ``get`` from the result, validating CRCs per request."""

    def __init__(self, engine: "CREngine", ckpt_dir: str,
                 reqs: list[ReadReq], crcs: dict[str, int] | None):
        self.engine = engine
        self.reqs = {r.key: r for r in reqs}
        self.crcs = dict(crcs or {}) if engine.config.checksum else {}
        self._out = engine.read(ckpt_dir, reqs)
        # the batch read staged every request in host memory at once — make
        # the stats say so (the stream path reports its bounded peak here)
        stats = engine.last_restore_stats
        stats.peak_staged_bytes = max(stats.peak_staged_bytes,
                                      sum(r.nbytes for r in reqs))
        self._state = "open"            # open → ended | aborted

    def get(self, key: str) -> np.ndarray:
        if self._state != "open":
            raise RuntimeError(f"get() on a {self._state} read stream")
        raw = self._out.pop(key)        # KeyError on unknown/repeated key
        expect = self.crcs.get(key)
        if expect is not None:
            got = crc32_of(raw)
            if got != expect:
                r = self.reqs[key]
                raise ChecksumError(key, r.path, r.offset, expect, got)
        return raw

    def end_restore(self) -> IOStats:
        if self._state != "open":
            raise RuntimeError("end_restore() called twice" if
                               self._state == "ended" else
                               "end_restore() after abort()")
        self._state = "ended"
        self._out.clear()
        return self.engine.last_restore_stats

    def abort(self) -> None:
        if self._state == "open":
            self._state = "aborted"
        self._out.clear()


class CREngine:
    """Base class. Subclasses set ``name`` and override save/restore."""

    name = "base"
    supports_streaming = False   # True: begin_save overlaps staging & flush
    supports_streaming_read = False  # True: begin_restore overlaps read/consume

    def __init__(self, config: EngineConfig | None = None,
                 pool: BufferPool | None = None):
        self.config = (config or EngineConfig()).normalized()
        self.pool = pool or BufferPool(disabled=not self.config.pooled_buffers)
        self.last_save_stats = IOStats()
        self.last_restore_stats = IOStats()

    # ------------------------------------------------------------------ API
    def save(self, ckpt_dir: str, items: list[SaveItem], *, step: int = 0,
             rank: int = 0, num_ranks: int = 1,
             rank_totals: list[int] | None = None) -> Manifest:
        raise NotImplementedError

    def begin_save(self, ckpt_dir: str, specs: list[SaveSpec], *,
                   step: int = 0, rank: int = 0, num_ranks: int = 1,
                   rank_totals: list[int] | None = None) -> SaveStream:
        """Open a streaming save: the layout is planned from ``specs`` up
        front, then payloads arrive via ``put`` in any key order. Engines
        with ``supports_streaming`` flush each staged extent as it lands;
        this base fallback buffers and delegates to batch ``save``."""
        return _BufferedSaveStream(self, ckpt_dir, specs, step, rank,
                                   num_ranks, rank_totals)

    def read(self, ckpt_dir: str, reqs: list[ReadReq]) -> dict[str, np.ndarray]:
        raise NotImplementedError

    def begin_restore(self, ckpt_dir: str, reqs: list[ReadReq], *,
                      crcs: dict[str, int] | None = None) -> ReadStream:
        """Open a streaming restore over ``reqs``. Engines with
        ``supports_streaming_read`` surface each request's bytes as its
        extents land, verifying CRCs incrementally (``crcs`` maps request
        key → expected crc32; checked only when ``config.checksum`` is set).
        This base fallback runs one batch ``read`` and validates per get."""
        return _BufferedReadStream(self, ckpt_dir, reqs, crcs)

    def close(self) -> None:
        self.pool.drain()

    # --------------------------------------------------------------- helpers
    def _make_io(self, fixed: list[AlignedBuffer] | None = None) -> IOEngine:
        kw = {}
        if self.config.backend == "uring":
            kw = {"entries": self.config.ring_entries, "sqpoll": self.config.sqpoll}
            if fixed and self.config.register_buffers:
                kw["fixed_buffers"] = fixed
        elif self.config.backend == "threadpool":
            kw = {"workers": min(self.config.queue_depth, 16)}
        return make_engine(self.config.backend, **kw)

    def _plan(self, items: list[SaveItem], rank: int,
              rank_totals: list[int] | None) -> WritePlan:
        objects = [ObjectSpec(i.key, i.nbytes) for i in items]
        if (Strategy.parse(self.config.strategy) is Strategy.SINGLE_FILE
                and rank_totals is None):
            rank_totals = [rank_padded_total(objects, self.config.align)]
        return plan_layout(objects, self.config.strategy, rank=rank,
                           rank_totals=rank_totals, align=self.config.align)

    def _manifest_from(self, items: list[SaveItem], plan: WritePlan, *,
                       step: int, num_ranks: int,
                       crcs: dict[str, int] | None = None) -> Manifest:
        m = Manifest(step=step, num_ranks=num_ranks,
                     strategy=Strategy.parse(self.config.strategy).value)
        by_key = {e.key: e for e in plan.extents}
        for it in items:
            e = by_key[it.key]
            crc = (crcs or {}).get(it.key)
            rkey = it.record_key or it.key
            if it.is_blob:
                m.blobs[rkey] = BlobRecord(rkey, e.path, e.offset,
                                           e.nbytes, crc)
            else:
                index = it.index
                if index is None:
                    index = tuple((0, s) for s in (it.global_shape if it.global_shape is not None else ()))
                m.add_shard(rkey, it.dtype or "uint8",
                            it.global_shape if it.global_shape is not None else (it.nbytes,),
                            ShardEntry(index, e.path, e.offset, e.nbytes, crc))
        # the writing rank, so a merge (rank-0 commit) is idempotent per rank
        m.extra["rank"] = plan.rank
        m.extra["engine"] = {
            "name": self.name, "backend": self.config.backend,
            "direct": self.config.direct, "queue_depth": self.config.queue_depth,
            "chunk_bytes": self.config.chunk_bytes,
            "coalesce_bytes": self.config.coalesce_bytes,
        }
        return m

    def _open_files(self, ckpt_dir: str, plan_or_paths, mode: str,
                    preallocate: bool = False,
                    regions: dict[str, tuple[int, int]] | None = None
                    ) -> dict[str, int]:
        """``regions`` maps path -> (offset, length) to preallocate instead
        of the whole file — in multi-rank shared-file mode each rank
        fallocates only ITS region, keeping the serialized metadata op
        O(per-rank bytes) rather than O(file size) × ranks."""
        fds: dict[str, int] = {}
        if isinstance(plan_or_paths, WritePlan):
            sizes = plan_or_paths.file_sizes
        else:
            sizes = {p: 0 for p in plan_or_paths}
        for path, size in sizes.items():
            full = os.path.join(ckpt_dir, path)
            mode_eff = "rw" if (mode == "w" and not self.config.truncate) \
                else mode
            fd = open_for(full, mode_eff, direct=self.config.direct)
            if preallocate and mode != "r" and size:
                off, length = (regions or {}).get(path, (0, size))
                try:
                    if length:
                        faults.posix_fallocate(fd, off, length)
                # modeled fallback for filesystems without fallocate — an
                # injected ENOSPC degrades to extend-on-write by design
                # crlint: allow(CRL005): fallocate fallback is the contract
                except OSError:
                    pass
            fds[path] = fd
        return fds

    @staticmethod
    def _close_files(fds: dict[str, int]) -> None:
        for fd in fds.values():
            os.close(fd)

    def _fsync_all(self, io: IOEngine, fds: dict[str, int]) -> None:
        if self.config.fsync_on_save:
            for fd in fds.values():
                io.fsync(fd)


def as_u8(data) -> memoryview:
    """Flat uint8 memoryview of any buffer-protocol object."""
    m = memoryview(data)
    if m.format != "B" or m.ndim != 1:
        m = m.cast("B")
    return m


def item_mv(it: "SaveItem") -> memoryview:
    return as_u8(it.data)
