"""DataStatesEngine — DataStates-LLM-faithful baseline (paper §2, §3.5).

Matches the behaviours the paper attributes to DataStates-LLM:
  · file-per-process layout ("file-per-shard" in DeepSpeed terms),
  · io_uring backend — the SAME backend as our AggregatedEngine,
  · but **per-object submission**: "coalesces objects into host buffers but
    submits I/O as soon as each object is available" — every object is its own
    write request; no cross-object coalescing into large transfers,
  · 64 MB chunking of large objects (paper §3.3),
  · buffered I/O (no O_DIRECT in its flush path),
  · restore issues a separate read *for every entry referenced in the
    metadata header* and allocates host memory for each read on the fly
    (paper Fig 13: allocation dominates restore). No native read stream:
    ``begin_restore`` is the validating buffered fallback (one batch read,
    CRC check per get, no read/consume overlap — DESIGN.md §10.3).

The deltas to AggregatedEngine are exactly the paper's findings; everything
else (ring, manifest) is shared, so benchmark gaps isolate the design axes.
"""

from __future__ import annotations


import numpy as np

from .. import trace
from ..aggregation import Strategy
from ..buffers import align_up
from ..io_engine import IORequest, OP_READ, OP_WRITE
from ..manifest import Manifest
from .base import CREngine, EngineConfig, IOStats, ReadReq, SaveItem, item_mv


class DataStatesEngine(CREngine):
    name = "datastates"

    def __init__(self, config: EngineConfig | None = None, pool=None):
        from dataclasses import replace
        cfg = replace(config) if config is not None else EngineConfig()
        cfg.backend = "auto"           # uring when the kernel has it
        cfg.strategy = Strategy.FILE_PER_PROCESS
        cfg.direct = False             # buffered flush path
        cfg.pooled_buffers = False     # dynamic allocation (paper Fig 13)
        super().__init__(cfg, pool)

    def save(self, ckpt_dir: str, items: list[SaveItem], *, step: int = 0,
             rank: int = 0, num_ranks: int = 1,
             rank_totals: list[int] | None = None) -> Manifest:
        cfg = self.config
        t0 = trace.clock()
        stats = IOStats()
        plan = self._plan(items, rank, rank_totals)
        by_key = {e.key: e for e in plan.extents}
        fds = self._open_files(ckpt_dir, plan, "w")
        stats.files = len(fds)
        io = self._make_io()
        inflight: dict[int, object] = {}
        token = 0

        def reap(block_min: int):
            for c in io.poll(min_n=block_min):
                buf = inflight.pop(c.user_data, None)
                if buf is not None:
                    buf.release()

        try:
            # per-OBJECT submission, in arrival order — no batch accumulation
            for it in items:
                e = by_key[it.key]
                mv = item_mv(it)
                pos = 0
                while pos < it.nbytes or (it.nbytes == 0 and pos == 0):
                    n = min(cfg.chunk_bytes, it.nbytes - pos)
                    ta = trace.clock()
                    buf = self.pool.get(max(n, 1))   # fresh buffer each time
                    tb = trace.clock()
                    buf.view(0, n)[:] = mv[pos:pos + n]
                    stats.alloc_seconds += tb - ta
                    stats.copy_seconds += trace.clock() - tb
                    token += 1
                    inflight[token] = buf
                    io.submit([IORequest(OP_WRITE, fds[e.path], e.offset + pos,
                                         buf, 0, max(n, 1), user_data=token)])
                    stats.io_requests += 1
                    pos += max(n, 1)
                    while io.inflight >= cfg.queue_depth:
                        reap(1)
            while io.inflight:
                reap(1)
            self._fsync_all(io, fds)
        finally:
            io.close()
            self._close_files(fds)
        stats.logical_bytes = plan.total_logical_bytes
        stats.seconds = trace.clock() - t0
        self.last_save_stats = stats
        return self._manifest_from(items, plan, step=step, num_ranks=num_ranks)

    def read(self, ckpt_dir: str, reqs: list[ReadReq]) -> dict[str, np.ndarray]:
        """One read per metadata entry; per-read dynamic allocation."""
        cfg = self.config
        t0 = trace.clock()
        stats = IOStats()
        out: dict[str, np.ndarray] = {}
        fds = self._open_files(ckpt_dir, {r.path for r in reqs}, "r")
        stats.files = len(fds)
        io = self._make_io()
        handlers: dict[int, tuple] = {}
        token = 0

        def reap(block_min: int):
            for c in io.poll(min_n=block_min):
                buf, key, nbytes = handlers.pop(c.user_data)
                tb = trace.clock()
                arr = np.empty(nbytes, dtype=np.uint8)
                arr[:] = np.frombuffer(buf.view(0, nbytes), np.uint8)
                out[key] = arr
                stats.copy_seconds += trace.clock() - tb
                buf.release()   # pool disabled → munmap'd, next get() realloc

        try:
            for r in reqs:
                # NOTE: one request per manifest entry, even tiny ones
                ta = trace.clock()
                buf = self.pool.get(max(r.nbytes, 1))
                stats.alloc_seconds += trace.clock() - ta
                token += 1
                handlers[token] = (buf, r.key, r.nbytes)
                io.submit([IORequest(OP_READ, fds[r.path], r.offset, buf, 0,
                                     max(r.nbytes, 1), user_data=token)])
                stats.io_requests += 1
                while io.inflight >= cfg.queue_depth:
                    reap(1)
            while io.inflight:
                reap(1)
        finally:
            io.close()
            self._close_files(fds)
        stats.logical_bytes = sum(r.nbytes for r in reqs)
        stats.seconds = trace.clock() - t0
        self.last_restore_stats = stats
        return out
