"""Unified cross-tier span/event tracer (DESIGN.md §17).

Every layer of the checkpoint stack times itself — ``SaveMetrics``,
``RestoreMetrics``, ``TransferStats``, ``RangeStats``, ``FlushStats`` —
but each slice lives on its own clock with no causal linkage, so "where
did the 96 MB save spend its 95 ms" has no end-to-end answer. This module
is the shared instrument:

  · one process-wide monotonic epoch (``clock()``): every timestamp in the
    stack is seconds since the same instant, so spans recorded on the
    pipeline worker, the io_uring reaper, the level-1 flush thread, and the
    rget pool land on one comparable timeline,
  · spans carry ``(name, tier, bytes, attrs, parent)``; events are instant
    marks (hedge issue/win, injected faults); counters/histograms aggregate,
  · per-thread ring buffers — appends touch only thread-local state (no
    lock on the hot path); overflow drops the OLDEST events and counts the
    drops, so a long soak degrades to "recent history" instead of OOM,
  · a module-level no-op fast path: when no tracer is installed, ``span()``
    returns a shared singleton and ``event()``/``count()`` return
    immediately — O(100 ns), no allocation — so instrumentation stays
    compiled into hot loops permanently,
  · two exporters: Chrome/Perfetto ``trace.json`` (spans as ``X`` events on
    tier-named tracks — open in ui.perfetto.dev, pipeline overlap is
    visually inspectable) and a Prometheus-style textfile of
    counters/histograms,
  · ``MetricsRegistry``: adapts the stack's existing Stats dataclasses
    (live, by reference — no copy at registration) into one queryable tree,
  · ``stall_report()``: attributes a save/restore span's wall time to
    {compute, d2h, stage_wait, level0_write, level1_flush, remote_put,
    remote_get, barrier} by same-thread span self-times, so the attribution
    sums to the wall exactly, and names the top bottleneck.

This module must stay stdlib-only and import-light: ``faults`` emits into
it from inside syscall shims and ``crlint`` mandates ``trace.clock()`` as
the one timing primitive in ``core/**`` (CRL006).
"""

from __future__ import annotations

import itertools
import json
import re
import threading
import time
from dataclasses import dataclass, fields as _dc_fields, is_dataclass

# --------------------------------------------------------------------- clock
# The process trace epoch: set once at import, shared by every thread. All
# core/** timing paths call clock() instead of raw time.perf_counter() so
# durations AND absolute span timestamps from different threads are
# comparable on one exported timeline (CRL006 enforces this).
_EPOCH = time.perf_counter()


def clock() -> float:
    """Monotonic seconds since the process trace epoch."""
    return time.perf_counter() - _EPOCH


@dataclass(slots=True)
class TraceEvent:
    """One recorded span ('X'), instant event ('i'), or counter sample."""
    kind: str             # "span" | "instant"
    name: str
    tier: str
    t0: float             # clock() seconds
    t1: float
    nbytes: int
    span_id: int
    parent_id: int
    tid: int
    thread: str
    attrs: dict | None

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class _Ring:
    """Fixed-capacity per-thread event ring: overwrite drops the oldest."""

    __slots__ = ("buf", "cap", "n", "dropped", "stack", "tid", "thread")

    def __init__(self, cap: int, tid: int, thread: str):
        self.buf: list = [None] * cap
        self.cap = cap
        self.n = 0          # total events ever appended
        self.dropped = 0
        self.stack: list[int] = []   # open span ids (parenting)
        self.tid = tid
        self.thread = thread

    def append(self, ev: TraceEvent) -> None:
        if self.n >= self.cap:
            self.dropped += 1
        self.buf[self.n % self.cap] = ev
        self.n += 1

    def events(self) -> list:
        if self.n <= self.cap:
            return self.buf[:self.n]
        i = self.n % self.cap
        return self.buf[i:] + self.buf[:i]


class Tracer:
    """Recording state: per-thread rings + aggregated counters/histograms."""

    # exponential latency buckets (seconds) for histograms
    BUCKETS = (1e-5, 1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0)

    def __init__(self, capacity: int = 1 << 16):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._lock = threading.Lock()
        # crlint: guarded-by(_lock)
        self._rings: list[_Ring] = []
        self._local = threading.local()
        self._ids = itertools.count(1)
        # crlint: guarded-by(_lock)
        self._counters: dict[str, float] = {}
        # crlint: guarded-by(_lock)
        self._hists: dict[str, list] = {}   # name -> [bucket_counts, sum, n]

    def _ring(self) -> _Ring:
        r = getattr(self._local, "ring", None)
        if r is None:
            t = threading.current_thread()
            r = _Ring(self.capacity, t.ident or 0, t.name)
            self._local.ring = r
            with self._lock:
                self._rings.append(r)
        return r

    def count(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + value

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = [[0] * (len(self.BUCKETS) + 1),
                                         0.0, 0]
            for i, edge in enumerate(self.BUCKETS):
                if value <= edge:
                    h[0][i] += 1
                    break
            else:
                h[0][-1] += 1
            h[1] += value
            h[2] += 1

    def events(self) -> list[TraceEvent]:
        """Snapshot of every thread's ring, globally time-ordered."""
        with self._lock:
            rings = list(self._rings)
        out: list[TraceEvent] = []
        for r in rings:
            out.extend(r.events())
        out.sort(key=lambda e: (e.t0, e.t1))
        return out

    def dropped_events(self) -> int:
        with self._lock:
            return sum(r.dropped for r in self._rings)

    def counters(self) -> dict[str, float]:
        with self._lock:
            return dict(self._counters)


# ----------------------------------------------------------- module fast path
_TRACER: Tracer | None = None


def enable(capacity: int = 1 << 16) -> Tracer:
    """Install a fresh process tracer (replacing any prior one)."""
    global _TRACER
    _TRACER = Tracer(capacity)
    return _TRACER


def disable() -> None:
    global _TRACER
    _TRACER = None


def is_enabled() -> bool:
    return _TRACER is not None


def active() -> Tracer | None:
    return _TRACER


class _NoopSpan:
    """Shared do-nothing span: the disabled-mode fast path (no allocation)."""

    __slots__ = ()
    id = 0

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NOOP = _NoopSpan()


class _Span:
    """Context-manager span; records on exit into the exiting thread's ring."""

    __slots__ = ("tr", "name", "tier", "nbytes", "parent", "attrs",
                 "t0", "id", "_ring")

    def __init__(self, tr: Tracer, name: str, tier: str, nbytes: int,
                 parent: int | None, attrs: dict | None):
        self.tr = tr
        self.name, self.tier, self.nbytes = name, tier, nbytes
        self.parent, self.attrs = parent, attrs
        self.t0 = 0.0
        self.id = 0
        self._ring: _Ring | None = None

    def __enter__(self) -> "_Span":
        ring = self._ring = self.tr._ring()
        self.id = next(self.tr._ids)
        if self.parent is None:
            self.parent = ring.stack[-1] if ring.stack else 0
        ring.stack.append(self.id)
        self.t0 = clock()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = clock()
        ring = self._ring
        if ring.stack and ring.stack[-1] == self.id:
            ring.stack.pop()
        elif self.id in ring.stack:          # unbalanced exit: repair
            ring.stack.remove(self.id)
        ring.append(TraceEvent("span", self.name, self.tier, self.t0, t1,
                               self.nbytes, self.id, self.parent or 0,
                               ring.tid, ring.thread, self.attrs))
        return False


def span(name: str, tier: str = "host", nbytes: int = 0,
         parent: int | None = None, attrs: dict | None = None):
    """Open a span; ``with trace.span("flush", tier="level0", nbytes=n):``.

    Disabled mode returns the shared no-op singleton (no allocation)."""
    tr = _TRACER
    if tr is None:
        return _NOOP
    return _Span(tr, name, tier, nbytes, parent, attrs)


def complete(name: str, t0: float, t1: float | None = None, *,
             tier: str = "host", nbytes: int = 0,
             parent: int | None = None, attrs: dict | None = None) -> None:
    """Record an already-timed span from explicit ``clock()`` stamps — the
    shape submit→completion pairs take (submit stamps t0, the completion
    reaper emits) and what converted metrics brackets use."""
    tr = _TRACER
    if tr is None:
        return
    ring = tr._ring()
    if parent is None:
        parent = ring.stack[-1] if ring.stack else 0
    ring.append(TraceEvent("span", name, tier, t0,
                           clock() if t1 is None else t1, nbytes,
                           next(tr._ids), parent, ring.tid, ring.thread,
                           attrs))


def event(name: str, *, tier: str = "host", nbytes: int = 0,
          attrs: dict | None = None) -> None:
    """Record an instant event (hedge issue/win, injected fault, retry)."""
    tr = _TRACER
    if tr is None:
        return
    ring = tr._ring()
    now = clock()
    ring.append(TraceEvent("instant", name, tier, now, now, nbytes,
                           next(tr._ids),
                           ring.stack[-1] if ring.stack else 0,
                           ring.tid, ring.thread, attrs))


def count(name: str, value: float = 1.0) -> None:
    tr = _TRACER
    if tr is None:
        return
    tr.count(name, value)


def observe(name: str, value: float) -> None:
    tr = _TRACER
    if tr is None:
        return
    tr.observe(name, value)


def drain() -> list[TraceEvent]:
    """Time-ordered snapshot of all recorded events ([] when disabled)."""
    tr = _TRACER
    return tr.events() if tr is not None else []


def dropped_events() -> int:
    tr = _TRACER
    return tr.dropped_events() if tr is not None else 0


# ------------------------------------------------------------------- exports
def export_perfetto(path: str | None = None,
                    events: list[TraceEvent] | None = None) -> dict:
    """Chrome/Perfetto trace-event JSON: spans as ``X`` events grouped on
    tier-named tracks (pid = tier, tid = recording thread), instants as
    ``i``. Load the written file in ui.perfetto.dev or chrome://tracing.
    Returns the document; writes it to ``path`` when given."""
    evs = drain() if events is None else events
    tiers: dict[str, int] = {}
    te: list[dict] = []
    threads_named: set[tuple[int, int]] = set()
    for e in evs:
        pid = tiers.get(e.tier)
        if pid is None:
            pid = tiers[e.tier] = len(tiers) + 1
            te.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": 0, "args": {"name": f"tier:{e.tier}"}})
        if (pid, e.tid) not in threads_named:
            threads_named.add((pid, e.tid))
            te.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": e.tid, "args": {"name": e.thread}})
        args: dict = dict(e.attrs) if e.attrs else {}
        if e.nbytes:
            args["bytes"] = e.nbytes
        if e.parent_id:
            args["parent"] = e.parent_id
        rec = {"name": e.name, "cat": e.tier, "pid": pid, "tid": e.tid,
               "ts": round(e.t0 * 1e6, 3), "args": args}
        if e.kind == "span":
            rec["ph"] = "X"
            rec["dur"] = round(max(e.t1 - e.t0, 0.0) * 1e6, 3)
        else:
            rec["ph"] = "i"
            rec["s"] = "t"
        te.append(rec)
    doc = {"traceEvents": te, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(doc, f)
    return doc


def _prom_name(name: str) -> str:
    return re.sub(r"[^a-zA-Z0-9_]", "_", name)


def export_prometheus(path: str | None = None,
                      events: list[TraceEvent] | None = None) -> str:
    """Prometheus textfile exposition: explicit counters, the dropped-event
    counter, and per-span-name duration/byte histograms derived from the
    recorded spans."""
    tr = _TRACER
    evs = drain() if events is None else events
    lines: list[str] = []
    counters = dict(tr.counters()) if tr is not None else {}
    counters["trace_dropped_events"] = (
        counters.get("trace_dropped_events", 0) + dropped_events())
    for name in sorted(counters):
        m = f"crtrace_{_prom_name(name)}"
        lines.append(f"# TYPE {m} counter")
        lines.append(f"{m} {counters[name]:g}")
    # span duration histograms per (name, tier)
    hists: dict[tuple[str, str], list] = {}
    for e in evs:
        if e.kind != "span":
            continue
        h = hists.setdefault((e.name, e.tier),
                             [[0] * (len(Tracer.BUCKETS) + 1), 0.0, 0])
        d = max(e.t1 - e.t0, 0.0)
        for i, edge in enumerate(Tracer.BUCKETS):
            if d <= edge:
                h[0][i] += 1
                break
        else:
            h[0][-1] += 1
        h[1] += d
        h[2] += 1
    explicit = tr._hists if tr is not None else {}
    with (tr._lock if tr is not None else threading.Lock()):
        for name, h in sorted(explicit.items()):
            hists[(name, "")] = [list(h[0]), h[1], h[2]]
    for (name, tier), (buckets, total, n) in sorted(hists.items()):
        m = f"crtrace_span_seconds_{_prom_name(name)}"
        tag = f'{{tier="{tier}"}}' if tier else ""
        lines.append(f"# TYPE {m} histogram")
        cum = 0
        for i, edge in enumerate(Tracer.BUCKETS):
            cum += buckets[i]
            le = f"{edge:g}"
            if tier:
                lines.append(f'{m}_bucket{{tier="{tier}",le="{le}"}} {cum}')
            else:
                lines.append(f'{m}_bucket{{le="{le}"}} {cum}')
        cum += buckets[-1]
        if tier:
            lines.append(f'{m}_bucket{{tier="{tier}",le="+Inf"}} {cum}')
        else:
            lines.append(f'{m}_bucket{{le="+Inf"}} {cum}')
        lines.append(f"{m}_sum{tag} {total:g}")
        lines.append(f"{m}_count{tag} {n}")
    text = "\n".join(lines) + "\n"
    if path is not None:
        with open(path, "w", encoding="utf-8") as f:
            f.write(text)
    return text


# ----------------------------------------------------------- metrics registry
class MetricsRegistry:
    """One queryable tree over the stack's live Stats objects.

    ``register`` takes an object OR a zero-arg callable resolved at
    ``snapshot()`` time; nothing is copied at registration, so a snapshot
    always reflects the source's CURRENT field values (including computed
    ``@property`` views like ``flush_gbps``). Dataclasses adapt recursively;
    dicts/lists adapt element-wise; everything else passes through."""

    def __init__(self):
        self._sources: dict[str, object] = {}

    def register(self, name: str, source) -> None:
        self._sources[name] = source

    def unregister(self, name: str) -> None:
        self._sources.pop(name, None)

    def names(self) -> list[str]:
        return sorted(self._sources)

    @staticmethod
    def _adapt(obj, depth: int = 0):
        if depth > 6 or obj is None or isinstance(obj, (bool, int, float,
                                                        str)):
            return obj
        if is_dataclass(obj) and not isinstance(obj, type):
            out = {f.name: MetricsRegistry._adapt(getattr(obj, f.name),
                                                  depth + 1)
                   for f in _dc_fields(obj)}
            for k in dir(type(obj)):
                if isinstance(getattr(type(obj), k, None), property):
                    try:
                        out[k] = MetricsRegistry._adapt(getattr(obj, k),
                                                        depth + 1)
                    except Exception as e:
                        out[k] = f"<error: {e!r}>"
            return out
        if isinstance(obj, dict):
            return {str(k): MetricsRegistry._adapt(v, depth + 1)
                    for k, v in obj.items()}
        if isinstance(obj, (list, tuple)):
            return [MetricsRegistry._adapt(v, depth + 1) for v in obj]
        if hasattr(obj, "as_dict"):
            return MetricsRegistry._adapt(obj.as_dict(), depth + 1)
        try:                       # numpy scalars and friends
            return float(obj)
        except (TypeError, ValueError):
            return repr(obj)

    def snapshot(self) -> dict:
        out = {}
        for name, src in self._sources.items():
            obj = src() if callable(src) else src
            out[name] = self._adapt(obj)
        return out

    def query(self, path: str):
        """Dotted lookup into a fresh snapshot: ``query("save.flush_gbps")``."""
        node = self.snapshot()
        for part in path.split("."):
            if not isinstance(node, dict) or part not in node:
                raise KeyError(path)
            node = node[part]
        return node


# --------------------------------------------------------------- stall report
# Wall-time attribution categories for a save/restore root span.
CATEGORIES = ("compute", "d2h", "stage_wait", "level0_write", "level1_flush",
              "remote_put", "remote_get", "barrier")

_D2H_NAMES = {"snapshot", "extract", "gather", "h2d", "d2h"}
_WAIT_NAMES = {"budget.wait", "read.stall", "stage.wait", "acquire.wait"}


def _category(ev: TraceEvent) -> str | None:
    n = ev.name
    if "barrier" in n:
        return "barrier"
    if n in _WAIT_NAMES:
        return "stage_wait"
    if n in _D2H_NAMES:
        return "d2h"
    if ev.tier == "remote":
        return "remote_put" if ("put" in n or "upload" in n) else "remote_get"
    if ev.tier == "level1":
        return "level1_flush"
    if ev.tier == "level0":
        return "level0_write"
    return None           # residual -> compute


@dataclass
class StallReport:
    root: str
    wall: float
    attribution: dict

    @property
    def top(self) -> str:
        return max(self.attribution, key=lambda k: self.attribution[k])

    def render(self) -> str:
        lines = [f"stall report — {self.root}: wall {self.wall * 1e3:.2f} ms"]
        for cat in sorted(self.attribution,
                          key=lambda k: -self.attribution[k]):
            sec = self.attribution[cat]
            pct = 100.0 * sec / self.wall if self.wall else 0.0
            lines.append(f"  {cat:<13} {sec * 1e3:9.2f} ms  {pct:5.1f}%")
        lines.append(f"top bottleneck: {self.top}")
        return "\n".join(lines)


def stall_report(events: list[TraceEvent] | None = None,
                 root: str = "save") -> StallReport | None:
    """Attribute the LAST ``root``-named span's wall time across the stall
    categories by a timeline sweep over the root thread's spans: every
    instant goes to the INNERMOST open span's category (``compute`` when
    none is open), so the categories sum to the wall exactly. Innermost
    handles both proper nesting (the child's interval never double-counts
    into the parent) and overlapping same-thread completions (async engines
    record many in-flight ``io.*`` spans on the reaping thread — a plain
    duration sum would overcount wall several times over). Spans on other
    threads (the overlap the pipeline exists to create) are excluded — see
    the Perfetto export for those."""
    evs = drain() if events is None else events
    roots = [e for e in evs if e.kind == "span" and e.name == root]
    if not roots:
        return None
    rt = roots[-1]
    inner = [e for e in evs
             if e.kind == "span" and e.tid == rt.tid
             and e.span_id != rt.span_id
             and e.t1 > rt.t0 and e.t0 < rt.t1]
    # boundary sweep: +1 at clipped start, -1 at clipped end
    marks: list[tuple[float, int, TraceEvent]] = []
    for e in inner:
        marks.append((max(e.t0, rt.t0), 1, e))
        marks.append((min(e.t1, rt.t1), -1, e))
    marks.sort(key=lambda m: (m[0], -m[1]))
    attribution = {c: 0.0 for c in CATEGORIES}
    open_spans: dict[int, TraceEvent] = {}
    prev = rt.t0
    for t, delta, e in marks:
        if t > prev:
            if open_spans:
                # innermost = the latest-started still-open span
                top = max(open_spans.values(),
                          key=lambda s: (s.t0, s.span_id))
                attribution[_category(top) or "compute"] += t - prev
            else:
                attribution["compute"] += t - prev
            prev = t
        if delta > 0:
            open_spans[e.span_id] = e
        else:
            open_spans.pop(e.span_id, None)
    if rt.t1 > prev:       # tail not covered by any descendant
        attribution["compute"] += rt.t1 - prev
    return StallReport(root=root, wall=rt.t1 - rt.t0,
                       attribution=attribution)
