"""Streaming checkpoint pipelines: SnapshotPipeline (save, DESIGN.md §9)
and RestorePipeline (load, DESIGN.md §10).

The legacy save materialized a full host copy of EVERY shard — plus inline
int8 quant-packing — on the blocking path before the first byte hit storage,
so async mode only hid the final flush stage. This module decomposes the save
into stages that overlap at sub-tensor granularity (DataStates-LLM's lazy
multi-stage pipeline, ByteCheckpoint's decomposed save; DESIGN.md §9):

  1. declare   — ``build_save_puts`` walks the extracted tensors and emits
                 ``SaveSpec``s (sizes only — quantized payload sizes are
                 deterministic via ``quant_codec.packed_nbytes``) plus lazy
                 ``resolve`` callables that materialize payload bytes.
  2. plan      — ``CREngine.begin_save`` maps every spec to file extents
                 before any payload exists; the cross-rank prefix sum runs
                 on spec sizes, so it too leaves the blocking path early.
  3. snapshot  — each ``resolve()`` produces host bytes (device→host view,
                 quant pack) which the engine stream memcpys chunk-by-chunk
                 into pooled ``AlignedBuffer``s — the staging copy IS the
                 snapshot, double-buffered against the writes in flight.
  4. flush     — every staged extent is submitted to the io_engine the
                 moment it lands; ``EngineConfig.inflight_bytes`` caps the
                 staged bytes in flight (``StageBudget`` backpressure).

Mutation safety: JAX arrays are immutable, so holding references is a stable
snapshot by construction. In-place-mutable sources (``np.ndarray``) are
eagerly copied on the blocking path when ``copy_mutable`` is set (async
saves); ``copy_all`` additionally copies device arrays for callers that will
donate their buffers before the pipeline drains.

``RestorePipeline`` is the load-path twin: the monolithic restore
materialized EVERY extent in host memory before the first ``device_put``, so
restore wall-clock was read + decode + assemble + H2D summed and peak host
memory was the full checkpoint. The pipeline instead consumes a streaming
``ReadStream`` (``CREngine.begin_restore``): as each tensor's extents land
they are dequantized, fed to incremental ``WindowAssembler``s, and placed on
device while the reads for later tensors are still in flight — peak host
staging stays bounded by ``EngineConfig.inflight_bytes``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from types import SimpleNamespace
from typing import Callable

import jax
import numpy as np

from . import trace
from .engines import ChecksumError, ReadReq, SaveSpec
from .manifest import CHUNK_KIND, Manifest, TensorRecord, crc32_of
from .resharding import WindowAssembler, normalize_index, record_dtype
from .serialization import (LEAN_KEY, LocalShard, as_bytes_view,
                            tensor_nbytes, to_numpy_view)


@dataclass
class PendingPut:
    """One declared object plus the deferred materialization of its bytes.

    ``source`` keeps the (immutable) origin array alongside the resolve
    closure so delta planning can fingerprint the bytes where they live —
    on device for ``jax.Array`` sources — instead of forcing the full D2H
    materialization that ``resolve()`` implies (DESIGN.md §14). ``quant``
    marks puts whose resolved payload is the int8 quant-packed stream
    (``spec.nbytes`` is the packed size, not the source's).
    """
    spec: SaveSpec
    resolve: Callable[[], object]   # -> buffer-protocol of spec.nbytes bytes
    source: object = None           # origin array (None: opaque/blob put)
    quant: bool = False


def iter_host_shards(t):
    """Yield (array, global_index) for the shards this process owns.

    No host copy happens here — materialization is deferred to stream time
    (``PendingPut.resolve``) so the D2H lands directly in staging order.
    DP replicas are deduplicated by ``replica_id == 0``.
    """
    if isinstance(t, LocalShard):
        # multi-writer rank leaf: the window was declared by the caller
        yield t.data, normalize_index(t.index, t.global_shape)
    elif isinstance(t, jax.Array) and hasattr(t, "addressable_shards"):
        for sh in t.addressable_shards:
            if sh.replica_id != 0:
                continue  # DP replica dedup
            yield sh.data, normalize_index(sh.index, t.shape)
    else:
        yield t, tuple((0, s) for s in t.shape)


def _n_elems(arr) -> int:
    return int(np.prod(arr.shape, dtype=np.int64))


def build_save_puts(tensors: dict, lean_blob: bytes, *,
                    quantize_prefixes: tuple[str, ...] = (),
                    quantize_min_bytes: int = 1 << 16,
                    copy_mutable: bool = False,
                    copy_all: bool = False
                    ) -> tuple[list[PendingPut], list[str]]:
    """Turn extracted tensors + the lean blob into declared pipeline puts.

    Returns ``(puts, quantized_keys)``. Quant-packing and device→host
    materialization are captured in the resolve closures, NOT executed —
    they run on the pipeline worker, off the training loop's blocking path.
    """
    from . import quant_codec
    puts: list[PendingPut] = []
    quantized: list[str] = []
    for key, t in tensors.items():
        quant = (any(key.startswith(p) for p in quantize_prefixes)
                 and tensor_nbytes(t) >= quantize_min_bytes
                 and np.dtype(t.dtype).kind == "f")
        if quant:
            quantized.append(key)
        for n, (arr, index) in enumerate(iter_host_shards(t)):
            if copy_all or (copy_mutable and isinstance(arr, np.ndarray)):
                # in-place-mutable source: stable pre-mutation snapshot now
                arr = np.array(arr, copy=True)
            if quant:
                nbytes = quant_codec.packed_nbytes(_n_elems(arr))
                resolve = (lambda a=arr: np.frombuffer(
                    quant_codec.pack(to_numpy_view(a)), np.uint8))
            else:
                nbytes = tensor_nbytes(arr)
                resolve = lambda a=arr: as_bytes_view(to_numpy_view(a))
            puts.append(PendingPut(
                SaveSpec(f"{key}#{n}", nbytes, str(arr.dtype),
                         tuple(t.shape), index, record_key=key), resolve,
                source=arr, quant=quant))
    puts.append(PendingPut(SaveSpec(LEAN_KEY, len(lean_blob), is_blob=True),
                           lambda: lean_blob))
    return puts, quantized


class SnapshotPipeline:
    """Drives declared puts through an engine's streaming save.

    With a ``supports_streaming`` engine (aggregated), resolve → stage →
    submit run interleaved: while the io backend writes extent k, the worker
    resolves and stages extent k+1. Engines without a native stream degrade
    to the buffered batch path behind the same API.
    """

    def __init__(self, engine):
        self.engine = engine

    def run(self, ckpt_dir: str, puts: list[PendingPut], *, step: int = 0,
            rank: int = 0, num_ranks: int = 1,
            rank_totals: list[int] | None = None,
            on_staged: Callable[[], None] | None = None) -> Manifest:
        """``on_staged`` fires once every put has been resolved and staged —
        from then on the save no longer reads any caller-owned memory, so
        callers may mutate or donate their arrays while the flush drains
        (CheckpointManager.wait_snapshotted)."""
        with trace.span("plan", nbytes=sum(p.spec.nbytes for p in puts)):
            stream = self.engine.begin_save(
                ckpt_dir, [p.spec for p in puts], step=step, rank=rank,
                num_ranks=num_ranks, rank_totals=rank_totals)
        try:
            for p in puts:
                # the resolve IS the snapshot: D2H view + quant pack
                with trace.span("snapshot", nbytes=p.spec.nbytes,
                                attrs={"key": p.spec.key}):
                    payload = p.resolve()
                stream.put(p.spec.key, payload)
            if on_staged is not None:
                on_staged()
            return stream.end_save()
        except BaseException:
            stream.abort()
            raise


@dataclass
class RestoreTask:
    """One tensor to materialize from the read stream.

    ``windows`` lists the (window, placement) pairs this process must build;
    placement is opaque to the pipeline — it is handed back to the caller's
    ``place`` callable (the CheckpointManager puts shards on devices there).
    """
    key: str
    record: TensorRecord            # shards already deduped (DP replicas)
    windows: list[tuple] = field(default_factory=list)
    quantized: bool = False


def _extent_req_key(task_key: str, path: str, offset: int) -> str:
    return f"{task_key}@{path}@{offset}"


class RestorePipeline:
    """Drives RestoreTasks through an engine's streaming read.

    With a ``supports_streaming_read`` engine (aggregated), the four restore
    stages overlap per tensor: while the io backend reads the extents of
    tensor k+1, the consumer thread dequantizes, window-assembles, and
    ``device_put``s tensor k. Engines without a native stream degrade to the
    buffered batch path behind the same API (decode/assemble/H2D still
    pipeline against each other, reads do not).
    """

    def __init__(self, engine):
        self.engine = engine

    def run(self, ckpt_dir: str, tasks: list[RestoreTask], *,
            crcs: dict[str, int] | None = None,
            place: Callable | None = None,
            on_reqs: Callable | None = None,
            metrics=None) -> dict[str, object]:
        """Materialize every task; returns ``{task.key: leaf}``.

        ``place(task, windows)`` turns the assembled ``{window: ndarray}``
        dict into the final leaf (device placement); ``on_reqs(reqs)`` fires
        with the planned extent reads before the stream opens (the restore
        prefetcher pulls exactly these from the remote tier); ``crcs`` maps
        request keys to expected crc32s for in-stream verification.
        ``metrics`` (RestoreMetrics-shaped) gains stall/decode/assemble/h2d
        seconds and the engine's peak staged bytes."""
        from . import quant_codec
        if place is None:
            place = lambda task, windows: next(iter(windows.values()))
        if metrics is None:
            metrics = SimpleNamespace(
                read_seconds=0.0, read_stall_seconds=0.0, decode_seconds=0.0,
                assemble_seconds=0.0, h2d_seconds=0.0, peak_staged_bytes=0)

        # Plan: per task, one assembler per distinct window and the ordered
        # set of extents feeding them (a resharded restore reads a subset of
        # the saved shards — only intersecting extents are requested). A
        # chunk-reference shard (delta, DESIGN.md §12) contributes its real
        # chunk extents and sorts by its FIRST chunk's location — the
        # synthetic entry path names nothing on disk.
        def _loc(sh):
            if sh.kind == CHUNK_KIND:
                return ((sh.chunks[0].path, sh.chunks[0].offset)
                        if sh.chunks else ("", -1))
            return (sh.path, sh.offset)

        plans = []
        for task in tasks:
            asms: dict[tuple, WindowAssembler] = {}
            for window, _placement in task.windows:
                wkey = tuple(window)
                if wkey not in asms:
                    asms[wkey] = WindowAssembler(task.record, window)
            extents = {}
            for asm in asms.values():
                for sh in asm.pending_shards():
                    extents[(sh.path, sh.offset)] = sh
            ordered = sorted(extents.values(), key=_loc)
            plans.append((task, asms, ordered))
        # consume in layout order so the stream's staged-byte budget admits
        # reads exactly as earlier results drain (no over-budget escapes)
        plans.sort(key=lambda p: _loc(p[2][0]) if p[2] else ("", -1))
        reqs = []
        for task, _asms, ordered in plans:
            for sh in ordered:
                if sh.kind == CHUNK_KIND:
                    reqs += [ReadReq(_extent_req_key(task.key, r.path,
                                                     r.offset),
                                     r.path, r.offset, r.nbytes, obj=task.key)
                             for r in sh.chunks or ()]
                else:
                    reqs.append(ReadReq(
                        _extent_req_key(task.key, sh.path, sh.offset),
                        sh.path, sh.offset, sh.nbytes, obj=task.key))
        if on_reqs is not None:
            on_reqs(reqs)

        stream = self.engine.begin_restore(ckpt_dir, reqs, crcs=crcs)
        out: dict[str, object] = {}
        try:
            for task, asms, ordered in plans:
                for sh in ordered:
                    t0 = trace.clock()
                    if sh.kind == CHUNK_KIND:
                        # reassemble the shard payload from its chunk refs
                        # as they land; per-chunk CRCs were verified inside
                        # the stream, the whole-payload CRC (under the
                        # entry's synthetic key) guards the concatenation
                        from .delta import reassemble_payload
                        raw = reassemble_payload(
                            sh, lambda r: stream.get(_extent_req_key(
                                task.key, r.path, r.offset)))
                        expect = (crcs or {}).get(_extent_req_key(
                            task.key, sh.path, sh.offset))
                        if expect is not None:
                            got = crc32_of(raw)
                            if got != expect:
                                raise ChecksumError(task.key, sh.path,
                                                    sh.offset, expect, got)
                    else:
                        raw = stream.get(
                            _extent_req_key(task.key, sh.path, sh.offset))
                    t1 = trace.clock()
                    metrics.read_stall_seconds += t1 - t0
                    trace.complete("read.stall", t0, t1, nbytes=sh.nbytes)
                    if task.quantized:
                        raw = quant_codec.unpack(raw,
                                                 record_dtype(task.record))
                        t2 = trace.clock()
                        metrics.decode_seconds += t2 - t1
                        trace.complete("decode", t1, t2, nbytes=sh.nbytes)
                    else:
                        t2 = t1
                    for asm in asms.values():
                        asm.feed(sh, raw)
                    t_asm = trace.clock()
                    metrics.assemble_seconds += t_asm - t2
                    trace.complete("assemble", t2, t_asm, nbytes=sh.nbytes)
                windows = {wkey: asm.result() for wkey, asm in asms.items()}
                t3 = trace.clock()
                out[task.key] = place(task, windows)
                t4 = trace.clock()
                metrics.h2d_seconds += t4 - t3
                trace.complete("h2d", t3, t4, tier="device")
            stats = stream.end_restore()
            metrics.read_seconds = stats.seconds
            metrics.peak_staged_bytes = stats.peak_staged_bytes
            return out
        except BaseException:
            # abort releases pooled buffers and settles the staged-byte
            # books — a failed restore must not wedge the engine
            stream.abort()
            raise
