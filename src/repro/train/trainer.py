"""Fault-tolerant training loop with first-class async checkpointing.

Wires together: model step (pjit), synthetic data pipeline, AdamW, and the
paper's checkpoint engine. Capabilities:

  · auto-resume from the latest valid checkpoint (corrupt/partial ones are
    skipped by manifest validity + CRC),
  · async checkpointing — flush overlaps subsequent train steps (the paper's
    stage-3 overlap); blocking time per checkpoint is reported,
  · checkpoint-every-N with versioned GC,
  · data pipeline state rides in the checkpoint (exact-step resume),
  · optional multi-level local→remote flush with hedged stragglers,
  · elastic restore: a run restarted on a different mesh reshards on load.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CheckpointManager, EngineConfig,
                        MultiLevelCheckpointer, MultiWriterCheckpointer)
from repro.core import trace
from repro.data import DataConfig, SyntheticPipeline
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.sharding.partition import Partitioner
from repro.train.steps import init_train_state, make_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 0                  # 0 = no checkpointing
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_engine: str = "aggregated"
    async_ckpt: bool = True
    streaming_ckpt: bool = True          # SnapshotPipeline save path
    multilevel_remote: str = ""          # non-empty enables two-level C/R
    ckpt_writers: int = 0                # >1: in-process N-rank concurrent
                                         # writers + rank-0 merge commit
                                         # (DESIGN.md §11)
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    trace: bool = False                  # span tracer on for the whole run
    trace_dir: str = ""                  # Perfetto + .prom exports land here


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 mesh=None, opt_cfg: AdamWConfig | None = None,
                 engine_config: EngineConfig | None = None,
                 data_cfg: DataConfig | None = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.data_cfg = data_cfg or DataConfig(
            vocab_size=cfg.vocab_size, seq_len=256, global_batch=8,
            seed=tcfg.seed, frontend_len=cfg.frontend_len,
            frontend_dim=cfg.frontend_dim)
        self.pipeline = SyntheticPipeline(
            self.data_cfg, jax.process_index(), jax.process_count())
        if tcfg.multilevel_remote and tcfg.ckpt_writers > 1:
            raise ValueError(
                "multilevel_remote and ckpt_writers > 1 are mutually "
                "exclusive: the two-level flusher wraps a single manager")
        if tcfg.multilevel_remote:
            self.ckpt = MultiLevelCheckpointer(
                tcfg.ckpt_dir, tcfg.multilevel_remote,
                engine=tcfg.ckpt_engine, config=engine_config,
                async_save=False, keep=tcfg.keep,
                streaming=tcfg.streaming_ckpt)
        elif tcfg.ckpt_every and tcfg.ckpt_writers > 1:
            # N concurrent writer ranks over one directory: the state is
            # row-partitioned per save, every rank flushes its windows, and
            # rank 0 merge-commits the step (restore is elastic: any later
            # run - multi-writer or not - reads the merged manifest)
            self.ckpt = MultiWriterCheckpointer(
                tcfg.ckpt_dir, tcfg.ckpt_writers,
                engine=tcfg.ckpt_engine, config=engine_config,
                async_save=tcfg.async_ckpt, keep=tcfg.keep,
                streaming=tcfg.streaming_ckpt)
        elif tcfg.ckpt_every:
            self.ckpt = CheckpointManager(
                tcfg.ckpt_dir, engine=tcfg.ckpt_engine, config=engine_config,
                async_save=tcfg.async_ckpt, keep=tcfg.keep,
                streaming=tcfg.streaming_ckpt)
        else:
            self.ckpt = None
        self.metrics_log: list[dict] = []
        # one queryable tree over every Stats producer in the stack
        self.registry = trace.MetricsRegistry()
        if self.ckpt is not None:
            self.registry.register(
                "save", lambda: getattr(self.ckpt, "last_save_metrics", None))
            self.registry.register(
                "restore",
                lambda: getattr(self.ckpt, "last_restore_metrics", None))

    # ------------------------------------------------------------------ state
    def init_state(self):
        key = jax.random.key(self.tcfg.seed)
        if self.mesh is not None:
            part = Partitioner(self.cfg, self.mesh)
            state_shape = jax.eval_shape(
                lambda: init_train_state(key, self.cfg))
            shardings = {
                "params": part.param_shardings(state_shape["params"]),
                "opt": part.opt_shardings(state_shape["opt"]["mu"]),
                "step": part.replicated(),
            }
            shardings["opt"]["count"] = part.replicated()
            with self.mesh:
                state = jax.jit(lambda: init_train_state(key, self.cfg),
                                out_shardings=shardings)()
            return state, shardings
        return init_train_state(key, self.cfg), None

    def _full_state(self, train_state):
        return {"train": train_state, "data": self.pipeline.state_dict()}

    # ------------------------------------------------------------------ run
    def run(self) -> dict:
        if self.tcfg.trace:
            trace.enable()
        try:
            return self._run_traced()
        finally:
            if self.tcfg.trace:
                self._export_trace()
                trace.disable()

    def _export_trace(self) -> None:
        import os
        d = self.tcfg.trace_dir or self.tcfg.ckpt_dir
        os.makedirs(d, exist_ok=True)
        trace.export_perfetto(os.path.join(d, "trace.json"))
        trace.export_prometheus(os.path.join(d, "metrics.prom"))

    def _run_traced(self) -> dict:
        state, shardings = self.init_state()
        step_fn = make_train_step(self.cfg, self.opt_cfg)
        if self.mesh is not None:
            step_fn = jax.jit(step_fn, donate_argnums=(0,))
        else:
            step_fn = jax.jit(step_fn, donate_argnums=(0,))

        start_step = 0
        restore_attr: dict = {}
        if self.ckpt is not None:
            latest = self._latest()
            if latest is not None:
                t0 = time.perf_counter()
                restored = self.ckpt.restore(
                    state_template=self._full_state(state), step=latest)
                restore_wall = time.perf_counter() - t0
                state = restored["train"]
                self.pipeline.load_state_dict(restored["data"])
                start_step = int(np.asarray(state["step"]))
                # stall attribution: where the resume time went (streaming
                # restores overlap stages, so they no longer sum to wall)
                rm = self.ckpt.last_restore_metrics
                restore_attr = {"restore_seconds": restore_wall}
                if rm is not None:
                    restore_attr.update(
                        restore_mode=rm.mode,
                        restore_read_stall_s=rm.read_stall_seconds,
                        restore_decode_s=rm.decode_seconds,
                        restore_assemble_s=rm.assemble_seconds,
                        restore_h2d_s=rm.h2d_seconds,
                        restore_overlap_s=rm.overlap_seconds,
                        restore_peak_staged_bytes=rm.peak_staged_bytes)

        ckpt_block_s = 0.0
        ckpt_reported_block_s = 0.0      # sum of SaveMetrics.blocking_seconds
        t_start = time.perf_counter()
        ctx = self.mesh if self.mesh is not None else _nullctx()
        with ctx:
            for step in range(start_step, self.tcfg.steps):
                batch = {k: jnp.asarray(v)
                         for k, v in self.pipeline.batch_at(step).items()}
                if self.ckpt is not None:
                    # step_fn donates the state buffers an in-flight pipelined
                    # save may still be snapshotting — barrier on the staged
                    # snapshot (NOT the flush), and count it as stall time
                    t0 = time.perf_counter()
                    self.ckpt.wait_snapshotted()
                    ckpt_block_s += time.perf_counter() - t0
                state, metrics = step_fn(state, batch)
                self.pipeline.state.step = step + 1
                if self.tcfg.log_every and step % self.tcfg.log_every == 0:
                    m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                    m["step"] = step
                    self.metrics_log.append(m)
                if (self.ckpt is not None and self.tcfg.ckpt_every
                        and (step + 1) % self.tcfg.ckpt_every == 0):
                    jax.block_until_ready(state["params"])
                    t0 = time.perf_counter()
                    sm = self.ckpt.save(step + 1, self._full_state(state))
                    ckpt_block_s += time.perf_counter() - t0
                    ckpt_reported_block_s += sm.blocking_seconds
        jax.block_until_ready(state["step"])
        wall = time.perf_counter() - t_start
        if self.ckpt is not None:
            self.ckpt.wait()
        out = {"state": state, "wall_seconds": wall,
               "ckpt_blocking_seconds": ckpt_block_s,
               "ckpt_blocking_reported_s": ckpt_reported_block_s,
               "metrics": self.metrics_log, **restore_attr}
        if trace.is_enabled():
            rep = trace.stall_report(root="save")
            if rep is not None:
                out["stall_report"] = rep.attribution
                out["stall_wall_seconds"] = rep.wall
        return out

    def _latest(self):
        try:
            if hasattr(self.ckpt, "local"):
                steps = sorted(set(self.ckpt.local.all_steps())
                               | set(self.ckpt._remote_steps()))
                return steps[-1] if steps else None
            return self.ckpt.latest_step()
        except FileNotFoundError:
            return None

    def close(self):
        if self.ckpt is not None:
            self.ckpt.close()


class _nullctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
