"""jit-able train / prefill / decode step factories.

``make_train_step`` builds the pjit'd update (fwd + bwd + AdamW); callers
provide in/out shardings from repro.sharding.partition. ``make_serve_step``
builds the one-token decode used by the decode_* / long_* dry-run cells.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import transformer as T
from repro.optim import AdamWConfig, apply_updates


def cross_entropy(logits, labels):
    """logits (B,S,V) fp32, labels (B,S) int32 -> scalar mean nll."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


CE_CHUNK = 512  # sequence chunk for the unembed+CE scan


def chunked_cross_entropy(params, cfg: ModelConfig, h, labels,
                          chunk: int = CE_CHUNK):
    """Unembed + CE without materializing (B,S,V) fp32 logits.

    Scans sequence chunks: each step computes (B,chunk,V) logits, reduces to
    per-token nll, and discards them — peak live logits drop by S/chunk
    (e.g. 2.5 GB → 0.3 GB/device on qwen3-32b train_4k)."""
    from repro.models.transformer import _unembed
    B, S, _ = h.shape
    if S <= chunk:
        return cross_entropy(_unembed(params, cfg, h), labels)
    assert S % chunk == 0
    hs = h.reshape(B, S // chunk, chunk, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, S // chunk, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        hc, lc = xs
        logits = _unembed(params, cfg, hc)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (B * S)


def make_loss_fn(cfg: ModelConfig, act_sharding=None):
    def loss_fn(params, batch):
        h, aux = T.forward(params, cfg, batch["tokens"],
                           batch.get("frontend_embeds"), return_hidden=True,
                           act_sharding=act_sharding)
        S = batch["labels"].shape[1]
        nll = chunked_cross_entropy(params, cfg, h[:, -S:, :],
                                    batch["labels"])
        loss = nll + cfg.router_aux_coef * aux
        return loss, {"nll": nll, "aux": aux}
    return loss_fn


def init_train_state(key, cfg: ModelConfig):
    from repro.optim import init_state
    params = T.init_params(key, cfg)
    params = T.cast_params(params, jnp.dtype(cfg.dtype))
    return {"params": params, "opt": init_state(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                    microbatches: int = 1, grad_shardings=None,
                    act_sharding=None):
    """fwd+bwd+AdamW. ``microbatches`` > 1 scans gradient-accumulation
    microbatches so live activations are O(batch/microbatches) — required to
    fit the 4k×256 training cells in per-device HBM at production scale.

    ``grad_shardings`` (a pytree of NamedShardings, typically the ZeRO-1
    moment shardings) additionally shards the fp32 grad accumulator over the
    data axis (ZeRO-2): GSPMD turns the per-microbatch gradient all-reduce
    into a reduce-scatter against the accumulator."""
    opt_cfg = opt_cfg or AdamWConfig()
    loss_fn = make_loss_fn(cfg, act_sharding=act_sharding)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _constrain(g):
        if grad_shardings is None:
            return g
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, g, grad_shardings)

    def train_step(state, batch):
        params = state["params"]
        if microbatches == 1:
            (loss, extras), grads = grad_fn(params, batch)
        else:
            mb_batch = jax.tree_util.tree_map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)

            def micro(carry, mb):
                g_acc, l_acc, a_acc = carry
                (l, ex), g = grad_fn(params, mb)
                g = _constrain(g)   # reduce-scatter grads before accumulating
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (_constrain(g_acc), l_acc + l, a_acc + ex["aux"]), None

            g0 = _constrain(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (g_acc, l_acc, a_acc), _ = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32),
                        jnp.zeros((), jnp.float32)), mb_batch)
            inv = 1.0 / microbatches
            grads = jax.tree_util.tree_map(lambda g: g * inv, g_acc)
            loss = l_acc * inv
            extras = {"nll": loss, "aux": a_acc * inv}
        new_params, new_opt, om = apply_updates(
            opt_cfg, params, grads, state["opt"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, **extras, **om}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, act_sharding=None):
    """Inference prefill: returns only the LAST position's logits (what the
    decoder needs to emit its first token) — materializing (B,S,V) fp32
    logits at 32k context would dominate per-device HBM for nothing."""
    def prefill(params, batch):
        h, _ = T.forward(params, cfg, batch["tokens"],
                         batch.get("frontend_embeds"), return_hidden=True,
                         act_sharding=act_sharding)
        from repro.models.transformer import _unembed
        return _unembed(params, cfg, h[:, -1:, :])
    return prefill


def make_serve_step(cfg: ModelConfig):
    """One new token against an existing decode cache."""
    def serve_step(params, cache, tokens, pos):
        return T.decode_step(params, cfg, cache, tokens, pos)
    return serve_step
