"""crlint — durability- and concurrency-invariant static analyzer.

The chaos campaign (core/chaos.py) proves the commit protocol holds at
every *instrumented* site; nothing dynamic can prove a site IS
instrumented.  A new engine or tier that calls ``os.replace`` directly
silently escapes fault injection — the coverage rots without any test
failing.  crlint closes that hole at lint time, the way a sanitizer
complements a fuzzer: the disciplines PRs 4–8 encoded by convention
become machine-checked.

Checkers
--------
CRL001  fault-shim coverage: raw durability calls (``os.replace`` /
        ``rename`` / ``fsync`` / ``fdatasync`` / ``pwrite`` / ``preadv``
        / ``posix_fallocate``, ``shutil.rmtree``) are forbidden in
        ``core/**`` outside ``faults.py``; they must route through the
        ``faults.*`` shims so chaos coverage can never rot.
CRL002  publish ordering: a ``faults.replace`` whose destination matches
        manifest/commit naming (manifest|publish|commit|final|fin) must
        be preceded by an fsync of the source and followed by a
        directory fsync — intra-function, or through a one-level
        call-graph walk (a called function that itself fsyncs counts).
CRL003  guarded-by lock discipline: a field annotated
        ``# crlint: guarded-by(<lock>)`` may only be touched inside a
        ``with self.<lock>:`` block (or in a method annotated
        ``# crlint: holds(<lock>)``); ``__init__`` is exempt (the object
        is not yet shared).
CRL004  resource pairing: a function that acquires staged resources
        (``*pool*.get`` / ``.acquire`` / ``*budget*.add``) must show a
        release path the checker can see — a release-ish call inside a
        ``finally``/``except``, the acquire under a ``with``, or an
        ``abort`` method on the same class that releases (the
        pipeline-stream contract).
CRL005  swallowed injected faults: an ``except`` that could absorb an
        ``InjectedCrash``/``InjectedIOError`` (bare / ``BaseException``
        / ``Exception`` / ``RuntimeError`` without re-raise or
        error-capture; ``OSError`` with ``faults.*`` calls in the try
        body and no preceding Injected* re-raise clause) — the bug
        class PR 6 fixed in ``replace_dir``'s retry loop.
CRL006  clock-epoch discipline: direct ``time.time()`` /
        ``time.perf_counter()`` / ``time.monotonic()`` (and ``_ns``
        variants) in ``core/**`` bypass the tracer's shared monotonic
        epoch — timestamps from different modules stop being
        comparable and spans can't be correlated.  Route timing
        through ``trace.clock()``; genuinely wall-clock sites (pidfile
        epochs, mtime comparisons) annotate ``allow(CRL006)``.
        ``trace.py`` itself (the clock implementation) is exempt.

Annotations (source comments)
-----------------------------
``# crlint: allow(CRL001[, CRL005]): <reason>``   suppress on this line
``# crlint: allow-file(CRL001): <reason>``        suppress module-wide
``# crlint: guarded-by(<lock>[, <lock>])``        on a field assignment
``# crlint: holds(<lock>)``                       on a ``def`` line
``# crlint: fixture``                             treat file as core/**

Baseline
--------
``crlint_baseline.txt`` (repo root) holds accepted pre-existing finding
keys (checker:path:scope:symbol — line numbers are excluded so the
baseline survives unrelated edits); the gate is zero NEW findings.
Regenerate with ``make lint-baseline``; the diff-stat shows reviewers
what was accepted.

CRL002's one-level walk resolves callees by name, so a call like
``m.save(...)`` is credited with an fsync if ANY analyzed function named
``save`` fsyncs directly — deliberately permissive (no false positives
on dynamic dispatch) at the cost of missing some violations; CRL001
independently guarantees new sites stay shim-routed.
"""

from __future__ import annotations

import argparse
import ast
import os
import re
import sys
from collections import Counter
from dataclasses import dataclass, field

CHECKERS = {
    "CRL001": "fault-shim coverage (raw durability syscall in core)",
    "CRL002": "publish ordering (fsync -> rename -> dir fsync)",
    "CRL003": "guarded-by lock discipline",
    "CRL004": "resource acquire/release pairing",
    "CRL005": "except clause can swallow injected faults",
    "CRL006": "un-epoched clock call (use trace.clock)",
}

DEFAULT_BASELINE = "crlint_baseline.txt"

# raw call -> the shim that must be used instead
RAW_SHIMS = {
    "os.replace": "faults.replace",
    "os.rename": "faults.replace",
    "os.fsync": "faults.fsync",
    "os.fdatasync": "faults.fdatasync",
    "os.pwrite": "faults.pwrite",
    "os.preadv": "faults.preadv",
    "os.posix_fallocate": "faults.posix_fallocate",
    "shutil.rmtree": "faults.rmtree",
}

# clock calls that fragment the shared trace epoch (CRL006)
CLOCK_CALLS = {"time.time", "time.time_ns", "time.perf_counter",
               "time.perf_counter_ns", "time.monotonic",
               "time.monotonic_ns"}

FSYNC_CALLS = ("faults.fsync", "faults.fdatasync")
PUBLISH_DST_RE = re.compile(r"manifest|publish|commit|final|\bfin\b", re.I)

BROAD_EXCEPTS = {"<bare>", "BaseException", "Exception", "RuntimeError",
                 "InjectedCrash", "InjectedIOError"}
OSERROR_EXCEPTS = {"OSError", "IOError", "EnvironmentError"}
INJECTED_NAMES = {"InjectedCrash", "InjectedIOError"}

ACQUIRE_RELEASE = {"release", "destroy", "put", "settle", "sub", "abort",
                   "close", "drain", "_forget"}

_DIRECTIVE_RE = re.compile(r"#\s*crlint:\s*(.+?)\s*$")
_ALLOW_RE = re.compile(r"allow\(([^)]*)\)")
_ALLOW_FILE_RE = re.compile(r"allow-file\(([^)]*)\)")
_GUARDED_RE = re.compile(r"guarded-by\(([^)]*)\)")
_HOLDS_RE = re.compile(r"holds\(([^)]*)\)")


@dataclass
class Finding:
    checker: str
    path: str          # repo-relative, forward slashes
    line: int
    scope: str         # Class.method | function | <module>
    symbol: str        # what the finding is about (stable across edits)
    message: str

    def key(self) -> str:
        """Baseline key — excludes the line number so the suppression
        survives edits elsewhere in the file."""
        return f"{self.checker}:{self.path}:{self.scope}:{self.symbol}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.checker} "
                f"[{self.scope}] {self.message}")


def _dotted(node: ast.AST) -> str | None:
    """'os.replace', 'self.pool.get', 'replace_dir', ... (None: dynamic)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base is not None else None
    return None


def _csv(text: str) -> list[str]:
    return [t.strip() for t in text.split(",") if t.strip()]


@dataclass
class Unit:
    """One analyzable function/method, nested defs flattened in."""
    qualname: str
    name: str                       # bare name
    cls: str | None
    node: ast.AST
    calls: list[tuple[int, int, str]] = field(default_factory=list)
    has_direct_fsync: bool = False


class Module:
    def __init__(self, path: str, rel: str, source: str, tree: ast.Module):
        self.path = path
        self.rel = rel
        self.source = source
        self.tree = tree
        self.lines = source.splitlines()
        self.allow_lines: dict[int, set[str]] = {}
        self.file_allows: set[str] = set()
        self.holds_lines: dict[int, set[str]] = {}
        self.guard_lines: dict[int, set[str]] = {}
        self.is_fixture = False
        self._parse_directives()
        parts = rel.replace(os.sep, "/").split("/")
        self.is_core = "core" in parts or self.is_fixture
        self.is_faults = os.path.basename(rel) == "faults.py"
        self.is_trace = os.path.basename(rel) == "trace.py"
        self.units: list[Unit] = []
        self.scope_of: dict[int, str] = {}   # id(node) -> qualname
        self._collect_units()
        self.raw_aliases = self._raw_import_aliases()

    # ------------------------------------------------------------ directives
    def _parse_directives(self) -> None:
        for i, raw in enumerate(self.lines, start=1):
            m = _DIRECTIVE_RE.search(raw)
            if not m:
                continue
            body = m.group(1)
            code_before = raw[:m.start()].strip()
            targets = [i] if code_before else [i, i + 1]
            if body.strip() == "fixture":
                self.is_fixture = True
                continue
            fa = _ALLOW_FILE_RE.search(body)
            if fa:
                self.file_allows.update(_csv(fa.group(1)))
                continue
            a = _ALLOW_RE.search(body)
            if a:
                for t in targets:
                    self.allow_lines.setdefault(t, set()).update(
                        _csv(a.group(1)))
            h = _HOLDS_RE.search(body)
            if h:
                for t in targets:
                    self.holds_lines.setdefault(t, set()).update(
                        _csv(h.group(1)))
            g = _GUARDED_RE.search(body)
            if g:
                for t in targets:
                    self.guard_lines.setdefault(t, set()).update(
                        _csv(g.group(1)))

    def allowed(self, checker: str, node: ast.AST) -> bool:
        if checker in self.file_allows:
            return True
        first = getattr(node, "lineno", 0)
        last = getattr(node, "end_lineno", first) or first
        for ln in range(first - 1, last + 1):
            if checker in self.allow_lines.get(ln, ()):
                return True
        return False

    # ----------------------------------------------------------------- units
    def _collect_units(self) -> None:
        def add(node, cls):
            qual = f"{cls}.{node.name}" if cls else node.name
            u = Unit(qual, node.name, cls, node)
            for n in ast.walk(node):
                if isinstance(n, ast.Call):
                    d = _dotted(n.func)
                    if d is None:
                        continue
                    u.calls.append((n.lineno, n.col_offset, d))
                    if d in FSYNC_CALLS:
                        u.has_direct_fsync = True
            u.calls.sort()
            for n in ast.walk(node):
                self.scope_of.setdefault(id(n), qual)
            self.units.append(u)

        for stmt in self.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                add(stmt, None)
            elif isinstance(stmt, ast.ClassDef):
                for sub in stmt.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        add(sub, stmt.name)

    def scope(self, node: ast.AST) -> str:
        return self.scope_of.get(id(node), "<module>")

    def _raw_import_aliases(self) -> dict[str, str]:
        """`from os import replace as rp` -> {'rp': 'os.replace'}."""
        out: dict[str, str] = {}
        for n in ast.walk(self.tree):
            if isinstance(n, ast.ImportFrom) and n.module == "time":
                for a in n.names:
                    full = f"time.{a.name}"
                    if full in CLOCK_CALLS:
                        out[a.asname or a.name] = full
            if isinstance(n, ast.ImportFrom) and n.module in ("os", "shutil"):
                for a in n.names:
                    full = f"{n.module}.{a.name}"
                    if full in RAW_SHIMS:
                        out[a.asname or a.name] = full
        return out


# =========================================================== CRL001 coverage
def check_shim_coverage(mod: Module) -> list[Finding]:
    if not mod.is_core or mod.is_faults:
        return []
    out = []
    for n in ast.walk(mod.tree):
        if not isinstance(n, ast.Call):
            continue
        d = _dotted(n.func)
        if d is None:
            continue
        raw = d if d in RAW_SHIMS else mod.raw_aliases.get(d)
        if raw not in RAW_SHIMS:
            continue
        if mod.allowed("CRL001", n):
            continue
        out.append(Finding(
            "CRL001", mod.rel, n.lineno, mod.scope(n), raw,
            f"raw {raw} escapes chaos injection; route through "
            f"{RAW_SHIMS[raw]}"))
    return out


# ====================================================== CRL002 publish order
def _fsync_units(modules: list[Module]) -> set[str]:
    """Bare names of units that call faults.fsync/fdatasync directly."""
    return {u.name for m in modules for u in m.units if u.has_direct_fsync}


def check_publish_ordering(mod: Module, fsync_names: set[str]
                           ) -> list[Finding]:
    if not mod.is_core:
        return []
    out = []
    for u in mod.units:
        events = []   # (line, col, kind) with kind in {"fsync", node}
        for line, col, d in u.calls:
            if d in FSYNC_CALLS:
                events.append((line, col, "fsync"))
            elif d.rsplit(".", 1)[-1] in fsync_names and not \
                    d.startswith(("os.", "shutil.")):
                # one-level walk: callee (resolved by name) fsyncs itself
                events.append((line, col, "fsync"))
        replaces = []
        for n in ast.walk(u.node):
            if (isinstance(n, ast.Call) and _dotted(n.func) == "faults.replace"
                    and len(n.args) >= 2):
                dst_src = ast.get_source_segment(mod.source, n.args[1]) or ""
                if PUBLISH_DST_RE.search(dst_src):
                    replaces.append((n, dst_src))
        for n, dst_src in replaces:
            if mod.allowed("CRL002", n):
                continue
            pos = (n.lineno, n.col_offset)
            before = any(e[:2] < pos for e in events)
            after = any(e[:2] > pos for e in events)
            if not before:
                out.append(Finding(
                    "CRL002", mod.rel, n.lineno, u.qualname,
                    "replace-unsynced-src",
                    f"publish rename to {dst_src!r} without a visible fsync "
                    f"of the source before it"))
            if not after:
                out.append(Finding(
                    "CRL002", mod.rel, n.lineno, u.qualname,
                    "replace-no-dirsync",
                    f"publish rename to {dst_src!r} without a directory "
                    f"fsync after it (rename is not durable until the "
                    f"parent dir is synced)"))
    return out


# ======================================================== CRL003 guarded-by
def _with_locks(node: ast.With) -> set[str]:
    got = set()
    for item in node.items:
        d = _dotted(item.context_expr)
        if d is not None and d.startswith("self."):
            got.add(d[len("self."):])
    return got


class _GuardVisitor(ast.NodeVisitor):
    def __init__(self, mod: Module, qualname: str,
                 guards: dict[str, set[str]], held: set[str]):
        self.mod = mod
        self.qualname = qualname
        self.guards = guards
        self.held = set(held)
        self.findings: list[Finding] = []
        self.seen: set[str] = set()     # fields already reported here

    def visit_With(self, node: ast.With) -> None:
        for item in node.items:
            self.visit(item.context_expr)
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
        saved = set(self.held)
        self.held |= _with_locks(node)
        for stmt in node.body:
            self.visit(stmt)
        self.held = saved

    visit_AsyncWith = visit_With

    def visit_FunctionDef(self, node) -> None:
        # a nested def runs later: locks held at the def site are NOT held
        # at the call site (unless the def line carries # crlint: holds())
        saved = set(self.held)
        self.held = set(self.mod.holds_lines.get(node.lineno, ()))
        for stmt in node.body:
            self.visit(stmt)
        self.held = saved

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if (isinstance(node.value, ast.Name) and node.value.id == "self"
                and node.attr in self.guards
                and node.attr not in self.seen
                and not self.held & self.guards[node.attr]
                and not self.mod.allowed("CRL003", node)):
            locks = " or ".join(
                f"self.{a}" for a in sorted(self.guards[node.attr]))
            self.seen.add(node.attr)
            self.findings.append(Finding(
                "CRL003", self.mod.rel, node.lineno, self.qualname,
                node.attr,
                f"self.{node.attr} accessed without holding {locks} "
                f"(guarded-by)"))
        self.generic_visit(node)


def check_guarded_by(mod: Module) -> list[Finding]:
    out = []
    for stmt in mod.tree.body:
        if not isinstance(stmt, ast.ClassDef):
            continue
        guards: dict[str, set[str]] = {}
        methods = [n for n in stmt.body
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for meth in methods:
            for n in ast.walk(meth):
                if not isinstance(n, (ast.Assign, ast.AnnAssign,
                                      ast.AugAssign)):
                    continue
                locks: set[str] = set()
                last = getattr(n, "end_lineno", n.lineno) or n.lineno
                for ln in range(n.lineno, last + 1):
                    locks |= mod.guard_lines.get(ln, set())
                if not locks:
                    continue
                targets = (n.targets if isinstance(n, ast.Assign)
                           else [n.target])
                for t in targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        guards.setdefault(t.attr, set()).update(locks)
        if not guards:
            continue
        for meth in methods:
            if meth.name == "__init__":   # not yet shared between threads
                continue
            held = set(mod.holds_lines.get(meth.lineno, ()))
            v = _GuardVisitor(mod, f"{stmt.name}.{meth.name}", guards, held)
            for sub in meth.body:
                v.visit(sub)
            out.extend(v.findings)
    return out


# ================================================== CRL004 resource pairing
def _is_acquire(dotted: str) -> bool:
    if "lock" in dotted.lower() or "cond" in dotted.lower():
        return False
    leaf = dotted.rsplit(".", 1)[-1]
    if leaf == "acquire" and "." in dotted:
        return True
    if leaf == "get" and "pool" in dotted.lower():
        return True
    if leaf == "add" and "budget" in dotted.lower():
        return True
    return False


def _release_calls(node: ast.AST):
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            d = _dotted(n.func)
            if d and "." in d and d.rsplit(".", 1)[-1] in ACQUIRE_RELEASE:
                yield n


def check_resource_pairing(mod: Module) -> list[Finding]:
    if not mod.is_core:
        return []
    # classes with an abort() that releases: the pipeline-stream contract
    # (the caller guarantees `except BaseException: stream.abort(); raise`)
    abort_classes = {
        u.cls for u in mod.units
        if u.cls and u.name == "abort" and any(_release_calls(u.node))}
    out = []
    for u in mod.units:
        acquires = [n for n in ast.walk(u.node)
                    if isinstance(n, ast.Call)
                    and _dotted(n.func) is not None
                    and _is_acquire(_dotted(n.func))]
        if not acquires:
            continue
        if u.cls in abort_classes:
            continue
        cleanup_release = False
        for n in ast.walk(u.node):
            if isinstance(n, ast.Try):
                for blk in ([n.finalbody]
                            + [h.body for h in n.handlers]):
                    for stmt in blk:
                        if any(_release_calls(stmt)):
                            cleanup_release = True
        if cleanup_release:
            continue
        managed_spans = [
            (w.lineno, w.end_lineno or w.lineno)
            for w in ast.walk(u.node) if isinstance(w, ast.With)]
        unmanaged = [
            n for n in acquires
            if not any(a <= n.lineno <= b for a, b in managed_spans)]
        if not unmanaged:
            continue
        first = min(unmanaged, key=lambda n: (n.lineno, n.col_offset))
        if mod.allowed("CRL004", first):
            continue
        what = _dotted(first.func)
        out.append(Finding(
            "CRL004", mod.rel, first.lineno, u.qualname, "acquire-no-release",
            f"{what}(...) has no release path on error (want a release/"
            f"settle in finally/except, a with-block, or an abort() on "
            f"the class)"))
    return out


# ================================================= CRL006 clock discipline
def check_clock_epoch(mod: Module) -> list[Finding]:
    """Direct stdlib clock reads in core/** fragment the tracer's shared
    monotonic epoch (trace.clock()); wall-clock sites must say so."""
    if not mod.is_core or mod.is_trace:
        return []
    out = []
    for n in ast.walk(mod.tree):
        if not isinstance(n, ast.Call):
            continue
        d = _dotted(n.func)
        if d is None:
            continue
        raw = d if d in CLOCK_CALLS else mod.raw_aliases.get(d)
        if raw not in CLOCK_CALLS:
            continue
        if mod.allowed("CRL006", n):
            continue
        out.append(Finding(
            "CRL006", mod.rel, n.lineno, mod.scope(n), raw,
            f"{raw}() bypasses the shared trace epoch; use trace.clock() "
            f"(or annotate allow(CRL006) for a true wall-clock site)"))
    return out


# ============================================== CRL005 swallowed injections
def _caught_names(handler: ast.ExceptHandler) -> set[str]:
    t = handler.type
    if t is None:
        return {"<bare>"}
    nodes = t.elts if isinstance(t, ast.Tuple) else [t]
    names = set()
    for n in nodes:
        if isinstance(n, ast.Name):
            names.add(n.id)
        elif isinstance(n, ast.Attribute):
            names.add(n.attr)
    return names


def check_swallowed_faults(mod: Module) -> list[Finding]:
    if not mod.is_core or mod.is_faults:
        return []
    out = []
    for t in ast.walk(mod.tree):
        if not isinstance(t, ast.Try):
            continue
        try_faults = any(
            isinstance(n, ast.Call) and (_dotted(n.func) or "").startswith(
                "faults.")
            for stmt in t.body for n in ast.walk(stmt))
        injected_guarded = False
        for h in t.handlers:
            caught = _caught_names(h)
            has_raise = any(isinstance(n, ast.Raise) for n in ast.walk(h))
            if caught & INJECTED_NAMES and has_raise:
                injected_guarded = True
                continue
            captures = h.name is not None and any(
                isinstance(n, ast.Name) and n.id == h.name
                and isinstance(n.ctx, ast.Load)
                for stmt in h.body for n in ast.walk(stmt))
            if caught & BROAD_EXCEPTS and not has_raise and not captures:
                if not mod.allowed("CRL005", h):
                    shown = ", ".join(sorted(caught & BROAD_EXCEPTS))
                    out.append(Finding(
                        "CRL005", mod.rel, h.lineno, mod.scope(h),
                        "except-broad",
                        f"except {shown} neither re-raises nor captures "
                        f"the error: an InjectedCrash unwinding here is "
                        f"silently absorbed"))
            elif (caught & OSERROR_EXCEPTS and try_faults
                    and not has_raise and not injected_guarded):
                if not mod.allowed("CRL005", h):
                    out.append(Finding(
                        "CRL005", mod.rel, h.lineno, mod.scope(h),
                        "except-oserror-near-faults",
                        "except OSError around faults.* calls absorbs "
                        "injected errnos (the PR-6 replace_dir bug class); "
                        "re-raise Injected* first"))
    return out


# ================================================================== driver
def _iter_py(paths: list[str]) -> list[str]:
    files = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for root, dirs, names in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d != "__pycache__")
            files.extend(os.path.join(root, n) for n in sorted(names)
                         if n.endswith(".py"))
    return sorted(set(files))


def _load_modules(files: list[str]) -> tuple[list[Module], list[Finding]]:
    mods, errs = [], []
    for f in files:
        rel = os.path.relpath(f).replace(os.sep, "/")
        with open(f, "r", encoding="utf-8") as fh:
            src = fh.read()
        try:
            tree = ast.parse(src, filename=f)
        except SyntaxError as e:
            errs.append(Finding("CRL000", rel, e.lineno or 0, "<module>",
                                "syntax-error", f"cannot parse: {e.msg}"))
            continue
        mods.append(Module(f, rel, src, tree))
    return mods, errs


def analyze_paths(paths: list[str]) -> list[Finding]:
    """Run every checker over the .py files under ``paths`` (inline
    ``allow``/``allow-file`` annotations already applied)."""
    mods, findings = _load_modules(_iter_py(paths))
    fsync_names = _fsync_units(mods)
    for m in mods:
        findings += check_shim_coverage(m)
        findings += check_publish_ordering(m, fsync_names)
        findings += check_guarded_by(m)
        findings += check_resource_pairing(m)
        findings += check_swallowed_faults(m)
        findings += check_clock_epoch(m)
    findings.sort(key=lambda f: (f.path, f.line, f.checker, f.symbol))
    return findings


def load_baseline(path: str) -> Counter:
    counts: Counter = Counter()
    if not os.path.exists(path):
        return counts
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line and not line.startswith("#"):
                counts[line] += 1
    return counts


def write_baseline(findings: list[Finding], path: str) -> tuple[int, int]:
    """Write the suppression file; returns (added, removed) vs the old."""
    old = load_baseline(path)
    new = Counter(f.key() for f in findings)
    added = sum((new - old).values())
    removed = sum((old - new).values())
    with open(path, "w", encoding="utf-8") as fh:
        fh.write("# crlint accepted pre-existing findings — one key per "
                 "line (checker:path:scope:symbol).\n"
                 "# Regenerate with `make lint-baseline`; review the "
                 "diff-stat before committing.\n")
        for key in sorted(new.elements()):
            fh.write(key + "\n")
    return added, removed


def apply_baseline(findings: list[Finding], baseline: Counter
                   ) -> tuple[list[Finding], int]:
    remaining = Counter(baseline)
    fresh = []
    suppressed = 0
    for f in findings:
        if remaining[f.key()] > 0:
            remaining[f.key()] -= 1
            suppressed += 1
        else:
            fresh.append(f)
    return fresh, suppressed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="crlint",
        description="durability/concurrency invariant linter "
                    "(see DESIGN.md §16)")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to analyze")
    ap.add_argument("--baseline", default=None,
                    help=f"suppression file (default: {DEFAULT_BASELINE} "
                         f"when present)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept current findings into the baseline and "
                         "print a diff-stat")
    args = ap.parse_args(argv)

    findings = analyze_paths(args.paths)
    bl_path = args.baseline or DEFAULT_BASELINE

    if args.write_baseline:
        added, removed = write_baseline(findings, bl_path)
        print(f"crlint: baseline {bl_path}: {len(findings)} accepted "
              f"finding(s) (+{added} / -{removed})")
        for f in findings:
            print("  " + f.render())
        return 0

    baseline = Counter() if args.no_baseline else load_baseline(bl_path)
    fresh, suppressed = apply_baseline(findings, baseline)
    for f in fresh:
        print(f.render())
    stale = sum((baseline - Counter(f.key() for f in findings)).values())
    tail = f", {stale} baseline entr{'y' if stale == 1 else 'ies'} stale" \
        if stale else ""
    print(f"crlint: {len(fresh)} new finding(s), "
          f"{suppressed} baselined{tail}")
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
