"""Static analysis over the checkpoint stack.

``crlint`` machine-checks the repo's two design invariants — every
durability syscall routes through the ``faults.*`` chaos shims, and the
fsync→rename→dir-fsync publish ordering — plus the lock/resource
disciplines the concurrent tiers rely on.  ``python -m
repro.analysis.crlint src/repro`` is the lint gate wired into
``make verify`` and CI (DESIGN.md §16).
"""
