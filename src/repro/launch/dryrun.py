import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent at production
scale (sharding resolves, no unsupported collective, memory fits) and
extracts the roofline inputs:

    memory_analysis()  → per-device bytes (argument/temp/output)
    cost_analysis()    → per-device HLO FLOPs and bytes accessed
    compiled.as_text() → collective op volumes (all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute)

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    python -m repro.launch.dryrun --all --out results/dryrun.json
    python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import axis_types_kw, make_production_mesh
from repro.launch.specs import input_specs
from repro.models.config import SHAPES_BY_NAME
from repro.train.steps import make_prefill_step, make_serve_step, make_train_step

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def f32_cast_artifact_bytes(hlo_text: str, min_bytes: int = 32 << 20) -> int:
    """XLA:CPU lowers bf16 dots by converting operands to f32 — params and KV
    caches get duplicated in f32 (loop-invariant param converts are LICM-
    hoisted and live for the whole program; cache converts ride the while
    carry). TPU MXUs consume bf16 natively, so these buffers DO NOT exist on
    the target hardware. Counts each convert-producing op instance once
    (unique op name) above ``min_bytes`` so the roofline reports a
    TPU-adjusted peak alongside the raw CPU-lowered number."""
    total = 0
    seen: set[str] = set()
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("ROOT "):            # fusion bodies: counted via the
            continue                         # fusion instance line instead
        m = re.match(r"%(\S+) = f32\[([0-9,]+)\]\S*\s+(convert|fusion)\(", s)
        if not m:
            continue
        name, dims, op = m.groups()
        if op == "fusion" and "wrapped_convert" not in name:
            continue
        if name in seen:
            continue
        seen.add(name)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        if n * 4 >= min_bytes:
            total += n * 4
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result bytes of every collective op in post-SPMD HLO (per device)."""
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        for c in _COLLECTIVES:
            # match op invocation like: bf16[..] all-gather(...)
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                ty = rhs.split(c)[0].strip()
                if c + "-done" in rhs:
                    continue  # volume was counted at -start
                out[c] += _shape_bytes(ty)
                out["count"] += 1
                break
    return out


def _microbatches(cfg, shape, mesh) -> int:
    """Gradient-accumulation depth: 1 sample per DP shard per microbatch,
    capped at 16 — keeps live activations ~(1, seq, d_model) per device."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    return max(1, min(16, shape.global_batch // dp))


def _act_sharding(mesh, batch: int, seq_parallel: bool = False):
    """Residual-stream layout. ``seq_parallel=True`` additionally shards the
    sequence dim over 'model' (Megatron-style SP): GSPMD then lowers the
    per-layer TP all-reduces as reduce-scatter+all-gather — half the ICI
    traffic (the §Perf hillclimb move)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sharding.partition import dp_axes
    import numpy as _np
    dp = dp_axes(mesh)
    dp_size = int(_np.prod([mesh.shape[a] for a in dp]))
    bdim = dp if batch % max(dp_size, 1) == 0 else None
    sdim = "model" if seq_parallel else None
    return NamedSharding(mesh, P(bdim, sdim, None))


def _jit_cell(cfg, shape, mesh, mode, specs, microbatches: int | None = None,
              seq_parallel: bool = False):
    """Build the jitted step + example ShapeDtypeStruct args for one cell."""
    if mode == "train":
        state_specs, batch_specs, shardings = specs
        mb = microbatches if microbatches is not None \
            else _microbatches(cfg, shape, mesh)
        act = _act_sharding(mesh, shape.global_batch // mb, seq_parallel)
        fn = jax.jit(make_train_step(cfg, microbatches=mb,
                                     grad_shardings=shardings["opt"]["mu"],
                                     act_sharding=act),
                     donate_argnums=(0,), out_shardings=(shardings, None))
        return fn, (state_specs, batch_specs)
    if mode == "prefill":
        param_specs, batch_specs, _ = specs
        fn = jax.jit(make_prefill_step(
            cfg, act_sharding=_act_sharding(mesh, shape.global_batch,
                                            seq_parallel)))
        return fn, (param_specs, batch_specs)
    param_specs, cache_specs, tok, pos, _, cache_sh = specs
    fn = jax.jit(make_serve_step(cfg), donate_argnums=(1,),
                 out_shardings=(None, cache_sh))
    return fn, (param_specs, cache_specs, tok, pos)


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             mesh_split: tuple[int, int] | None = None,
             microbatches: int | None = None,
             seq_parallel: bool = False) -> dict:
    """Lower + compile one cell. ``mesh_split=(dp, tp)`` overrides the
    default 16x16 single-pod split (hillclimb what-ifs)."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "2x16x16" if multi_pod else (
        f"{mesh_split[0]}x{mesh_split[1]}" if mesh_split else "16x16")
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "mode": shape.kind, "status": "ok",
           "microbatches": microbatches, "seq_parallel": seq_parallel}
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        rec["status"] = "skipped"
        rec["reason"] = ("pure full-attention arch: long_500k requires "
                         "sub-quadratic attention (DESIGN.md §6)")
        return rec
    try:
        t0 = time.perf_counter()
        if mesh_split is not None:
            mesh = jax.make_mesh(mesh_split, ("data", "model"),
                                 **axis_types_kw(2))
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
        spec_info = input_specs(cfg, shape, mesh)
        fn, args = _jit_cell(cfg, shape, mesh, spec_info["mode"],
                             spec_info["specs"], microbatches=microbatches,
                             seq_parallel=seq_parallel)
        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        casts = f32_cast_artifact_bytes(hlo)
        n_dev = mesh.devices.size
        peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        rec.update({
            "devices": n_dev,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "per_device": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_hbm_bytes": peak,
                "cpu_cast_artifact_bytes": casts,
                # TPU-adjusted: casts don't exist on MXU hardware, but live
                # args+outputs (params, caches) are a hard floor
                "tpu_adjusted_peak_bytes": max(
                    peak - casts,
                    mem.argument_size_in_bytes + mem.output_size_in_bytes
                    - mem.alias_size_in_bytes),
                "flops": ca.get("flops", 0.0),
                "bytes_accessed": ca.get("bytes accessed", 0.0),
                "collective_bytes": coll,
            },
            "model": {
                "params": cfg.param_count(),
                "active_params": cfg.active_param_count(),
            },
        })
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES_BY_NAME))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES_BY_NAME:
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    results = []
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, multi_pod=mp)
            results.append(rec)
            pd = rec.get("per_device", {})
            peak = pd.get("peak_hbm_bytes", 0) / 1e9
            print(f"[{rec['status']:7s}] {arch:22s} {shape:12s} "
                  f"{rec['mesh']:8s} peak={peak:6.2f}GB "
                  f"flops={pd.get('flops', 0):.3e} "
                  f"coll={sum(v for k, v in pd.get('collective_bytes', {}).items() if k != 'count') / 1e6:9.1f}MB"
                  + (f"  !! {rec.get('error', '')[:120]}"
                     if rec["status"] == "error" else ""),
                  flush=True)
            if args.out:
                os.makedirs(os.path.dirname(os.path.abspath(args.out)),
                            exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    bad = [r for r in results if r["status"] == "error"]
    print(f"\n{len(results) - len(bad)}/{len(results)} cells ok, "
          f"{len(bad)} errors")
    raise SystemExit(1 if bad else 0)


if __name__ == "__main__":
    main()
