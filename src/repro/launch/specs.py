"""ShapeDtypeStruct input specs for every (arch × shape) dry-run cell.

No device allocation happens here: every model input, parameter, optimizer
moment and decode-cache leaf is a ShapeDtypeStruct carrying its NamedSharding,
so ``jit(...).lower(**specs).compile()`` exercises the full SPMD partitioner
without touching HBM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.models.config import ModelConfig, ShapeConfig
from repro.sharding.partition import Partitioner, dp_axes
from repro.train.steps import init_train_state


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(shape_tree, sharding_tree):
    return jax.tree_util.tree_map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), shape_tree, sharding_tree)


def _batch_entry(part: Partitioner, batch: int):
    """Shard batch over DP axes only when divisible (long_500k has B=1)."""
    return part.dp if batch % max(part.dp_size, 1) == 0 else None


def train_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                fsdp: bool = True):
    """(state_specs, batch_specs, shardings) for train/prefill cells.

    Training defaults to FSDP (ZeRO-3) param sharding: at 32B-scale the
    per-layer fp32 grad accumulator otherwise exceeds per-device HBM."""
    part = Partitioner(cfg, mesh, fsdp=fsdp)
    state_shape = jax.eval_shape(
        lambda: init_train_state(jax.random.key(0), cfg))
    shardings = {
        "params": part.param_shardings(state_shape["params"]),
        "opt": part.opt_shardings(state_shape["opt"]["mu"]),
        "step": part.replicated(),
    }
    shardings["opt"]["count"] = part.replicated()
    state_specs = _with_shardings(state_shape, shardings)

    bdim = _batch_entry(part, shape.global_batch)
    tok_sh = NamedSharding(mesh, P(bdim, None))
    batch_specs = {
        "tokens": _sds((shape.global_batch, shape.seq_len), jnp.int32, tok_sh),
        "labels": _sds((shape.global_batch, shape.seq_len), jnp.int32, tok_sh),
    }
    if cfg.frontend:
        batch_specs["frontend_embeds"] = _sds(
            (shape.global_batch, cfg.frontend_len, cfg.frontend_dim),
            jnp.float32, NamedSharding(mesh, P(bdim, None, None)))
    return state_specs, batch_specs, shardings


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """(param_specs, batch_specs) for the prefill (inference fwd) cells.
    Inference keeps params TP-only (no FSDP gathers on the serving path)."""
    state_specs, batch_specs, shardings = train_specs(cfg, shape, mesh,
                                                      fsdp=False)
    return state_specs["params"], batch_specs, shardings["params"]


def serve_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """(param_specs, cache_specs, token_specs, pos_specs) for decode cells.

    The KV/recurrent cache is sized for shape.seq_len context; the step
    decodes ONE new token (the assignment's serve_step semantics).
    """
    part = Partitioner(cfg, mesh)
    params_shape = jax.eval_shape(
        lambda: init_train_state(jax.random.key(0), cfg))["params"]
    param_shardings = part.param_shardings(params_shape)
    param_specs = _with_shardings(params_shape, param_shardings)

    B = shape.global_batch
    cache_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, B, shape.seq_len))
    bdim = _batch_entry(part, B)

    kv_shardable = (cfg.num_kv_heads % part.model == 0
                    and cfg.num_kv_heads >= part.model)

    def cache_sharding(leaf):
        shp = tuple(leaf.shape)
        entries: list = []
        if len(shp) >= 1:
            entries.append(None)            # stacked group axis
        if len(shp) >= 2:
            entries.append(bdim)            # batch
        used_model = False
        for i, dim in enumerate(shp[2:], start=2):
            if used_model:
                entries.append(None)
                continue
            if dim in (cfg.num_kv_heads, cfg.num_heads) and \
                    dim % part.model == 0 and dim >= part.model:
                entries.append("model")
                used_model = True
            elif dim == cfg.lru_dim and dim % part.model == 0:
                entries.append("model")
                used_model = True
            elif (not kv_shardable and len(shp) == 5 and i == 2
                  and dim % part.model == 0 and dim > part.model):
                # K/V (G, B, W, kv, hd) with unshardable kv heads: shard the
                # cache TIMELINE over 'model' (flash-decoding style — partial
                # softmax reductions become collectives)
                entries.append("model")
                used_model = True
            else:
                entries.append(None)
        return NamedSharding(mesh, P(*entries[:len(shp)]))

    cache_shardings = jax.tree_util.tree_map(cache_sharding, cache_shape)
    cache_specs = _with_shardings(cache_shape, cache_shardings)
    tok = _sds((B, 1), jnp.int32, NamedSharding(mesh, P(bdim, None)))
    pos = _sds((B, 1), jnp.int32, NamedSharding(mesh, P(bdim, None)))
    return param_specs, cache_specs, tok, pos, param_shardings, cache_shardings


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Uniform entry: kind-dispatched specs for a dry-run cell."""
    if shape.kind == "train":
        return {"mode": "train", "specs": train_specs(cfg, shape, mesh)}
    if shape.kind == "prefill":
        return {"mode": "prefill", "specs": prefill_specs(cfg, shape, mesh)}
    return {"mode": "decode", "specs": serve_specs(cfg, shape, mesh)}
