"""End-to-end training driver: ``python -m repro.launch.train --arch <id>``.

Runs a real (CPU-sized by default) training loop with the paper's checkpoint
engine in the loop: periodic async checkpoints, kill-resume fault tolerance,
engine/strategy selection, and a final report of checkpoint overheads —
the framework-level analogue of the paper's Fig 3 experiment.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import EngineConfig
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def build_trainer(args) -> Trainer:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.scaled_down(layers=args.layers, width_div=args.width_div,
                              vocab=args.vocab)
    tcfg = TrainerConfig(
        steps=args.steps, ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        ckpt_engine=args.engine, async_ckpt=not args.sync_ckpt,
        multilevel_remote=args.remote_dir, log_every=args.log_every,
        seed=args.seed)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.batch, seed=args.seed,
                          frontend_len=cfg.frontend_len,
                          frontend_dim=cfg.frontend_dim)
    eng_cfg = EngineConfig(strategy=args.strategy, direct=not args.buffered,
                           queue_depth=args.queue_depth)
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_host_mesh(d, m)
    return Trainer(cfg, tcfg, mesh=mesh, data_cfg=data_cfg,
                   opt_cfg=AdamWConfig(lr=args.lr),
                   engine_config=eng_cfg)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="xlstm-350m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="scaled-down config (full config needs a real pod)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--width-div", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--mesh", default="", help="e.g. 2x4 (data x model)")
    # checkpointing
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--remote-dir", default="")
    ap.add_argument("--engine", default="aggregated",
                    choices=["aggregated", "datastates", "snapshot",
                             "torchsave"])
    ap.add_argument("--strategy", default="single_file",
                    choices=["single_file", "file_per_process",
                             "file_per_tensor"])
    ap.add_argument("--sync-ckpt", action="store_true")
    ap.add_argument("--buffered", action="store_true")
    ap.add_argument("--queue-depth", type=int, default=64)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--json-out", default="")
    args = ap.parse_args()

    trainer = build_trainer(args)
    try:
        out = trainer.run()
    finally:
        trainer.close()
    losses = [m["loss"] for m in out["metrics"]]
    print(f"\narch={args.arch} steps={args.steps} "
          f"wall={out['wall_seconds']:.1f}s "
          f"ckpt_blocking={out['ckpt_blocking_seconds']:.2f}s")
    if losses:
        print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"metrics": out["metrics"],
                       "wall_seconds": out["wall_seconds"],
                       "ckpt_blocking_seconds": out["ckpt_blocking_seconds"]},
                      f, indent=1)


if __name__ == "__main__":
    main()
