"""Production mesh construction (single-pod 16×16 and multi-pod 2×16×16).

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=_auto(2))
