"""Production mesh construction (single-pod 16×16 and multi-pod 2×16×16).

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def axis_types_kw(n):
    """``axis_types=(Auto,)*n`` kwargs where the jax version has AxisType
    (≥ 0.6); empty on older jax, whose meshes are Auto by default."""
    at = getattr(jax.sharding, "AxisType", None)
    return {} if at is None else {"axis_types": (at.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_types_kw(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"),
                         **axis_types_kw(2))
