"""AdamW optimizer, built natively (no optax), ZeRO-1 shardable.

State = {mu, nu} pytrees (fp32) + count. With ZeRO-1 the moment tensors get an
extra sharding over the ``data`` axis on their largest divisible dim (see
repro.sharding.partition.zero1_sharding); the update math is unchanged —
GSPMD partitions it.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, count):
    warm = jnp.minimum(count.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = _schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        vhat = nu / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (step + cfg.weight_decay * p32)
        return p32.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "count": count}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
