"""Fault-tolerance demo: crash a training run mid-flight (SIGKILL), then
restart it — the trainer auto-resumes from the latest valid checkpoint,
including the data-pipeline position. A corrupt (partially-written)
checkpoint left by the crash is detected and skipped.

Also exercises the two-level (local + "PFS") MultiLevelCheckpointer: after a
simulated node loss (local dir wiped), restore falls back to the remote copy.

    PYTHONPATH=src python examples/failover.py
"""

import os
import shutil
import signal
import subprocess
import sys
import time

LOCAL = "/tmp/repro_failover_local"
REMOTE = "/tmp/repro_failover_remote"

CHILD = r"""
import sys
from repro.data import DataConfig
from repro.configs import get_config
from repro.train.trainer import Trainer, TrainerConfig

cfg = get_config("stablelm-3b").scaled_down(layers=2, width_div=16, vocab=512)
tcfg = TrainerConfig(steps=int(sys.argv[1]), ckpt_every=10,
                     ckpt_dir=sys.argv[2], multilevel_remote=sys.argv[3],
                     log_every=10)
data = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)
t = Trainer(cfg, tcfg, data_cfg=data)
out = t.run()
t.close()
print("FINAL", float(out["state"]["step"]), flush=True)
"""


def run_child(steps, timeout=None, kill_after=None):
    p = subprocess.Popen(
        [sys.executable, "-c", CHILD, str(steps), LOCAL, REMOTE],
        env={**os.environ, "PYTHONPATH": "src"},
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    if kill_after is not None:
        time.sleep(kill_after)
        p.send_signal(signal.SIGKILL)
        p.wait()
        return None
    out, _ = p.communicate(timeout=timeout)
    print(out[-800:])
    return out


def main():
    for d in (LOCAL, REMOTE):
        shutil.rmtree(d, ignore_errors=True)

    print("=== phase 1: start training, SIGKILL mid-run ===")
    run_child(500, kill_after=30)
    ckpts = sorted(os.listdir(LOCAL)) if os.path.exists(LOCAL) else []
    print("checkpoints left by the crashed run:", ckpts)
    resumed_from = max((int(c.split("_")[1]) for c in ckpts
                        if c.startswith("step_") and ".tmp" not in c),
                       default=0)

    def final_step(out):
        return int(float(out.strip().splitlines()[-1].split()[-1]))

    print("\n=== phase 2: restart — auto-resumes from latest valid ===")
    target = resumed_from + 20
    out = run_child(target, timeout=600)
    assert final_step(out) == target, (final_step(out), target)
    print(f"resumed from step {resumed_from}, completed to {target} ✓")

    print("=== phase 3: node loss — wipe local, restore from remote ===")
    shutil.rmtree(LOCAL)
    out = run_child(target + 10, timeout=600)
    assert final_step(out) == target + 10
    print("recovered from remote level after local wipe ✓")


if __name__ == "__main__":
    main()
