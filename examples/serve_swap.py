"""Model-swap serving: the paper's inference-restore scenario.

"To serve inference requests that need a large number of different models,
all of which don't fit into the GPU memory at the same time and therefore
need to be swapped in and out of slower memory tiers as needed." (§1)

Three reduced models are checkpointed once; the server then round-robins
batched generation requests across them, restoring ("swapping in") each model
from its checkpoint on demand. Reports per-swap restore bandwidth per engine —
the restore-path half of the paper's engine comparison.

    PYTHONPATH=src python examples/serve_swap.py
"""

import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import CheckpointManager
from repro.models import transformer as T
from repro.train.steps import init_train_state

ROOT = "/tmp/repro_serve"
ARCHS = ["qwen2.5-3b", "stablelm-3b", "gemma2-9b"]


def generate(cfg, params, prompt, steps=16):
    """Greedy decode `steps` tokens from a (B, S) prompt batch."""
    B, S = prompt.shape
    cache = T.init_cache(cfg, B, max_len=S + steps)
    dec = jax.jit(lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))
    tok = prompt[:, :1]
    out = []
    for t in range(S + steps - 1):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, cache = dec(params, cache, tok, pos)
        if t + 1 < S:
            tok = prompt[:, t + 1:t + 2]        # teacher-force the prompt
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def main():
    shutil.rmtree(ROOT, ignore_errors=True)
    # 1. checkpoint three models (the "model zoo" on slow storage)
    zoo = {}
    for arch in ARCHS:
        cfg = get_config(arch).scaled_down(layers=2, width_div=16, vocab=512)
        params = init_train_state(jax.random.key(hash(arch) % 2**31),
                                  cfg)["params"]
        with CheckpointManager(f"{ROOT}/{arch}") as mgr:
            mgr.save(0, {"params": params})
        zoo[arch] = (cfg, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params))
        del params

    # 2. serve a stream of requests, swapping models in on demand
    rng = np.random.default_rng(0)
    requests = [ARCHS[i % 3] for i in range(6)]
    for arch in requests:
        cfg, tmpl = zoo[arch]
        t0 = time.perf_counter()
        with CheckpointManager(f"{ROOT}/{arch}") as mgr:
            params = mgr.restore(state_template={"params": tmpl})["params"]
            swap_s = time.perf_counter() - t0
            bw = mgr.last_restore_metrics.total_bytes / swap_s / 1e6
        prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 8)),
                             jnp.int32)
        toks = generate(cfg, params, prompt, steps=12)
        print(f"{arch:14s} swap-in {swap_s*1e3:7.1f} ms ({bw:7.1f} MB/s)  "
              f"generated {toks.shape[1]} tokens/req x{toks.shape[0]} reqs")
    print("serving with model swap ✓")


if __name__ == "__main__":
    main()
