"""Model-swap serving: the paper's inference-restore scenario.

"To serve inference requests that need a large number of different models,
all of which don't fit into the GPU memory at the same time and therefore
need to be swapped in and out of slower memory tiers as needed." (§1)

Three reduced models are checkpointed once; the server then round-robins
batched generation requests across them, restoring ("swapping in") each model
from its checkpoint on demand. Reports per-swap restore bandwidth per engine —
the restore-path half of the paper's engine comparison.

Part 2 is the delta-aware swap variant (DESIGN.md §12): the zoo is kept
under ``delta=True`` managers, a served model is lightly fine-tuned (one
embedding row block + the final norm), and the UPDATE is pushed back into
the zoo as a delta save — only dirty chunks move, and the example reports
per-swap bytes moved vs the full model image before re-serving it.

    PYTHONPATH=src python examples/serve_swap.py
"""

import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import CheckpointManager
from repro.models import transformer as T
from repro.train.steps import init_train_state

ROOT = "/tmp/repro_serve"
ARCHS = ["qwen2.5-3b", "stablelm-3b", "gemma2-9b"]
DELTA_CHUNK = 64 << 10   # reduced models are small; keep the grid fine


def generate(cfg, params, prompt, steps=16):
    """Greedy decode `steps` tokens from a (B, S) prompt batch."""
    B, S = prompt.shape
    cache = T.init_cache(cfg, B, max_len=S + steps)
    dec = jax.jit(lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))
    tok = prompt[:, :1]
    out = []
    for t in range(S + steps - 1):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, cache = dec(params, cache, tok, pos)
        if t + 1 < S:
            tok = prompt[:, t + 1:t + 2]        # teacher-force the prompt
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def _light_update(params):
    """Simulate a light fine-tune touching a sliver of the weights."""
    flat, treedef = jax.tree_util.tree_flatten(params)
    out = []
    touched = 0
    for i, leaf in enumerate(flat):
        if i in (0, len(flat) - 1) and hasattr(leaf, "shape") and leaf.ndim:
            arr = np.asarray(leaf).copy()
            n = max(1, arr.shape[0] // 16)
            # += of an exactly-representable constant: changes bits even in
            # bfloat16 (a tiny multiplicative nudge rounds away to identity)
            arr[:n] += np.asarray(0.125, dtype=arr.dtype)
            touched += arr[:n].nbytes
            out.append(jnp.asarray(arr))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out), touched


def serve_one(arch, cfg, tmpl, rng):
    t0 = time.perf_counter()
    with CheckpointManager(f"{ROOT}/{arch}", delta=True, keep=2,
                           delta_chunk_bytes=DELTA_CHUNK) as mgr:
        params = mgr.restore(state_template={"params": tmpl})["params"]
        swap_s = time.perf_counter() - t0
        bw = mgr.last_restore_metrics.total_bytes / swap_s / 1e6
    prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 8)),
                         jnp.int32)
    toks = generate(cfg, params, prompt, steps=12)
    print(f"{arch:14s} swap-in {swap_s*1e3:7.1f} ms ({bw:7.1f} MB/s)  "
          f"generated {toks.shape[1]} tokens/req x{toks.shape[0]} reqs")
    return params


def main():
    shutil.rmtree(ROOT, ignore_errors=True)
    # 1. checkpoint three models (the "model zoo" on slow storage, kept by
    #    delta-aware managers so later updates move only dirty chunks)
    zoo = {}
    for arch in ARCHS:
        cfg = get_config(arch).scaled_down(layers=2, width_div=16, vocab=512)
        params = init_train_state(jax.random.key(hash(arch) % 2**31),
                                  cfg)["params"]
        with CheckpointManager(f"{ROOT}/{arch}", delta=True, keep=2,
                               delta_chunk_bytes=DELTA_CHUNK) as mgr:
            mgr.save(0, {"params": params})
        zoo[arch] = (cfg, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params))
        del params

    # 2. serve a stream of requests, swapping models in on demand
    rng = np.random.default_rng(0)
    for arch in [ARCHS[i % 3] for i in range(6)]:
        cfg, tmpl = zoo[arch]
        serve_one(arch, cfg, tmpl, rng)
    print("serving with model swap ✓")

    # 3. delta-aware re-swap: lightly fine-tune a served model and push the
    #    UPDATE back into the zoo — only dirty chunks move
    print("\ndelta update + re-swap (bytes moved per update):")
    for arch in ARCHS:
        cfg, tmpl = zoo[arch]
        with CheckpointManager(f"{ROOT}/{arch}", delta=True, keep=2,
                               delta_chunk_bytes=DELTA_CHUNK) as mgr:
            params = mgr.restore(state_template={"params": tmpl})["params"]
            params, touched = _light_update(params)
            m = mgr.save(1, {"params": params})
            print(f"{arch:14s} touched {touched/1e3:7.1f} KB -> moved "
                  f"{m.written_bytes/1e3:8.1f} KB of "
                  f"{m.total_bytes/1e6:6.2f} MB model "
                  f"({m.written_bytes/m.total_bytes:6.1%}; "
                  f"{m.chunks_dirty}/{m.chunks_total} chunks)")
        serve_one(arch, cfg, tmpl, rng)   # re-swap the updated model
    print("delta-aware model swap ✓")


if __name__ == "__main__":
    main()
