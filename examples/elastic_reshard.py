"""Elastic restart: save on a 2×4 mesh, restore onto 4×2 and 1×8 meshes.

Demonstrates the manifest's global-index windows letting a checkpoint written
under one (DP × TP) layout be consumed under another — the mechanism that
makes restart-after-topology-change (spot loss, pod resize) work at scale.

    PYTHONPATH=src python examples/elastic_reshard.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import shutil

import jax
import numpy as np

from repro.configs import get_config
from repro.core import CheckpointManager
from repro.launch.mesh import make_host_mesh
from repro.sharding.partition import Partitioner
from repro.train.steps import init_train_state

CKPT = "/tmp/repro_elastic"


def sharded_state(cfg, mesh, seed=0):
    part = Partitioner(cfg, mesh)
    shape = jax.eval_shape(lambda: init_train_state(jax.random.key(seed), cfg))
    shardings = {"params": part.param_shardings(shape["params"]),
                 "opt": part.opt_shardings(shape["opt"]["mu"]),
                 "step": part.replicated()}
    shardings["opt"]["count"] = part.replicated()
    with mesh:
        state = jax.jit(lambda: init_train_state(jax.random.key(seed), cfg),
                        out_shardings=shardings)()
    return state, shardings


def template(state, shardings):
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        state, shardings)


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = get_config("olmoe-1b-7b").scaled_down(layers=2, width_div=16,
                                                vocab=512)
    mesh_a = make_host_mesh(2, 4)
    state_a, _ = sharded_state(cfg, mesh_a)
    with CheckpointManager(CKPT) as mgr:
        mgr.save(1, state_a)

        for d, m in [(4, 2), (1, 8)]:
            mesh_b = make_host_mesh(d, m)
            shape_b, shardings_b = sharded_state(cfg, mesh_b, seed=1)
            restored = mgr.restore(
                state_template=template(shape_b, shardings_b))
            # value equality against the original, despite new layout
            jax.tree.map(
                lambda a, b: np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b)),
                restored["params"], state_a["params"])
            ws = restored["params"]["blocks"]["b0_attn"]["wq"].sharding
            print(f"restored onto {d}x{m} mesh; wq spec={ws.spec} ✓")
    print("elastic resharding across topologies ✓")


if __name__ == "__main__":
    main()
