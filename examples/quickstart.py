"""Quickstart: train a reduced qwen2.5 for 100 steps with async checkpointing,
then restore the checkpoint and verify bit-exact state recovery.

    PYTHONPATH=src python examples/quickstart.py
"""

import shutil

import jax
import numpy as np

from repro.configs import get_config
from repro.core import CheckpointManager
from repro.data import DataConfig
from repro.train.trainer import Trainer, TrainerConfig

CKPT = "/tmp/repro_quickstart"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = get_config("qwen2.5-3b").scaled_down(layers=2, width_div=16,
                                               vocab=512)
    tcfg = TrainerConfig(steps=100, ckpt_every=50, ckpt_dir=CKPT,
                         ckpt_engine="aggregated", async_ckpt=True,
                         log_every=20)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)
    trainer = Trainer(cfg, tcfg, data_cfg=data)
    out = trainer.run()
    trainer.close()

    print("\nloss curve:")
    for m in out["metrics"]:
        print(f"  step {m['step']:>3}: loss={m['loss']:.4f}")
    print(f"checkpoint blocking time: {out['ckpt_blocking_seconds']*1e3:.1f} ms"
          f" over {tcfg.steps // tcfg.ckpt_every} checkpoints (async flush)")

    # restore and verify
    with CheckpointManager(CKPT) as mgr:
        state = mgr.restore(state_template={"train": out["state"],
                                            "data": {"data_step": 0}})
    got = state["train"]["params"]
    want = out["state"]["params"]
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got, want)
    print("restored state is bit-exact ✓")


if __name__ == "__main__":
    main()
