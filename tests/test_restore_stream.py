"""Streaming restore pipeline: streaming/monolithic parity, out-of-order
extent arrival, in-stream CRC verification, backpressure, prefetcher-fed
streams, and abort cleanup (DESIGN.md §10)."""

import os
import shutil
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CheckpointManager, ChecksumError, EngineConfig,
                        MultiLevelCheckpointer, make_cr_engine)
from repro.core.aggregation import Strategy
from repro.core.engines import ReadReq, SaveItem
from repro.core.manifest import Manifest, crc32_of


def _state(scale=1):
    return {
        "params": {"w": jnp.arange(256 * 64 * scale,
                                   dtype=jnp.float32).reshape(256, -1),
                   "b": jnp.full((64,), 0.5, jnp.bfloat16)},
        "opt": {"mu": jax.random.normal(jax.random.key(3),
                                        (128, 512 * scale))},
        "data": {"cursor": np.arange(777, dtype=np.int64)},
        "step": 11,
    }


def _leaves(tree):
    flat, _ = jax.tree_util.tree_flatten(tree)
    return [np.asarray(x) for x in flat if hasattr(x, "shape")]


def _assert_tree_equal(a, b):
    la, lb = _leaves(a), _leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(x, y)


# -------------------------------------------------------------- mode parity
@pytest.mark.parametrize("quantize", [False, True])
def test_streaming_bit_identical_to_monolithic(quantize, tmp_path):
    """One checkpoint, restored by both modes: every leaf (incl. dequantized
    moments) must be bit-identical — streaming changes scheduling, not data."""
    state = _state(scale=2)
    qp = ("opt/mu",) if quantize else ()
    d = str(tmp_path / "ck")
    with CheckpointManager(d, quantize_prefixes=qp) as mgr:
        mgr.save(1, state)
    with CheckpointManager(d, quantize_prefixes=qp, streaming=True) as m_s:
        r_stream = m_s.restore(state_template=state)
        assert m_s.last_restore_metrics.mode == "streaming"
    with CheckpointManager(d, quantize_prefixes=qp, streaming=False) as m_m:
        r_mono = m_m.restore(state_template=state)
        assert m_m.last_restore_metrics.mode == "monolithic"
    _assert_tree_equal(r_stream, r_mono)
    np.testing.assert_array_equal(np.asarray(r_stream["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_streaming_metrics_overlap_accounting(tmp_path):
    state = _state(scale=4)
    d = str(tmp_path / "ck")
    with CheckpointManager(d, quantize_prefixes=("opt/mu",)) as mgr:
        mgr.save(1, state)
        mgr.restore(state_template=state)
        m = mgr.last_restore_metrics
    assert m.mode == "streaming"
    assert m.peak_staged_bytes > 0
    assert m.decode_seconds > 0          # quantized moments were unpacked
    # the read stage spans the whole stream, so it alone can approach e2e;
    # the consumer's stall must not exceed the stage span
    assert m.read_stall_seconds <= m.read_seconds + 1e-3
    assert m.stage_seconds >= m.read_seconds
    assert m.overlap_seconds >= 0.0
    assert m.end_to_end_seconds > 0


RESHARD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, sys
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import CheckpointManager
devs = jax.devices()
mesh_a = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))
mesh_b = Mesh(np.array(devs).reshape(4, 2), ("data", "model"))
w = jnp.arange(64 * 128, dtype=jnp.float32).reshape(64, 128)
state = {"w": jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))}
d = sys.argv[1]
tmpl = {"w": jax.ShapeDtypeStruct(w.shape, w.dtype,
        sharding=NamedSharding(mesh_b, P("model", "data")))}
with CheckpointManager(d, streaming=True) as mgr:
    mgr.save(1, state)
    r_s = mgr.restore(state_template=tmpl)
    assert mgr.last_restore_metrics.mode == "streaming"
with CheckpointManager(d, streaming=False) as mgr:
    r_m = mgr.restore(state_template=tmpl)
np.testing.assert_array_equal(np.asarray(r_s["w"]), np.asarray(w))
np.testing.assert_array_equal(np.asarray(r_s["w"]), np.asarray(r_m["w"]))
print("RESHARD-STREAM-OK")
"""


def test_streaming_resharded_restore_multidevice(tmp_path):
    """Save on a 2x4 mesh, restore on 4x2 through the streaming pipeline —
    windowed assembly fed by streamed pieces must match the monolithic
    full-lookup result bit for bit."""
    env = {**os.environ, "PYTHONPATH": "src"}
    p = subprocess.run([sys.executable, "-c", RESHARD, str(tmp_path / "d")],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=300)
    assert "RESHARD-STREAM-OK" in p.stdout, p.stderr[-2000:]


# -------------------------------------------------- stream-level behaviours
def _save_items(eng, d, sizes, rng, **kw):
    items = [SaveItem(f"t{i}", rng.integers(0, 256, (n,), np.uint8)
                      if n else np.zeros((0,), np.uint8),
                      "uint8", (n,), ((0, n),)) for i, n in enumerate(sizes)]
    m = eng.save(d, items, **kw)
    return items, m


def test_out_of_order_get(tmp_path, rng):
    """Consumers may get keys in any order (the stream exceeds its budget
    one unit at a time rather than deadlocking on landed results)."""
    eng = make_cr_engine("aggregated", EngineConfig(
        chunk_bytes=1 << 20, coalesce_bytes=1 << 20, inflight_bytes=2 << 20,
        strategy=Strategy.FILE_PER_PROCESS))
    d = str(tmp_path / "ooo")
    sizes = [1 << 20, 777, 3 << 20, 0, 65536, 1 << 20]   # incl. chunked + empty
    items, m = _save_items(eng, d, sizes, rng, step=1)
    reqs = [ReadReq(k, r.shards[0].path, r.shards[0].offset,
                    r.shards[0].nbytes) for k, r in m.tensors.items()]
    stream = eng.begin_restore(d, reqs)
    for it in reversed(items):          # reverse of layout order
        got = stream.get(it.key)
        assert got.tobytes() == bytes(memoryview(it.data)), it.key
    stream.end_restore()
    with pytest.raises(KeyError):
        stream2 = eng.begin_restore(d, reqs)
        stream2.get("t0")
        stream2.get("t0")               # double consumption
    stream2.abort()
    eng.close()


def test_restore_backpressure_caps_staged_bytes(tmp_path, rng):
    """In-order consumption keeps staged bytes (read buffers + landed
    results) within inflight_bytes; monolithic read of the same checkpoint
    peaks at full size."""
    budget = 2 << 20
    eng = make_cr_engine("aggregated", EngineConfig(
        chunk_bytes=1 << 20, coalesce_bytes=1 << 20, inflight_bytes=budget,
        strategy=Strategy.FILE_PER_PROCESS))
    d = str(tmp_path / "bp")
    sizes = [1 << 20] * 8 + [6 << 20]
    items, m = _save_items(eng, d, sizes, rng, step=1)
    reqs = [ReadReq(it.key, m.tensors[it.key].shards[0].path,
                    m.tensors[it.key].shards[0].offset,
                    m.tensors[it.key].shards[0].nbytes) for it in items]
    stream = eng.begin_restore(d, reqs)
    for it in items:                    # layout order
        stream.get(it.key)
    stats = stream.end_restore()
    assert 0 < stats.peak_staged_bytes <= budget
    assert stats.logical_bytes == sum(sizes)
    eng.close()


def test_manager_restore_reports_bounded_staging(tmp_ckpt_dir):
    budget = 4 << 20
    cfg = EngineConfig(inflight_bytes=budget, chunk_bytes=1 << 20,
                       coalesce_bytes=1 << 20)
    state = _state(scale=8)             # ~several MB of tensors
    with CheckpointManager(tmp_ckpt_dir, config=cfg) as mgr:
        mgr.save(1, state)
        mgr.restore(state_template=state)
        assert 0 < mgr.last_restore_metrics.peak_staged_bytes <= budget
    with CheckpointManager(tmp_ckpt_dir, config=cfg, streaming=False) as mgr:
        mgr.restore(state_template=state)
        total = mgr.last_restore_metrics.total_bytes
        # monolithic stages every extent at once
        assert mgr.last_restore_metrics.peak_staged_bytes >= total // 2


# ------------------------------------------------------------ CRC verification
def _corrupt_extent(ckpt_root, step, key):
    man = Manifest.load(os.path.join(ckpt_root, f"step_{step:08d}"))
    sh = man.tensors[key].shards[0]
    path = os.path.join(ckpt_root, f"step_{step:08d}", sh.path)
    with open(path, "r+b") as f:
        f.seek(sh.offset + min(8, max(sh.nbytes - 4, 0)))
        f.write(b"\xde\xad\xbe\xef")
    return sh


def test_crc_mismatch_raises_checksum_error(tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, verify_crc=True) as mgr:
        mgr.save(1, state)
        sh = _corrupt_extent(tmp_ckpt_dir, 1, "params/w")
        with pytest.raises(ChecksumError) as ei:
            mgr.restore(state_template=state)
        assert "params/w" in str(ei.value)      # names the key...
        assert str(sh.offset) in str(ei.value)  # ...and the offset


def test_crc_optout_restores_corrupt_bytes(tmp_ckpt_dir):
    """verify_crc=False (EngineConfig.checksum unset) skips verification —
    the corrupted bytes come back unchecked."""
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, verify_crc=True) as mgr:
        mgr.save(1, state)
    _corrupt_extent(tmp_ckpt_dir, 1, "params/w")
    with CheckpointManager(tmp_ckpt_dir, verify_crc=False) as mgr:
        r = mgr.restore(state_template=state)   # no raise
    assert not np.array_equal(np.asarray(r["params"]["w"]),
                              np.asarray(state["params"]["w"]))


def test_crc_verified_in_buffered_fallback(tmp_ckpt_dir):
    """Engines without a native read stream still verify through the
    buffered fallback. datastates/snapshot record no CRCs, so drive the
    fallback through the base-class path on the aggregated format."""
    from repro.core.engines.base import CREngine
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, verify_crc=True) as mgr:
        mgr.save(1, state)
        sh = _corrupt_extent(tmp_ckpt_dir, 1, "params/w")
        step_dir = os.path.join(tmp_ckpt_dir, "step_00000001")
        req = ReadReq("params/w@0", sh.path, sh.offset, sh.nbytes)
        # the base-class buffered fallback batches one read, verifies per get
        stream = CREngine.begin_restore(mgr.engine, step_dir, [req],
                                        crcs={req.key: sh.crc32})
        with pytest.raises(ChecksumError, match="params/w"):
            stream.get(req.key)
        stream.abort()


# -------------------------------------------------------------- abort cleanup
def test_restore_abort_releases_buffers_and_budget(tmp_ckpt_dir):
    """A mid-restore ChecksumError must settle the pooled-buffer and budget
    books: the SAME manager can save and restore again without wedging."""
    state = _state(scale=2)
    with CheckpointManager(tmp_ckpt_dir, verify_crc=True,
                           config=EngineConfig(inflight_bytes=2 << 20)
                           ) as mgr:
        mgr.save(1, state)
        _corrupt_extent(tmp_ckpt_dir, 1, "params/w")
        with pytest.raises(ChecksumError):
            mgr.restore(state_template=state, step=1)
        assert mgr.engine.pool.outstanding_bytes == 0   # books settled
        mgr.save(2, state)                              # no budget deadlock
        r = mgr.restore(state_template=state, step=2)
        np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                      np.asarray(state["params"]["w"]))


# ------------------------------------------------------------ prefetcher-fed
def test_prefetcher_fed_stream_parity(tmp_path):
    """A level-1-only step prefetched into level 0 must stream to the same
    bytes as a local restore, promote the step, and attribute the pull."""
    state = _state()
    local, remote = str(tmp_path / "l"), str(tmp_path / "r")
    with MultiLevelCheckpointer(local, remote) as ml:
        ml.save(5, state)
        ml.wait()
        with CheckpointManager(local) as direct:
            r_local = direct.restore(state_template=state, step=5)
        shutil.rmtree(local)            # node loss: only level 1 remains
        os.makedirs(local)
        r = ml.restore(state_template=state)
        m = ml.last_restore_metrics
        assert m.mode == "streaming"
        assert m.prefetch_seconds > 0
        assert os.path.exists(os.path.join(local, "step_00000005",
                                           "manifest.json"))
    _assert_tree_equal(r, r_local)


def test_end_restore_drains_unconsumed_keys(tmp_path, rng):
    """Keys MAY be left unconsumed: end_restore must still drain (the final
    drain escapes the budget when landed results would otherwise wedge it)."""
    eng = make_cr_engine("aggregated", EngineConfig(
        chunk_bytes=1 << 20, coalesce_bytes=1 << 20, inflight_bytes=2 << 20,
        strategy=Strategy.FILE_PER_PROCESS))
    d = str(tmp_path / "uncons")
    sizes = [1 << 20] * 6          # 6 MB of requests vs a 2 MB budget
    items, m = _save_items(eng, d, sizes, rng, step=1)
    reqs = [ReadReq(it.key, m.tensors[it.key].shards[0].path,
                    m.tensors[it.key].shards[0].offset,
                    m.tensors[it.key].shards[0].nbytes) for it in items]
    stream = eng.begin_restore(d, reqs)
    assert stream.get("t0").tobytes() == bytes(memoryview(items[0].data))
    stream.end_restore()           # 5 unconsumed keys: must not spin
    assert eng.pool.outstanding_bytes == 0
    eng.close()


# ----------------------------------------------------- degenerate batch read
def test_batch_read_is_stream_client(tmp_path, rng):
    """engine.read() now drives the stream: same results, and small extents
    still coalesce to one I/O per group region."""
    eng = make_cr_engine("aggregated", EngineConfig(
        coalesce_bytes=64 << 20, strategy=Strategy.FILE_PER_PROCESS))
    d = str(tmp_path / "batch")
    sizes = [4096] * 16
    items, m = _save_items(eng, d, sizes, rng, step=1)
    reqs = [ReadReq(it.key, m.tensors[it.key].shards[0].path,
                    m.tensors[it.key].shards[0].offset,
                    m.tensors[it.key].shards[0].nbytes) for it in items]
    out = eng.read(d, reqs)
    for it in items:
        assert out[it.key].tobytes() == bytes(memoryview(it.data))
    assert eng.last_restore_stats.io_requests == 1   # one coalesced read
    eng.close()


def test_restore_abort_after_injected_engine_error(tmp_ckpt_dir):
    """A raw EIO (fault-injected at the pread syscall) mid-stream must take
    the same abort path as a CRC mismatch: budget units settled, pooled
    buffers returned, and the SAME manager saves and restores afterwards."""
    import errno

    from repro.core import faults

    state = _state(scale=2)
    with CheckpointManager(tmp_ckpt_dir, verify_crc=True,
                           config=EngineConfig(backend="threadpool",
                                               inflight_bytes=2 << 20)
                           ) as mgr:
        mgr.save(1, state)
        plan = faults.FaultPlan([faults.Fault(
            faults.OP_READ, at=2, action=faults.A_ERRNO, err=errno.EIO)])
        with faults.inject(plan):
            with pytest.raises(Exception) as ei:
                mgr.restore(state_template=state, step=1)
        assert plan.fired
        chain, e = [], ei.value
        while e is not None and e not in chain:
            chain.append(e)
            e = e.__cause__ or e.__context__
        assert any(isinstance(x, faults.InjectedIOError) for x in chain)
        assert mgr.engine.pool.outstanding_bytes == 0   # books settled
        mgr.save(2, state)                              # no budget deadlock
        r = mgr.restore(state_template=state, step=2)
        np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                      np.asarray(state["params"]["w"]))


def test_restore_abort_after_injected_crash_mid_stream(tmp_ckpt_dir):
    """An InjectedCrash (worker death mid-pread) must also leave the engine
    reusable — the stream's abort path cannot depend on the error type."""
    from repro.core import faults

    state = _state(scale=2)
    with CheckpointManager(tmp_ckpt_dir, verify_crc=True,
                           config=EngineConfig(backend="threadpool",
                                               inflight_bytes=2 << 20)
                           ) as mgr:
        mgr.save(1, state)
        plan = faults.FaultPlan([faults.Fault(faults.OP_READ, at=1,
                                              action=faults.A_CRASH)])
        with faults.inject(plan):
            with pytest.raises(Exception):
                mgr.restore(state_template=state, step=1)
        assert plan.fired
        assert mgr.engine.pool.outstanding_bytes == 0
        r = mgr.restore(state_template=state, step=1)   # retry, clean run
        np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                      np.asarray(state["params"]["w"]))
