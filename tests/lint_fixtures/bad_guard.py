# crlint: fixture
"""CRL003 canary — guarded fields touched without their lock."""
import threading


class Registry:
    def __init__(self):
        self._lock = threading.Lock()
        # crlint: guarded-by(_lock)
        self._items: dict[str, int] = {}

    def add(self, key: str, val: int) -> None:
        self._items[key] = val               # CRL003: _lock not held

    def size_unlocked(self) -> int:
        return len(self._items)              # CRL003: _lock not held

    def get(self, key: str) -> int:
        with self._lock:
            return self._items[key]          # fine: lock held
