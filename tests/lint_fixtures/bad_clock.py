# crlint: fixture
"""CRL006 canary — un-epoched clocks fragmenting the trace epoch."""
import os
import time
from time import perf_counter as pc


def measure() -> float:
    t0 = time.perf_counter()                 # CRL006: use trace.clock()
    return time.perf_counter() - t0          # CRL006: use trace.clock()


def stamp() -> float:
    return time.time()                       # CRL006: un-annotated wall clock


def deadline(timeout: float) -> float:
    return time.monotonic() + timeout        # CRL006: use trace.clock()


def aliased() -> float:
    return pc()                              # CRL006: from-import alias


def mtime_age(path: str) -> float:
    # crlint: allow(CRL006): mtime comparison needs the wall clock
    return time.time() - os.path.getmtime(path)
