# crlint: fixture
"""CRL004 canary — acquire without a visible release path."""


def stage(pool, budget, n: int) -> bytes:
    buf = pool.get(n)                        # CRL004: no release on error
    budget.add(n)                            # CRL004: no sub/settle on error
    data = bytes(buf.view(0, n))
    buf.release()
    budget.sub(n)
    return data


def stage_safe(pool, n: int) -> bytes:
    buf = pool.get(n)
    try:
        return bytes(buf.view(0, n))
    finally:
        buf.release()
