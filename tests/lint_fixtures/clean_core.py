# crlint: fixture
"""Clean twin — idiomatic code every checker must pass untouched."""
import os
import threading

from repro.core import faults


def publish(fd: int, tmp: str, final_path: str) -> None:
    faults.fsync(fd)
    faults.replace(tmp, final_path)
    dfd = os.open(os.path.dirname(final_path) or ".", os.O_RDONLY)
    try:
        faults.fsync(dfd)
    finally:
        os.close(dfd)


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        # crlint: guarded-by(_lock)
        self._n = 0

    def bump(self) -> None:
        with self._lock:
            self._n += 1

    def _bump_locked(self) -> None:  # crlint: holds(_lock)
        self._n += 1


def stage(pool, n: int) -> bytes:
    buf = pool.get(n)
    try:
        return bytes(buf.view(0, n))
    finally:
        buf.release()


def guarded(path: str) -> None:
    try:
        faults.replace(path + ".tmp", path)
    except (faults.InjectedCrash, faults.InjectedIOError):
        raise
    except OSError:
        pass
