# crlint: fixture
"""CRL001 canary — every raw syscall below must be flagged."""
import os
import shutil

from repro.core import faults


def publish(tmp: str, final: str) -> None:
    os.rename(tmp, final)                    # CRL001: want faults.replace
    os.replace(tmp, final)                   # CRL001: want faults.replace
    fd = os.open(final, os.O_RDONLY)
    os.fsync(fd)                             # CRL001: want faults.fsync
    os.fdatasync(fd)                         # CRL001: want faults.fdatasync
    os.close(fd)


def write_block(fd: int, data: bytes) -> None:
    os.pwrite(fd, data, 0)                   # CRL001: want faults.pwrite
    os.preadv(fd, [bytearray(4)], 0)         # CRL001: want faults.preadv
    os.posix_fallocate(fd, 0, 4096)          # CRL001: want faults.posix_fallocate


def cleanup(path: str) -> None:
    shutil.rmtree(path, ignore_errors=True)  # CRL001: want faults.rmtree


def aliased(tmp: str, final: str) -> None:
    from os import replace
    replace(tmp, final)                      # CRL001: aliased raw import


def fine(tmp: str, dst: str) -> None:
    faults.replace(tmp, dst)
    faults.rmtree(tmp, ignore_errors=True)
