# crlint: fixture
"""CRL005 canary — handlers that absorb injected faults."""
from repro.core import faults


def swallow_all(path: str) -> None:
    try:
        faults.replace(path + ".tmp", path)
    except Exception:                        # CRL005: absorbs InjectedCrash
        pass


def swallow_bare(fn) -> None:
    try:
        fn()
    except:                                  # CRL005: bare except
        pass


def absorb_injected_errno(path: str) -> None:
    try:
        faults.replace(path + ".tmp", path)
    except OSError:                          # CRL005: InjectedIOError is an OSError
        pass


def fine_reraise(path: str) -> None:
    try:
        faults.replace(path + ".tmp", path)
    except (faults.InjectedCrash, faults.InjectedIOError):
        raise
    except OSError:
        pass


def fine_bound(fn, log) -> None:
    try:
        fn()
    except Exception as e:
        log(e)
