# crlint: fixture
"""CRL002 canary — publish renames missing the fsync protocol."""
import os

from repro.core import faults


def publish_no_presync(tmp: str) -> None:
    final = tmp[:-4]
    faults.replace(tmp, final)               # CRL002: no fsync before
    fd = os.open(".", os.O_RDONLY)
    faults.fsync(fd)
    os.close(fd)


def publish_no_dirsync(fd: int, tmp: str, manifest_path: str) -> None:
    faults.fsync(fd)
    faults.replace(tmp, manifest_path)       # CRL002: no dir fsync after


def publish_naked(tmp: str, commit_path: str) -> None:
    faults.replace(tmp, commit_path)         # CRL002: both findings
