"""Resharding planner properties + multi-device elastic restore."""

import os
import subprocess
import sys

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container without hypothesis
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.manifest import ShardEntry, TensorRecord
from repro.core.resharding import (assemble, dedupe_shards, intersect,
                                   normalize_index, plan_window)


def _grid_record(shape, splits):
    """Shard a tensor on an even grid; payload = offsets into arange."""
    rec = TensorRecord("t", "float32", shape)
    data = np.arange(np.prod(shape), dtype=np.float32).reshape(shape)
    extents = {}
    steps = [s // k for s, k in zip(shape, splits)]
    idx = [0] * len(shape)

    def rec_dims(d, window):
        if d == len(shape):
            window = tuple(window)
            sub = data[tuple(slice(lo, hi) for lo, hi in window)]
            path = f"data/{len(extents)}.bin"
            rec.shards.append(ShardEntry(window, path, 0, sub.nbytes))
            extents[(path, 0)] = np.ascontiguousarray(sub).view(np.uint8).reshape(-1)
            return
        for i in range(splits[d]):
            rec_dims(d + 1, window + [(i * steps[d], (i + 1) * steps[d])])

    rec_dims(0, [])
    return rec, data, extents


@settings(max_examples=25, deadline=None)
@given(splits=st.tuples(st.sampled_from([1, 2, 4]), st.sampled_from([1, 2, 4])),
       wsplits=st.tuples(st.sampled_from([1, 2, 4]), st.sampled_from([1, 2, 4])))
def test_any_regrid_assembles_exactly(splits, wsplits):
    """Property: saving on grid A and reading on grid B reproduces the tensor."""
    shape = (16, 32)
    rec, data, extents = _grid_record(shape, splits)
    lookup = lambda sh: extents[(sh.path, sh.offset)]
    steps = [s // k for s, k in zip(shape, wsplits)]
    for i in range(wsplits[0]):
        for j in range(wsplits[1]):
            window = ((i * steps[0], (i + 1) * steps[0]),
                      (j * steps[1], (j + 1) * steps[1]))
            out = assemble(rec, window, lookup)
            np.testing.assert_array_equal(
                out, data[window[0][0]:window[0][1],
                          window[1][0]:window[1][1]])


def test_intersect():
    assert intersect(((0, 4),), ((2, 8),)) == ((2, 4),)
    assert intersect(((0, 4),), ((4, 8),)) is None
    assert intersect(((0, 4), (0, 2)), ((1, 2), (0, 2))) == ((1, 2), (0, 2))


def test_normalize_index():
    assert normalize_index((slice(2, 5),), (10,)) == ((2, 5),)
    assert normalize_index((slice(None),), (10,)) == ((0, 10),)
    assert normalize_index(None, (3, 4)) == ((0, 3), (0, 4))


def test_plan_window_incomplete_coverage_raises():
    rec = TensorRecord("t", "float32", (8,))
    rec.shards.append(ShardEntry(((0, 4),), "a", 0, 16))
    with pytest.raises(ValueError):
        plan_window(rec, ((0, 8),))


def test_dedupe_replicas():
    rec = TensorRecord("t", "float32", (4,))
    rec.shards.append(ShardEntry(((0, 4),), "a", 0, 16))
    rec.shards.append(ShardEntry(((0, 4),), "b", 0, 16))
    assert len(dedupe_shards(rec)) == 1


ELASTIC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, shutil, sys
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import CheckpointManager
devs = jax.devices()
mesh_a = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))
mesh_b = Mesh(np.array(devs).reshape(4, 2), ("data", "model"))
w = jnp.arange(64 * 128, dtype=jnp.float32).reshape(64, 128)
state = {"w": jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))}
d = sys.argv[1]
with CheckpointManager(d) as mgr:
    mgr.save(1, state)
    tmpl = {"w": jax.ShapeDtypeStruct(w.shape, w.dtype,
            sharding=NamedSharding(mesh_b, P("model", "data")))}
    r = mgr.restore(state_template=tmpl)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(w))
print("ELASTIC-OK")
"""


def test_elastic_restore_multidevice(tmp_path):
    """Save under a 2x4 mesh, restore under 4x2 — in a fresh process with
    8 host devices (tests must not pollute this process's jax)."""
    env = {**os.environ, "PYTHONPATH": "src"}
    p = subprocess.run([sys.executable, "-c", ELASTIC, str(tmp_path / "d")],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=300)
    assert "ELASTIC-OK" in p.stdout, p.stderr[-2000:]
