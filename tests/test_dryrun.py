"""Dry-run machinery: collective-bytes HLO parser + one real (small-mesh)
lower/compile per mode, in a subprocess with forced host devices."""

import json
import os
import subprocess
import sys

from repro.launch.dryrun import _shape_bytes, collective_bytes

HLO_SAMPLE = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %p), replica_groups=...
  %ar.1 = f32[256]{0} all-reduce(f32[256]{0} %x), to_apply=%sum
  %rs = (f32[8,32]{1,0}, f32[8,32]{1,0}) reduce-scatter(f32[64,32]{1,0} %y, f32[64,32]{1,0} %z)
  %cp = u32[4]{0} collective-permute(u32[4]{0} %c), source_target_pairs=...
  %dot = f32[128,128]{1,0} dot(f32[128,64] %a, f32[64,128] %b)
  %a2a.s = f32[16]{0} all-to-all-start(f32[16]{0} %w)
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,1024]{1,0}") == 16 * 1024 * 2
    assert _shape_bytes("f32[256]{0}") == 1024
    assert _shape_bytes("(f32[8,32]{1,0}, f32[8,32]{1,0})") == 2 * 8 * 32 * 4
    assert _shape_bytes("pred[]") == 1  # scalar pred = one byte


def test_collective_bytes_parser():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == 1024
    assert out["reduce-scatter"] == 2 * 8 * 32 * 4
    assert out["collective-permute"] == 16
    assert out["all-to-all"] == 64
    assert out["count"] == 5
    # the dot must NOT be counted
    assert "dot" not in out


SMALL_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_config
from repro.launch.specs import input_specs
from repro.launch.dryrun import _jit_cell, collective_bytes
from repro.models.config import ShapeConfig

from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(2, 4)
cfg = get_config("qwen2.5-3b").scaled_down(layers=2, width_div=8, vocab=512)
for shape in [ShapeConfig("t", 256, 8, "train"),
              ShapeConfig("p", 256, 8, "prefill"),
              ShapeConfig("d", 256, 8, "decode")]:
    si = input_specs(cfg, shape, mesh)
    fn, args = _jit_cell(cfg, shape, mesh, si["mode"], si["specs"])
    with mesh:
        compiled = fn.lower(*args).compile()
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    assert mem.temp_size_in_bytes >= 0
    assert coll["count"] > 0, (shape.kind, "expected collectives on 2x4 mesh")
    print(shape.kind, "ok", coll["count"])
print("DRYRUN-SMALL-OK")
"""


def test_small_mesh_dryrun_all_modes():
    env = {**os.environ, "PYTHONPATH": "src"}
    p = subprocess.run([sys.executable, "-c", SMALL_DRYRUN],
                       capture_output=True, text=True, env=env, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "DRYRUN-SMALL-OK" in p.stdout, p.stdout + p.stderr[-3000:]


def test_production_dryrun_results_if_present():
    """Validate the committed full-sweep results (produced by
    python -m repro.launch.dryrun --all --both-meshes)."""
    path = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                        "results", "dryrun_all.json")
    if not os.path.exists(path):
        import pytest
        pytest.skip("full dry-run results not generated yet")
    recs = json.load(open(path))
    assert len(recs) == 80   # 10 archs x 4 shapes x 2 meshes
    bad = [r for r in recs if r["status"] == "error"]
    assert not bad, [(r["arch"], r["shape"], r["mesh"]) for r in bad]
    ok = [r for r in recs if r["status"] == "ok"]
    # every ok cell fits v5e HBM (TPU-adjusted: XLA:CPU bf16→f32 dot-operand
    # duplicates excluded, see dryrun.f32_cast_artifact_bytes) + did real work
    for r in ok:
        peak = r["per_device"].get("tpu_adjusted_peak_bytes",
                                   r["per_device"]["peak_hbm_bytes"])
        assert peak < 16e9, (r["arch"], r["shape"], r["mesh"], peak)
        assert r["per_device"]["flops"] > 0
    # multi-pod proof: every single-pod ok cell also compiled multi-pod
    single = {(r["arch"], r["shape"]) for r in ok if r["mesh"] == "16x16"}
    multi = {(r["arch"], r["shape"]) for r in ok if r["mesh"] == "2x16x16"}
    assert single == multi
