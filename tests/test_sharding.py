"""Partition rules: divisibility guards, FSDP/ZeRO specs, spec shapes.

Runs in a subprocess with 16 host devices (a 4x4 mesh) so the main pytest
process keeps its single-device view.
"""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.sharding.partition import Partitioner
from repro.train.steps import init_train_state

from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(4, 4)

# qwen2.5: kv heads (2) cannot shard over model=4 -> wk replicated on dim1?
cfg = get_config("qwen2.5-3b").scaled_down(layers=2, width_div=8, vocab=512)
part = Partitioner(cfg, mesh)
shape = jax.eval_shape(lambda: init_train_state(jax.random.key(0), cfg))
sh = part.param_shardings(shape["params"])

wq = sh["blocks"]["b0_attn"]["wq"].spec
assert wq == P(None, None, "model"), wq
embed = sh["embed"].spec
assert embed in (P("model", None), P(None, "model")), embed

# divisibility guard: kv dim for scaled config
kvd = cfg.kv_dim
wk = sh["blocks"]["b0_attn"]["wk"].spec
if kvd % 4 == 0:
    assert wk == P(None, None, "model"), wk
else:
    assert wk == P(None, None, None), wk

# MoE expert parallelism
mcfg = get_config("olmoe-1b-7b").scaled_down(layers=2, width_div=8, vocab=512)
mpart = Partitioner(mcfg, mesh)
mshape = jax.eval_shape(lambda: init_train_state(jax.random.key(0), mcfg))
msh = mpart.param_shardings(mshape["params"])
wg = msh["blocks"]["b0_attn"]["moe"]["wg"].spec
assert wg[1] == "model", wg          # experts sharded (EP)
router = msh["blocks"]["b0_attn"]["moe"]["router"].spec
assert "model" not in router, router # router replicated

# ZeRO-1 moments pick up the data axis
opt_sh = mpart.opt_shardings(mshape["params"])
mu_wq = opt_sh["mu"]["blocks"]["b0_attn"]["wq"].spec
assert "data" in mu_wq, mu_wq

# FSDP: params pick up data axis but never on the stacked dim 0
fpart = Partitioner(cfg, mesh, fsdp=True)
fsh = fpart.param_shardings(shape["params"])
fwq = fsh["blocks"]["b0_attn"]["wq"].spec
assert fwq[0] is None and "data" in fwq, fwq

# norms replicated
assert "model" not in sh["final_norm"]["scale"].spec

print("SHARDING-OK")
"""


def test_partition_rules():
    env = {**os.environ, "PYTHONPATH": "src"}
    p = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "SHARDING-OK" in p.stdout, p.stdout + p.stderr[-3000:]
