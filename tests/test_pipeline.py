"""Streaming snapshot pipeline: async semantics, backpressure, config
hygiene, streaming engine API."""

import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CheckpointManager, EngineConfig, SaveSpec,
                        TieredTransferEngine, make_cr_engine)
from repro.core.aggregation import Strategy
from repro.core.buffers import PAGE, BufferPool, StageBudget
from repro.core.engines import SaveItem, spec_of
from repro.core import quant_codec


def _state(scale=1):
    return {
        "params": {"w": jnp.arange(256 * 256 * scale,
                                   dtype=jnp.float32).reshape(256, -1),
                   "b": jnp.full((64,), 0.5, jnp.bfloat16)},
        "data": {"cursor": np.arange(1024, dtype=np.int64)},  # mutable source
        "step": 7,
    }


# ------------------------------------------------------------ async semantics
def test_async_error_surfaces_on_wait(tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, async_save=True) as mgr:
        def boom(*a, **kw):
            raise IOError("disk gone")
        mgr.engine.begin_save = boom
        mgr.save(1, state)          # returns: submission happened
        with pytest.raises(RuntimeError, match="async checkpoint flush"):
            mgr.wait()
        # error must not be sticky
        del mgr.engine.begin_save   # restore the class method
        mgr.save(2, state)
        mgr.wait()
        assert mgr.latest_step() == 2


def test_async_error_surfaces_on_next_save(tmp_ckpt_dir):
    state = _state()
    mgr = CheckpointManager(tmp_ckpt_dir, async_save=True)
    mgr.engine.begin_save = lambda *a, **kw: (_ for _ in ()).throw(
        IOError("enospc"))
    mgr.save(1, state)
    with pytest.raises(RuntimeError, match="async checkpoint flush"):
        mgr.save(2, state)          # save() waits on the in-flight pipeline
    del mgr.engine.begin_save
    mgr.close()


def test_mutation_after_async_save_restores_pre_mutation(tmp_ckpt_dir):
    """The pipeline snapshot must be stable against caller-side mutation:
    numpy sources are eagerly copied; JAX sources are immutable refs."""
    state = _state(scale=4)
    want_w = np.asarray(state["params"]["w"]).copy()
    want_cursor = state["data"]["cursor"].copy()
    with CheckpointManager(tmp_ckpt_dir, async_save=True) as mgr:
        mgr.save(1, state)
        # overlap: mutate the numpy leaf IN PLACE and rebind the jax leaf
        state["data"]["cursor"][:] = -1
        state["params"]["w"] = state["params"]["w"] * 0.0
        mgr.wait()
        r = mgr.restore(step=1)
    np.testing.assert_array_equal(r["params"]["w"], want_w)
    np.testing.assert_array_equal(r["data"]["cursor"], want_cursor)


def test_wait_snapshotted_allows_donation_style_deletion(tmp_ckpt_dir):
    """After wait_snapshotted() the pipeline owns every byte: deleting the
    source arrays (what jit donation does) must not corrupt the save."""
    state = _state(scale=4)
    want_w = np.asarray(state["params"]["w"]).copy()
    with CheckpointManager(tmp_ckpt_dir, async_save=True) as mgr:
        mgr.save(1, state)
        mgr.wait_snapshotted()
        state["params"]["w"].delete()   # simulate buffer donation
        state.clear()
        mgr.wait()
        r = mgr.restore(step=1)
    np.testing.assert_array_equal(r["params"]["w"], want_w)


def test_pipelined_blocking_below_end_to_end(tmp_ckpt_dir):
    state = _state(scale=8)
    with CheckpointManager(tmp_ckpt_dir, async_save=True) as mgr:
        m = mgr.save(1, state)
        assert m.mode == "pipelined"
        mgr.wait()
        assert m.end_to_end_seconds > 0
        assert m.blocking_seconds <= m.end_to_end_seconds


# --------------------------------------------------------------- backpressure
def test_stream_backpressure_caps_staged_bytes(tmp_path):
    budget = 2 << 20
    eng = make_cr_engine("aggregated", EngineConfig(
        chunk_bytes=1 << 20, coalesce_bytes=1 << 20, inflight_bytes=budget,
        strategy=Strategy.FILE_PER_PROCESS))
    rng = np.random.default_rng(1)
    items = [SaveItem(f"t{i}", rng.integers(0, 256, (1 << 20,), np.uint8),
                      "uint8", (1 << 20,), ((0, 1 << 20),))
             for i in range(8)]
    items.append(SaveItem("big", rng.integers(0, 256, (6 << 20,), np.uint8),
                          "uint8", (6 << 20,), ((0, 6 << 20),)))
    eng.save(str(tmp_path / "bp"), items, step=1)
    s = eng.last_save_stats
    assert 0 < s.peak_staged_bytes <= budget
    eng.close()


def test_tiered_backpressure_caps_staged_bytes(tmp_path):
    src = tmp_path / "src"
    src.mkdir()
    files = []
    for i in range(4):
        p = src / f"f{i}.bin"
        p.write_bytes(os.urandom(3 << 20))
        files.append((str(p), str(tmp_path / "dst" / f"f{i}.bin")))
    budget = 2 << 20
    eng = TieredTransferEngine("threadpool", chunk_bytes=1 << 20,
                               inflight_bytes=budget)
    ts = eng.transfer(files)
    assert ts.bytes == 4 * (3 << 20)
    assert 0 < ts.peak_staged_bytes <= budget
    eng.close()
    for _src, dst in files:
        assert os.path.getsize(dst) == 3 << 20


def test_pool_acquire_blocks_on_budget():
    pool = BufferPool()
    a = pool.acquire(PAGE, budget=2 * PAGE)
    b = pool.acquire(PAGE, budget=2 * PAGE)
    with pytest.raises(TimeoutError):
        pool.acquire(PAGE, budget=2 * PAGE, timeout=0.05)
    t = threading.Timer(0.05, a.release)
    t.start()
    c = pool.acquire(PAGE, budget=2 * PAGE, timeout=5.0)  # unblocked by put
    t.join()
    for buf in (b, c):
        buf.release()
    assert pool.stats.peak_outstanding_bytes <= 2 * PAGE
    pool.drain()


def test_pool_acquire_oversized_grants_when_idle():
    pool = BufferPool()
    buf = pool.acquire(8 * PAGE, budget=PAGE)   # over budget but nothing out
    buf.release()
    pool.drain()


def test_stage_budget_accounting():
    b = StageBudget(100)
    assert b.admits(100) and b.admits(1000)     # empty: always grants
    b.add(60)
    assert b.admits(40) and not b.admits(41)
    b.sub(60)
    assert b.in_flight == 0 and b.peak == 60
    assert StageBudget(None).admits(1 << 40)    # unbounded


# ------------------------------------------------------------- config hygiene
def test_engine_config_not_aliased(tmp_path):
    cfg = EngineConfig()
    m1 = CheckpointManager(str(tmp_path / "a"), config=cfg, verify_crc=True)
    m2 = CheckpointManager(str(tmp_path / "b"), config=cfg, verify_crc=False)
    assert cfg.checksum is False          # caller's object untouched
    assert cfg.backend == "auto"          # not normalized in place
    assert m1.config.checksum is True and m2.config.checksum is False
    m1.close()
    m2.close()


def test_engine_subclasses_do_not_mutate_config():
    cfg = EngineConfig(direct=True)
    eng = make_cr_engine("datastates", cfg)
    assert cfg.direct is True and cfg.strategy is Strategy.SINGLE_FILE
    assert eng.config.direct is False
    eng.close()


def test_normalized_is_pure():
    cfg = EngineConfig(backend="auto", strategy="single_file")
    n = cfg.normalized()
    assert cfg.backend == "auto" and cfg.strategy == "single_file"
    assert n.backend in ("uring", "threadpool")
    assert n.strategy is Strategy.SINGLE_FILE


# ------------------------------------------------------- streaming engine API
@pytest.mark.parametrize("strategy", list(Strategy))
def test_streaming_api_roundtrip(strategy, tmp_path, rng):
    from repro.core.engines import ReadReq
    eng = make_cr_engine("aggregated", EngineConfig(
        strategy=strategy, chunk_bytes=1 << 20, coalesce_bytes=1 << 21))
    sizes = [3 << 20, 777, 65536, 0, 4096]
    items = [SaveItem(f"t{i}", rng.integers(0, 256, (n,), np.uint8)
                      if n else np.zeros((0,), np.uint8),
                      "uint8", (n,), ((0, n),)) for i, n in enumerate(sizes)]
    d = str(tmp_path / "stream")
    stream = eng.begin_save(d, [spec_of(it) for it in items], step=3)
    for it in reversed(items):      # any key order is valid
        stream.put(it.key, it.data)
    m = stream.end_save()
    reqs = [ReadReq(k, r.shards[0].path, r.shards[0].offset,
                    r.shards[0].nbytes) for k, r in m.tensors.items()]
    out = eng.read(d, reqs)
    for it in items:
        assert out[it.key].tobytes() == bytes(memoryview(it.data)), it.key
    eng.close()


def test_streaming_chunked_partial_puts(tmp_path, rng):
    from repro.core.engines import ReadReq
    eng = make_cr_engine("aggregated",
                         EngineConfig(chunk_bytes=1 << 20, align=4096))
    data = rng.integers(0, 256, (3 << 20,), np.uint8)
    d = str(tmp_path / "chunked")
    stream = eng.begin_save(d, [SaveSpec("big", data.nbytes, "uint8",
                                         (data.nbytes,), ((0, data.nbytes),))])
    half = 2 << 20                  # align-granular split
    stream.put("big", data[:half], pos=0)
    stream.put("big", data[half:], pos=half)
    m = stream.end_save()
    sh = m.tensors["big"].shards[0]
    out = eng.read(d, [ReadReq("big", sh.path, sh.offset, sh.nbytes)])
    assert out["big"].tobytes() == data.tobytes()
    eng.close()


def test_end_save_with_missing_put_raises(tmp_path):
    eng = make_cr_engine("aggregated", EngineConfig())
    stream = eng.begin_save(str(tmp_path / "x"),
                            [SaveSpec("a", 100, "uint8", (100,), ((0, 100),))])
    with pytest.raises(RuntimeError, match="unfilled"):
        stream.end_save()
    eng.close()


# ---------------------------------------------------------------- quant moves
def test_packed_nbytes_matches_pack():
    for n in (1, 511, 512, 513, 512 * 8, 512 * 8 + 1, 100_000):
        arr = np.random.default_rng(n).normal(size=(n,)).astype(np.float32)
        assert len(quant_codec.pack(arr)) == quant_codec.packed_nbytes(n)


def test_quant_pack_runs_off_blocking_path(tmp_ckpt_dir, monkeypatch):
    """With async_save, pack() must execute on the pipeline worker, not on
    the caller thread — quantization stays off the training loop."""
    pack_threads = []
    real_pack = quant_codec.pack

    def spy(arr):
        pack_threads.append(threading.current_thread().name)
        return real_pack(arr)

    monkeypatch.setattr(quant_codec, "pack", spy)
    state = {"opt": {"mu": jax.random.normal(jax.random.key(0), (512, 512))},
             "params": {"w": jnp.ones((128,), jnp.float32)}}
    with CheckpointManager(tmp_ckpt_dir, async_save=True,
                           quantize_prefixes=("opt/mu",)) as mgr:
        mgr.save(1, state)
        mgr.wait()
        r = mgr.restore(state_template=state)
    assert pack_threads and all(t.startswith("ckpt-pipeline")
                                for t in pack_threads)
    a, b = np.asarray(r["opt"]["mu"]), np.asarray(state["opt"]["mu"])
    assert np.max(np.abs(a - b)) / np.max(np.abs(b)) < 0.01


# ------------------------------------------------------------- mode parity
@pytest.mark.parametrize("streaming,async_", [(True, False), (True, True),
                                              (False, True)])
def test_modes_roundtrip_identically(streaming, async_, tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, async_save=async_,
                           streaming=streaming) as mgr:
        mgr.save(1, state)
        mgr.wait()
        r = mgr.restore(state_template=state)
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    np.testing.assert_array_equal(r["data"]["cursor"],
                                  state["data"]["cursor"])
