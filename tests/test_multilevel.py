"""Multi-level checkpointing: flush, node-loss recovery, hedged stragglers,
tiered transfers (extent hedging, restore prefetch, per-tier stats)."""

import os
import shutil
import subprocess
import sys
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core import Manifest, MultiLevelCheckpointer
from repro.core.aggregation import Extent
from repro.core.io_engine import OP_WRITE, ThreadPoolEngine
from repro.core.tiered import RestorePrefetcher, TieredTransferEngine


def _state():
    return {"w": jnp.arange(8192, dtype=jnp.float32), "step": 3}


def test_flush_and_restore(tmp_path):
    local, remote = str(tmp_path / "l"), str(tmp_path / "r")
    with MultiLevelCheckpointer(local, remote) as ml:
        ml.save(10, _state())
        ml.wait()
        assert ml.last_flush_stats.files >= 2
        assert os.path.exists(os.path.join(remote, "step_00000010",
                                           "manifest.json"))
        r = ml.restore(state_template=_state())
    np.testing.assert_array_equal(np.asarray(r["w"]),
                                  np.asarray(_state()["w"]))


def test_node_loss_recovery(tmp_path):
    local, remote = str(tmp_path / "l"), str(tmp_path / "r")
    with MultiLevelCheckpointer(local, remote) as ml:
        ml.save(10, _state())
        ml.wait()
        shutil.rmtree(local)
        os.makedirs(local)
        r = ml.restore(state_template=_state())
        np.testing.assert_array_equal(np.asarray(r["w"]),
                                      np.asarray(_state()["w"]))


def test_hedged_straggler(tmp_path):
    """First copy of one file hangs; the hedge must win and flush completes."""
    local, remote = str(tmp_path / "l"), str(tmp_path / "r")
    stall_once = {"armed": True}

    def slow_copy(src, dst):
        if src.endswith(".bin") and stall_once["armed"] and \
                not dst.endswith(".hedge"):
            stall_once["armed"] = False
            time.sleep(8)          # straggler: slower than hedge deadline
        with open(src, "rb") as fi, open(dst + ".t", "wb") as fo:
            fo.write(fi.read())
        os.replace(dst + ".t", dst)

    with MultiLevelCheckpointer(local, remote, hedge_after_s=0.5,
                                min_bw_bytes_s=1e12,
                                copy_fn=slow_copy) as ml:
        ml.save(5, _state())
        ml.wait()
        assert ml.last_flush_stats.hedged >= 1
        assert os.path.exists(os.path.join(remote, "step_00000005",
                                           "manifest.json"))
        # remote copy must be complete & valid despite the straggler
        shutil.rmtree(local)
        os.makedirs(local)
        r = ml.restore(state_template=_state())
        np.testing.assert_array_equal(np.asarray(r["w"]),
                                      np.asarray(_state()["w"]))


# --------------------------------------------------------- tiered transfers
class _StallFirstWrite(ThreadPoolEngine):
    """Injects one slow write — an extent-level straggler."""

    def __init__(self, stall_s: float):
        super().__init__(workers=4)
        self.stall_s = stall_s
        self._lock = threading.Lock()
        self._armed = True

    def _do(self, r):
        if r.op == OP_WRITE and r.nbytes >= 4096:
            with self._lock:
                fire, self._armed = self._armed, False
            if fire:
                time.sleep(self.stall_s)
        return ThreadPoolEngine._do(r)


def test_extent_hedging(tmp_path):
    """A stalled extent write is hedged; the duplicate wins and the
    destination bytes are exact."""
    src = tmp_path / "src.bin"
    dst = tmp_path / "dst.bin"
    data = np.random.default_rng(0).integers(
        0, 256, size=(3 << 20) + 123, dtype=np.uint8).tobytes()
    src.write_bytes(data)

    def factory(role):
        return _StallFirstWrite(2.0) if role == "write" \
            else ThreadPoolEngine(workers=4)

    eng = TieredTransferEngine(engine_factory=factory, chunk_bytes=1 << 20,
                               hedge_after_s=0.3, min_bw_bytes_s=1e15)
    stats = eng.transfer([(str(src), str(dst))])
    assert stats.hedged >= 1
    assert stats.hedge_wins >= 1
    assert stats.extents >= 3          # 1 MB chunking of a >3 MB file
    assert dst.read_bytes() == data
    eng.close()


class _FailPrimaryAfterHedge(ThreadPoolEngine):
    """Primary write blocks until its hedge arrives, then fails — the
    transfer must tolerate the loser's error because the hedge wins."""

    def __init__(self):
        super().__init__(workers=4)
        self.lk = threading.Lock()
        self.seen = set()
        self.hedge_arrived = threading.Event()

    def _do(self, r):
        if r.op == OP_WRITE and r.nbytes >= 4096:
            with self.lk:
                first = r.offset not in self.seen
                self.seen.add(r.offset)
            if first:
                assert self.hedge_arrived.wait(timeout=10)
                raise OSError(5, "injected EIO on the straggling primary")
            self.hedge_arrived.set()
        return ThreadPoolEngine._do(r)


def test_failed_loser_tolerated(tmp_path):
    src, dst = tmp_path / "s.bin", tmp_path / "d.bin"
    data = np.random.default_rng(3).integers(
        0, 256, size=1 << 20, dtype=np.uint8).tobytes()
    src.write_bytes(data)
    eng = TieredTransferEngine(
        engine_factory=lambda role: _FailPrimaryAfterHedge()
        if role == "write" else ThreadPoolEngine(workers=4),
        chunk_bytes=1 << 20, hedge_after_s=0.2, min_bw_bytes_s=1e15)
    stats = eng.transfer([(str(src), str(dst))])
    assert stats.hedged == 1
    assert dst.read_bytes() == data
    eng.close()


class _AlwaysFailWrite(ThreadPoolEngine):
    def __init__(self):
        super().__init__(workers=4)

    def _do(self, r):
        if r.op == OP_WRITE:
            raise OSError(28, "injected ENOSPC")
        return ThreadPoolEngine._do(r)


class _ReArmStallWrite(ThreadPoolEngine):
    """Stalls one write per ``arm()`` — a straggler on every transfer."""

    def __init__(self):
        super().__init__(workers=4)
        self.lk = threading.Lock()
        self.armed = True          # engines are built lazily mid-transfer

    def arm(self):
        with self.lk:
            self.armed = True

    def _do(self, r):
        if r.op == OP_WRITE and r.nbytes >= 4096:
            with self.lk:
                fire, self.armed = self.armed, False
            if fire:
                time.sleep(0.7)
        return ThreadPoolEngine._do(r)


def test_hedge_loser_engines_pooled(tmp_path):
    """The janitor drains hedge losers and parks the engine pair for
    reuse: repeated hedged transfers must not grow the engine population
    monotonically (each used to leak a live pair to a janitor thread)."""
    stallers = []

    def factory(role):
        if role == "write":
            e = _ReArmStallWrite()
            stallers.append(e)
            return e
        return ThreadPoolEngine(workers=4)

    data = np.random.default_rng(1).integers(
        0, 256, size=1 << 20, dtype=np.uint8).tobytes()
    src = tmp_path / "s.bin"
    src.write_bytes(data)
    eng = TieredTransferEngine(engine_factory=factory, chunk_bytes=1 << 20,
                               hedge_after_s=0.2, min_bw_bytes_s=1e15)
    for i in range(3):
        for s in stallers:
            s.arm()
        dst = tmp_path / f"d{i}.bin"
        stats = eng.transfer([(str(src), str(dst))])
        assert stats.hedged >= 1
        assert dst.read_bytes() == data
        # wait for the janitor to drain the straggler and park the pair
        deadline = time.perf_counter() + 10
        while time.perf_counter() < deadline and not eng._engine_pool:
            time.sleep(0.05)
        assert eng._engine_pool, "janitor did not park the drained pair"
    assert eng.engines_built == 2, \
        f"engine population grew: {eng.engines_built} built for 3 transfers"
    eng.close()


def test_all_attempts_failed_raises(tmp_path):
    """When every attempt for an extent fails, the transfer must fail."""
    src = tmp_path / "s.bin"
    src.write_bytes(b"z" * 8192)
    eng = TieredTransferEngine(
        engine_factory=lambda role: _AlwaysFailWrite()
        if role == "write" else ThreadPoolEngine(workers=4))
    import pytest
    with pytest.raises(OSError):
        eng.transfer([(str(src), str(tmp_path / "d.bin"))])
    eng.close()


def test_flush_stats_accounting(tmp_path):
    """Tiered flush reports logical bytes, extents, and per-tier engine
    stats that attribute bandwidth to each side of the transfer."""
    local, remote = str(tmp_path / "l"), str(tmp_path / "r")
    with MultiLevelCheckpointer(local, remote) as ml:
        ml.save(12, _state())
        ml.wait()
        s = ml.last_flush_stats
        src_dir = os.path.join(local, "step_00000012")
        sizes = [os.path.getsize(os.path.join(root, n))
                 for root, _d, names in os.walk(src_dir) for n in names]
        assert s.files == len(sizes)
        assert s.bytes == sum(sizes)
        assert s.extents >= s.files
        assert s.backend in ("uring", "threadpool", "posix")
        assert s.per_tier["source"]["bytes_read"] == sum(sizes)
        assert s.per_tier["destination"]["bytes_written"] == sum(sizes)
        assert s.gbps > 0 and s.read_gbps > 0 and s.write_gbps > 0


def test_prefetch_promotes_full_restore(tmp_path):
    """A full prefetch restore commits the step back at level 0 with no
    staging leftovers."""
    local, remote = str(tmp_path / "l"), str(tmp_path / "r")
    with MultiLevelCheckpointer(local, remote) as ml:
        ml.save(9, _state())
        ml.wait()
        shutil.rmtree(local)
        os.makedirs(local)
        r = ml.restore(state_template=_state())
        np.testing.assert_array_equal(np.asarray(r["w"]),
                                      np.asarray(_state()["w"]))
        assert os.path.exists(os.path.join(local, "step_00000009",
                                           "manifest.json"))
        assert not [n for n in os.listdir(local) if ".tmp" in n]
        # second restore must be served from level 0 (no staging dir made)
        r2 = ml.restore(state_template=_state())
        np.testing.assert_array_equal(np.asarray(r2["w"]),
                                      np.asarray(_state()["w"]))


def test_partial_prefetch_stays_staged(tmp_path):
    """Fetching a subset of extents stages correct bytes but must NOT
    commit the step at level 0 (partial data is never restorable)."""
    local, remote = str(tmp_path / "l"), str(tmp_path / "r")
    with MultiLevelCheckpointer(local, remote) as ml:
        ml.save(4, _state())
        ml.wait()
    scratch = str(tmp_path / "scratch")
    os.makedirs(scratch)
    pf = RestorePrefetcher(remote)
    staged = pf.begin(4, scratch)
    assert staged is not None and os.path.exists(
        os.path.join(staged, "manifest.json"))
    m = Manifest.load(os.path.join(remote, "step_00000004"))
    rec = next(iter(m.tensors.values()))
    sh = rec.shards[0]
    n = min(4096, sh.nbytes)
    pf.fetch_extents(staged, [Extent(rec.key, sh.path, sh.offset, n)])
    with open(os.path.join(staged, sh.path), "rb") as f:
        f.seek(sh.offset)
        got = f.read(n)
    with open(os.path.join(remote, "step_00000004", sh.path), "rb") as f:
        f.seek(sh.offset)
        assert got == f.read(n)
    final = os.path.join(scratch, "step_00000004")
    assert pf.finish(staged, final) is False
    assert not os.path.exists(staged) and not os.path.exists(final)
    pf.close()


ELASTIC_ML = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, shutil, sys
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import MultiLevelCheckpointer
devs = jax.devices()
mesh_a = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))
mesh_b = Mesh(np.array(devs).reshape(4, 2), ("data", "model"))
w = jnp.arange(64 * 128, dtype=jnp.float32).reshape(64, 128)
state = {"w": jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))}
local, remote = sys.argv[1], sys.argv[2]
with MultiLevelCheckpointer(local, remote) as ml:
    ml.save(1, state)
    ml.wait()
    shutil.rmtree(local)           # node loss
    os.makedirs(local)
    tmpl = {"w": jax.ShapeDtypeStruct(w.shape, w.dtype,
            sharding=NamedSharding(mesh_b, P("model", "data")))}
    r = ml.restore(state_template=tmpl)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(w))
print("ELASTIC-ML-OK")
"""


def test_prefetch_elastic_reshard_multidevice(tmp_path):
    """Save on a 2x4 mesh, lose the node, restore on a 4x2 mesh — the
    level-1 prefetch path must feed the resharded read plan exactly."""
    env = {**os.environ, "PYTHONPATH": "src"}
    p = subprocess.run(
        [sys.executable, "-c", ELASTIC_ML,
         str(tmp_path / "l"), str(tmp_path / "r")],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=300)
    assert "ELASTIC-ML-OK" in p.stdout, p.stderr[-2000:]
