"""Multi-level checkpointing: flush, node-loss recovery, hedged stragglers."""

import os
import shutil
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core import MultiLevelCheckpointer


def _state():
    return {"w": jnp.arange(8192, dtype=jnp.float32), "step": 3}


def test_flush_and_restore(tmp_path):
    local, remote = str(tmp_path / "l"), str(tmp_path / "r")
    with MultiLevelCheckpointer(local, remote) as ml:
        ml.save(10, _state())
        ml.wait()
        assert ml.last_flush_stats.files >= 2
        assert os.path.exists(os.path.join(remote, "step_00000010",
                                           "manifest.json"))
        r = ml.restore(state_template=_state())
    np.testing.assert_array_equal(np.asarray(r["w"]),
                                  np.asarray(_state()["w"]))


def test_node_loss_recovery(tmp_path):
    local, remote = str(tmp_path / "l"), str(tmp_path / "r")
    with MultiLevelCheckpointer(local, remote) as ml:
        ml.save(10, _state())
        ml.wait()
        shutil.rmtree(local)
        os.makedirs(local)
        r = ml.restore(state_template=_state())
        np.testing.assert_array_equal(np.asarray(r["w"]),
                                      np.asarray(_state()["w"]))


def test_hedged_straggler(tmp_path):
    """First copy of one file hangs; the hedge must win and flush completes."""
    local, remote = str(tmp_path / "l"), str(tmp_path / "r")
    stall_once = {"armed": True}

    def slow_copy(src, dst):
        if src.endswith(".bin") and stall_once["armed"] and \
                not dst.endswith(".hedge"):
            stall_once["armed"] = False
            time.sleep(8)          # straggler: slower than hedge deadline
        with open(src, "rb") as fi, open(dst + ".t", "wb") as fo:
            fo.write(fi.read())
        os.replace(dst + ".t", dst)

    with MultiLevelCheckpointer(local, remote, hedge_after_s=0.5,
                                min_bw_bytes_s=1e12,
                                copy_fn=slow_copy) as ml:
        ml.save(5, _state())
        ml.wait()
        assert ml.last_flush_stats.hedged >= 1
        assert os.path.exists(os.path.join(remote, "step_00000005",
                                           "manifest.json"))
        # remote copy must be complete & valid despite the straggler
        shutil.rmtree(local)
        os.makedirs(local)
        r = ml.restore(state_template=_state())
        np.testing.assert_array_equal(np.asarray(r["w"]),
                                      np.asarray(_state()["w"]))
