"""C/R engines: byte-exact roundtrips on heterogeneous LLM-like layouts."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container without hypothesis
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.engines import (ENGINES, EngineConfig, ReadReq, SaveItem,
                                make_cr_engine)
from repro.core.aggregation import Strategy
from repro.core.uring import probe_io_uring

BACKENDS = ["threadpool", "posix"] + (["uring"] if probe_io_uring() else [])


def _items(rng, sizes):
    items = []
    for i, n in enumerate(sizes):
        a = rng.integers(0, 256, size=(n,), dtype=np.uint8) if n else \
            np.zeros((0,), np.uint8)
        items.append(SaveItem(f"t/{i}", a, "uint8", (n,), ((0, n),)))
    items.append(SaveItem("__lean__", b"lean-bytes", is_blob=True))
    return items


def _roundtrip(engine_name, items, tmp_path, **cfg_kw):
    cfg = EngineConfig(chunk_bytes=1 << 20, coalesce_bytes=1 << 21, **cfg_kw)
    eng = make_cr_engine(engine_name, cfg)
    d = str(tmp_path / engine_name)
    m = eng.save(d, items, step=1)
    reqs = []
    for key, rec in m.tensors.items():
        sh = rec.shards[0]
        reqs.append(ReadReq(key, sh.path, sh.offset, sh.nbytes, obj=key))
    for key, b in m.blobs.items():
        reqs.append(ReadReq(key, b.path, b.offset, b.nbytes, obj=key))
    out = eng.read(d, reqs)
    eng.close()
    for it in items:
        want = bytes(memoryview(it.data)) if not isinstance(it.data, bytes) \
            else it.data
        assert out[it.key].tobytes() == want, it.key
    return m, eng


@pytest.mark.parametrize("engine", list(ENGINES))
def test_roundtrip_heterogeneous(engine, tmp_path, rng):
    sizes = [3 << 20, 1 << 20] + [int(rng.integers(1, 99999))
                                  for _ in range(30)]
    _roundtrip(engine, _items(rng, sizes), tmp_path)


@pytest.mark.parametrize("engine", ["aggregated", "datastates"])
@pytest.mark.parametrize("strategy", list(Strategy))
def test_roundtrip_strategies(engine, strategy, tmp_path, rng):
    items = _items(rng, [1 << 18] * 3 + [777, 4096, 12345])
    _roundtrip(engine, items, tmp_path, strategy=strategy)


@pytest.mark.parametrize("engine", ["aggregated"])
@pytest.mark.parametrize("direct", [True, False])
@pytest.mark.parametrize("backend", BACKENDS)
def test_aggregated_backends(engine, direct, backend, tmp_path, rng):
    items = _items(rng, [1 << 19, 100, 5000, 65536])
    _roundtrip(engine, items, tmp_path, direct=direct, backend=backend)


@settings(max_examples=10, deadline=None)
@given(sizes=st.lists(st.integers(0, 1 << 18), min_size=1, max_size=20),
       engine=st.sampled_from(["aggregated", "datastates"]))
def test_roundtrip_property(sizes, engine, tmp_path_factory):
    """Property: any object-size multiset roundtrips byte-exactly."""
    rng = np.random.default_rng(sum(sizes) + len(sizes))
    tmp = tmp_path_factory.mktemp(f"prop_{engine}")
    _roundtrip(engine, _items(rng, sizes), tmp)


def test_zero_copy_stats(tmp_path, rng):
    items = _items(rng, [1 << 20] * 4)
    m, eng = _roundtrip("aggregated", items, tmp_path)
    s = eng.last_save_stats
    assert s.logical_bytes == sum(i.nbytes for i in items)
    assert s.io_requests >= 1
    assert s.gbps > 0


def test_file_counts_match_design(tmp_path, rng):
    """snapshot = chunk-per-file; aggregated single_file = 1 data file."""
    items = _items(rng, [3 << 20, 100])
    m, eng = _roundtrip("snapshot", items, tmp_path)
    assert eng.last_save_stats.files == 3 + 1 + 1  # 3 chunks + 1 + blob
    m2, eng2 = _roundtrip("aggregated", items, tmp_path)
    assert eng2.last_save_stats.files == 1
