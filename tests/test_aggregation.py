"""Aggregation planners: layout invariants under all three strategies."""

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container without hypothesis
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.aggregation import (ObjectSpec, Strategy, coalesce,
                                    plan_layout, rank_padded_total,
                                    single_file_base_offsets)

ALIGN = 4096


def _objects(sizes):
    return [ObjectSpec(f"t{i}", n) for i, n in enumerate(sizes)]


sizes_strategy = st.lists(st.integers(0, 1 << 22), min_size=1, max_size=40)


@settings(max_examples=40, deadline=None)
@given(sizes=sizes_strategy,
       strategy=st.sampled_from(list(Strategy)))
def test_plan_covers_all_objects_without_overlap(sizes, strategy):
    objs = _objects(sizes)
    totals = [rank_padded_total(objs, ALIGN)]
    plan = plan_layout(objs, strategy, rank=0, rank_totals=totals,
                       align=ALIGN)
    assert {e.key for e in plan.extents} == {o.key for o in objs}
    # per-file extents must be aligned and non-overlapping
    for path, extents in plan.by_file().items():
        end = 0
        for e in extents:
            assert e.offset % ALIGN == 0
            assert e.offset >= end
            end = e.offset + e.nbytes
            assert end <= plan.file_sizes[path] or e.nbytes == 0
    by_key = {e.key: e for e in plan.extents}
    for o in objs:
        assert by_key[o.key].nbytes == o.nbytes


@settings(max_examples=40, deadline=None)
@given(rank_sizes=st.lists(sizes_strategy, min_size=2, max_size=5))
def test_single_file_ranks_disjoint(rank_sizes):
    """Property: ranks' extents in the shared file never overlap."""
    all_objs = [_objects(s) for s in rank_sizes]
    totals = [rank_padded_total(o, ALIGN) for o in all_objs]
    spans = []
    for r, objs in enumerate(all_objs):
        plan = plan_layout(objs, Strategy.SINGLE_FILE, rank=r,
                           rank_totals=totals, align=ALIGN)
        lo = min((e.offset for e in plan.extents), default=0)
        hi = max((e.offset + e.nbytes for e in plan.extents), default=0)
        spans.append((lo, hi))
    bases = single_file_base_offsets(totals, ALIGN)
    for r, (lo, hi) in enumerate(spans):
        assert lo >= bases[r]
        if r + 1 < len(bases):
            assert hi <= bases[r + 1]


def test_file_counts_per_strategy():
    objs = _objects([100, 200, 300])
    assert plan_layout(objs, Strategy.FILE_PER_TENSOR).num_files == 3
    assert plan_layout(objs, Strategy.FILE_PER_PROCESS).num_files == 1
    assert plan_layout(objs, Strategy.SINGLE_FILE, rank=0,
                       rank_totals=[rank_padded_total(objs)]).num_files == 1


@settings(max_examples=40, deadline=None)
@given(sizes=sizes_strategy, threshold=st.sampled_from(
    [1 << 12, 1 << 16, 1 << 20, 1 << 24]))
def test_coalesce_groups_are_contiguous(sizes, threshold):
    """Property: every coalesced group is file-contiguous and preserves all
    extents exactly once."""
    objs = _objects(sizes)
    plan = plan_layout(objs, Strategy.FILE_PER_PROCESS, align=ALIGN)
    groups = coalesce(plan.extents, threshold, ALIGN)
    flat = [e for g in groups for e in g]
    assert sorted(e.key for e in flat) == sorted(e.key for e in plan.extents)
    for g in groups:
        for a, b in zip(g, g[1:]):
            assert b.path == a.path
            pad = -a.nbytes % ALIGN
            assert b.offset == a.offset + a.nbytes + pad


def test_single_file_requires_totals():
    with pytest.raises(ValueError):
        plan_layout(_objects([10]), Strategy.SINGLE_FILE, rank=0)
