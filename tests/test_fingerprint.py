"""Property + compat tests for the fp128 chunk fingerprint (DESIGN.md §14).

The load-bearing claim is *bit-identity across implementations*: the
Pallas kernel (run in interpret mode here — no TPU in CI), the jitted
XLA oracle, and the numpy host fallback must produce the same digest for
the same bytes, so the delta planner's dirty set never depends on WHERE
the fingerprint ran. Plus the digest-kind compat contract: flipping the
digest engine between saves degrades to a full write — never a wrong
delta — and blake2b manifests stay readable by pre-fp128 readers.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import CheckpointManager, EngineConfig
from repro.core import delta as delta_mod
from repro.core.manifest import (DIGEST_BLAKE2B, DIGEST_FP128,
                                 FORMAT_VERSION, Manifest)
from repro.kernels import fingerprint as fpk

DTYPES = ("float32", "int16", "uint8", "int8")


def _cfg():
    return EngineConfig(backend="posix", strategy="single_file",
                        direct=False)


def _payload(nbytes: int, seed: int) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        0, 256, nbytes, dtype=np.int64).astype(np.uint8)


def _all_impl_hexes(arr: np.ndarray, chunk_bytes: int) -> dict:
    """Digest the same byte image through every implementation."""
    flat = jnp.asarray(arr)
    host = fpk.digests_hex(
        fpk.fingerprint_chunks_host(arr.reshape(-1).view(np.uint8),
                                    chunk_bytes))
    oracle = fpk.digests_hex(fpk._fp_device_jit(flat, chunk_bytes))
    lanes, lens = fpk._fp_prep_jit(flat, chunk_bytes)
    kernel = fpk.digests_hex(
        np.asarray(fpk.fingerprint_chunks(lanes, lens, interpret=True)))
    return {"host": host, "oracle": oracle, "interpret-kernel": kernel}


# ------------------------------------------------- implementation bit-identity
@settings(max_examples=10, deadline=None)
@given(chunk=st.sampled_from([64, 256, 1024, 4096]),
       dtype=st.sampled_from(DTYPES),
       n=st.integers(min_value=1, max_value=6000),
       seed=st.integers(min_value=0, max_value=2 ** 31))
def test_fingerprint_impls_bit_identical(chunk, dtype, n, seed):
    """Host / XLA oracle / Pallas-interpret digests agree word for word
    over random sizes (ragged tails included), grids and dtypes."""
    arr = _payload(n * np.dtype(dtype).itemsize, seed).view(dtype)
    impls = _all_impl_hexes(arr, chunk)
    assert impls["host"] == impls["oracle"] == impls["interpret-kernel"]
    # and the ragged tail folds the true byte length, not the padded one
    assert len(impls["host"]) == -(-arr.nbytes // chunk)


@settings(max_examples=10, deadline=None)
@given(chunk=st.sampled_from([256, 1024]),
       dtype=st.sampled_from(DTYPES),
       nchunks=st.integers(min_value=2, max_value=12),
       seed=st.integers(min_value=0, max_value=2 ** 31))
def test_dirty_sets_identical_across_impls(chunk, dtype, nchunks, seed):
    """Random dirty masks: every implementation marks exactly the chunks
    whose bytes changed — the delta planner's dirty set is engine-free."""
    r = np.random.default_rng(seed)
    nbytes = nchunks * chunk - r.integers(0, chunk)   # ragged last chunk
    nbytes = max(int(nbytes) // np.dtype(dtype).itemsize, 1) \
        * np.dtype(dtype).itemsize
    base = _payload(nbytes, seed)
    mut = base.copy()
    mask = r.random(-(-nbytes // chunk)) < 0.4
    for c in np.flatnonzero(mask):
        lo = c * chunk
        hi = min(lo + chunk, nbytes)
        mut[lo:hi - 1 if hi - lo > 1 else hi] ^= np.uint8(0x5A)
    truth = [bool((base[i * chunk:(i + 1) * chunk]
                   != mut[i * chunk:(i + 1) * chunk]).any())
             for i in range(-(-nbytes // chunk))]
    a = _all_impl_hexes(base.view(dtype), chunk)
    b = _all_impl_hexes(mut.view(dtype), chunk)
    for impl in a:
        dirty = [x != y for x, y in zip(a[impl], b[impl])]
        assert dirty == truth, impl


def test_single_lane_and_length_sensitivity():
    """Odd weights: any single-lane change flips the digest; the length
    fold separates a ragged chunk from its zero-padded twin."""
    base = _payload(4096, 7)
    h0 = fpk.digests_hex(fpk.fingerprint_chunks_host(base, 4096))[0]
    seen = {h0}
    r = np.random.default_rng(8)
    for pos in r.choice(4096, 64, replace=False):
        mut = base.copy()
        mut[pos] ^= np.uint8(1 + r.integers(0, 255))
        h = fpk.digests_hex(fpk.fingerprint_chunks_host(mut, 4096))[0]
        assert h not in seen, f"collision at byte {pos}"
        seen.add(h)
    # trailing zeros vs truncation must differ (length fold)
    padded = base.copy()
    padded[4000:] = 0
    h_pad = fpk.digests_hex(fpk.fingerprint_chunks_host(padded, 4096))[0]
    h_cut = fpk.digests_hex(
        fpk.fingerprint_chunks_host(base[:4000], 4096))[0]
    assert h_pad != h_cut


def test_digest_bytes_matches_chunk_table():
    data = _payload(1234, 3)
    assert fpk.digest_bytes(data.tobytes()) == fpk.digests_hex(
        fpk.fingerprint_chunks_host(data, 1234))[0]
    assert fpk.digest_bytes(b"") == "0" * 32


# ------------------------------------------------------- fused quant kernel
def test_fused_quant_fingerprint_matches_packed_payload():
    """Kernel (interpret), XLA oracle and host-fp-of-pack() agree: the
    fused digest covers exactly the bytes quant_codec would write."""
    from repro.core import quant_codec

    rng = np.random.default_rng(11)
    arr = rng.standard_normal((64, 512)).astype(np.float32)
    packed = quant_codec.pack(arr)
    hb = quant_codec.HEADER.size
    cb = 2048
    rows = quant_codec.packed_rows(arr.size)
    padded = jnp.zeros((rows, 512), jnp.float32) \
        .at[:arr.size // 512].set(jnp.asarray(arr.reshape(-1, 512)))

    q_o, s_o, d_oracle = fpk._quant_fp_ref_jit(padded, cb)
    # oracle q/s bytes == the packed payload's q/s regions
    qs = np.asarray(q_o).tobytes() + np.asarray(s_o).tobytes()
    assert qs == packed[hb:]
    want = fpk.digests_hex(
        fpk.fingerprint_chunks_host(np.frombuffer(packed[hb:], np.uint8),
                                    cb))
    assert fpk.digests_hex(np.asarray(d_oracle)) == want

    # fused Pallas kernel (interpret mode) over the q-only body chunks
    body_rows = (arr.size * 1 // cb) * (cb // 512)
    qk, sk, dk = fpk.quantize_fingerprint_blocks(padded[:body_rows], cb,
                                                 interpret=True)
    assert np.array_equal(np.asarray(qk), np.asarray(q_o)[:body_rows])
    assert fpk.digests_hex(np.asarray(dk)) \
        == want[:arr.size // cb]


# -------------------------------------------------- digest-kind compat rules
def test_digest_kind_flip_degrades_to_full_write(tmp_path):
    """fp128 index + blake2b save (and vice versa) must full-write — a
    kind mismatch can never produce a wrong (partial) delta."""
    d = str(tmp_path / "flip")
    rng = np.random.default_rng(5)
    state = {"w": rng.standard_normal(8192).astype(np.float32)}
    chunk = 4096
    for first, second in ((True, False), (False, True)):
        root = d + ("_fp_first" if first else "_bl_first")
        with CheckpointManager(root, config=_cfg(), delta=True, keep=None,
                               delta_chunk_bytes=chunk,
                               device_fingerprint=first) as mgr:
            m0 = mgr.save(0, state)
        state2 = {"w": state["w"].copy()}
        state2["w"][:1] += 1.0          # 1 dirty chunk under a SAME-kind diff
        with CheckpointManager(root, config=_cfg(), delta=True, keep=None,
                               delta_chunk_bytes=chunk,
                               device_fingerprint=second) as mgr:
            m1 = mgr.save(1, state2)
            assert m1.chunks_dirty == m1.chunks_total == m0.chunks_total
            got = mgr.restore(step=1)
        assert np.array_equal(got["w"], state2["w"])
        man = Manifest.load(os.path.join(root, "step_00000001"))
        kinds = {sh.digest_kind for rec in man.tensors.values()
                 for sh in rec.shards if delta_mod.is_chunked(sh)}
        assert kinds == {DIGEST_FP128 if second else DIGEST_BLAKE2B}


def test_blake2b_manifest_stays_pre_fp128_readable(tmp_path):
    """device_fingerprint=False emits no 'digest' field and floats only to
    the chunk format version — bytes a pre-§14 reader already accepts."""
    import json

    d = str(tmp_path / "bl")
    state = {"w": np.arange(4096, dtype=np.float32)}
    with CheckpointManager(d, config=_cfg(), delta=True, keep=None,
                           delta_chunk_bytes=4096,
                           device_fingerprint=False) as mgr:
        mgr.save(0, state)
    with open(os.path.join(d, "step_00000000", "manifest.json"),
              "rb") as f:
        doc = json.load(f)
    assert doc["format_version"] < FORMAT_VERSION
    for rec in doc["tensors"].values():
        for sh in rec["shards"]:
            assert "digest" not in sh


def test_fp128_manifest_is_version_gated(tmp_path):
    """fp128 manifests carry v4 + the digest field, so a pre-§14 reader
    refuses them typed (future-version) instead of mis-diffing."""
    d = str(tmp_path / "fp")
    state = {"w": np.arange(4096, dtype=np.float32)}
    with CheckpointManager(d, config=_cfg(), delta=True, keep=None,
                           delta_chunk_bytes=4096) as mgr:
        mgr.save(0, state)
    man = Manifest.load(os.path.join(d, "step_00000000"))
    assert man.format_version == FORMAT_VERSION
    shards = [sh for rec in man.tensors.values() for sh in rec.shards
              if delta_mod.is_chunked(sh)]
    assert shards and all(sh.digest_kind == DIGEST_FP128 for sh in shards)


# --------------------------------------------------- integration / d2h gates
def test_device_state_d2h_accounting(tmp_path):
    """Device-held (jax) state: D2H traffic is digest tables + dirty
    gathers, never the clean bytes; restores stay bit-identical."""
    d = str(tmp_path / "dev")
    rng = np.random.default_rng(9)
    host = rng.standard_normal((256, 1024)).astype(np.float32)  # 1 MB
    chunk = 16 << 10
    with CheckpointManager(d, config=_cfg(), delta=True, keep=None,
                           delta_chunk_bytes=chunk) as mgr:
        m0 = mgr.save(0, {"w": jnp.asarray(host)})
        assert m0.d2h_bytes > 0
        host2 = host.copy()
        host2[:4] += 1.0                       # 1 of 64 chunks dirty
        m1 = mgr.save(1, {"w": jnp.asarray(host2)})
        assert m1.chunks_dirty < m1.chunks_total
        assert m1.d2h_bytes <= (m1.written_bytes
                                + 16 * m1.chunks_total + 4096)
        got = mgr.restore(step=1)
    assert np.array_equal(got["w"], host2)


def test_quantized_device_delta_roundtrip(tmp_path):
    """quant × fp128 × delta: packed-payload digests diff correctly and
    the delta restore equals a full quantized save bit-for-bit."""
    d = str(tmp_path / "qdev")
    rng = np.random.default_rng(13)
    mu = rng.standard_normal((512, 512)).astype(np.float32)
    kw = dict(config=_cfg(), delta=True, keep=None,
              delta_chunk_bytes=16 << 10,
              quantize_prefixes=("opt/",), quantize_min_bytes=1024)
    with CheckpointManager(d, **kw) as mgr:
        m0 = mgr.save(0, {"opt": {"mu": jnp.asarray(mu)}})
        mu2 = mu.copy()
        mu2[:8] += 0.25
        m1 = mgr.save(1, {"opt": {"mu": jnp.asarray(mu2)}})
        assert 0 < m1.written_bytes < m0.written_bytes
        got = mgr.restore(step=1)
    with CheckpointManager(d + "_full", **{k: v for k, v in kw.items()
                                           if k != "delta"}) as ref:
        ref.save(1, {"opt": {"mu": mu2}})
        want = ref.restore(step=1)
    assert np.array_equal(got["opt"]["mu"], want["opt"]["mu"])


def test_multiwriter_composition_uses_fp128(tmp_path):
    from repro.core.multiwriter import MultiWriterCheckpointer

    d = str(tmp_path / "mw")
    rng = np.random.default_rng(17)
    state = {"w": rng.standard_normal((512, 64)).astype(np.float32)}
    with MultiWriterCheckpointer(d, 2, delta=True, keep=None,
                                 delta_chunk_bytes=4096) as w:
        w.save(0, state)
        state["w"][:4] += 1.0
        w.save(1, state)
        got = w.restore(step=1)
    assert np.array_equal(got["w"], state["w"])
    man = Manifest.load(os.path.join(d, "step_00000001"))
    kinds = {sh.digest_kind for rec in man.tensors.values()
             for sh in rec.shards if delta_mod.is_chunked(sh)}
    assert kinds == {DIGEST_FP128}


def test_host_fallback_for_unsupported_dtypes(tmp_path):
    """f64 / bool tensors (no 1/2/4-byte lane view or jax support) ride
    the host path inside the same fp128 plan — same digest kind, exact."""
    d = str(tmp_path / "f64")
    rng = np.random.default_rng(19)
    state = {"a": rng.standard_normal(3000),            # float64
             "b": rng.random(2048) < 0.5,               # bool
             "c": jnp.asarray(rng.standard_normal(2048).astype(np.float32))}
    with CheckpointManager(d, config=_cfg(), delta=True, keep=None,
                           delta_chunk_bytes=4096) as mgr:
        mgr.save(0, state)
        m = mgr.save(1, {"a": state["a"], "b": state["b"],
                         "c": state["c"]})
        assert m.chunks_dirty == 0        # bit-identical re-save: all clean
        got = mgr.restore(step=1)
    for k in state:
        assert np.array_equal(np.asarray(got[k]), np.asarray(state[k]))


def test_device_digestable_predicate():
    assert delta_mod._device_digestable(jnp.zeros(8, jnp.float32), 256)
    assert delta_mod._device_digestable(jnp.zeros(8, jnp.int8), 256)
    assert not delta_mod._device_digestable(np.zeros(8, np.float32), 256)
    assert not delta_mod._device_digestable(jnp.zeros(8, jnp.float32), 254)
    assert not delta_mod._device_digestable(jnp.zeros(8, jnp.bool_), 256)
