"""Multi-writer checkpointing: N concurrent rank writers, two-phase rank-0
merge commit, crash-window publish, corrupt-manifest fallback, tmp-GC
ownership, and N→M elastic restore (DESIGN.md §11)."""

import os
import shutil
import threading
import time

import numpy as np
import pytest

from repro.core import (CheckpointManager, EngineConfig, LocalShard,
                        Manifest, ManifestError, MultiWriterCheckpointer,
                        shard_state)
from repro.core.checkpoint import (OWNER_NAME, step_dir_name, tmp_in_flight,
                                   write_owner)
from repro.core.multiwriter import InProcessGroup, MultiWriterAborted


def _state(seed=0, rows=16, cols=32):
    rng = np.random.default_rng(seed)
    return {"params": {"w": rng.standard_normal((rows, cols))
                       .astype(np.float32),
                       "b": rng.standard_normal((3,)).astype(np.float32)},
            "step": seed, "note": f"lean-{seed}"}


def _reassemble(trees, key, like):
    out = np.zeros_like(like)
    for tree in trees:
        leaf = tree["params"][key]
        if isinstance(leaf, LocalShard):
            lo, hi = leaf.index[0]
            out[lo:hi] = leaf.data
        else:
            out[:] = leaf
    return out


# ------------------------------------------------------------ group shim
def test_allgather_rounds():
    group = InProcessGroup(4)
    results = [None] * 4

    def run(r):
        a = group.allgather(r * 10, r, 4)
        b = group.allgather(r + 100, r)
        results[r] = (a, b)

    ts = [threading.Thread(target=run, args=(r,)) for r in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    for r in range(4):
        assert results[r] == ([0, 10, 20, 30], [100, 101, 102, 103])


def test_allgather_rejects_wrong_world_size():
    group = InProcessGroup(1)
    with pytest.raises(ValueError):
        group.allgather(1, 0, 8)


# -------------------------------------------------- concurrent save+commit
@pytest.mark.parametrize("strategy", ["single_file", "file_per_process",
                                      "file_per_tensor"])
def test_concurrent_save_one_commit(tmp_ckpt_dir, strategy):
    """N rank threads, one shared dir → exactly ONE committed step dir with
    a merged manifest; every rank's windows present."""
    state = _state(1)
    with MultiWriterCheckpointer(
            tmp_ckpt_dir, 4,
            config=EngineConfig(strategy=strategy)) as mw:
        mw.save(3, state)
        assert sorted(os.listdir(tmp_ckpt_dir)) == [step_dir_name(3)]
        step_dir = os.path.join(tmp_ckpt_dir, step_dir_name(3))
        man = Manifest.load(step_dir)
        assert man.num_ranks == 4
        assert sorted(man.extra["merged_ranks"]) == [0, 1, 2, 3]
        assert Manifest.rank_manifests(step_dir) == [0, 1, 2, 3]
        # the 16-row tensor was split 4 ways: 4 disjoint windows
        idx = sorted(tuple(s.index) for s in man.tensors["params/w"].shards)
        assert idx == [(((0, 4), (0, 32))), (((4, 8), (0, 32))),
                       (((8, 12), (0, 32))), (((12, 16), (0, 32)))]
        out = mw.restore()
    np.testing.assert_array_equal(out["params"]["w"], state["params"]["w"])
    np.testing.assert_array_equal(out["params"]["b"], state["params"]["b"])
    assert out["note"] == "lean-1"


def test_single_file_disjoint_regions(tmp_ckpt_dir):
    """SINGLE_FILE: ranks write disjoint extents of ONE shared file (the
    prefix-sum exchange ran through the in-process allgather)."""
    with MultiWriterCheckpointer(
            tmp_ckpt_dir, 4,
            config=EngineConfig(strategy="single_file")) as mw:
        mw.save(1, _state(1))
        man = Manifest.load(os.path.join(tmp_ckpt_dir, step_dir_name(1)))
    paths = {s.path for r in man.tensors.values() for s in r.shards}
    paths |= {b.path for b in man.blobs.values()}
    assert paths == {"data/checkpoint.bin"}
    spans = sorted((s.offset, s.offset + s.nbytes)
                   for r in man.tensors.values() for s in r.shards)
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 <= b0, f"overlapping extents {a0, a1} and {b0, b1}"


def test_elastic_restore_n_to_m(tmp_ckpt_dir):
    """A 4-rank checkpoint restores bit-identically on 1/2/3/8-rank meshes
    (windows assembled from whatever saved shards they intersect)."""
    state = _state(5)
    with MultiWriterCheckpointer(
            tmp_ckpt_dir, 4,
            config=EngineConfig(strategy="single_file")) as mw:
        mw.save(2, state)
        for m_ranks in (1, 2, 3, 8):
            trees = mw.restore_sharded(m_ranks, step=2)
            assert len(trees) == m_ranks
            got = _reassemble(trees, "w", state["params"]["w"])
            np.testing.assert_array_equal(got, state["params"]["w"])
            got_b = _reassemble(trees, "b", state["params"]["b"])
            np.testing.assert_array_equal(got_b, state["params"]["b"])


def test_multiwriter_async_and_overwrite(tmp_ckpt_dir):
    """Async driver: save returns early, wait() commits; re-saving the same
    step replaces it atomically."""
    s1, s2 = _state(1), _state(2)
    with MultiWriterCheckpointer(
            tmp_ckpt_dir, 2, async_save=True,
            config=EngineConfig(strategy="single_file")) as mw:
        m = mw.save(9, s1)
        assert m.mode == "async"
        mw.wait()
        assert m.total_bytes > 0 and m.end_to_end_seconds > 0
        mw.save(9, s2)
        mw.wait()
        out = mw.restore(step=9)
    np.testing.assert_array_equal(out["params"]["w"], s2["params"]["w"])
    assert sorted(os.listdir(tmp_ckpt_dir)) == [step_dir_name(9)]


def test_rank_failure_aborts_group_not_hangs(tmp_ckpt_dir):
    """A failing rank breaks the barrier: peers get MultiWriterAborted
    instead of hanging, nothing is committed, and the NEXT save works."""
    state = _state(3)
    with MultiWriterCheckpointer(
            tmp_ckpt_dir, 3,
            config=EngineConfig(strategy="single_file")) as mw:
        def boom(*a, **kw):
            raise IOError("injected rank-1 flush failure")
        mw.managers[1].engine.begin_save = boom
        with pytest.raises(RuntimeError) as ei:
            mw.save(1, state)
        assert isinstance(ei.value.__cause__, IOError)
        assert mw.latest_step() is None   # nothing committed
        del mw.managers[1].engine.begin_save   # restore class method
        mw.save(2, state)                 # barrier was repaired
        out = mw.restore()
    np.testing.assert_array_equal(out["params"]["w"], state["params"]["w"])


def test_rank0_commit_failure_reclaims_staging(tmp_ckpt_dir):
    """A phase-2 (rank-0 publish) failure must leave no staging dir behind
    and must not poison the next save of the same step."""
    state = _state(6)
    with MultiWriterCheckpointer(
            tmp_ckpt_dir, 2,
            config=EngineConfig(strategy="single_file")) as mw:
        def boom(tmp, step):
            raise OSError("injected publish failure")
        mw.managers[0]._publish = boom
        with pytest.raises(RuntimeError):
            mw.save(4, state)
        assert not any(".tmp-" in n for n in os.listdir(tmp_ckpt_dir))
        del mw.managers[0]._publish
        mw.save(4, state)
        out = mw.restore(step=4)
    np.testing.assert_array_equal(out["params"]["w"], state["params"]["w"])


def test_shard_state_replication_and_snapshot():
    state = {"big": np.arange(12, dtype=np.float32).reshape(6, 2),
             "small": np.arange(2, dtype=np.float32),
             "scalar": np.float32(3.0), "lean": "x"}
    shards = shard_state(state, 4, snapshot=True)
    assert len(shards) == 4
    assert isinstance(shards[0]["big"], LocalShard)
    assert shards[0]["big"].global_shape == (6, 2)
    # 6 rows over 4 ranks: (2, 2, 1, 1), contiguous and covering
    spans = [s["big"].index[0] for s in shards]
    assert spans == [(0, 2), (2, 4), (4, 5), (5, 6)]
    # short tensors replicated, snapshot copies detached from the source
    assert isinstance(shards[1]["small"], np.ndarray)
    state["small"][0] = 99.0
    assert shards[1]["small"][0] == 0.0


# --------------------------------------------------- crash-window publish
def test_commit_crash_window_keeps_previous(tmp_ckpt_dir, monkeypatch):
    """Crash between displacing the old step dir and renaming the new one
    in must NOT lose the previous checkpoint: restart recovers it."""
    s1, s2 = _state(1), _state(2)
    with CheckpointManager(tmp_ckpt_dir) as mgr:
        mgr.save(5, s1)
    final = os.path.join(tmp_ckpt_dir, step_dir_name(5))

    real_replace = os.replace

    def crashy(src, dst, *a, **kw):
        if dst == final and ".tmp-" in src and ".tmp-old-" not in src:
            raise RuntimeError("simulated crash mid-publish")
        return real_replace(src, dst, *a, **kw)

    monkeypatch.setattr(os, "replace", crashy)
    mgr2 = CheckpointManager(tmp_ckpt_dir)
    with pytest.raises(RuntimeError, match="simulated crash"):
        mgr2.save(5, s2)
    mgr2.close()
    monkeypatch.setattr(os, "replace", real_replace)

    # "restart": a fresh manager's GC rolls the displaced version back
    with CheckpointManager(tmp_ckpt_dir) as mgr3:
        assert mgr3.all_steps() == [5]
        out = mgr3.restore(step=5)
    np.testing.assert_array_equal(out["params"]["w"], s1["params"]["w"])


# --------------------------------------------- corrupt-manifest fallback
def test_corrupt_manifest_falls_back_to_older_step(tmp_ckpt_dir):
    s1, s2 = _state(1), _state(2)
    with CheckpointManager(tmp_ckpt_dir) as mgr:
        mgr.save(1, s1)
        mgr.save(2, s2)
        with open(os.path.join(tmp_ckpt_dir, step_dir_name(2),
                               "manifest.json"), "wb") as f:
            f.write(b'{"format_version": 2, "step"')   # truncated
        # explicit step: typed error, no silent fallback
        with pytest.raises(ManifestError):
            mgr.restore(step=2)
        # latest-step restore: falls back to the older valid step
        out = mgr.restore()
    np.testing.assert_array_equal(out["params"]["w"], s1["params"]["w"])
    assert out["note"] == "lean-1"


def test_all_manifests_corrupt_raises_typed(tmp_ckpt_dir):
    with CheckpointManager(tmp_ckpt_dir) as mgr:
        mgr.save(1, _state(1))
        with open(os.path.join(tmp_ckpt_dir, step_dir_name(1),
                               "manifest.json"), "wb") as f:
            f.write(b"not json at all")
        with pytest.raises(ManifestError):
            mgr.restore()


# ------------------------------------------------------- tmp GC ownership
def test_gc_spares_live_tmp_dirs(tmp_ckpt_dir):
    """A second manager starting up mid-flush must not reap a live save's
    tmp dir (owner pid alive) nor a young ownerless one; stale dirs go."""
    os.makedirs(tmp_ckpt_dir, exist_ok=True)
    live = os.path.join(tmp_ckpt_dir, "step_00000001.tmp-live")
    os.makedirs(live)
    write_owner(live)                      # owned by THIS (alive) process
    young = os.path.join(tmp_ckpt_dir, "step_00000002.tmp-young")
    os.makedirs(young)                     # no owner, but brand new
    stale = os.path.join(tmp_ckpt_dir, "step_00000003.tmp-stale")
    os.makedirs(stale)
    old = time.time() - 3600
    os.utime(stale, (old, old))            # no owner, an hour old
    dead = os.path.join(tmp_ckpt_dir, "step_00000004.tmp-dead")
    os.makedirs(dead)
    with open(os.path.join(dead, OWNER_NAME), "w") as f:
        f.write(f"{2**30} 0")              # pid beyond pid_max: not alive
    assert tmp_in_flight(live) and tmp_in_flight(young)
    assert not tmp_in_flight(stale) and not tmp_in_flight(dead)

    CheckpointManager(tmp_ckpt_dir).engine.close()
    left = sorted(os.listdir(tmp_ckpt_dir))
    assert "step_00000001.tmp-live" in left
    assert "step_00000002.tmp-young" in left
    assert "step_00000003.tmp-stale" not in left
    assert "step_00000004.tmp-dead" not in left


def test_tmp_owner_on_other_host_falls_back_to_age(tmp_path):
    """A shared-FS dir owned by ANOTHER host: its pids mean nothing to this
    kernel, so liveness falls back to the age signal."""
    p = os.path.join(str(tmp_path), "step_00000001.tmp-remote")
    os.makedirs(p)
    with open(os.path.join(p, OWNER_NAME), "w") as f:
        f.write(f"{os.getpid()} 0 some-other-host")   # pid alive HERE
    assert tmp_in_flight(p)            # young: assumed live
    old = time.time() - 3600
    os.utime(p, (old, old))
    assert not tmp_in_flight(p)        # aged out: reapable


def test_concurrent_manager_startup_does_not_break_async_save(tmp_ckpt_dir):
    """The race the guard exists for: a manager starts while another's
    async save is mid-flight in the same directory — the save must still
    commit."""
    state = _state(4, rows=256, cols=512)
    with CheckpointManager(tmp_ckpt_dir, async_save=True) as mgr:
        mgr.save(1, state)
        # second manager's __init__ runs _gc_tmp while the flush drains
        CheckpointManager(tmp_ckpt_dir).engine.close()
        mgr.wait()
        assert mgr.latest_step() == 1
        out = mgr.restore(step=1)
    np.testing.assert_array_equal(out["params"]["w"], state["params"]["w"])
