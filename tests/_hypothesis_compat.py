"""Stdlib fallback for the subset of `hypothesis` the test suite uses.

The property tests guard their import with::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st

so the tier-1 suite runs (with deterministic pseudo-random examples instead
of shrinking search) on containers where hypothesis isn't installed.
Supported: ``st.integers``, ``st.lists``, ``st.sampled_from``, ``st.tuples``,
``@settings(max_examples=..., deadline=...)``, ``@given(**kwargs)``.
"""

from __future__ import annotations

import inspect
import random

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random):
        return self._draw(rnd)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rnd):
            n = rnd.randint(min_size, max_size)
            return [elements.example(rnd) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rnd: rnd.choice(seq))

    @staticmethod
    def tuples(*strats: _Strategy) -> _Strategy:
        return _Strategy(lambda rnd: tuple(s.example(rnd) for s in strats))


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def apply(fn):
        fn._max_examples = max_examples
        return fn
    return apply


def given(**strats):
    def wrap(fn):
        def runner(**kwargs):
            # pytest fixtures (e.g. tmp_path_factory) arrive via kwargs;
            # strategy kwargs are drawn per example.
            n = getattr(runner, "_max_examples", _DEFAULT_EXAMPLES)
            rnd = random.Random(f"{fn.__module__}.{fn.__name__}")
            for _ in range(n):
                drawn = {k: s.example(rnd) for k, s in strats.items()}
                fn(**drawn, **kwargs)
        # expose only the non-strategy params so pytest injects its fixtures
        sig = inspect.signature(fn)
        runner.__signature__ = inspect.Signature(
            [p for name, p in sig.parameters.items() if name not in strats])
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        if hasattr(fn, "_max_examples"):
            runner._max_examples = fn._max_examples
        return runner
    return wrap
