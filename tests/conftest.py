import os

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.


@pytest.fixture
def tmp_ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
