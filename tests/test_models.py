"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.train.steps import init_train_state, make_train_step

KEY = jax.random.key(0)


def _reduced(arch):
    cfg = get_config(arch)
    layers = 13 if arch == "recurrentgemma-2b" else 2
    return cfg.scaled_down(layers=layers, width_div=16, vocab=128)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """One forward+backward+update on CPU: shapes + finiteness."""
    cfg = _reduced(arch)
    B, S = 2, 32
    state = init_train_state(KEY, cfg)
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend:
        batch["frontend_embeds"] = jnp.ones(
            (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    step = jax.jit(make_train_step(cfg))
    state2, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(state2["step"]) == 1
    # params changed
    w0 = np.asarray(jax.tree.leaves(state["params"])[0])
    w1 = np.asarray(jax.tree.leaves(state2["params"])[0])
    assert not np.array_equal(w0, w1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_shapes(arch):
    cfg = _reduced(arch)
    B, S = 2, 16
    params = init_train_state(KEY, cfg)["params"]
    fe = (jnp.ones((B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
          if cfg.frontend else None)
    logits, aux = T.forward(params, cfg, jnp.ones((B, S), jnp.int32), fe)
    S_total = S + (cfg.frontend_len if cfg.frontend else 0)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "qwen3-32b", "gemma2-9b",
                                  "xlstm-350m", "recurrentgemma-2b",
                                  "olmoe-1b-7b", "musicgen-large"])
def test_decode_matches_forward(arch):
    """Token-by-token decode with cache ≈ teacher-forced full forward."""
    cfg = _reduced(arch).replace(frontend="", frontend_dim=0, frontend_len=0)
    if cfg.is_moe:
        cfg = cfg.replace(moe_capacity_factor=8.0)  # no dropping in the test
    B, S = 2, 16
    params = init_train_state(jax.random.key(1), cfg)["params"]
    tokens = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    full_logits, _ = T.forward(params, cfg, tokens)
    cache = T.init_cache(cfg, B, max_len=S)
    dec = jax.jit(lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))
    outs = []
    for t in range(S):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, cache = dec(params, cache, tokens[:, t:t + 1], pos)
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    rel = (float(jnp.max(jnp.abs(dec_logits - full_logits)))
           / (float(jnp.max(jnp.abs(full_logits))) + 1e-9))
    assert rel < 0.05, rel


def test_chunked_attention_matches_direct():
    from repro.models import layers as L
    cfg = _reduced("stablelm-3b")
    rng = jax.random.key(3)
    B, S, H, D = 2, 64, cfg.num_heads, cfg.head_dim
    q = jax.random.normal(rng, (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, cfg.num_kv_heads, D))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, cfg.num_kv_heads, D))
    direct = L.attention_scores(q, k, v, L.causal_mask(S, S, 0, 0), cfg)
    chunked = L.chunked_attention(q, k, v, cfg, window=0, chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(direct),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attention_non_divisible():
    from repro.models import layers as L
    cfg = _reduced("stablelm-3b")
    B, S = 1, 50   # 50 % 16 != 0 -> padded path
    q = jnp.ones((B, S, cfg.num_heads, cfg.head_dim))
    k = jnp.ones((B, S, cfg.num_kv_heads, cfg.head_dim))
    v = jnp.ones((B, S, cfg.num_kv_heads, cfg.head_dim))
    out = L.chunked_attention(q, k, v, cfg, window=0, chunk=16)
    assert out.shape == (B, S, cfg.num_heads, cfg.head_dim)


def test_mlstm_chunked_matches_quadratic():
    from repro.models import layers as L
    rng = np.random.default_rng(0)
    B, S, H, D = 2, 64, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    li = jnp.asarray(rng.standard_normal((B, S, H)).astype(np.float32))
    lf = jnp.asarray((rng.standard_normal((B, S, H)) + 2).astype(np.float32))
    lf = jax.nn.log_sigmoid(lf)
    full = L.mlstm_sequence(q, k, v, li, lf)
    chunked = L._mlstm_chunked(q, k, v, li, lf, chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=0.02, atol=0.02)


def test_param_count_analytic_matches_actual():
    for arch in ("stablelm-3b", "olmoe-1b-7b", "xlstm-350m"):
        cfg = _reduced(arch)
        params = init_train_state(KEY, cfg)["params"]
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.15, (arch, actual, analytic)


def test_moe_capacity_drops_are_bounded():
    from repro.models import layers as L
    cfg = _reduced("olmoe-1b-7b")
    params = init_train_state(KEY, cfg)["params"]
    moe_p = jax.tree.map(lambda x: x[0],
                         params["blocks"]["b0_attn"]["moe"])
    x = jax.random.normal(jax.random.key(5), (2, 32, cfg.d_model),
                          jnp.float32)
    y, aux = L.moe_apply(moe_p, x, cfg)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3   # Switch aux loss lower bound ≈ 1
