"""crlint — the durability/concurrency linter must flag every canary
fixture, pass the clean twins, hold a zero-new-findings gate at HEAD, and
round-trip its baseline stably (DESIGN.md §16)."""

import os
import subprocess
import sys

import pytest

from repro.analysis import crlint

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
FIXTURES = os.path.join(HERE, "lint_fixtures")
SRC = os.path.join(REPO, "src", "repro")
BASELINE = os.path.join(REPO, "crlint_baseline.txt")


def _fixture_findings():
    cwd = os.getcwd()
    os.chdir(REPO)
    try:
        return crlint.analyze_paths([FIXTURES])
    finally:
        os.chdir(cwd)


@pytest.fixture(scope="module")
def findings():
    return _fixture_findings()


def _by(findings, fname):
    return [f for f in findings if os.path.basename(f.path) == fname]


# ------------------------------------------------------------ must flag
def test_raw_syscalls_flagged(findings):
    got = _by(findings, "bad_raw_os.py")
    assert all(f.checker == "CRL001" for f in got)
    assert len(got) == 9         # rename, replace, fsync, fdatasync,
    #                              pwrite, preadv, fallocate, rmtree, alias
    assert any("shutil.rmtree" in f.message for f in got)
    assert any(f.scope == "aliased" for f in got)   # from-import alias


def test_publish_ordering_flagged(findings):
    got = _by(findings, "bad_publish.py")
    assert all(f.checker == "CRL002" for f in got)
    kinds = sorted(f.symbol for f in got)
    assert kinds == ["replace-no-dirsync", "replace-no-dirsync",
                     "replace-unsynced-src", "replace-unsynced-src"]


def test_guarded_by_flagged(findings):
    got = _by(findings, "bad_guard.py")
    assert [f.checker for f in got] == ["CRL003", "CRL003"]
    assert {f.scope for f in got} == {"Registry.add",
                                      "Registry.size_unlocked"}


def test_resource_pairing_flagged(findings):
    got = _by(findings, "bad_pairing.py")
    assert [f.checker for f in got] == ["CRL004"]
    assert got[0].scope == "stage"       # stage_safe's finally passes


def test_swallowed_faults_flagged(findings):
    got = _by(findings, "bad_swallow.py")
    assert [f.checker for f in got] == ["CRL005"] * 3
    assert {f.scope for f in got} == {"swallow_all", "swallow_bare",
                                      "absorb_injected_errno"}


def test_unepoched_clocks_flagged(findings):
    got = _by(findings, "bad_clock.py")
    assert [f.checker for f in got] == ["CRL006"] * 5
    assert {f.scope for f in got} == {"measure", "stamp", "deadline",
                                      "aliased"}
    assert all("trace.clock()" in f.message for f in got)
    assert any(f.symbol == "time.perf_counter" for f in got)
    assert any(f.symbol == "time.time" for f in got)
    assert any(f.symbol == "time.monotonic" for f in got)
    # the annotated mtime comparison is NOT flagged
    assert not any(f.scope == "mtime_age" for f in got)


# -------------------------------------------------------- must NOT flag
def test_clean_twin_passes(findings):
    assert _by(findings, "clean_core.py") == []


def test_allow_directive_suppresses(tmp_path):
    f = tmp_path / "core" / "mod.py"
    f.parent.mkdir()
    f.write_text(
        "# crlint: fixture\n"
        "import os\n\n\n"
        "def publish(tmp, dst):\n"
        "    # crlint: allow(CRL001): canary suppression\n"
        "    os.replace(tmp, dst)\n")
    assert crlint.analyze_paths([str(f)]) == []


def test_non_core_modules_exempt_from_shim_rule(tmp_path):
    f = tmp_path / "bench.py"    # no `core` path part, no fixture marker
    f.write_text("import os\n\n\ndef go(a, b):\n    os.replace(a, b)\n")
    assert crlint.analyze_paths([str(f)]) == []


# ------------------------------------------------------------ CLI + gate
def _run_cli(*args, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.crlint", *args],
        capture_output=True, text=True, env=env, cwd=cwd)

def test_cli_nonzero_on_fixtures():
    p = _run_cli(FIXTURES, "--no-baseline")
    assert p.returncode == 1
    assert "CRL001" in p.stdout and "CRL005" in p.stdout


def test_cli_clean_at_head_with_baseline():
    p = _run_cli(SRC, "--baseline", BASELINE)
    assert p.returncode == 0, p.stdout + p.stderr
    assert "0 new finding(s)" in p.stdout


def test_reverting_a_shim_fails_the_gate(tmp_path):
    """The acceptance canary: faults.replace -> os.replace in a core
    module must produce a fresh finding the committed baseline misses."""
    victim = os.path.join(SRC, "core", "checkpoint.py")
    with open(victim, "r", encoding="utf-8") as fh:
        src = fh.read()
    assert "faults.replace(" in src
    bad = tmp_path / "core" / "checkpoint.py"
    bad.parent.mkdir()
    bad.write_text("# crlint: fixture\n"
                   + src.replace("faults.replace(", "os.replace(", 1))
    p = _run_cli(str(bad), "--baseline", BASELINE)
    assert p.returncode == 1
    assert "CRL001" in p.stdout and "os.replace" in p.stdout


# ------------------------------------------------------------- baseline
def test_baseline_round_trip_and_stable(tmp_path, findings):
    bl = str(tmp_path / "bl.txt")
    crlint.write_baseline(findings, bl)
    first = open(bl).read()
    fresh, suppressed = crlint.apply_baseline(
        findings, crlint.load_baseline(bl))
    assert fresh == [] and suppressed == len(findings)
    # re-writing the same findings is byte-stable and reports no churn
    added, removed = crlint.write_baseline(findings, bl)
    assert (added, removed) == (0, 0)
    assert open(bl).read() == first


def test_baseline_keys_are_line_number_free(findings):
    for f in findings:
        assert f.key() == f"{f.checker}:{f.path}:{f.scope}:{f.symbol}"
        assert str(f.line) not in f.key().split(":")


def test_stale_baseline_entries_reported(tmp_path):
    bl = tmp_path / "bl.txt"
    bl.write_text("CRL001:tests/gone.py:nope:os.replace\n")
    clean = tmp_path / "ok.py"
    clean.write_text("x = 1\n")
    p = _run_cli(str(clean), "--baseline", str(bl))
    assert p.returncode == 0
    assert "1 baseline entry stale" in p.stdout
