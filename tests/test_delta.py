"""Content-addressed delta checkpointing (core.delta, DESIGN.md §12):
chunk/hash/diff planning, chunk-reference manifests, store publish,
refcounted retention GC (incl. the in-flight-save concurrency guarantee),
and composition with quantization, multi-writer, and multi-level."""

import glob
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (CheckpointManager, EngineConfig, Manifest,
                        ManifestError, MultiLevelCheckpointer,
                        MultiWriterCheckpointer)
from repro.core import delta as delta_mod
from repro.core.manifest import CHUNK_KIND


def _state(rng, n=3, rows=256, cols=128):
    return {"params": {
        f"w{i}": rng.standard_normal((rows, cols)).astype(np.float32)
        for i in range(n)}, "step": 0}


def _assert_equal(tree, state):
    for k, v in state["params"].items():
        assert np.array_equal(tree["params"][k], v), k


def _packs(d):
    return sorted(glob.glob(os.path.join(
        d, delta_mod.CHUNKSTORE_DIR, delta_mod.PACK_SUBDIR, "*")))


CHUNK = 16 << 10   # small grid so small test tensors span many chunks


# ----------------------------------------------------------- save/restore
def test_delta_roundtrip_and_dirty_scaling(tmp_ckpt_dir, rng):
    state = _state(rng)
    with CheckpointManager(tmp_ckpt_dir, delta=True, keep=None,
                           delta_chunk_bytes=CHUNK) as mgr:
        m0 = mgr.save(0, state)
        assert m0.mode == "delta-blocking"
        assert m0.chunks_dirty == m0.chunks_total > 0
        assert m0.written_bytes == m0.total_bytes
        orig_rows = state["params"]["w1"][:2].copy()
        # touch two rows of one tensor: only its chunks rewrite
        state["params"]["w1"][:2] += 1.0
        state["step"] = 1
        m1 = mgr.save(1, state)
        assert 0 < m1.chunks_dirty < m1.chunks_total
        assert m1.written_bytes < m0.written_bytes / 4
        out = mgr.restore(step=1)
        _assert_equal(out, state)
        assert out["step"] == 1
        # the older step still restores (its chunks are still referenced)
        out0 = mgr.restore(step=0)
        assert np.array_equal(out0["params"]["w1"][:2], orig_rows)


def test_delta_identical_state_writes_only_metadata(tmp_ckpt_dir, rng):
    state = _state(rng, n=2)
    with CheckpointManager(tmp_ckpt_dir, delta=True, keep=None,
                           delta_chunk_bytes=CHUNK) as mgr:
        mgr.save(0, state)
        m1 = mgr.save(1, state)
        assert m1.chunks_dirty == 0
        # only the lean blob is written
        assert m1.written_bytes < 4096
        _assert_equal(mgr.restore(step=1), state)


def test_delta_manifest_entries_reference_store(tmp_ckpt_dir, rng):
    state = _state(rng, n=1)
    with CheckpointManager(tmp_ckpt_dir, delta=True, keep=None,
                           delta_chunk_bytes=CHUNK) as mgr:
        mgr.save(0, state)
        man = Manifest.load(os.path.join(tmp_ckpt_dir, "step_00000000"))
        assert man.format_version == 4      # fp128 digest kind needs v4
        (rec,) = [r for k, r in man.tensors.items()]
        for sh in rec.shards:
            assert sh.kind == CHUNK_KIND
            assert sh.digest_kind == "fp128"
            assert sh.chunks and sum(r.nbytes for r in sh.chunks) == sh.nbytes
            for r in sh.chunks:
                assert r.path.startswith(delta_mod.STORE_PREFIX)
                assert len(r.hash) == 32    # fp128 hex, blake2b-128 width
        # step dir holds only metadata; payload lives in the store
        files = os.listdir(os.path.join(tmp_ckpt_dir, "step_00000000"))
        assert files == ["manifest.json"]
        assert len(_packs(tmp_ckpt_dir)) == 1


def test_delta_monolithic_restore_parity(tmp_ckpt_dir, rng):
    state = _state(rng)
    with CheckpointManager(tmp_ckpt_dir, delta=True, keep=None,
                           delta_chunk_bytes=CHUNK) as mgr:
        mgr.save(0, state)
        state["params"]["w0"][5:7] -= 3.0
        mgr.save(1, state)
    with CheckpointManager(tmp_ckpt_dir, streaming=False,
                           keep=None) as mono:
        _assert_equal(mono.restore(step=1), state)


def test_delta_quantized_roundtrip(tmp_ckpt_dir, rng):
    """Delta chunks the PACKED payload; restore matches a full quantized
    save bit-for-bit (quantization is lossy, delta must not add to it)."""
    state = {"opt": {"mu": rng.standard_normal((512, 64)).astype(np.float32)},
             "w": rng.standard_normal((64, 64)).astype(np.float32)}
    kw = dict(quantize_prefixes=("opt/",), quantize_min_bytes=1024,
              keep=None)
    with CheckpointManager(tmp_ckpt_dir, delta=True,
                           delta_chunk_bytes=CHUNK, **kw) as mgr:
        m0 = mgr.save(0, state)
        state["opt"]["mu"][:1] += 0.5
        m1 = mgr.save(1, state)
        assert m1.written_bytes < m0.written_bytes
        got = mgr.restore(step=1)
    with CheckpointManager(tmp_ckpt_dir + "_full", **kw) as ref:
        ref.save(1, state)
        want = ref.restore(step=1)
    assert np.array_equal(got["opt"]["mu"], want["opt"]["mu"])
    assert np.array_equal(got["w"], want["w"])


def test_delta_async_save_hash_off_blocking_path(tmp_ckpt_dir, rng):
    state = _state(rng, rows=2048)
    with CheckpointManager(tmp_ckpt_dir, delta=True, async_save=True,
                           keep=None, delta_chunk_bytes=CHUNK) as mgr:
        m = mgr.save(0, state)
        # hash pass runs on the worker: not yet accounted when save returns
        blocked = m.blocking_seconds
        mgr.wait()
        assert m.hash_seconds > 0.0
        assert blocked < m.end_to_end_seconds
        state["params"]["w2"][-2:] *= 2.0
        mgr.save(1, state)
        mgr.wait()
        _assert_equal(mgr.restore(step=1), state)


def test_delta_requires_streaming(tmp_ckpt_dir):
    with pytest.raises(ValueError, match="streaming"):
        CheckpointManager(tmp_ckpt_dir, delta=True, streaming=False)


def test_delta_chunk_size_change_degrades_to_full(tmp_ckpt_dir, rng):
    state = _state(rng, n=1)
    with CheckpointManager(tmp_ckpt_dir, delta=True, keep=None,
                           delta_chunk_bytes=CHUNK) as mgr:
        mgr.save(0, state)
    with CheckpointManager(tmp_ckpt_dir, delta=True, keep=None,
                           delta_chunk_bytes=CHUNK * 2) as mgr:
        m = mgr.save(1, state)
        assert m.chunks_dirty == m.chunks_total   # no index match: full write
        _assert_equal(mgr.restore(step=1), state)


def test_delta_crc_detects_store_corruption(tmp_ckpt_dir, rng):
    from repro.core import ChecksumError
    state = _state(rng, n=1)
    with CheckpointManager(tmp_ckpt_dir, delta=True, keep=None,
                           delta_chunk_bytes=CHUNK) as mgr:
        mgr.save(0, state)
        state["params"]["w0"][:1] += 1.0
        mgr.save(1, state)
        # flip a byte inside the step-0 pack (a chunk step 1 references)
        pack_files = glob.glob(os.path.join(_packs(tmp_ckpt_dir)[0],
                                            "**", "*.bin"), recursive=True)
        with open(pack_files[0], "r+b") as f:
            f.seek(CHUNK + 17)
            b = f.read(1)
            f.seek(CHUNK + 17)
            f.write(bytes([b[0] ^ 0xFF]))
        with pytest.raises(ChecksumError):
            mgr.restore(step=1)


# ------------------------------------------------------------ retention GC
def test_gc_refcount_keeps_referenced_reaps_orphans(tmp_ckpt_dir, rng):
    state = _state(rng, n=2)
    with CheckpointManager(tmp_ckpt_dir, delta=True, keep=2,
                           delta_chunk_bytes=CHUNK) as mgr:
        mgr.delta_gc_grace_s = 0.0
        for s in range(5):
            state["params"]["w0"][s:s + 1] += 1.0
            mgr.save(s, state)
        assert mgr.all_steps() == [3, 4]
        gc = mgr.last_gc_stats
        assert gc is not None and gc.kept > 0
        # step 0's pack survives: steps 3/4 still reference its clean chunks
        refs = set(gc.refcounts)
        assert any("step_00000000" in r for r in refs)
        # dropped intermediate steps' packs were reaped once unreferenced
        packs = _packs(tmp_ckpt_dir)
        assert all(os.path.basename(p).startswith(
            ("step_00000000", "step_00000003", "step_00000004"))
            for p in packs)
        _assert_equal(mgr.restore(step=4), state)


def test_gc_keep_none_retains_everything(tmp_ckpt_dir, rng):
    state = _state(rng, n=1)
    with CheckpointManager(tmp_ckpt_dir, delta=True, keep=None,
                           delta_chunk_bytes=CHUNK) as mgr:
        mgr.delta_gc_grace_s = 0.0
        for s in range(4):
            state["params"]["w0"][s:s + 1] += 1.0
            mgr.save(s, state)
        assert mgr.all_steps() == [0, 1, 2, 3]
        assert len(_packs(tmp_ckpt_dir)) == 4
        gc = mgr.last_gc_stats
        assert gc.deleted == 0


def test_gc_grace_spares_young_orphans(tmp_ckpt_dir, rng):
    state = _state(rng, n=1)
    with CheckpointManager(tmp_ckpt_dir, delta=True, keep=1,
                           delta_chunk_bytes=CHUNK) as mgr:
        # default grace: orphaned packs too young to reap survive
        state["params"]["w0"][:] = 1.0    # fully dirty → step 0 pack orphan
        mgr.save(0, state)
        state["params"]["w0"][:] = 2.0
        mgr.save(1, state)
        assert mgr.all_steps() == [1]
        assert len(_packs(tmp_ckpt_dir)) == 2   # young orphan spared
        delta_mod.gc_store(tmp_ckpt_dir, grace_s=0.0)
        assert len(_packs(tmp_ckpt_dir)) == 1   # now reaped


def test_gc_never_reaps_chunks_referenced_by_inflight_save(tmp_ckpt_dir,
                                                           rng):
    """The §12 acceptance concurrency case: a refcount GC pass racing an
    in-flight ASYNC delta save must not delete any chunk a kept (or
    about-to-commit) step references — restores stay bit-exact."""
    state = _state(rng, n=2, rows=2048)
    with CheckpointManager(tmp_ckpt_dir, delta=True, keep=2,
                           async_save=True,
                           delta_chunk_bytes=CHUNK) as mgr:
        mgr.delta_gc_grace_s = 0.0
        mgr.save(0, state)
        mgr.wait()
        stop = threading.Event()
        errs: list = []

        def hammer():
            # an adversarial concurrent GC (as a second manager's startup
            # or commit would run it) while the save is in flight
            while not stop.is_set():
                try:
                    delta_mod.gc_store(tmp_ckpt_dir, grace_s=0.0)
                except Exception as e:  # noqa: BLE001
                    errs.append(e)
                time.sleep(0.001)

        th = threading.Thread(target=hammer)
        th.start()
        try:
            for s in range(1, 4):
                state["params"]["w1"][s:s + 2] += 1.0
                mgr.save(s, state)
                mgr.wait()
        finally:
            stop.set()
            th.join()
        assert not errs
        _assert_equal(mgr.restore(step=3), state)
        # older kept step restores too — no referenced chunk was reaped
        mgr.restore(step=2)


def test_gc_pins_inflight_tmp_manifests(tmp_path, rng):
    """A live .tmp-* dir whose staged manifest references store chunks pins
    them even when no committed step does (cross-manager window)."""
    import shutil
    d = str(tmp_path / "ckpt")
    state = _state(rng, n=1)
    with CheckpointManager(d, delta=True, keep=None,
                           delta_chunk_bytes=CHUNK) as mgr:
        mgr.save(0, state)
    # simulate an in-flight save that already staged its manifest: move the
    # committed step to a live-owned tmp dir
    from repro.core.checkpoint import write_owner
    src = os.path.join(d, "step_00000000")
    tmp = os.path.join(d, "step_00000000.tmp-test")
    shutil.move(src, tmp)
    write_owner(tmp)
    stats = delta_mod.gc_store(d, grace_s=0.0)
    assert stats.deleted == 0 and stats.kept == stats.scanned > 0
    # without the pin, everything is an orphan
    os.remove(os.path.join(tmp, ".owner.pid"))
    os.remove(os.path.join(tmp, "manifest.json"))
    stats = delta_mod.gc_store(d, grace_s=0.0)
    assert stats.deleted > 0


# ------------------------------------------------------------- composition
def test_delta_multiwriter_merge_and_restore(tmp_ckpt_dir, rng):
    state = _state(rng, n=3, rows=512)
    with MultiWriterCheckpointer(
            tmp_ckpt_dir, 4, config=EngineConfig(strategy="single_file"),
            delta=True, delta_chunk_bytes=CHUNK, keep=None) as mw:
        m0 = mw.save(0, state)
        state["params"]["w0"][:2] += 1.0          # rank 0's partition
        state["params"]["w2"][-2:] += 1.0         # last rank's partition
        m1 = mw.save(1, state)
        w0 = sum(r.written_bytes for r in m0.per_rank)
        w1 = sum(r.written_bytes for r in m1.per_rank)
        assert w1 < w0 / 4
        # per-rank chunk indexes merged by rank 0 into one manifest
        man = Manifest.load(os.path.join(tmp_ckpt_dir, "step_00000001"))
        assert sorted(man.extra["merged_ranks"]) == [0, 1, 2, 3]
        chunked = [sh for rec in man.tensors.values() for sh in rec.shards
                   if sh.kind == CHUNK_KIND]
        assert chunked and all(
            r.path.startswith(delta_mod.STORE_PREFIX)
            for sh in chunked for r in (sh.chunks or ()))
        _assert_equal(mw.restore(step=1), state)
        # elastic: the 4-writer delta checkpoint restores on a 2-rank mesh
        from repro.core import LocalShard
        trees = mw.restore_sharded(2, step=1)
        for k, want in state["params"].items():
            got = np.zeros_like(want)
            for tree in trees:
                leaf = tree["params"][k]
                if isinstance(leaf, LocalShard):
                    lo, hi = leaf.index[0]
                    got[lo:hi] = leaf.data
                else:
                    got[:] = leaf
            assert np.array_equal(got, want), k


def test_delta_multilevel_flush_skips_resident_chunks(tmp_path, rng):
    l0, l1 = str(tmp_path / "l0"), str(tmp_path / "l1")
    state = _state(rng, n=2, rows=512)
    with MultiLevelCheckpointer(l0, l1, delta=True, keep=None,
                                delta_chunk_bytes=CHUNK) as ml:
        ml.save(0, state)
        ml.wait()
        s0 = ml.last_flush_stats
        assert s0.chunks_flushed > 0 and s0.chunks_skipped == 0
        state["params"]["w1"][3:5] *= 0.5
        ml.save(1, state)
        ml.wait()
        s1 = ml.last_flush_stats
        # the step-0 pack is already resident at level 1: never re-flushed
        assert s1.chunks_skipped >= 1
        assert s1.chunks_flushed >= 1
    # node loss: fresh level 0 restores the delta step from level 1 alone
    import shutil
    shutil.rmtree(l0)
    with MultiLevelCheckpointer(l0, l1, delta=True, keep=None,
                                delta_chunk_bytes=CHUNK) as ml2:
        out = ml2.restore(step=1)
        _assert_equal(out, state)
        # full-coverage prefetch promoted the step to level 0
        assert 1 in ml2.local.all_steps()
        _assert_equal(ml2.local.restore(step=1), state)


# ------------------------------------------- manifest compat / fallback
def test_restore_falls_back_past_unknown_entry_kind(tmp_ckpt_dir, rng):
    """A newer writer's manifest (unknown shard kind) raises typed
    ManifestError on this reader; latest-step restore falls back to the
    next-older valid step instead of dying."""
    import json
    state = _state(rng, n=1)
    with CheckpointManager(tmp_ckpt_dir, delta=True, keep=None,
                           delta_chunk_bytes=CHUNK) as mgr:
        mgr.save(0, state)
        newer = _state(rng, n=1)
        mgr.save(1, newer)
        mpath = os.path.join(tmp_ckpt_dir, "step_00000001", "manifest.json")
        with open(mpath) as f:
            doc = json.load(f)
        for rec in doc["tensors"].values():
            for sh in rec["shards"]:
                sh["kind"] = "erasure-coded-v9"
        with open(mpath, "w") as f:
            json.dump(doc, f)
        with pytest.raises(ManifestError):
            mgr.restore(step=1)
        out = mgr.restore()          # falls back to step 0
        _assert_equal(out, state)
