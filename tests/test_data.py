"""Data pipeline: determinism, host sharding, checkpointable state."""

import numpy as np
import pytest

from repro.data import DataConfig, SyntheticPipeline


def _cfg(**kw):
    base = dict(vocab_size=1000, seq_len=64, global_batch=8, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_per_step():
    p1 = SyntheticPipeline(_cfg())
    p2 = SyntheticPipeline(_cfg())
    b1, b2 = p1.batch_at(5), p2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p1.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_host_shards_are_disjoint_streams():
    hosts = [SyntheticPipeline(_cfg(), host_index=i, host_count=4)
             for i in range(4)]
    batches = [h.batch_at(0)["tokens"] for h in hosts]
    assert all(b.shape == (2, 63) for b in batches)
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(batches[i], batches[j])


def test_labels_are_shifted_tokens():
    b = SyntheticPipeline(_cfg()).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_state_roundtrip():
    p = SyntheticPipeline(_cfg())
    next(p)
    next(p)
    sd = p.state_dict()
    p2 = SyntheticPipeline(_cfg())
    p2.load_state_dict(sd)
    np.testing.assert_array_equal(next(p)["tokens"], next(p2)["tokens"])


def test_vocab_bounds():
    b = SyntheticPipeline(_cfg(vocab_size=50)).batch_at(3)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 50


def test_frontend_embeds():
    p = SyntheticPipeline(_cfg(frontend_len=16, frontend_dim=32))
    b = p.batch_at(0)
    assert b["frontend_embeds"].shape == (8, 16, 32)


def test_batch_not_divisible_raises():
    with pytest.raises(ValueError):
        SyntheticPipeline(_cfg(global_batch=7), host_index=0, host_count=2)
