"""core.trace — span nesting/parenting across threads, the disabled-mode
no-op fast path, drop-oldest ring overflow, Perfetto export round-trips,
and the MetricsRegistry's live (non-copying) adaptation of the stack's
Stats dataclasses (DESIGN.md §17)."""

import json
import threading
import time

import pytest

from repro.core import trace
from repro.core.checkpoint import RestoreMetrics, SaveMetrics
from repro.core.remote import RangeStats


@pytest.fixture(autouse=True)
def _fresh_tracer():
    trace.disable()
    yield
    trace.disable()


# ------------------------------------------------------------------ spans
def test_span_nesting_and_parenting_across_threads():
    trace.enable()
    with trace.span("outer"):
        with trace.span("inner"):
            pass

    def worker():
        with trace.span("outer_t2"):
            with trace.span("inner_t2"):
                pass

    th = threading.Thread(target=worker, name="trace-worker")
    th.start()
    th.join()
    by = {e.name: e for e in trace.drain()}
    assert by["inner"].parent_id == by["outer"].span_id
    assert by["outer"].parent_id == 0
    # each thread keeps its own stack: no cross-thread auto-parenting
    assert by["outer_t2"].parent_id == 0
    assert by["inner_t2"].parent_id == by["outer_t2"].span_id
    assert by["inner_t2"].tid != by["inner"].tid
    assert by["inner_t2"].thread == "trace-worker"
    # timestamps nest
    assert by["outer"].t0 <= by["inner"].t0 <= by["inner"].t1 <= by["outer"].t1


def test_explicit_parent_links_across_threads():
    trace.enable()
    with trace.span("root") as root:
        root_id = root.id

        def worker():
            with trace.span("cross", parent=root_id):
                pass

        th = threading.Thread(target=worker)
        th.start()
        th.join()
    by = {e.name: e for e in trace.drain()}
    assert by["cross"].parent_id == root_id


def test_complete_records_pre_timed_span():
    trace.enable()
    t0 = trace.clock()
    time.sleep(0.001)
    trace.complete("io.write", t0, tier="level0", nbytes=4096)
    (ev,) = trace.drain()
    assert ev.name == "io.write" and ev.tier == "level0"
    assert ev.nbytes == 4096 and ev.t1 >= ev.t0 == t0


# ------------------------------------------------------ disabled fast path
def test_disabled_fast_path_is_shared_noop():
    assert not trace.is_enabled()
    s1 = trace.span("a", tier="level0", nbytes=123)
    s2 = trace.span("b")
    # one shared singleton: the disabled path allocates nothing per call
    assert s1 is s2 is trace._NOOP
    with s1:
        pass
    trace.event("x", attrs={"k": "v"})
    trace.count("c", 2.0)
    trace.observe("h", 0.5)
    trace.complete("y", 0.0, 1.0)
    assert trace.drain() == []
    assert trace.dropped_events() == 0
    assert trace.stall_report(root="save") is None


# ------------------------------------------------------------ ring overflow
def test_ring_overflow_drops_oldest_with_counter():
    trace.enable(capacity=8)
    for i in range(20):
        trace.event(f"e{i}")
    evs = trace.drain()
    assert [e.name for e in evs] == [f"e{i}" for i in range(12, 20)]
    assert trace.dropped_events() == 12
    # drops are per-thread: a fresh thread's ring starts clean
    def worker():
        trace.event("t2")
    th = threading.Thread(target=worker)
    th.start()
    th.join()
    assert trace.dropped_events() == 12
    assert any(e.name == "t2" for e in trace.drain())


# ---------------------------------------------------------- perfetto export
def test_perfetto_export_round_trips(tmp_path):
    trace.enable()
    with trace.span("save", tier="host", nbytes=96 << 20,
                    attrs={"step": 7}):
        with trace.span("flush", tier="level0"):
            trace.event("hedge.issue", tier="level1",
                        attrs={"path": "data.bin"})
    path = tmp_path / "trace.json"
    trace.export_perfetto(str(path))
    doc = json.loads(path.read_text())
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert {e["name"] for e in xs} == {"save", "flush"}
    by = {e["name"]: e for e in xs}
    # microsecond timestamps, monotonically consistent nesting
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0
    assert by["save"]["ts"] <= by["flush"]["ts"]
    assert (by["flush"]["ts"] + by["flush"]["dur"]
            <= by["save"]["ts"] + by["save"]["dur"] + 1.0)
    assert by["save"]["args"]["step"] == 7
    assert by["save"]["args"]["bytes"] == 96 << 20
    # spans land on tier-named tracks; instants ride along
    procs = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("name") == "process_name"}
    assert {"tier:host", "tier:level0", "tier:level1"} <= procs
    insts = [e for e in doc["traceEvents"] if e.get("ph") == "i"]
    assert [e["name"] for e in insts] == ["hedge.issue"]
    assert insts[0]["args"]["path"] == "data.bin"


def test_prometheus_export_textfile(tmp_path):
    trace.enable()
    trace.count("faults_injected", 3)
    with trace.span("flush", tier="level0"):
        pass
    text = trace.export_prometheus(str(tmp_path / "metrics.prom"))
    assert (tmp_path / "metrics.prom").read_text() == text
    assert "crtrace_faults_injected 3" in text
    assert "crtrace_trace_dropped_events 0" in text
    assert 'crtrace_span_seconds_flush_bucket{tier="level0",le="+Inf"} 1' \
        in text
    assert "crtrace_span_seconds_flush_count" in text


# --------------------------------------------------------- metrics registry
def test_registry_adapts_stats_without_copying_semantics_drift():
    sm = SaveMetrics(step=3)
    rm = RestoreMetrics(step=3)
    rs = RangeStats()
    reg = trace.MetricsRegistry()
    reg.register("save", sm)
    reg.register("restore", lambda: rm)      # callables resolve per snapshot
    reg.register("range", rs)
    snap1 = reg.snapshot()
    assert snap1["save"]["written_bytes"] == 0
    # mutate AFTER registration: the registry holds the live object
    sm.written_bytes = 123
    sm.total_bytes = 2_000_000_000
    sm.flush_seconds = 2.0
    rm.read_seconds = 1.0
    rm.decode_seconds = 0.5
    rs.range_seconds.append(0.25)
    snap2 = reg.snapshot()
    assert snap2["save"]["written_bytes"] == 123
    assert snap2["range"]["range_seconds"] == [0.25]
    # @property views are computed at snapshot time, not frozen
    assert snap2["save"]["flush_gbps"] == pytest.approx(1.0)
    assert snap2["restore"]["stage_seconds"] == pytest.approx(1.5)
    assert reg.query("save.flush_gbps") == pytest.approx(1.0)
    # the snapshot is detached: mutating it never writes back to the source
    snap2["range"]["range_seconds"].append(9.9)
    snap2["save"]["written_bytes"] = -1
    assert rs.range_seconds == [0.25]
    assert sm.written_bytes == 123
    with pytest.raises(KeyError):
        reg.query("save.no_such_field")


# ------------------------------------------------------------- stall report
def test_stall_report_attribution_sums_to_wall():
    trace.enable()
    with trace.span("save", nbytes=1 << 20):
        with trace.span("extract"):           # d2h
            time.sleep(0.004)
        with trace.span("fingerprint"):       # uncategorized -> compute
            time.sleep(0.002)
        with trace.span("flush", tier="level0"):
            with trace.span("budget.wait"):   # stage wait inside the flush
                time.sleep(0.002)
            time.sleep(0.004)
    rep = trace.stall_report(root="save")
    assert rep is not None
    assert set(rep.attribution) == set(trace.CATEGORIES)
    assert sum(rep.attribution.values()) == pytest.approx(rep.wall, rel=1e-6)
    assert rep.attribution["d2h"] >= 0.003
    assert rep.attribution["stage_wait"] >= 0.001
    # the nested wait is NOT double-counted into the flush
    assert rep.attribution["level0_write"] >= 0.003
    assert rep.wall >= 0.011
    out = rep.render()
    assert "top bottleneck" in out and "save" in out
