"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container without hypothesis
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.quantize import LANE_COLS, ROW_BLK, dequantize_blocks, quantize_blocks
from repro.kernels.rglru import FEAT_BLK, SEQ_CHUNK, rglru_scan


@pytest.mark.parametrize("rows", [8, 16, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_kernel_matches_ref(rows, dtype, rng):
    x = jnp.asarray(rng.standard_normal((rows, LANE_COLS)), dtype)
    qk, sk = quantize_blocks(x, interpret=True)
    qr, sr = ref.quantize_blocks_ref(x)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    # dequant
    dk = dequantize_blocks(qk, sk, out_dtype=jnp.float32, interpret=True)
    dr = ref.dequantize_blocks_ref(qr, sr, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), rtol=1e-6)


def test_quantize_edge_cases():
    # all-zero rows must not divide by zero
    x = jnp.zeros((ROW_BLK, LANE_COLS), jnp.float32)
    q, s = quantize_blocks(x, interpret=True)
    assert np.all(np.asarray(q) == 0) and np.all(np.asarray(s) == 1.0)
    # extreme magnitudes
    x = jnp.full((ROW_BLK, LANE_COLS), 1e30, jnp.float32)
    q, s = quantize_blocks(x, interpret=True)
    assert np.all(np.asarray(q) == 127)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(1, 5000))
def test_quantize_tensor_any_shape(n):
    """Property: arbitrary-size tensors survive pad→quant→dequant ≈ identity."""
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    q, s = ops.quantize_tensor(x, interpret=True)
    y = ops.dequantize_tensor(q, s, (n,), jnp.float32, interpret=True)
    scale = float(jnp.max(jnp.abs(x))) + 1e-9
    assert float(jnp.max(jnp.abs(y - x))) <= scale / 100


@pytest.mark.parametrize("B,S,R", [(1, SEQ_CHUNK, FEAT_BLK),
                                   (2, 2 * SEQ_CHUNK, FEAT_BLK),
                                   (2, SEQ_CHUNK, 2 * FEAT_BLK),
                                   (3, 3 * SEQ_CHUNK, 2 * FEAT_BLK)])
def test_rglru_kernel_matches_ref(B, S, R, rng):
    a = jnp.asarray(rng.uniform(0.7, 0.999, (B, S, R)).astype(np.float32))
    b = jnp.asarray((rng.standard_normal((B, S, R)) * 0.1).astype(np.float32))
    hk = rglru_scan(a, b, interpret=True)
    hr = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               rtol=3e-4, atol=3e-5)


def test_rglru_ops_padding(rng):
    """Non-aligned (S, R) go through the padded wrapper."""
    a = jnp.asarray(rng.uniform(0.8, 0.99, (2, 300, 200)).astype(np.float32))
    b = jnp.asarray((rng.standard_normal((2, 300, 200)) * 0.1).astype(np.float32))
    hk = ops.rglru_scan(a, b, interpret=True)
    hr = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               rtol=3e-4, atol=3e-5)


def test_rglru_carry_across_chunks(rng):
    """State must flow across SEQ_CHUNK boundaries (grid carry)."""
    B, S, R = 1, 2 * SEQ_CHUNK, FEAT_BLK
    a = jnp.full((B, S, R), 0.999, jnp.float32)   # long memory
    b = jnp.zeros((B, S, R), jnp.float32).at[:, 0, :].set(1.0)
    h = rglru_scan(a, b, interpret=True)
    # h_t = 0.999^t exactly; check at a point past the chunk boundary
    t = SEQ_CHUNK + 5
    np.testing.assert_allclose(np.asarray(h[0, t, 0]), 0.999 ** t, rtol=1e-4)
