"""CheckpointManager: roundtrips, async, crash consistency, corruption, GC,
quantized moments."""

import glob
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CheckpointManager, EngineConfig
from repro.core.manifest import Manifest


def _state():
    return {
        "params": {"w": jnp.arange(4096, dtype=jnp.float32).reshape(64, 64),
                   "b": jnp.full((64,), 0.5, jnp.bfloat16)},
        "opt": {"mu": jnp.zeros((64, 64)), "count": jnp.zeros((), jnp.int32)},
        "step": 42,
        "rng": jax.random.key(7),
        "note": "lean-data",
    }


@pytest.mark.parametrize("engine", ["aggregated", "datastates", "snapshot",
                                    "torchsave"])
def test_roundtrip(engine, tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, engine=engine) as mgr:
        mgr.save(10, state)
        r = mgr.restore(state_template=state)
    assert r["step"] == 42 and r["note"] == "lean-data"
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert r["params"]["b"].dtype == jnp.bfloat16
    assert (jax.random.key_data(r["rng"]).tolist()
            == jax.random.key_data(state["rng"]).tolist())


def test_async_overlap(tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, async_save=True) as mgr:
        m = mgr.save(1, state)
        assert m.blocking_seconds < m.end_to_end_seconds or \
            m.end_to_end_seconds == 0.0  # e2e filled after flush
        mgr.wait()
        assert mgr.latest_step() == 1
        r = mgr.restore(state_template=state)
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_versioning_and_gc(tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, keep=2) as mgr:
        for s in (10, 20, 30, 40):
            mgr.save(s, state)
        assert mgr.all_steps() == [30, 40]
        r = mgr.restore(state_template=state, step=30)
        assert r["step"] == 42


def test_crash_leaves_no_valid_partial(tmp_ckpt_dir):
    """A .tmp dir (simulated crash) must be invisible and GC'd.

    Every save stamps its staging dir with an ownership pidfile
    (checkpoint.OWNER_NAME), so a crashed process leaves a dir owned by a
    dead pid — which GC reaps. (A LIVE owner's dir is spared; that race is
    covered in test_multiwriter.)"""
    from repro.core.checkpoint import OWNER_NAME
    state = _state()
    with CheckpointManager(tmp_ckpt_dir) as mgr:
        mgr.save(1, state)
    # simulate a crashed save: a tmp dir with data whose owner pid is dead
    crash = os.path.join(tmp_ckpt_dir, "step_00000002.tmp-dead")
    os.makedirs(os.path.join(crash, "data"))
    with open(os.path.join(crash, "data", "junk.bin"), "wb") as f:
        f.write(b"x" * 100)
    with open(os.path.join(crash, OWNER_NAME), "w") as f:
        f.write(f"{2**30} 0")
    with CheckpointManager(tmp_ckpt_dir) as mgr2:
        assert mgr2.all_steps() == [1]          # tmp not listed
        assert not glob.glob(os.path.join(tmp_ckpt_dir, "*.tmp-*"))  # GC'd


def test_corruption_detected(tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, verify_crc=True) as mgr:
        mgr.save(1, state)
        # flip bytes in the data file
        man = Manifest.load(os.path.join(tmp_ckpt_dir, "step_00000001"))
        sh = man.tensors["params/w"].shards[0]
        path = os.path.join(tmp_ckpt_dir, "step_00000001", sh.path)
        with open(path, "r+b") as f:
            f.seek(sh.offset + 10)
            f.write(b"\xff\xfe\xfd\xfc")
        with pytest.raises((IOError, OSError)):
            mgr.restore(state_template=state)


def test_quantized_moments(tmp_ckpt_dir):
    state = {"opt": {"mu": jax.random.normal(jax.random.key(0), (256, 512))},
             "params": {"w": jnp.ones((128,), jnp.float32)}}
    with CheckpointManager(tmp_ckpt_dir,
                           quantize_prefixes=("opt/mu",)) as mgr:
        mgr.save(1, state)
        man = Manifest.load(os.path.join(tmp_ckpt_dir, "step_00000001"))
        assert "opt/mu" in man.extra["quantized"]
        stored = sum(s.nbytes for s in man.tensors["opt/mu"].shards)
        assert stored < 256 * 512 * 4 / 2.5      # ~4x smaller than fp32
        r = mgr.restore(state_template=state)
    a, b = np.asarray(r["opt"]["mu"]), np.asarray(state["opt"]["mu"])
    assert np.max(np.abs(a - b)) / np.max(np.abs(b)) < 0.01
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_restore_without_template(tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir) as mgr:
        mgr.save(5, state)
        r = mgr.restore()
    assert isinstance(r["params"]["w"], np.ndarray)
    np.testing.assert_array_equal(r["params"]["w"],
                                  np.asarray(state["params"]["w"]))


def test_missing_checkpoint_raises(tmp_ckpt_dir):
    with CheckpointManager(tmp_ckpt_dir) as mgr:
        with pytest.raises(FileNotFoundError):
            mgr.restore()


def test_keep_none_retains_every_step(tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, keep=None) as mgr:
        for s in (1, 2, 3, 4, 5):
            mgr.save(s, state)
        assert mgr.all_steps() == [1, 2, 3, 4, 5]


def test_keep_zero_rejected(tmp_ckpt_dir):
    """keep=0 used to silently mean "keep everything"; it is now an
    explicit error steering callers to keep=None."""
    with pytest.raises(ValueError, match="keep=None"):
        CheckpointManager(tmp_ckpt_dir, keep=0)
    with pytest.raises(ValueError, match="keep"):
        CheckpointManager(tmp_ckpt_dir, keep=-3)
