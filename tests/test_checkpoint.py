"""CheckpointManager: roundtrips, async, crash consistency, corruption, GC,
quantized moments."""

import glob
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CheckpointManager, EngineConfig
from repro.core.manifest import Manifest


def _state():
    return {
        "params": {"w": jnp.arange(4096, dtype=jnp.float32).reshape(64, 64),
                   "b": jnp.full((64,), 0.5, jnp.bfloat16)},
        "opt": {"mu": jnp.zeros((64, 64)), "count": jnp.zeros((), jnp.int32)},
        "step": 42,
        "rng": jax.random.key(7),
        "note": "lean-data",
    }


@pytest.mark.parametrize("engine", ["aggregated", "datastates", "snapshot",
                                    "torchsave"])
def test_roundtrip(engine, tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, engine=engine) as mgr:
        mgr.save(10, state)
        r = mgr.restore(state_template=state)
    assert r["step"] == 42 and r["note"] == "lean-data"
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert r["params"]["b"].dtype == jnp.bfloat16
    assert (jax.random.key_data(r["rng"]).tolist()
            == jax.random.key_data(state["rng"]).tolist())


def test_async_overlap(tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, async_save=True) as mgr:
        m = mgr.save(1, state)
        assert m.blocking_seconds < m.end_to_end_seconds or \
            m.end_to_end_seconds == 0.0  # e2e filled after flush
        mgr.wait()
        assert mgr.latest_step() == 1
        r = mgr.restore(state_template=state)
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_versioning_and_gc(tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, keep=2) as mgr:
        for s in (10, 20, 30, 40):
            mgr.save(s, state)
        assert mgr.all_steps() == [30, 40]
        r = mgr.restore(state_template=state, step=30)
        assert r["step"] == 42


def test_crash_leaves_no_valid_partial(tmp_ckpt_dir):
    """A .tmp dir (simulated crash) must be invisible and GC'd.

    Every save stamps its staging dir with an ownership pidfile
    (checkpoint.OWNER_NAME), so a crashed process leaves a dir owned by a
    dead pid — which GC reaps. (A LIVE owner's dir is spared; that race is
    covered in test_multiwriter.)"""
    from repro.core.checkpoint import OWNER_NAME
    state = _state()
    with CheckpointManager(tmp_ckpt_dir) as mgr:
        mgr.save(1, state)
    # simulate a crashed save: a tmp dir with data whose owner pid is dead
    crash = os.path.join(tmp_ckpt_dir, "step_00000002.tmp-dead")
    os.makedirs(os.path.join(crash, "data"))
    with open(os.path.join(crash, "data", "junk.bin"), "wb") as f:
        f.write(b"x" * 100)
    with open(os.path.join(crash, OWNER_NAME), "w") as f:
        f.write(f"{2**30} 0")
    with CheckpointManager(tmp_ckpt_dir) as mgr2:
        assert mgr2.all_steps() == [1]          # tmp not listed
        assert not glob.glob(os.path.join(tmp_ckpt_dir, "*.tmp-*"))  # GC'd


def test_corruption_detected(tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, verify_crc=True) as mgr:
        mgr.save(1, state)
        # flip bytes in the data file
        man = Manifest.load(os.path.join(tmp_ckpt_dir, "step_00000001"))
        sh = man.tensors["params/w"].shards[0]
        path = os.path.join(tmp_ckpt_dir, "step_00000001", sh.path)
        with open(path, "r+b") as f:
            f.seek(sh.offset + 10)
            f.write(b"\xff\xfe\xfd\xfc")
        with pytest.raises((IOError, OSError)):
            mgr.restore(state_template=state)


def test_quantized_moments(tmp_ckpt_dir):
    state = {"opt": {"mu": jax.random.normal(jax.random.key(0), (256, 512))},
             "params": {"w": jnp.ones((128,), jnp.float32)}}
    with CheckpointManager(tmp_ckpt_dir,
                           quantize_prefixes=("opt/mu",)) as mgr:
        mgr.save(1, state)
        man = Manifest.load(os.path.join(tmp_ckpt_dir, "step_00000001"))
        assert "opt/mu" in man.extra["quantized"]
        stored = sum(s.nbytes for s in man.tensors["opt/mu"].shards)
        assert stored < 256 * 512 * 4 / 2.5      # ~4x smaller than fp32
        r = mgr.restore(state_template=state)
    a, b = np.asarray(r["opt"]["mu"]), np.asarray(state["opt"]["mu"])
    assert np.max(np.abs(a - b)) / np.max(np.abs(b)) < 0.01
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_restore_without_template(tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir) as mgr:
        mgr.save(5, state)
        r = mgr.restore()
    assert isinstance(r["params"]["w"], np.ndarray)
    np.testing.assert_array_equal(r["params"]["w"],
                                  np.asarray(state["params"]["w"]))


def test_missing_checkpoint_raises(tmp_ckpt_dir):
    with CheckpointManager(tmp_ckpt_dir) as mgr:
        with pytest.raises(FileNotFoundError):
            mgr.restore()


def test_keep_none_retains_every_step(tmp_ckpt_dir):
    state = _state()
    with CheckpointManager(tmp_ckpt_dir, keep=None) as mgr:
        for s in (1, 2, 3, 4, 5):
            mgr.save(s, state)
        assert mgr.all_steps() == [1, 2, 3, 4, 5]


def test_keep_zero_rejected(tmp_ckpt_dir):
    """keep=0 used to silently mean "keep everything"; it is now an
    explicit error steering callers to keep=None."""
    with pytest.raises(ValueError, match="keep=None"):
        CheckpointManager(tmp_ckpt_dir, keep=0)
    with pytest.raises(ValueError, match="keep"):
        CheckpointManager(tmp_ckpt_dir, keep=-3)


# ----------------------------------------------------- _gc_tmp ownership edges
def _tmp_dir_with_owner(root, owner_line, *, backdate_s=3600.0):
    """A staged .tmp dir with a hand-written owner record, mtime backdated
    past TMP_GRACE_S so the age fallback cannot spare it."""
    from repro.core import checkpoint as ck
    tmp = os.path.join(root, "step_00000009.tmp-deadbeef")
    os.makedirs(tmp)
    with open(os.path.join(tmp, ck.OWNER_NAME), "w") as f:
        f.write(owner_line)
    old = __import__("time").time() - backdate_s
    os.utime(tmp, (old, old))
    return tmp


def test_gc_tmp_reaps_recycled_pid_owner(tmp_ckpt_dir):
    """A stale owner record whose pid has been RECYCLED by a live unrelated
    process must still be reaped: the pidfile epoch predates that process's
    /proc start time, proving the recording save is dead."""
    from repro.core import checkpoint as ck
    if ck._proc_start_time(1) is None:
        pytest.skip("no readable procfs start times on this platform")
    import socket
    os.makedirs(tmp_ckpt_dir, exist_ok=True)
    # pid 1 is alive (and is not us); an epoch far before the system booted
    # is strictly before ANY live process started
    line = f"1 1.000 {socket.gethostname()}"
    tmp = _tmp_dir_with_owner(tmp_ckpt_dir, line)
    assert not ck.tmp_in_flight(tmp)
    CheckpointManager(tmp_ckpt_dir).close()     # init runs _gc_tmp
    assert not os.path.exists(tmp)


def test_gc_tmp_spares_live_owner_even_when_old(tmp_ckpt_dir):
    """A genuinely live owner (this process) is spared regardless of dir
    age — a long-running save must never be reaped out from under."""
    import socket
    import time as _t
    from repro.core import checkpoint as ck
    os.makedirs(tmp_ckpt_dir, exist_ok=True)
    line = f"{os.getpid()} {_t.time():.3f} {socket.gethostname()}"
    tmp = _tmp_dir_with_owner(tmp_ckpt_dir, line)
    assert ck.tmp_in_flight(tmp)
    CheckpointManager(tmp_ckpt_dir).close()
    assert os.path.exists(tmp)


def test_gc_tmp_pidfile_unlinked_mid_scan_falls_back_to_age(tmp_ckpt_dir):
    """When the owner pidfile vanishes between listdir and the ownership
    probe (publisher removed it at commit), liveness falls back to dir age:
    young dirs are spared, past-grace dirs are reaped."""
    import time as _t
    from repro.core import checkpoint as ck
    os.makedirs(tmp_ckpt_dir, exist_ok=True)
    young = os.path.join(tmp_ckpt_dir, "step_00000001.tmp-aaaaaaaa")
    stale = os.path.join(tmp_ckpt_dir, "step_00000002.tmp-bbbbbbbb")
    os.makedirs(young)
    os.makedirs(stale)          # neither has an owner file: the mid-scan
    old = _t.time() - 3600.0    # unlink means the probe sees none either
    os.utime(stale, (old, old))
    assert ck.tmp_in_flight(young)
    assert not ck.tmp_in_flight(stale)
    CheckpointManager(tmp_ckpt_dir).close()
    assert os.path.exists(young)
    assert not os.path.exists(stale)


def test_gc_tmp_foreign_host_owner_judged_by_age(tmp_ckpt_dir):
    """An owner record from ANOTHER host: its pid is meaningless to this
    kernel, so only age decides — stale foreign dirs are reaped."""
    import time as _t
    from repro.core import checkpoint as ck
    os.makedirs(tmp_ckpt_dir, exist_ok=True)
    line = f"{os.getpid()} {_t.time():.3f} not-this-host.example"
    tmp = _tmp_dir_with_owner(tmp_ckpt_dir, line)
    assert not ck.tmp_in_flight(tmp)        # old dir, foreign host
    fresh = os.path.join(tmp_ckpt_dir, "step_00000003.tmp-cccccccc")
    os.makedirs(fresh)
    with open(os.path.join(fresh, ck.OWNER_NAME), "w") as f:
        f.write(line)
    assert ck.tmp_in_flight(fresh)          # young dir, foreign host
