"""Trainer: resume determinism (the gold fault-tolerance property)."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig
from repro.train.trainer import Trainer, TrainerConfig


def _make(tmp, steps, ckpt_every, engine="aggregated", seed=0, writers=0):
    cfg = get_config("qwen2.5-3b").scaled_down(layers=2, width_div=16,
                                               vocab=256)
    tcfg = TrainerConfig(steps=steps, ckpt_every=ckpt_every,
                         ckpt_dir=tmp, ckpt_engine=engine,
                         async_ckpt=False, log_every=0, seed=seed,
                         ckpt_writers=writers)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4,
                      seed=seed)
    return Trainer(cfg, tcfg, data_cfg=data)


def test_resume_is_bit_exact(tmp_path):
    """train(8) straight == train(4) + kill + resume train(8)."""
    t_straight = _make(str(tmp_path / "a"), steps=8, ckpt_every=0)
    out_a = t_straight.run()
    t_straight.close()

    t1 = _make(str(tmp_path / "b"), steps=4, ckpt_every=4)
    t1.run()
    t1.close()
    t2 = _make(str(tmp_path / "b"), steps=8, ckpt_every=4)
    out_b = t2.run()
    t2.close()

    pa = jax.tree.leaves(out_a["state"]["params"])
    pb = jax.tree.leaves(out_b["state"]["params"])
    for a, b in zip(pa, pb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(out_b["state"]["step"]) == 8


def test_loss_decreases(tmp_path):
    t = _make(str(tmp_path / "c"), steps=40, ckpt_every=0)
    t.tcfg.log_every = 5
    out = t.run()
    t.close()
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("engine", ["aggregated", "datastates"])
def test_resume_across_engines(tmp_path, engine):
    t1 = _make(str(tmp_path / engine), steps=3, ckpt_every=3, engine=engine)
    t1.run()
    t1.close()
    t2 = _make(str(tmp_path / engine), steps=5, ckpt_every=0, engine=engine)
    out = t2.run()
    t2.close()
    assert int(out["state"]["step"]) == 5


def test_resume_from_multiwriter_checkpoint(tmp_path):
    """A 2-writer concurrent checkpoint resumes bit-exactly — on a
    multi-writer trainer AND on a plain single-manager one (the merged
    manifest is an ordinary checkpoint)."""
    t_straight = _make(str(tmp_path / "a"), steps=6, ckpt_every=0)
    out_a = t_straight.run()
    t_straight.close()

    t1 = _make(str(tmp_path / "b"), steps=3, ckpt_every=3, writers=2)
    t1.run()
    t1.close()
    # resume WITHOUT multi-writer: any reader restores the merged step
    t2 = _make(str(tmp_path / "b"), steps=6, ckpt_every=6, writers=0)
    out_b = t2.run()
    t2.close()

    pa = jax.tree.leaves(out_a["state"]["params"])
    pb = jax.tree.leaves(out_b["state"]["params"])
    for a, b in zip(pa, pb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(out_b["state"]["step"]) == 6
