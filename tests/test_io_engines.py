"""I/O engine backends: roundtrips, queue-depth bounds, stats, O_DIRECT."""

import os

import pytest

from repro.core.buffers import BufferPool
from repro.core.io_engine import (IORequest, OP_READ, OP_WRITE, PosixEngine,
                                  ThreadPoolEngine, UringEngine, make_engine,
                                  open_for)
from repro.core.uring import probe_io_uring

BACKENDS = ["threadpool", "posix"] + (["uring"] if probe_io_uring() else [])


@pytest.fixture
def pool():
    p = BufferPool()
    yield p
    p.drain()


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("direct", [False, True])
def test_roundtrip(backend, direct, tmp_path, pool, rng):
    data = rng.integers(0, 256, size=(1 << 20,), dtype="uint8").tobytes()
    path = str(tmp_path / "f.bin")
    wb = pool.get(len(data))
    wb.write_bytes(data)
    fd = open_for(path, "w", direct=direct)
    with make_engine(backend) as eng:
        CH = 1 << 17
        reqs = [IORequest(OP_WRITE, fd, off, wb, off, CH, user_data=i)
                for i, off in enumerate(range(0, len(data), CH))]
        comps = eng.run(reqs, queue_depth=8)
        assert len(comps) == len(reqs)
        eng.fsync(fd)
    os.close(fd)
    rb = pool.get(len(data))
    fd = open_for(path, "r", direct=direct)
    with make_engine(backend) as eng:
        reqs = [IORequest(OP_READ, fd, off, rb, off, CH, user_data=i)
                for i, off in enumerate(range(0, len(data), CH))]
        eng.run(reqs, queue_depth=8)
    os.close(fd)
    assert bytes(rb.view(0, len(data))) == data
    wb.release()
    rb.release()


@pytest.mark.parametrize("backend", BACKENDS)
def test_queue_depth_respected(backend, tmp_path, pool):
    fd = open_for(str(tmp_path / "q.bin"), "w")
    buf = pool.get(4096 * 64)
    with make_engine(backend) as eng:
        reqs = [IORequest(OP_WRITE, fd, i * 4096, buf, i * 4096, 4096,
                          user_data=i) for i in range(64)]
        comps = eng.run(reqs, queue_depth=4)
        assert len(comps) == 64
        if backend != "posix":
            assert eng.stats.max_inflight <= 8  # qd + one refill batch
    os.close(fd)
    buf.release()


def test_stats_accounting(tmp_path, pool):
    fd = open_for(str(tmp_path / "s.bin"), "w")
    buf = pool.get(1 << 16)
    with make_engine("posix") as eng:
        eng.run([IORequest(OP_WRITE, fd, 0, buf, 0, 1 << 16, user_data=1)])
        assert eng.stats.bytes_written == 1 << 16
        assert eng.stats.ops == 1
    os.close(fd)
    buf.release()


def test_auto_prefers_uring():
    eng = make_engine("auto")
    want = "uring" if probe_io_uring() else "threadpool"
    assert eng.name == want
    eng.close()


def test_open_for_creates_dirs(tmp_path):
    p = str(tmp_path / "a" / "b" / "c.bin")
    fd = open_for(p, "w")
    os.close(fd)
    assert os.path.exists(p)
