"""End-to-end system behaviour: train → checkpoint → crash → resume → serve,
and a small-scale engine ordering sanity check (aggregated ≥ baselines on
realistic fragmented layouts)."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import CheckpointManager, EngineConfig
from repro.core.engines import ReadReq, SaveItem, make_cr_engine
from repro.data import DataConfig
from repro.models import transformer as T
from repro.train.trainer import Trainer, TrainerConfig


def test_train_checkpoint_resume_serve(tmp_path):
    """The full lifecycle on one reduced model."""
    ckpt = str(tmp_path / "ckpt")
    cfg = get_config("gemma2-9b").scaled_down(layers=2, width_div=16,
                                              vocab=256)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)

    # phase 1: train 6 steps with checkpoints every 3
    t1 = Trainer(cfg, TrainerConfig(steps=6, ckpt_every=3, ckpt_dir=ckpt,
                                    async_ckpt=True, log_every=0),
                 data_cfg=data)
    out1 = t1.run()
    t1.close()
    assert int(out1["state"]["step"]) == 6

    # phase 2: "crash" (new trainer) and train to 9 — resumes from 6
    t2 = Trainer(cfg, TrainerConfig(steps=9, ckpt_every=3, ckpt_dir=ckpt,
                                    log_every=0), data_cfg=data)
    out2 = t2.run()
    t2.close()
    assert int(out2["state"]["step"]) == 9

    # phase 3: serve — restore params only and decode a few tokens
    with CheckpointManager(ckpt) as mgr:
        tmpl = {"train": out2["state"], "data": {"data_step": 0}}
        restored = mgr.restore(state_template=tmpl)
    params = restored["train"]["params"]
    B = 2
    cache = T.init_cache(cfg, B, max_len=8)
    tok = jnp.ones((B, 1), jnp.int32)
    for t in range(4):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, cache = T.decode_step(params, cfg, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("engine", ["aggregated", "datastates", "snapshot"])
def test_request_counts_reflect_design(engine, tmp_path, rng):
    """The design axes the paper measures must be visible in the stats:
    aggregated coalesces to few requests; baselines issue per-object."""
    sizes = [int(rng.integers(1000, 400_000)) for _ in range(64)]
    items = [SaveItem(f"t{i}", rng.integers(0, 256, (n,), dtype=np.uint8),
                      "uint8", (n,), ((0, n),)) for i, n in enumerate(sizes)]
    eng = make_cr_engine(engine, EngineConfig(chunk_bytes=1 << 20,
                                              coalesce_bytes=32 << 20))
    eng.save(str(tmp_path / engine), items, step=1)
    s = eng.last_save_stats
    if engine == "aggregated":
        assert s.io_requests <= 4, s.io_requests        # coalesced
        assert s.files == 1
    else:
        assert s.io_requests >= len(items)              # per-object
    eng.close()


def test_fragmented_layout_read_counts(tmp_path, rng):
    """Restore read-coalescing: aggregated reads few extents for many objs."""
    sizes = [4096] * 128
    items = [SaveItem(f"t{i}", rng.integers(0, 256, (n,), dtype=np.uint8),
                      "uint8", (n,), ((0, n),)) for i, n in enumerate(sizes)]
    eng = make_cr_engine("aggregated", EngineConfig(coalesce_bytes=1 << 20))
    d = str(tmp_path / "frag")
    m = eng.save(d, items, step=1)
    reqs = [ReadReq(k, r.shards[0].path, r.shards[0].offset,
                    r.shards[0].nbytes) for k, r in m.tensors.items()]
    out = eng.read(d, reqs)
    assert eng.last_restore_stats.io_requests <= 2      # one coalesced read
    assert all(out[f"t{i}"].nbytes == 4096 for i in range(128))
    eng.close()
