"""Buffer pool: alignment, size classes, reuse accounting, disabled mode."""

import mmap

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container without hypothesis
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.buffers import AlignedBuffer, BufferPool, PAGE, align_up


def test_alignment():
    for n in (1, 100, PAGE, PAGE + 1, 10 * PAGE + 7):
        b = AlignedBuffer(n)
        assert b.address % PAGE == 0
        assert b.nbytes % PAGE == 0 and b.nbytes >= n
        b.destroy()


def test_size_class_power_of_two():
    assert BufferPool.size_class(1) == PAGE
    assert BufferPool.size_class(PAGE) == PAGE
    assert BufferPool.size_class(PAGE + 1) == 2 * PAGE
    assert BufferPool.size_class(3 * PAGE) == 4 * PAGE


def test_reuse():
    pool = BufferPool()
    a = pool.get(1000)
    a.release()
    b = pool.get(2000)  # same class (1 page vs 1 page? 2000 <= PAGE=4096)
    assert pool.stats.reuses == 1 and pool.stats.allocations == 1
    b.release()
    pool.drain()


def test_disabled_pool_never_reuses():
    pool = BufferPool(disabled=True)
    for _ in range(5):
        buf = pool.get(PAGE)
        buf.release()
    assert pool.stats.reuses == 0
    assert pool.stats.allocations == 5


def test_write_view_roundtrip():
    pool = BufferPool()
    b = pool.get(8192)
    b.write_bytes(b"x" * 100, offset=50)
    assert bytes(b.view(50, 100)) == b"x" * 100
    b.release()
    pool.drain()


@settings(max_examples=30, deadline=None)
@given(sizes=st.lists(st.integers(1, 1 << 20), min_size=1, max_size=24))
def test_pool_invariants(sizes):
    """Property: get/release of arbitrary size sequences keeps the books."""
    pool = BufferPool()
    held = []
    for i, n in enumerate(sizes):
        buf = pool.get(n)
        assert buf.nbytes >= n and buf.address % PAGE == 0
        held.append(buf)
        if i % 2:
            held.pop(0).release()
    s = pool.stats
    assert s.allocations + s.reuses == len(sizes)
    assert s.released == len(sizes) - len(held)
    for b in held:
        b.release()
    assert pool.free_buffers() <= len(sizes)
    pool.drain()
