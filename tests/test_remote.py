"""Object-store level-2 tier (DESIGN.md §15): simulator semantics, the
parallel hedged range scheduler, chunk-dedup upload with manifest-last
publish, direct-to-pipeline stream restore, and remote promotion."""

import os
import time

import numpy as np
import pytest

from repro.core import (CheckpointManager, EngineConfig, Manifest,
                        RemoteCheckpointer, RemoteConfig, RemotePrefetcher,
                        RemoteTier, RemoteTransferEngine, SimObjectStore,
                        SimProfile)
from repro.core import faults
from repro.core.aggregation import Extent
from repro.core.remote import join_key


def _state():
    rng = np.random.default_rng(9)
    return {"w": rng.standard_normal((64, 1024)).astype(np.float32),
            "b": rng.standard_normal(512),
            "step": 7}


def _assert_same(got, want):
    for k, v in want.items():
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(v))


# ------------------------------------------------------------- store basics
def test_sim_store_put_get_head_list(tmp_path):
    store = SimObjectStore(str(tmp_path / "bucket"))
    data = os.urandom(10_000)
    meta = store.put("a/b/obj", data)
    assert meta.size == len(data)
    assert store.head("a/b/obj").size == len(data)
    assert store.head("missing") is None
    assert store.get_range("a/b/obj", 100, 50) == data[100:150]
    assert store.get("a/b/obj") == data
    assert store.list_prefix("a/") == ["a/b/obj"]
    # atomic PUT: no tmp staging files are ever listed or left behind
    assert not [k for k in store.list_prefix("a/") if ".tmp-put-" in k]
    store.delete("a/b/obj")
    assert store.head("a/b/obj") is None


def test_join_key_normalizes_chunk_refs():
    # a manifest's ../chunkstore/<pack> ref under a step key resolves to
    # the tier-wide chunkstore object
    assert join_key("p/step_00000001", "../chunkstore/x.pack") == \
        "p/chunkstore/x.pack"
    store = SimObjectStore("/tmp/does-not-matter")
    with pytest.raises(ValueError):
        store.backing_path("../escape")


def test_partial_range_responses_reassembled(tmp_path):
    """A store that always answers ranged GETs with a prefix still yields
    complete objects (the scheduler re-requests the remainder)."""
    store = SimObjectStore(str(tmp_path / "bucket"),
                           SimProfile(partial_prob=1.0, seed=3))
    data = os.urandom(300_000)
    store.put("o", data)
    assert store.get("o") == data
    eng = RemoteTransferEngine(store, RemoteConfig(range_bytes=64 << 10))
    dst = str(tmp_path / "o.local")
    stats = eng.transfer([("o", dst)])
    with open(dst, "rb") as f:
        assert f.read() == data
    assert stats.retries >= 1
    eng.close()


class _FlakyStore(SimObjectStore):
    """First N ranged GETs fail with a transient 503."""

    def __init__(self, root, fail_n):
        super().__init__(root)
        self.fail_n = fail_n

    def get_range(self, key, offset, nbytes):
        if self.fail_n > 0:
            self.fail_n -= 1
            from repro.core import RemoteTransientError
            raise RemoteTransientError(503, key, "GET")
        return super().get_range(key, offset, nbytes)


def test_transient_errors_retried(tmp_path):
    store = _FlakyStore(str(tmp_path / "bucket"), fail_n=2)
    data = os.urandom(200_000)
    store.put("o", data)
    eng = RemoteTransferEngine(
        store, RemoteConfig(range_bytes=1 << 20, retry_backoff_s=0.001))
    dst = str(tmp_path / "o.local")
    stats = eng.transfer([("o", dst)])
    with open(dst, "rb") as f:
        assert f.read() == data
    assert stats.retries >= 2
    eng.close()


# ---------------------------------------------------------------- scheduler
def test_hedged_stall_masked(tmp_path):
    """An injected stall on one range is masked by a hedged duplicate: the
    transfer completes well under the stall time, bytes exact."""
    store = SimObjectStore(str(tmp_path / "bucket"))
    data = os.urandom(1 << 20)
    store.put("o", data)
    eng = RemoteTransferEngine(
        store, RemoteConfig(range_bytes=256 << 10, window=4,
                            hedge_after_s=0.05, min_bw_bytes_s=1e12))
    fault = faults.Fault(faults.OP_RGET, at=1, action=faults.A_STALL,
                         delay_s=1.2)
    dst = str(tmp_path / "o.local")
    t0 = time.perf_counter()
    with faults.inject(faults.FaultPlan([fault])):
        stats = eng.transfer([("o", dst)])
    wall = time.perf_counter() - t0
    assert wall < 1.0, f"stall was not masked (wall {wall:.2f}s)"
    assert stats.hedged >= 1
    assert stats.hedge_wins >= 1
    with open(dst, "rb") as f:
        assert f.read() == data
    eng.close()


def test_short_range_refetched(tmp_path):
    store = SimObjectStore(str(tmp_path / "bucket"))
    data = os.urandom(512 << 10)
    store.put("o", data)
    eng = RemoteTransferEngine(store, RemoteConfig(range_bytes=128 << 10))
    fault = faults.Fault(faults.OP_RGET, at=2, action=faults.A_SHORT,
                         frac=0.5)
    dst = str(tmp_path / "o.local")
    with faults.inject(faults.FaultPlan([fault])):
        stats = eng.transfer([("o", dst)])
    with open(dst, "rb") as f:
        assert f.read() == data
    assert stats.retries >= 1
    eng.close()


# ----------------------------------------------------------- dedup uploads
def test_upload_dedup_skips_clean_chunks(tmp_path):
    """Re-uploading a lightly-mutated delta step ships only the new packs;
    clean chunkstore packs dedup via HEAD."""
    store = SimObjectStore(str(tmp_path / "bucket"))
    state = _state()
    with RemoteCheckpointer(str(tmp_path / "l"), store, upload_async=False,
                            delta=True, delta_chunk_bytes=4096,
                            keep=None) as mgr:
        mgr.save(0, state)
        full_wire = store.bytes_in
        full_up = mgr.last_upload_stats
        assert full_up.chunks_shipped > 0 and full_up.chunks_skipped == 0
        state["w"][:2] += 1.0                  # dirty a couple of chunks
        mgr.save(1, state)
        up = mgr.last_upload_stats
        assert up.chunks_skipped > 0
        assert up.bytes_skipped > 0
        dirty_wire = store.bytes_in - full_wire
        assert dirty_wire < full_wire / 2
        assert mgr.tier.committed_steps() == [0, 1]
    # the delta step stream-restores bit-exactly on a fresh machine
    with RemoteCheckpointer(str(tmp_path / "v"), store,
                            restore_mode="stream") as v:
        _assert_same(v.restore(step=1), state)


def test_upload_crash_never_publishes(tmp_path):
    """A crashed upload must leave the step unpublished (manifest is PUT
    last); the prior step stays restorable and a retry converges."""
    store = SimObjectStore(str(tmp_path / "bucket"))
    s1, s2 = _state(), _state()
    s2["w"] = s2["w"] + 1.0
    mgr = RemoteCheckpointer(str(tmp_path / "l"), store, upload_async=False,
                             keep=None)
    mgr.save(1, s1)
    fault = faults.Fault(faults.OP_RPUT, at=1)
    with pytest.raises(faults.InjectedCrash):
        with faults.inject(faults.FaultPlan([fault])):
            mgr.save(2, s2)
    assert mgr.tier.committed_steps() == [1]
    with RemoteCheckpointer(str(tmp_path / "v1"), store,
                            restore_mode="stream") as v:
        _assert_same(v.restore(step=1), s1)
    # retry: the local step committed, so a plain re-upload publishes it
    mgr.tier.upload_step(mgr.local.directory, 2)
    assert mgr.tier.committed_steps() == [1, 2]
    with RemoteCheckpointer(str(tmp_path / "v2"), store,
                            restore_mode="stream") as v:
        _assert_same(v.restore(step=2), s2)
    mgr.close()


# ------------------------------------------------------------------ restore
def test_stream_restore_no_local_staging(tmp_path):
    """Stream restore on a fresh machine: bit-exact, and no local copy of
    the checkpoint is ever staged (only the private metadata manifest)."""
    store = SimObjectStore(str(tmp_path / "bucket"))
    state = _state()
    with RemoteCheckpointer(str(tmp_path / "l"), store,
                            upload_async=False) as mgr:
        mgr.save(3, state)
    with RemoteCheckpointer(str(tmp_path / "fresh"), store,
                            restore_mode="stream") as v:
        got = v.restore(step=3)
        _assert_same(got, state)
        assert v.last_restore_metrics is not None
        assert v.local.all_steps() == []       # nothing promoted or staged
        assert not [n for n in os.listdir(str(tmp_path / "fresh"))
                    if n.startswith("step_")]


def test_promote_restore_commits_level0(tmp_path):
    """Promote mode: a full remote pull becomes a committed level-0 step
    bit-exactly; the next restore is served locally."""
    store = SimObjectStore(str(tmp_path / "bucket"))
    state = _state()
    with RemoteCheckpointer(str(tmp_path / "l"), store,
                            upload_async=False) as mgr:
        mgr.save(5, state)
    fresh = str(tmp_path / "fresh")
    with RemoteCheckpointer(fresh, store, restore_mode="promote") as v:
        got = v.restore(step=5)
        _assert_same(got, state)
        assert os.path.exists(os.path.join(fresh, "step_00000005",
                                           "manifest.json"))
        assert not [n for n in os.listdir(fresh) if ".tmp" in n]
        assert v.local.all_steps() == [5]
        # a second restore must not touch the remote tier's data path
        gets_before = store.gets
        _assert_same(v.restore(step=5), state)
        assert store.gets == gets_before


def test_promote_partial_pull_stays_staged(tmp_path):
    """Fetching a subset of extents from the remote tier stages correct
    bytes but must NOT commit the step at level 0."""
    store = SimObjectStore(str(tmp_path / "bucket"))
    state = _state()
    with RemoteCheckpointer(str(tmp_path / "l"), store,
                            upload_async=False) as mgr:
        mgr.save(4, state)
    scratch = str(tmp_path / "scratch")
    os.makedirs(scratch)
    pf = RemotePrefetcher(store)
    staged = pf.begin(4, scratch)
    assert staged is not None and os.path.exists(
        os.path.join(staged, "manifest.json"))
    m = Manifest.loads(store.get("step_00000004/manifest.json"))
    rec = next(iter(m.tensors.values()))
    sh = rec.shards[0]
    n = min(4096, sh.nbytes)
    pf.fetch_extents(staged, [Extent(rec.key, sh.path, sh.offset, n)])
    with open(os.path.join(staged, sh.path), "rb") as f:
        f.seek(sh.offset)
        got = f.read(n)
    assert got == store.get_range(join_key("step_00000004", sh.path),
                                  sh.offset, n)
    final = os.path.join(scratch, "step_00000004")
    assert pf.finish(staged, final) is False
    assert not os.path.exists(staged) and not os.path.exists(final)
    pf.close()


def test_missing_step_restores_from_remote_union(tmp_path):
    """all_steps is the union of both tiers; a step present only remotely
    restores even after the local copy is retired by retention."""
    store = SimObjectStore(str(tmp_path / "bucket"))
    states = {}
    with RemoteCheckpointer(str(tmp_path / "l"), store, upload_async=False,
                            keep=1) as mgr:
        for s in (1, 2, 3):
            st = _state()
            st["w"] = st["w"] + s
            states[s] = st
            mgr.save(s, st)
        assert mgr.local.all_steps() == [3]    # keep=1 retired 1 and 2
        assert mgr.all_steps() == [1, 2, 3]    # remote kept everything
        _assert_same(mgr.restore(step=2), states[2])
