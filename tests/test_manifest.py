"""Manifest: serialization roundtrip, merge, validity semantics."""

import os

import pytest

from repro.core.manifest import (BlobRecord, Manifest, ShardEntry,
                                 TensorRecord, crc32_of)


def _manifest():
    m = Manifest(step=7, num_ranks=2, strategy="single_file")
    m.add_shard("w", "float32", (8, 8),
                ShardEntry(((0, 4), (0, 8)), "data/c.bin", 0, 128, 123))
    m.add_shard("w", "float32", (8, 8),
                ShardEntry(((4, 8), (0, 8)), "data/c.bin", 4096, 128, 456))
    m.blobs["__lean__"] = BlobRecord("__lean__", "data/c.bin", 8192, 10)
    m.extra["engine"] = {"name": "aggregated"}
    return m


def test_json_roundtrip():
    m = _manifest()
    m2 = Manifest.loads(m.dumps())
    assert m2.step == 7 and m2.num_ranks == 2
    assert m2.tensors["w"].global_shape == (8, 8)
    assert m2.tensors["w"].shards[1].index == ((4, 8), (0, 8))
    assert m2.blobs["__lean__"].offset == 8192
    assert m2.extra["engine"]["name"] == "aggregated"
    assert m2.total_bytes == 128 * 2 + 10


def test_save_load_atomic(tmp_path):
    d = str(tmp_path)
    m = _manifest()
    assert not Manifest.exists(d)
    m.save(d)
    assert Manifest.exists(d)
    m2 = Manifest.load(d)
    assert m2.dumps() == m.dumps()
    assert not os.path.exists(os.path.join(d, "manifest.json.tmp"))


def test_merge():
    a = _manifest()
    b = Manifest(step=7, num_ranks=2, strategy="single_file")
    b.add_shard("v", "bfloat16", (4,),
                ShardEntry(((0, 4),), "data/c.bin", 9000, 8))
    a.merge(b)
    assert set(a.tensors) == {"w", "v"}


def test_inconsistent_record_rejected():
    m = _manifest()
    with pytest.raises(ValueError):
        m.add_shard("w", "int8", (8, 8),
                    ShardEntry(((0, 8), (0, 8)), "x", 0, 64))


def test_future_format_rejected():
    m = _manifest()
    m.format_version = 99
    with pytest.raises(ValueError):
        Manifest.loads(m.dumps())


def test_crc():
    assert crc32_of(b"hello") == crc32_of(bytearray(b"hello"))
    assert crc32_of(b"hello") != crc32_of(b"hellp")
