"""Manifest: serialization roundtrip, merge, validity semantics."""

import os

import pytest

from repro.core.manifest import (BlobRecord, Manifest, ManifestError,
                                 ManifestMergeError, ShardEntry,
                                 TensorRecord, crc32_of)


def _manifest():
    m = Manifest(step=7, num_ranks=2, strategy="single_file")
    m.add_shard("w", "float32", (8, 8),
                ShardEntry(((0, 4), (0, 8)), "data/c.bin", 0, 128, 123))
    m.add_shard("w", "float32", (8, 8),
                ShardEntry(((4, 8), (0, 8)), "data/c.bin", 4096, 128, 456))
    m.blobs["__lean__"] = BlobRecord("__lean__", "data/c.bin", 8192, 10)
    m.extra["engine"] = {"name": "aggregated"}
    return m


def test_json_roundtrip():
    m = _manifest()
    m2 = Manifest.loads(m.dumps())
    assert m2.step == 7 and m2.num_ranks == 2
    assert m2.tensors["w"].global_shape == (8, 8)
    assert m2.tensors["w"].shards[1].index == ((4, 8), (0, 8))
    assert m2.blobs["__lean__"].offset == 8192
    assert m2.extra["engine"]["name"] == "aggregated"
    assert m2.total_bytes == 128 * 2 + 10


def test_save_load_atomic(tmp_path):
    d = str(tmp_path)
    m = _manifest()
    assert not Manifest.exists(d)
    m.save(d)
    assert Manifest.exists(d)
    m2 = Manifest.load(d)
    assert m2.dumps() == m.dumps()
    assert not os.path.exists(os.path.join(d, "manifest.json.tmp"))


def test_merge():
    a = _manifest()
    b = Manifest(step=7, num_ranks=2, strategy="single_file")
    b.add_shard("v", "bfloat16", (4,),
                ShardEntry(((0, 4),), "data/c.bin", 9000, 8))
    a.merge(b)
    assert set(a.tensors) == {"w", "v"}


def test_merge_rejects_mismatched_step():
    a = _manifest()
    b = Manifest(step=8, num_ranks=2, strategy="single_file")
    with pytest.raises(ManifestMergeError):
        a.merge(b)


def test_merge_rejects_mismatched_strategy():
    a = _manifest()
    b = Manifest(step=7, num_ranks=2, strategy="file_per_process")
    with pytest.raises(ManifestMergeError):
        a.merge(b)


def test_merge_rejects_mismatched_global_shape():
    a = _manifest()
    b = Manifest(step=7, num_ranks=2, strategy="single_file")
    b.add_shard("w", "float32", (16, 8),
                ShardEntry(((8, 16), (0, 8)), "data/d.bin", 0, 256))
    with pytest.raises(ManifestMergeError):
        a.merge(b)
    c = Manifest(step=7, num_ranks=2, strategy="single_file")
    c.add_shard("w", "int8", (8, 8),
                ShardEntry(((0, 8), (0, 8)), "data/d.bin", 0, 64))
    with pytest.raises(ManifestMergeError):
        a.merge(c)


def test_merge_same_rank_idempotent():
    """Re-merging a rank (retried commit) must not duplicate ShardEntrys —
    duplicates corrupt restore windows."""
    a = _manifest()
    a.extra["rank"] = 0
    b = Manifest(step=7, num_ranks=2, strategy="single_file")
    b.extra["rank"] = 1
    b.add_shard("v", "bfloat16", (4,),
                ShardEntry(((0, 4),), "data/c.bin", 9000, 8))
    a.merge(b)
    n = len(a.tensors["v"].shards)
    a.merge(b)                       # rank recorded: whole merge is a no-op
    a.merge(b, rank=1)               # explicit rank: same
    assert len(a.tensors["v"].shards) == n
    assert sorted(a.extra["merged_ranks"]) == [0, 1]


def test_failed_merge_leaves_target_unmodified():
    """A merge that raises must not half-apply NOR mark the rank merged —
    otherwise a retry would no-op and silently drop shards."""
    a = _manifest()
    b = Manifest(step=7, num_ranks=2, strategy="single_file")
    b.extra["rank"] = 1
    b.add_shard("v", "bfloat16", (4,),
                ShardEntry(((0, 4),), "data/c.bin", 9000, 8))
    b.add_shard("w", "int8", (8, 8),                    # conflicts with a
                ShardEntry(((0, 8), (0, 8)), "x", 0, 64))
    with pytest.raises(ManifestMergeError):
        a.merge(b)
    assert 1 not in a.extra.get("merged_ranks", [])
    assert "v" not in a.tensors
    # fix b's conflict: the retry now merges completely
    del b.tensors["w"]
    a.merge(b)
    assert "v" in a.tensors and 1 in a.extra["merged_ranks"]


def test_merge_duplicate_entries_skipped_without_rank():
    """Even rank-less manifests (legacy) dedupe exact-identical entries."""
    a = _manifest()
    b = Manifest.loads(_manifest().dumps())
    before = len(a.tensors["w"].shards)
    a.merge(b)
    assert len(a.tensors["w"].shards) == before


def test_loads_corrupt_raises_typed():
    for blob in (b"", b"{", b'{"step": 1}', b"\x00\xff garbage"):
        with pytest.raises(ManifestError):
            Manifest.loads(blob)


def test_load_missing_raises_typed(tmp_path):
    with pytest.raises(ManifestError):
        Manifest.load(str(tmp_path))


def test_rank_manifest_roundtrip(tmp_path):
    d = str(tmp_path)
    m = _manifest()
    m.save_rank(d, 3)
    assert not Manifest.exists(d)        # rank manifests don't commit
    assert Manifest.rank_manifests(d) == [3]
    m2 = Manifest.load_rank(d, 3)
    assert m2.dumps() == m.dumps()


def test_inconsistent_record_rejected():
    m = _manifest()
    with pytest.raises(ValueError):
        m.add_shard("w", "int8", (8, 8),
                    ShardEntry(((0, 8), (0, 8)), "x", 0, 64))


def test_future_format_rejected():
    m = _manifest()
    m.format_version = 99
    with pytest.raises(ValueError):
        Manifest.loads(m.dumps())


def test_crc():
    assert crc32_of(b"hello") == crc32_of(bytearray(b"hello"))
    assert crc32_of(b"hello") != crc32_of(b"hellp")


# ------------------------------------------ forward/backward compatibility
def test_chunk_entry_roundtrip():
    from repro.core.manifest import CHUNK_KIND, ChunkRef
    m = Manifest(step=1, num_ranks=1, strategy="single_file")
    refs = (ChunkRef("ab" * 16, "../chunkstore/packs/p0/data/c.bin", 0,
                     256, 7),
            ChunkRef("cd" * 16, "data/c.bin", 4096, 128, 9))
    m.add_shard("w", "float32", (8, 8),
                ShardEntry(((0, 8), (0, 8)), "<chunks:deadbeef>", 0, 384,
                           42, CHUNK_KIND, refs))
    m2 = Manifest.loads(m.dumps())
    sh = m2.tensors["w"].shards[0]
    assert sh.kind == CHUNK_KIND and sh.chunks == refs
    assert sh.crc32 == 42 and sh.nbytes == 384


def test_format_version_floats_with_content():
    """Non-delta manifests stay at the base version (old readers keep
    loading them); blake2b chunk entries bump to v3, fp128 digests to v4."""
    from repro.core.manifest import (BASE_FORMAT_VERSION, CHUNK_FORMAT_VERSION,
                                     CHUNK_KIND, ChunkRef, DIGEST_FP128,
                                     FORMAT_VERSION)
    m = _manifest()
    assert m.to_json()["format_version"] == BASE_FORMAT_VERSION
    m.add_shard("d", "uint8", (4,),
                ShardEntry(((0, 4),), "<chunks:x>", 0, 4, None, CHUNK_KIND,
                           (ChunkRef("00" * 16, "../chunkstore/p", 0, 4),)))
    assert m.to_json()["format_version"] == CHUNK_FORMAT_VERSION
    m.add_shard("e", "uint8", (4,),
                ShardEntry(((0, 4),), "<chunks:y>", 0, 4, None, CHUNK_KIND,
                           (ChunkRef("00" * 16, "../chunkstore/q", 0, 4),),
                           digest=DIGEST_FP128))
    assert m.to_json()["format_version"] == FORMAT_VERSION


def test_unknown_entry_kind_raises_typed():
    """A manifest written by a NEWER writer with an entry kind this reader
    does not understand raises ManifestError — not KeyError — so the
    latest-step fallback can skip it."""
    import json
    m = _manifest()
    doc = m.to_json()
    doc["tensors"]["w"]["shards"][0]["kind"] = "parity-raid7"
    with pytest.raises(ManifestError, match="unknown shard entry kind"):
        Manifest.loads(json.dumps(doc).encode())
    # unknown kinds never silently pass as extents
    doc["tensors"]["w"]["shards"][0]["kind"] = "extent"
    Manifest.loads(json.dumps(doc).encode())


def test_future_format_raises_typed_manifest_error():
    m = _manifest()
    m.format_version = 99
    with pytest.raises(ManifestError, match="future"):
        Manifest.loads(m.dumps())
